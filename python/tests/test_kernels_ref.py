"""Tests for the pure-jnp oracles themselves (ref.py).

The oracles are the root of the correctness chain (Pallas kernel -> HLO
artifacts -> rust SP algorithms), so they get their own algebra tests:
the (O', l, m) merge must be a commutative monoid action whose fold equals
full softmax attention no matter how the KV sequence is partitioned.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

RNG = np.random.default_rng(1234)


def rand(*shape):
    return jnp.array(RNG.standard_normal(shape) * 0.5, jnp.float32)


def make_qkv(b, l, h, d, lk=None):
    lk = lk or l
    return rand(b, l, h, d), rand(b, lk, h, d), rand(b, lk, h, d)


class TestAttentionOracle:
    def test_softmax_rows_sum_to_one_property(self):
        """attention(q,k,v) with v=ones must return ones (softmax rows sum to 1)."""
        q, k, _ = make_qkv(2, 16, 2, 8)
        v = jnp.ones((2, 16, 2, 8), jnp.float32)
        o = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(o), 1.0, atol=1e-6)

    def test_single_key_returns_its_value(self):
        """With one key, output == that key's value regardless of scores."""
        q = rand(1, 8, 2, 4)
        k = rand(1, 1, 2, 4)
        v = rand(1, 1, 2, 4)
        o = ref.attention(q, k, v)
        np.testing.assert_allclose(
            np.array(o), np.broadcast_to(np.array(v), o.shape), atol=1e-6)

    def test_head_independence(self):
        """Attention must be head-independent — the property Ulysses
        Attention exploits (Section 2.2)."""
        q, k, v = make_qkv(1, 12, 4, 8)
        full = ref.attention(q, k, v)
        for h in range(4):
            per_head = ref.attention(q[:, :, h:h+1], k[:, :, h:h+1], v[:, :, h:h+1])
            np.testing.assert_allclose(
                np.array(full[:, :, h:h+1]), np.array(per_head), atol=1e-6)

    def test_permuting_keys_is_invariant(self):
        """Softmax attention is permutation-invariant in the KV sequence —
        why Ring/Torus arrival order doesn't matter."""
        q, k, v = make_qkv(1, 8, 2, 4, lk=10)
        perm = RNG.permutation(10)
        o1 = ref.attention(q, k, v)
        o2 = ref.attention(q, k[:, perm], v[:, perm])
        np.testing.assert_allclose(np.array(o1), np.array(o2), atol=1e-6)

    def test_scale_default_is_rsqrt_d(self):
        q, k, v = make_qkv(1, 8, 1, 16)
        o1 = ref.attention(q, k, v)
        o2 = ref.attention(q, k, v, scale=1.0 / np.sqrt(16.0))
        np.testing.assert_allclose(np.array(o1), np.array(o2), atol=1e-7)


class TestPartialMergeAlgebra:
    def fold(self, q, parts):
        o, l, m = ref.attention_partial(q, *parts[0])
        for k, v in parts[1:]:
            o2, l2, m2 = ref.attention_partial(q, k, v)
            o, l, m = ref.merge_partials(o, l, m, o2, l2, m2)
        return ref.finalize(o, l)

    @pytest.mark.parametrize("nparts", [1, 2, 3, 4, 8])
    def test_fold_equals_full_attention(self, nparts):
        """Partition-invariance: merging per-partition partials == full
        attention (Appendix C correctness)."""
        b, l, h, d = 2, 24, 2, 8
        q, k, v = make_qkv(b, l, h, d)
        step = l // nparts
        parts = [(k[:, i*step:(i+1)*step], v[:, i*step:(i+1)*step])
                 for i in range(nparts)]
        got = self.fold(q, parts)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5)

    def test_merge_commutative(self):
        q, k, v = make_qkv(1, 8, 2, 4, lk=16)
        a = ref.attention_partial(q, k[:, :8], v[:, :8])
        b = ref.attention_partial(q, k[:, 8:], v[:, 8:])
        ab = ref.merge_partials(*a, *b)
        ba = ref.merge_partials(*b, *a)
        for x, y in zip(ab, ba):
            np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-6)

    def test_merge_associative(self):
        q, k, v = make_qkv(1, 8, 2, 4, lk=24)
        ps = [ref.attention_partial(q, k[:, i*8:(i+1)*8], v[:, i*8:(i+1)*8])
              for i in range(3)]
        left = ref.merge_partials(*ref.merge_partials(*ps[0], *ps[1]), *ps[2])
        right = ref.merge_partials(*ps[0], *ref.merge_partials(*ps[1], *ps[2]))
        for x, y in zip(left, right):
            np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-5, atol=1e-6)

    def test_zero_state_is_identity(self):
        """(0, 0, -inf) is the identity of the merge monoid."""
        q, k, v = make_qkv(1, 8, 2, 4)
        p = ref.attention_partial(q, k, v)
        z = ref.zero_state(1, 8, 2, 4)
        merged = ref.merge_partials(*z, *p)
        for x, y in zip(merged, p):
            np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-6)
        merged = ref.merge_partials(*p, *z)
        for x, y in zip(merged, p):
            np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-6)

    def test_no_nan_from_identity_merge(self):
        """Merging two identity states must not produce NaN (the -inf - -inf
        guard)."""
        z1 = ref.zero_state(1, 4, 1, 4)
        z2 = ref.zero_state(1, 4, 1, 4)
        o, l, m = ref.merge_partials(*z1, *z2)
        assert not np.isnan(np.array(o)).any()
        assert not np.isnan(np.array(l)).any()

    def test_finalize_zero_l_gives_zero_not_nan(self):
        o, l, m = ref.zero_state(1, 4, 1, 4)
        out = ref.finalize(o, l)
        assert np.all(np.array(out) == 0.0)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 2),
        l=st.sampled_from([8, 16, 24]),
        h=st.integers(1, 3),
        d=st.sampled_from([4, 8, 16]),
        nparts=st.integers(1, 4),
    )
    def test_partition_invariance_hypothesis(self, b, l, h, d, nparts):
        """Random uneven partitions of the KV sequence all fold to the
        same attention output."""
        rng = np.random.default_rng(b * 1000 + l * 10 + h + d + nparts)
        q = jnp.array(rng.standard_normal((b, l, h, d)), jnp.float32)
        k = jnp.array(rng.standard_normal((b, l, h, d)), jnp.float32)
        v = jnp.array(rng.standard_normal((b, l, h, d)), jnp.float32)
        # random cut points
        cuts = sorted(rng.choice(np.arange(1, l), size=min(nparts - 1, l - 1),
                                 replace=False).tolist()) if nparts > 1 else []
        bounds = [0] + cuts + [l]
        parts = [(k[:, a:bnd], v[:, a:bnd]) for a, bnd in zip(bounds, bounds[1:])]
        got = ref.attention_multi_kv(q, parts)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-5)

    def test_extreme_scores_stable(self):
        """Large-magnitude Q/K (score overflow territory) stays finite —
        the running-max subtraction at work."""
        q = jnp.full((1, 4, 1, 8), 30.0, jnp.float32)
        k = jnp.full((1, 8, 1, 8), 30.0, jnp.float32)
        v = rand(1, 8, 1, 8)
        parts = [(k[:, :4], v[:, :4]), (k[:, 4:], v[:, 4:])]
        got = ref.attention_multi_kv(q, parts)
        assert np.isfinite(np.array(got)).all()
