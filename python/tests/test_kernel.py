"""Pallas kernel vs pure-jnp reference — the CORE correctness signal.

Everything downstream (the AOT HLO artifacts, and through them every rust
SP algorithm) computes attention with this kernel, so it is swept across
shapes, tile sizes, partition counts, and numeric regimes against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ref,
    flash_attention,
    flash_attention_carry,
    flash_attention_multi_kv,
    merge_states,
)

RNG = np.random.default_rng(7)


def rand(*shape, scale=0.5):
    return jnp.array(RNG.standard_normal(shape) * scale, jnp.float32)


def make_qkv(b, l, h, d, lk=None):
    lk = lk or l
    return rand(b, l, h, d), rand(b, lk, h, d), rand(b, lk, h, d)


class TestSingleShot:
    @pytest.mark.parametrize("b,l,h,d", [
        (1, 16, 1, 8),
        (2, 64, 4, 32),
        (1, 128, 2, 64),
        (1, 96, 3, 16),   # L not a power of two
        (3, 32, 24, 8),   # paper's H=24
    ])
    def test_matches_reference(self, b, l, h, d):
        q, k, v = make_qkv(b, l, h, d)
        got = flash_attention(q, k, v)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   atol=2e-5, rtol=1e-4)

    @pytest.mark.parametrize("bq,bk", [(8, 8), (16, 32), (32, 16), (128, 128), (7, 5)])
    def test_tile_size_invariance(self, bq, bk):
        """Output must not depend on the tiling (the kernel's analog of the
        paper's tQO/tKV parameters)."""
        q, k, v = make_qkv(1, 64, 2, 16)
        want = ref.attention(q, k, v)
        got = flash_attention(q, k, v, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   atol=2e-5, rtol=1e-4)

    def test_rectangular_lq_ne_lk(self):
        q, k, v = make_qkv(1, 32, 2, 16, lk=48)
        got = flash_attention(q, k, v, block_q=16, block_k=16)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-5)

    def test_custom_scale(self):
        q, k, v = make_qkv(1, 16, 1, 8)
        got = flash_attention(q, k, v, scale=0.25)
        want_s = ref.attention_partial(q, k, v, scale=0.25)
        want = ref.finalize(want_s[0], want_s[1])
        np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-5)

    def test_large_scores_stable(self):
        q = jnp.full((1, 8, 1, 8), 20.0, jnp.float32)
        k = jnp.full((1, 8, 1, 8), 20.0, jnp.float32)
        v = rand(1, 8, 1, 8)
        got = flash_attention(q, k, v, block_q=4, block_k=4)
        assert np.isfinite(np.array(got)).all()


class TestCarrySemantics:
    """The Algorithm-2 analog behaviours: carry-in, no finalize, finalize."""

    def test_carry_chain_equals_full(self):
        b, l, h, d = 1, 48, 2, 8
        q, k, v = make_qkv(b, l, h, d)
        o = jnp.zeros((b, l, h, d), jnp.float32)
        lacc = jnp.zeros((b, h, l), jnp.float32)
        m = jnp.full((b, h, l), -np.inf, jnp.float32)
        for i in range(3):
            ks, vs = k[:, i*16:(i+1)*16], v[:, i*16:(i+1)*16]
            o, lacc, m = flash_attention_carry(
                q, ks, vs, o, lacc, m, finalize=(i == 2),
                block_q=16, block_k=8)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(o), np.array(want), atol=2e-5)

    def test_unfinalized_state_matches_ref_partial(self):
        """finalize=False must return the raw (O', l, m) triplet so a later
        partition (arriving over the ring) can be merged in."""
        q, k, v = make_qkv(1, 16, 2, 8)
        o0 = jnp.zeros((1, 16, 2, 8), jnp.float32)
        l0 = jnp.zeros((1, 2, 16), jnp.float32)
        m0 = jnp.full((1, 2, 16), -np.inf, jnp.float32)
        o, l, m = flash_attention_carry(q, k, v, o0, l0, m0,
                                        finalize=False, block_q=16, block_k=16)
        ro, rl, rm = ref.attention_partial(q, k, v)
        np.testing.assert_allclose(np.array(o), np.array(ro), atol=2e-5, rtol=1e-4)
        np.testing.assert_allclose(np.array(l), np.array(rl), atol=2e-5, rtol=1e-4)
        np.testing.assert_allclose(np.array(m), np.array(rm), atol=1e-6)

    def test_carry_tiled_k_no_double_count(self):
        """The paper's threadIdx%4 l-duplication bug class: chaining with
        multiple K tiles per call must not double-count the carried l."""
        q, k, v = make_qkv(1, 16, 1, 8, lk=32)
        o0 = jnp.zeros((1, 16, 1, 8), jnp.float32)
        l0 = jnp.zeros((1, 1, 16), jnp.float32)
        m0 = jnp.full((1, 1, 16), -np.inf, jnp.float32)
        # partition 1 with 4 internal K tiles, then partition 2 finalizing
        o, l, m = flash_attention_carry(q, k[:, :16], v[:, :16], o0, l0, m0,
                                        finalize=False, block_q=8, block_k=4)
        o, l, m = flash_attention_carry(q, k[:, 16:], v[:, 16:], o, l, m,
                                        finalize=True, block_q=8, block_k=4)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(o), np.array(want), atol=2e-5)

    def test_merge_states_matches_ref(self):
        q, k, v = make_qkv(1, 8, 2, 4, lk=16)
        a = ref.attention_partial(q, k[:, :8], v[:, :8])
        b = ref.attention_partial(q, k[:, 8:], v[:, 8:])
        got = merge_states(*a, *b)
        want = ref.merge_partials(*a, *b)
        for x, y in zip(got, want):
            np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-5, atol=1e-6)


class TestMultiKV:
    @pytest.mark.parametrize("nparts", [1, 2, 4, 6])
    def test_matches_full(self, nparts):
        b, l, h, d = 1, 48, 2, 16
        q, k, v = make_qkv(b, l, h, d)
        step = l // nparts
        kvs = [(k[:, i*step:(i+1)*step], v[:, i*step:(i+1)*step])
               for i in range(nparts)]
        got = flash_attention_multi_kv(q, kvs, block_q=16, block_k=8)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-5)

    def test_uneven_partitions(self):
        """Torus Attention delivers discontiguous, uneven KV partitions."""
        q, k, v = make_qkv(1, 32, 2, 8, lk=40)
        bounds = [0, 8, 24, 40]
        kvs = [(k[:, a:b], v[:, a:b]) for a, b in zip(bounds, bounds[1:])]
        got = flash_attention_multi_kv(q, kvs, block_q=8, block_k=8)
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-5)

    def test_arrival_order_invariance(self):
        """Ring vs Torus deliver KV partitions in different orders; the
        result must be identical (merge commutativity end-to-end)."""
        q, k, v = make_qkv(1, 24, 2, 8)
        parts = [(k[:, i*8:(i+1)*8], v[:, i*8:(i+1)*8]) for i in range(3)]
        o1 = flash_attention_multi_kv(q, parts, block_q=8, block_k=8)
        o2 = flash_attention_multi_kv(q, parts[::-1], block_q=8, block_k=8)
        np.testing.assert_allclose(np.array(o1), np.array(o2), atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2),
    l=st.sampled_from([16, 32, 48]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([8, 16, 128]),
    bk=st.sampled_from([8, 16, 128]),
)
def test_kernel_hypothesis_sweep(b, l, h, d, bq, bk):
    """Hypothesis sweep over shapes x tile sizes vs the oracle."""
    rng = np.random.default_rng(b + l + h + d + bq + bk)
    q = jnp.array(rng.standard_normal((b, l, h, d)), jnp.float32)
    k = jnp.array(rng.standard_normal((b, l, h, d)), jnp.float32)
    v = jnp.array(rng.standard_normal((b, l, h, d)), jnp.float32)
    got = flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               atol=3e-5, rtol=1e-4)
