"""Make `compile` importable regardless of pytest invocation directory
(the canonical invocations are `cd python && pytest tests/` and
`pytest python/tests/` from the repo root)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
