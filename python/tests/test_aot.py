"""AOT pipeline tests: manifest integrity and HLO-text artifact validity.

These run against a small throwaway lowering (tmp dir) so they don't
require `make artifacts` to have run, plus consistency checks on the real
artifacts/ directory when it exists.
"""

import json
import os

import pytest

from compile import aot, model

ARTIFACTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def mini_build(tmp_path_factory):
    """Lower just the attention tiles of small4 into a tmp dir."""
    out = str(tmp_path_factory.mktemp("artifacts"))
    b = aot.Builder(out, verbose=False)
    cfg = model.VALIDATION_CONFIGS[0]
    b.add_config(cfg)
    aot.lower_attention_tiles(b, cfg)
    b.save_manifest()
    return out, b.manifest


class TestBuilder:
    def test_manifest_written(self, mini_build):
        out, _ = mini_build
        with open(os.path.join(out, "manifest.json")) as f:
            m = json.load(f)
        assert m["version"] == 1
        assert m["configs"][0]["name"] == "small4"

    def test_every_artifact_file_exists(self, mini_build):
        out, manifest = mini_build
        for a in manifest["artifacts"]:
            path = os.path.join(out, a["file"])
            assert os.path.exists(path), a["name"]
            text = open(path).read()
            # HLO text sanity: module header + entry computation
            assert text.startswith("HloModule"), a["name"]
            assert "ENTRY" in text, a["name"]

    def test_attention_tile_shapes(self, mini_build):
        _, manifest = mini_build
        cfg = model.VALIDATION_CONFIGS[0]
        by_name = {a["name"]: a for a in manifest["artifacts"]}
        for g in cfg.head_groups():
            a = by_name[f"attn_partial_{cfg.name}_h{g}"]
            assert a["inputs"][0] == [cfg.b, cfg.chunk, g, cfg.d]
            assert a["inputs"][4] == [cfg.b, g, cfg.chunk]
            assert a["outputs"][0] == [cfg.b, cfg.chunk, g, cfg.d]
            m = by_name[f"attn_merge_{cfg.name}_h{g}"]
            assert len(m["inputs"]) == 6 and len(m["outputs"]) == 3
            f = by_name[f"attn_finalize_{cfg.name}_h{g}"]
            assert len(f["inputs"]) == 2 and len(f["outputs"]) == 1

    def test_full_oracle_shape(self, mini_build):
        _, manifest = mini_build
        cfg = model.VALIDATION_CONFIGS[0]
        by_name = {a["name"]: a for a in manifest["artifacts"]}
        a = by_name[f"attn_full_{cfg.name}"]
        assert a["inputs"][0] == [cfg.b, cfg.l, cfg.h, cfg.d]

    def test_config_record_complete(self, mini_build):
        _, manifest = mini_build
        c = manifest["configs"][0]
        for key in ("name", "b", "l", "h", "d", "depth", "c_in", "mesh",
                    "hidden", "chunk", "head_groups", "seed"):
            assert key in c


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS_DIR, "manifest.json")),
                    reason="run `make artifacts` first")
class TestRealArtifacts:
    """Consistency of the checked-out artifacts/ build (if present)."""

    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_all_configs_present(self, manifest):
        names = {c["name"] for c in manifest["configs"]}
        assert names == {c.name for c in model.VALIDATION_CONFIGS}

    def test_all_files_exist(self, manifest):
        for a in manifest["artifacts"]:
            assert os.path.exists(os.path.join(ARTIFACTS_DIR, a["file"])), a["name"]

    def test_expected_entry_points(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        for cfg in model.VALIDATION_CONFIGS:
            assert f"dit_forward_{cfg.name}" in names
            assert f"ddim_step_{cfg.name}" in names
            assert f"vae_decode_{cfg.name}" in names
            for g in cfg.head_groups():
                assert f"attn_partial_{cfg.name}_h{g}" in names
            for ls in {cfg.l, cfg.chunk}:
                assert f"dit_embed_{cfg.name}_l{ls}" in names
                for i in range(cfg.depth):
                    assert f"dit_block{i}_qkv_{cfg.name}_l{ls}" in names
                    assert f"dit_block{i}_post_{cfg.name}_l{ls}" in names

    def test_no_dangling_files(self, manifest):
        listed = {a["file"] for a in manifest["artifacts"]} | {"manifest.json"}
        on_disk = {f for f in os.listdir(ARTIFACTS_DIR) if not f.startswith(".")}
        assert on_disk <= listed, on_disk - listed


class TestNoElidedConstants:
    def test_hlo_text_keeps_large_constants(self, mini_build):
        """Regression: as_hlo_text must print weight arrays, not elide
        them as `constant({...})` (the text parser zeroes elisions)."""
        out, manifest = mini_build
        for a in manifest["artifacts"]:
            text = open(os.path.join(out, a["file"])).read()
            assert "constant({...})" not in text, a["name"]

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(ARTIFACTS_DIR, "manifest.json")),
        reason="run `make artifacts` first")
    def test_real_artifacts_have_no_elisions(self):
        with open(os.path.join(ARTIFACTS_DIR, "manifest.json")) as f:
            m = json.load(f)
        for a in m["artifacts"]:
            text = open(os.path.join(ARTIFACTS_DIR, a["file"])).read()
            assert "constant({...})" not in text, a["name"]
