"""L2 model tests: shape contracts, split-vs-fused equivalence, sampler."""

import math

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref, flash_attention

CFG = model.VALIDATION_CONFIGS[0]  # small4
W = model.make_weights(CFG)
RNG = np.random.default_rng(99)


def rand(*shape):
    return jnp.array(RNG.standard_normal(shape).astype(np.float32))


class TestConfig:
    def test_hidden_is_h_times_d(self):
        for cfg in model.VALIDATION_CONFIGS:
            assert cfg.hidden == cfg.h * cfg.d

    def test_chunk_divides_l(self):
        for cfg in model.VALIDATION_CONFIGS:
            assert cfg.l % cfg.mesh == 0
            assert cfg.chunk * cfg.mesh == cfg.l

    def test_head_groups_are_divisors(self):
        for cfg in model.VALIDATION_CONFIGS:
            for g in cfg.head_groups():
                assert cfg.h % g == 0

    def test_get_config(self):
        assert model.get_config("small4") is model.VALIDATION_CONFIGS[0]
        with pytest.raises(KeyError):
            model.get_config("nope")


class TestWeights:
    def test_deterministic(self):
        w1 = model.make_weights(CFG)
        w2 = model.make_weights(CFG)
        np.testing.assert_array_equal(np.array(w1["embed"][0]),
                                      np.array(w2["embed"][0]))

    def test_seed_matters(self):
        import dataclasses
        other = dataclasses.replace(CFG, seed=CFG.seed + 1)
        w2 = model.make_weights(other)
        assert not np.array_equal(np.array(W["embed"][0]),
                                  np.array(w2["embed"][0]))


class TestShapes:
    def test_embed(self):
        x = rand(CFG.b, CFG.l, CFG.c_in)
        t = jnp.full((CFG.b,), 10.0, jnp.float32)
        h0, c = model.embed(CFG, W, x, t)
        assert h0.shape == (CFG.b, CFG.l, CFG.hidden)
        assert c.shape == (CFG.b, CFG.hidden)

    def test_block_qkv(self):
        x = rand(CFG.b, CFG.l, CFG.hidden)
        c = rand(CFG.b, CFG.hidden)
        q, k, v = model.block_qkv(CFG, W["block0"], x, c)
        for tns in (q, k, v):
            assert tns.shape == (CFG.b, CFG.l, CFG.h, CFG.d)

    def test_block_post(self):
        x = rand(CFG.b, CFG.l, CFG.hidden)
        a = rand(CFG.b, CFG.l, CFG.h, CFG.d)
        c = rand(CFG.b, CFG.hidden)
        y = model.block_post(CFG, W["block0"], x, a, c)
        assert y.shape == x.shape

    def test_forward(self):
        x = rand(CFG.b, CFG.l, CFG.c_in)
        t = jnp.full((CFG.b,), 10.0, jnp.float32)
        eps = model.dit_forward(CFG, W, x, t)
        assert eps.shape == (CFG.b, CFG.l, CFG.c_in)
        assert np.isfinite(np.array(eps)).all()


class TestSplitEqualsFused:
    """The distributed engine's decomposition contract: running the split
    entry points with oracle attention must equal the fused forward."""

    def test_stagewise_forward_matches(self):
        x = rand(CFG.b, CFG.l, CFG.c_in)
        t = jnp.full((CFG.b,), 500.0, jnp.float32)
        want = model.dit_forward(CFG, W, x, t)

        h, c = model.embed(CFG, W, x, t)
        for i in range(CFG.depth):
            wb = W[f"block{i}"]
            q, k, v = model.block_qkv(CFG, wb, h, c)
            attn = flash_attention(q, k, v)
            h = model.block_post(CFG, wb, h, attn, c)
        got = model.final_layer(CFG, W, h, c)
        np.testing.assert_allclose(np.array(got), np.array(want),
                                   atol=1e-5, rtol=1e-4)

    def test_seq_sharding_pointwise_stages(self):
        """Every non-attention stage must commute with sequence sharding —
        the property SP relies on. Run embed/qkv/post/final on shards and
        compare against the full-sequence run."""
        P = CFG.mesh
        x = rand(CFG.b, CFG.l, CFG.c_in)
        t = jnp.full((CFG.b,), 123.0, jnp.float32)
        h_full, c = model.embed(CFG, W, x, t)
        shards = jnp.split(x, P, axis=1)
        h_shards = [model.embed(CFG, W, s, t)[0] for s in shards]
        np.testing.assert_allclose(
            np.array(jnp.concatenate(h_shards, axis=1)),
            np.array(h_full), atol=1e-6)

        wb = W["block0"]
        q_full, _, _ = model.block_qkv(CFG, wb, h_full, c)
        q_shards = [model.block_qkv(CFG, wb, hs, c)[0]
                    for hs in jnp.split(h_full, P, axis=1)]
        np.testing.assert_allclose(
            np.array(jnp.concatenate(q_shards, axis=1)),
            np.array(q_full), atol=1e-6)

    def test_distributed_attention_matches_oracle(self):
        """Simulate ulysses-style head-sharded + ring-style seq-chunked
        attention in pure python over the model's actual q/k/v."""
        x = rand(CFG.b, CFG.l, CFG.c_in)
        t = jnp.full((CFG.b,), 42.0, jnp.float32)
        h, c = model.embed(CFG, W, x, t)
        q, k, v = model.block_qkv(CFG, W["block0"], h, c)
        want = ref.attention(q, k, v)
        # shard heads into 2 groups, sequence into 4 chunks per group
        outs = []
        for hg in range(2):
            qg = q[:, :, hg*2:(hg+1)*2]
            parts = [(k[:, i*32:(i+1)*32, hg*2:(hg+1)*2],
                      v[:, i*32:(i+1)*32, hg*2:(hg+1)*2]) for i in range(4)]
            outs.append(ref.attention_multi_kv(qg, parts))
        got = jnp.concatenate(outs, axis=2)
        np.testing.assert_allclose(np.array(got), np.array(want), atol=2e-5)


class TestSampler:
    def test_ddim_identity_when_alphas_equal(self):
        x = rand(1, 8, CFG.c_in)
        eps = rand(1, 8, CFG.c_in)
        abar = jnp.array(0.5, jnp.float32)
        out = model.ddim_step(x, eps, abar, abar)
        np.testing.assert_allclose(np.array(out), np.array(x), atol=1e-5)

    def test_ddim_final_step_returns_x0(self):
        """abar_prev = 1 reconstructs x0 exactly."""
        x0 = rand(1, 8, CFG.c_in)
        eps = rand(1, 8, CFG.c_in)
        abar_t = jnp.array(0.3, jnp.float32)
        xt = jnp.sqrt(abar_t) * x0 + jnp.sqrt(1 - abar_t) * eps
        got = model.ddim_step(xt, eps, abar_t, jnp.array(1.0, jnp.float32))
        np.testing.assert_allclose(np.array(got), np.array(x0), atol=1e-5)

    def test_schedule_monotone(self):
        ts, abars = model.ddim_alphas(10)
        assert ts == sorted(ts, reverse=True)
        assert abars == sorted(abars)  # abar grows as t falls
        assert all(0.0 < a <= 1.0 for a in abars)

    def test_timestep_embedding_range(self):
        emb = model.timestep_embedding(jnp.array([0.0, 999.0]), 64)
        assert emb.shape == (2, 64)
        assert np.abs(np.array(emb)).max() <= 1.0 + 1e-6


class TestVae:
    def test_decode_in_unit_range(self):
        x0 = rand(CFG.b, CFG.l, CFG.c_in) * 3
        img = model.vae_decode(CFG, W, x0)
        arr = np.array(img)
        assert img.shape == (CFG.b, CFG.l, 12)
        assert (arr >= 0).all() and (arr <= 1).all()
