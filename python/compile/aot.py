"""AOT lowering: every L2 entry point -> artifacts/*.hlo.txt + manifest.json.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE, at build time (`make artifacts`); the rust binary is
self-contained afterwards and never touches python on the request path.

Artifact inventory (per validation config, see model.VALIDATION_CONFIGS):

  attention tiles — the universal decomposition every SP algorithm uses
  (DESIGN.md §4): all distributed attention reduces to carry-kernel calls
  on [B, chunk, g, D] tiles, g ranging over divisors of H:
    attn_partial_{cfg}_h{g}   q,k,v tile + (O',l,m) carry -> (O',l,m)
    attn_merge_{cfg}_h{g}     two states -> merged state
    attn_finalize_{cfg}_h{g}  (O',l) -> O
    attn_full_{cfg}           [B,L,H,D] single-device oracle

  model stages (Ls in {L, chunk} — full and per-rank shard):
    dit_embed_{cfg}_l{Ls}     x_tokens,t -> h0,c
    dit_block{i}_qkv_{cfg}_l{Ls}
    dit_block{i}_post_{cfg}_l{Ls}
    dit_final_{cfg}_l{Ls}
    dit_forward_{cfg}         fused oracle (x,t -> eps)
    ddim_step_{cfg}           sampler update
    vae_decode_{cfg}          toy VAE decode

The manifest lists every artifact with exact input/output shapes; the rust
runtime refuses shape-mismatched calls at load time rather than at runtime.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import flash_attention_carry, merge_states
from .kernels.ref import finalize as ref_finalize

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange).

    print_large_constants=True is ESSENTIAL: the default elides weight
    arrays as `constant({...})`, which the text parser silently turns
    into zeros — the model would "run" but with zero weights.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


class Builder:
    def __init__(self, out_dir: str, verbose: bool = True):
        self.out_dir = out_dir
        self.manifest = {"version": 1, "configs": [], "artifacts": []}
        self.verbose = verbose

    def add(self, name: str, fn, in_specs):
        """Lower `fn` at `in_specs` and record it in the manifest."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out = jax.eval_shape(fn, *in_specs)
        out_shapes = [list(o.shape) for o in jax.tree_util.tree_leaves(out)]
        self.manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "inputs": [list(s.shape) for s in in_specs],
            "outputs": out_shapes,
        })
        if self.verbose:
            print(f"  lowered {name}: "
                  f"{[tuple(s.shape) for s in in_specs]} -> {out_shapes}")

    def add_config(self, cfg: model.DiTConfig):
        self.manifest["configs"].append({
            "name": cfg.name, "b": cfg.b, "l": cfg.l, "h": cfg.h,
            "d": cfg.d, "depth": cfg.depth, "c_in": cfg.c_in,
            "mesh": cfg.mesh, "hidden": cfg.hidden, "chunk": cfg.chunk,
            "head_groups": cfg.head_groups(), "seed": cfg.seed,
        })

    def save_manifest(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)


def lower_attention_tiles(b: Builder, cfg: model.DiTConfig):
    """The universal attention tile set (see module docstring)."""
    B, Lc, D = cfg.b, cfg.chunk, cfg.d

    for g in cfg.head_groups():
        q = spec(B, Lc, g, D)
        kv = spec(B, Lc, g, D)
        st_o, st_l, st_m = spec(B, Lc, g, D), spec(B, g, Lc), spec(B, g, Lc)

        def partial(qq, kk, vv, oc, lc, mc):
            return flash_attention_carry(qq, kk, vv, oc, lc, mc,
                                         finalize=False)

        def merge(o1, l1, m1, o2, l2, m2):
            return merge_states(o1, l1, m1, o2, l2, m2)

        def fin(o, l):
            return ref_finalize(o, l)

        b.add(f"attn_partial_{cfg.name}_h{g}", partial,
              [q, kv, kv, st_o, st_l, st_m])
        b.add(f"attn_merge_{cfg.name}_h{g}", merge,
              [st_o, st_l, st_m, st_o, st_l, st_m])
        b.add(f"attn_finalize_{cfg.name}_h{g}", fin, [st_o, st_l])

        # span variants (§Perf L3-2): one fused call absorbing 2^k chunk
        # tiles of KV at once — fewer kernel dispatches on the rust hot
        # path, exactly the fusion the paper's Algorithm-2 kernel does.
        span = 2
        while span <= cfg.mesh:
            kv_s = spec(B, span * Lc, g, D)
            b.add(f"attn_partial_{cfg.name}_h{g}_s{span}", partial,
                  [q, kv_s, kv_s, st_o, st_l, st_m])
            span *= 2

    # single-device oracle at full shape
    from .kernels import flash_attention

    def full(qq, kk, vv):
        return flash_attention(qq, kk, vv)

    s = spec(cfg.b, cfg.l, cfg.h, cfg.d)
    b.add(f"attn_full_{cfg.name}", full, [s, s, s])


def lower_model_stages(b: Builder, cfg: model.DiTConfig):
    w = model.make_weights(cfg)
    B, L, Lc, hid, cin = cfg.b, cfg.l, cfg.chunk, cfg.hidden, cfg.c_in

    for ls in sorted({L, Lc}):
        b.add(f"dit_embed_{cfg.name}_l{ls}",
              functools.partial(model.embed, cfg, w),
              [spec(B, ls, cin), spec(B)])
        for i in range(cfg.depth):
            wb = w[f"block{i}"]
            b.add(f"dit_block{i}_qkv_{cfg.name}_l{ls}",
                  functools.partial(model.block_qkv, cfg, wb),
                  [spec(B, ls, hid), spec(B, hid)])
            b.add(f"dit_block{i}_post_{cfg.name}_l{ls}",
                  functools.partial(model.block_post, cfg, wb),
                  [spec(B, ls, hid), spec(B, ls, cfg.h, cfg.d), spec(B, hid)])
        b.add(f"dit_final_{cfg.name}_l{ls}",
              functools.partial(model.final_layer, cfg, w),
              [spec(B, ls, hid), spec(B, hid)])

    b.add(f"dit_forward_{cfg.name}",
          functools.partial(model.dit_forward, cfg, w),
          [spec(B, L, cin), spec(B)])
    b.add(f"ddim_step_{cfg.name}", model.ddim_step,
          [spec(B, L, cin), spec(B, L, cin), spec(), spec()])
    b.add(f"vae_decode_{cfg.name}",
          functools.partial(model.vae_decode, cfg, w),
          [spec(B, L, cin)])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="directory to write *.hlo.txt + manifest.json")
    ap.add_argument("--configs", default=None,
                    help="comma-separated config names (default: all)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.configs.split(",") if args.configs else None
    b = Builder(args.out_dir)
    for cfg in model.VALIDATION_CONFIGS:
        if names and cfg.name not in names:
            continue
        print(f"config {cfg.name}: B={cfg.b} L={cfg.l} H={cfg.h} D={cfg.d} "
              f"hidden={cfg.hidden} chunk={cfg.chunk}")
        b.add_config(cfg)
        lower_attention_tiles(b, cfg)
        lower_model_stages(b, cfg)
    b.save_manifest()
    n = len(b.manifest["artifacts"])
    print(f"wrote {n} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
