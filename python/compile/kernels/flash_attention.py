"""L1 Pallas kernel: fused multi-QKV flash attention with softmax-state carry.

This is the TPU rethink of the paper's Algorithm 2 (an Ampere CUDA kernel
built on mma.m16n8k16 + ldmatrix + warp shuffles). The insight preserved —
see DESIGN.md §Hardware-Adaptation — is a single fused kernel that:

  (a) computes attention of a Q tile against a KV partition,
  (b) *carries in* the running softmax state (O', l, m) accumulated from
      previously-seen KV partitions (as Ring / Torus Attention deliver
      them), instead of re-initializing to (0, 0, -inf), and
  (c) finalizes (divides O' by l) only when told this is the last partition,

so that chunked arrivals never pay re-normalization, extra kernel launches,
or global-memory round trips of the full score matrix.

CUDA -> Pallas mapping:
  threadblock tile over (q-tile, batch, head) -> grid=(B, H, nq, nk) with
    BlockSpec index maps (nk innermost, revisiting the same output block);
  shared-memory staging of K/V tiles          -> VMEM blocks via BlockSpec,
    double-buffered by the Pallas pipeline;
  mma.sync.m16n8k16 tensor-core tiles         -> MXU-shaped jnp.dot with
    f32 accumulation (preferred_element_type);
  warp-shuffle rowmax/rowsum (%4 lanes)       -> whole-row VPU reductions
    along the minor axis — the threadIdx.x%4==0 de-duplication trick is
    unnecessary because reductions here are not distributed across lanes;
  `finalize` kernel parameter                 -> static specialization (two
    compiled variants share the body).

The kernel is lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, so real-TPU performance is argued structurally (VMEM
footprint / MXU alignment) in DESIGN.md, and correctness is validated here
against kernels.ref via pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

# Default tile sizes. 128 matches the MXU systolic-array edge; interp mode
# doesn't care, but the lowered structure is what we'd ship to TPU.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _pick_block(block: int, length: int) -> int:
    """Largest tile <= `block` that divides `length` (keeps the kernel
    mask-free; ragged partitions are padded by the L2 caller instead)."""
    b = min(block, length)
    while length % b != 0:
        b -= 1
    return b


def _attn_kernel(q_ref, k_ref, v_ref, oc_ref, lc_ref, mc_ref,
                 o_ref, l_ref, m_ref, *, scale: float, nk: int,
                 finalize: bool):
    """Grid point = (b, h, iq, ik); ik is innermost and revisits the same
    output block, accumulating the running (O', l, m) state in-place."""
    ik = pl.program_id(3)

    # [bq, d] / [bk, d] tiles in VMEM (leading singleton b,h squeezed).
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)

    @pl.when(ik == 0)
    def _init():
        # First KV tile of this partition: seed the output refs from the
        # carried-in state of previously merged partitions.
        o_ref[0, 0] = oc_ref[0, 0]
        l_ref[0, 0] = lc_ref[0, 0]
        m_ref[0, 0] = mc_ref[0, 0]

    m_prev = m_ref[0, 0]                       # [bq]
    l_prev = l_ref[0, 0]                       # [bq]
    o_prev = o_ref[0, 0]                       # [bq, d]

    # MXU matmul, f32 accumulate (the mma.m16n8k16 analog).
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [bq, bk]

    m_cur = jnp.max(s, axis=-1)                # row-max on the VPU
    m_new = jnp.maximum(m_prev, m_cur)
    # alpha rescales the carried state; guard the -inf - -inf = nan case
    # (state that has never seen a key: l=0, contributes nothing).
    alpha = jnp.where(jnp.isneginf(m_prev) & jnp.isneginf(m_new),
                      0.0, jnp.exp(m_prev - m_new))
    p = jnp.exp(s - m_new[:, None])            # [bq, bk]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # [bq, d]
    o_new = o_prev * alpha[:, None] + pv

    if finalize:
        is_last = ik == nk - 1

        @pl.when(is_last)
        def _fin():
            inv = jnp.where(l_new == 0.0, 0.0, 1.0 / l_new)
            o_ref[0, 0] = o_new * inv[:, None]
            l_ref[0, 0] = l_new
            m_ref[0, 0] = m_new

        @pl.when(jnp.logical_not(is_last))
        def _acc():
            o_ref[0, 0] = o_new
            l_ref[0, 0] = l_new
            m_ref[0, 0] = m_new
    else:
        o_ref[0, 0] = o_new
        l_ref[0, 0] = l_new
        m_ref[0, 0] = m_new


@functools.partial(
    jax.jit,
    static_argnames=("finalize", "block_q", "block_k", "scale"))
def flash_attention_carry(q, k, v, o_carry, l_carry, m_carry, *,
                          finalize: bool = False,
                          block_q: int = DEFAULT_BLOCK_Q,
                          block_k: int = DEFAULT_BLOCK_K,
                          scale: float | None = None):
    """Attention of q against one KV partition, merged into carried state.

    Args:
      q:        [B, Lq, H, D]
      k, v:     [B, Lk, H, D]   one KV partition (e.g. one Ring step's tile)
      o_carry:  [B, Lq, H, D]   running O' (unnormalized output)
      l_carry:  [B, H, Lq]      running softmax sum
      m_carry:  [B, H, Lq]      running softmax max
      finalize: if True, the returned o is normalized (O = O'/l)

    Returns (o, l, m) with the same layouts as the carries.
    """
    b, lq, h, d = q.shape
    _, lk, _, _ = k.shape
    if scale is None:
        scale = float(1.0 / (d ** 0.5))

    bq = _pick_block(block_q, lq)
    bk = _pick_block(block_k, lk)
    nq, nk = lq // bq, lk // bk

    # [B, H, L, D] layout so tiles are contiguous [bq, D] VMEM blocks.
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    ot = jnp.transpose(o_carry, (0, 2, 1, 3))

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, nk=nk, finalize=finalize)

    o, l, m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
            pl.BlockSpec((1, 1, bq), lambda ib, ih, iq, ik: (ib, ih, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, lq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, lq), jnp.float32),
            jax.ShapeDtypeStruct((b, h, lq), jnp.float32),
        ],
        interpret=True,
    )(qt, kt, vt, ot, l_carry, m_carry)

    return jnp.transpose(o, (0, 2, 1, 3)), l, m


def flash_attention(q, k, v, *, block_q=DEFAULT_BLOCK_Q,
                    block_k=DEFAULT_BLOCK_K, scale=None):
    """Single-shot fused attention (the FlashAttention-2 baseline path,
    used by the Fig. 12 microbenchmark and the single-device oracle)."""
    b, lq, h, d = q.shape
    o0 = jnp.zeros((b, lq, h, d), jnp.float32)
    l0 = jnp.zeros((b, h, lq), jnp.float32)
    m0 = jnp.full((b, h, lq), NEG_INF, jnp.float32)
    o, _, _ = flash_attention_carry(
        q, k, v, o0, l0, m0, finalize=True,
        block_q=block_q, block_k=block_k, scale=scale)
    return o


def flash_attention_multi_kv(q, kvs, *, block_q=DEFAULT_BLOCK_Q,
                             block_k=DEFAULT_BLOCK_K, scale=None):
    """Multi-KV entry point (Algorithm-2 semantics): fold a list of KV
    partitions through the carry kernel, finalizing on the last one."""
    b, lq, h, d = q.shape
    o = jnp.zeros((b, lq, h, d), jnp.float32)
    l = jnp.zeros((b, h, lq), jnp.float32)
    m = jnp.full((b, h, lq), NEG_INF, jnp.float32)
    for i, (k, v) in enumerate(kvs):
        o, l, m = flash_attention_carry(
            q, k, v, o, l, m, finalize=(i == len(kvs) - 1),
            block_q=block_q, block_k=block_k, scale=scale)
    return o


def merge_states(o1, l1, m1, o2, l2, m2):
    """Pure-jnp merge of two carried states (Appendix C Eq. 3) — used by
    the L2 graph when Torus Attention merges partials computed on
    *different* Q chunks' timelines; lowered into the same HLO artifact."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.where(jnp.isneginf(m1) & jnp.isneginf(m), 0.0, jnp.exp(m1 - m))
    a2 = jnp.where(jnp.isneginf(m2) & jnp.isneginf(m), 0.0, jnp.exp(m2 - m))
    l = l1 * a1 + l2 * a2
    s1 = jnp.transpose(a1, (0, 2, 1))[..., None]
    s2 = jnp.transpose(a2, (0, 2, 1))[..., None]
    return o1 * s1 + o2 * s2, l, m
