"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These implement the math of the paper's Appendix C exactly, with no tiling
or memory-hierarchy tricks, and serve as the ground truth that the Pallas
kernels (and, transitively, the rust SP algorithms that consume the lowered
HLO) are validated against.

Notation follows the paper: attention over Q [B, Lq, H, D] and K/V
[B, Lk, H, D]; the partial-softmax state is the triplet (O', l, m) with
O' = O * l (the FlashAttention-2 "unnormalized output" trick, Appendix C
"Optimizing Floating-Point Operations"), so merging two partials costs no
divisions and the single division happens at finalization.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = float("-inf")


def attention(q, k, v, scale=None):
    """Vanilla full softmax attention. q,k,v: [B, L{q,k}, H, D] -> [B, Lq, H, D].

    The global oracle: every distributed algorithm must reproduce this.
    """
    b, lq, h, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    # [B, H, Lq, Lk]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def attention_partial(q, k, v, scale=None):
    """One KV-partition's contribution as an (O', l, m) triplet (Eq. 1).

    Returns:
      o_prime: [B, Lq, H, D]  -- unnormalized output O' = O * l
      l:       [B, H, Lq]     -- running softmax sum
      m:       [B, H, Lq]     -- running softmax max (of scaled scores)
    """
    b, lq, h, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    m = jnp.max(s, axis=-1)  # [B, H, Lq]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)  # [B, H, Lq]
    o_prime = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o_prime, l, m


def merge_partials(o1, l1, m1, o2, l2, m2):
    """Merge two (O', l, m) partial states (Appendix C, Eq. 2/3).

    m  = max(m1, m2)
    l  = l1·e^{m1−m} + l2·e^{m2−m}
    O' = O'1·e^{m1−m} + O'2·e^{m2−m}
    """
    m = jnp.maximum(m1, m2)
    # e^{-inf - -inf} would be nan; a partial that never saw a key has
    # m = -inf and l = 0 and contributes nothing.
    a1 = jnp.where(jnp.isneginf(m1) & jnp.isneginf(m), 0.0, jnp.exp(m1 - m))
    a2 = jnp.where(jnp.isneginf(m2) & jnp.isneginf(m), 0.0, jnp.exp(m2 - m))
    l = l1 * a1 + l2 * a2
    # broadcast [B,H,Lq] scale onto [B,Lq,H,D]
    s1 = jnp.transpose(a1, (0, 2, 1))[..., None]
    s2 = jnp.transpose(a2, (0, 2, 1))[..., None]
    o = o1 * s1 + o2 * s2
    return o, l, m


def finalize(o_prime, l):
    """O = O' / l  (the single division, Appendix C)."""
    inv = jnp.where(l == 0.0, 0.0, 1.0 / l)
    return o_prime * jnp.transpose(inv, (0, 2, 1))[..., None]


def attention_multi_kv(q, kvs, scale=None):
    """Reference for the multi-KV fused kernel: sequential merge over
    KV partitions, as Ring/Torus Attention would see them arrive."""
    o = l = m = None
    for k, v in kvs:
        op, lp, mp = attention_partial(q, k, v, scale=scale)
        if o is None:
            o, l, m = op, lp, mp
        else:
            o, l, m = merge_partials(o, l, m, op, lp, mp)
    return finalize(o, l)


def zero_state(b, lq, h, d, dtype=jnp.float32):
    """Identity element of the merge monoid: O'=0, l=0, m=-inf."""
    o = jnp.zeros((b, lq, h, d), dtype=dtype)
    l = jnp.zeros((b, h, lq), dtype=dtype)
    m = jnp.full((b, h, lq), NEG_INF, dtype=dtype)
    return o, l, m
