"""L1 Pallas kernels for SwiftFusion: fused multi-QKV flash attention with
softmax-state carry (the Algorithm-2 analog) plus pure-jnp oracles."""

from . import ref  # noqa: F401
from .flash_attention import (  # noqa: F401
    flash_attention,
    flash_attention_carry,
    flash_attention_multi_kv,
    merge_states,
)
