"""L2: the DiT (Diffusion Transformer) compute graph in JAX.

This is the scaled-down analog of the paper's Flux / CogVideoX backbones:
adaLN-Zero DiT blocks (Peebles & Xie architecture, which both models build
on), with the attention hot-spot delegated to the L1 Pallas kernel
(kernels.flash_attention). The paper's figures depend on (B, L, H, D) and
the network constants — not on trained weight values — so weights are
synthetic, deterministic per config, and baked into the lowered HLO as
constants (the rust runtime then needs no weight I/O; see DESIGN.md).

The model is lowered by aot.py into *split* entry points so the rust L3
coordinator can interleave its distributed attention algorithms between
them, exactly where NCCL/NVSHMEM calls sit in the paper's engine:

    dit_embed       x_tokens, t            -> h0, c
    dit_block{i}_qkv   x_shard, c          -> q, k, v      (pre-attention)
    [ distributed attention: rust sp::* over attn_partial/merge/finalize ]
    dit_block{i}_post  x_shard, attn_out, c -> x_shard'    (proj+MLP)
    dit_final       x_shard, c             -> eps_tokens
    ddim_step       x, eps, abar_t, abar_p -> x_prev
    vae_decode      x0_tokens              -> pixel patches

plus a fused single-device oracle `dit_forward` used by the quickstart and
by rust integration tests as ground truth for the distributed paths.

Every function is pointwise in the sequence dimension except attention, so
sequence-sharded shards can be fed directly — the property sequence
parallelism relies on (Section 2.2 of the paper).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax.numpy as jnp

from .kernels import flash_attention


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    """A scaled-down DiT instance + the workload shape it serves.

    `l` is the *global* sequence length (number of latent tokens); `chunk`
    is the finest sequence granularity the distributed engine uses
    (l / P_total for the largest mesh this config is validated on).
    """
    name: str
    b: int          # batch size
    l: int          # global sequence length (tokens)
    h: int          # number of attention heads (paper: 24)
    d: int          # head dimension (paper: 64 / 128)
    depth: int      # number of DiT blocks
    c_in: int       # patchified input channels (C * p^2)
    mesh: int       # max total ranks this config is validated on
    seed: int = 0

    @property
    def hidden(self) -> int:
        return self.h * self.d

    @property
    def chunk(self) -> int:
        return self.l // self.mesh

    def head_groups(self):
        """Head-group sizes the SP algorithms may shard to (divisors of h)."""
        return [g for g in range(1, self.h + 1) if self.h % g == 0]


# The configs the rust engine validates real numerics on. Mirrored in
# rust/src/config/validation.rs — keep in sync (checked by manifest tests).
VALIDATION_CONFIGS = [
    DiTConfig(name="small4", b=1, l=128, h=4, d=16, depth=2, c_in=16, mesh=4, seed=1),
    DiTConfig(name="small8", b=2, l=256, h=8, d=16, depth=2, c_in=16, mesh=8, seed=2),
]


def get_config(name: str) -> DiTConfig:
    for c in VALIDATION_CONFIGS:
        if c.name == name:
            return c
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def _linear_init(rng, fan_in, fan_out, gain=1.0):
    w = rng.standard_normal((fan_in, fan_out)).astype(np.float32)
    w *= gain / math.sqrt(fan_in)
    b = np.zeros((fan_out,), np.float32)
    return jnp.asarray(w), jnp.asarray(b)


def make_weights(cfg: DiTConfig):
    """Deterministic synthetic weights for `cfg` (seeded; identical across
    processes so python tests and rust artifacts agree bit-for-bit)."""
    rng = np.random.default_rng(cfg.seed)
    hid = cfg.hidden
    w = {}
    w["embed"] = _linear_init(rng, cfg.c_in, hid)
    w["t_mlp1"] = _linear_init(rng, hid, hid)
    w["t_mlp2"] = _linear_init(rng, hid, hid)
    for i in range(cfg.depth):
        blk = {}
        # adaLN-Zero starts modulation at zero (identity blocks); we use
        # small-random instead so validation numerics are non-trivial.
        blk["mod"] = _linear_init(rng, hid, 6 * hid, gain=0.1)
        blk["qkv"] = _linear_init(rng, hid, 3 * hid)
        blk["proj"] = _linear_init(rng, hid, hid)
        blk["mlp1"] = _linear_init(rng, hid, 4 * hid)
        blk["mlp2"] = _linear_init(rng, 4 * hid, hid)
        w[f"block{i}"] = blk
    # final adaLN (shift, scale) + projection back to token space
    w["final_mod"] = _linear_init(rng, hid, 2 * hid, gain=0.1)
    w["final"] = _linear_init(rng, hid, cfg.c_in)
    # toy linear VAE decoder: latent token -> 2x2 RGB patch (12 values)
    w["vae"] = _linear_init(rng, cfg.c_in, 12)
    return w


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def _linear(x, wb):
    w, b = wb
    return x @ w + b


def _layer_norm(x, eps=1e-6):
    # elementwise_affine=False, as in DiT adaLN blocks
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def _modulate(x, shift, scale):
    # shift/scale: [B, hidden] broadcast over the sequence dim
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def _silu(x):
    return x * jnp.reciprocal(1.0 + jnp.exp(-x))


def timestep_embedding(t, dim):
    """Sinusoidal timestep embedding (DDPM convention). t: [B] float32."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def embed(cfg: DiTConfig, w, x_tokens, t):
    """Patch-embedded tokens + conditioning vector.

    x_tokens: [B, Ls, c_in], t: [B] -> (h0 [B, Ls, hidden], c [B, hidden])
    """
    h0 = _linear(x_tokens, w["embed"])
    te = timestep_embedding(t, cfg.hidden)
    c = _linear(_silu(_linear(te, w["t_mlp1"])), w["t_mlp2"])
    return h0, c


def block_modulation(w_blk, c):
    """The six adaLN-Zero modulation tensors of one block: [B, hidden] each."""
    mod = _linear(_silu(c), w_blk["mod"])
    return jnp.split(mod, 6, axis=-1)


def block_qkv(cfg: DiTConfig, w_blk, x, c):
    """Pre-attention half of a DiT block (pointwise in sequence).

    x: [B, Ls, hidden] -> q, k, v: [B, Ls, H, D]
    """
    shift1, scale1, _, _, _, _ = block_modulation(w_blk, c)
    xin = _modulate(_layer_norm(x), shift1, scale1)
    qkv = _linear(xin, w_blk["qkv"])
    b, ls, _ = qkv.shape
    qkv = qkv.reshape(b, ls, 3, cfg.h, cfg.d)
    return qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]


def block_post(cfg: DiTConfig, w_blk, x, attn_out, c):
    """Post-attention half: out-projection, gated residual, MLP.

    x: [B, Ls, hidden], attn_out: [B, Ls, H, D] -> [B, Ls, hidden]
    """
    _, _, gate1, shift2, scale2, gate2 = block_modulation(w_blk, c)
    b, ls = x.shape[:2]
    a = attn_out.reshape(b, ls, cfg.hidden)
    x = x + gate1[:, None, :] * _linear(a, w_blk["proj"])
    m = _modulate(_layer_norm(x), shift2, scale2)
    m = _linear(_silu(_linear(m, w_blk["mlp1"])), w_blk["mlp2"])
    return x + gate2[:, None, :] * m


def final_layer(cfg: DiTConfig, w, x, c):
    """adaLN final layer -> eps prediction in token space. [B, Ls, c_in]."""
    mod = _linear(_silu(c), w["final_mod"])
    shift, scale = jnp.split(mod, 2, axis=-1)
    return _linear(_modulate(_layer_norm(x), shift, scale), w["final"])


def dit_forward(cfg: DiTConfig, w, x_tokens, t):
    """Fused single-device forward — the oracle for all distributed paths.

    Attention goes through the L1 Pallas kernel so the oracle exercises the
    identical numeric path the distributed artifacts use.
    """
    x, c = embed(cfg, w, x_tokens, t)
    for i in range(cfg.depth):
        w_blk = w[f"block{i}"]
        q, k, v = block_qkv(cfg, w_blk, x, c)
        attn = flash_attention(q, k, v)
        x = block_post(cfg, w_blk, x, attn, c)
    return final_layer(cfg, w, x, c)


# ---------------------------------------------------------------------------
# Sampler + toy VAE
# ---------------------------------------------------------------------------

def ddim_step(x, eps, abar_t, abar_prev):
    """One deterministic DDIM update. x, eps: [B, Ls, c_in]; abar_*: [] f32."""
    sqrt_abar = jnp.sqrt(abar_t)
    sqrt_1m = jnp.sqrt(1.0 - abar_t)
    x0 = (x - sqrt_1m * eps) / sqrt_abar
    return jnp.sqrt(abar_prev) * x0 + jnp.sqrt(1.0 - abar_prev) * eps


def ddim_alphas(num_steps: int, total: int = 1000):
    """Host-side schedule: cosine alpha-bar at `num_steps` evenly spaced t's.

    Returns (ts, abars) as python lists; mirrored in rust model/sampler.rs.
    """
    def abar(t):
        return math.cos((t / total + 0.008) / 1.008 * math.pi / 2) ** 2
    ts = [total - 1 - i * (total // num_steps) for i in range(num_steps)]
    return ts, [abar(t) for t in ts]


def vae_decode(cfg: DiTConfig, w, x0_tokens):
    """Toy linear VAE decoder: latent token -> 2x2 RGB patch values in [0,1].
    Stands in for the paper's VAE stage (Figure 1) on the serving path."""
    pix = _linear(x0_tokens, w["vae"])
    return jnp.reciprocal(1.0 + jnp.exp(-pix))  # sigmoid to [0,1]
