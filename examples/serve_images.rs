//! End-to-end serving driver (the repo's headline example): batched
//! image-generation requests flow through the full coordinator stack —
//! router → batcher → engine — and are served by **real numeric
//! sampling** on the simulated cluster (every attention tile through the
//! AOT Pallas artifacts, real tensors between rank threads). Reports
//! per-request latency and throughput; writes the generated images.
//!
//!     make artifacts && cargo run --release --example serve_images \
//!         [--requests 8] [--steps 4] [--algo swiftfusion]

use std::sync::Mutex;

use swiftfusion::config::{AttnShape, ClusterSpec, SpDegrees};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::serve;
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::{CostModel, Planner};
use swiftfusion::model::DiTModel;
use swiftfusion::runtime::Runtime;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::cli::Args;
use swiftfusion::workload::{Request, Workload};

/// Numeric service: each batch triggers a real distributed sampling run;
/// service time is the *simulated GPU time* of that run (virtual seconds
/// on the modelled A100 cluster), so the serving report reads like the
/// paper's testbed, while the numerics are bit-exact.
struct NumericService {
    model: DiTModel,
    cluster: ClusterSpec,
    algo: SpAlgo,
    degrees: SpDegrees,
    steps: usize,
    images: Mutex<Vec<swiftfusion::Tensor>>,
    wall: Mutex<f64>,
}

impl CostModel for NumericService {
    fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
        let t0 = std::time::Instant::now();
        let mut sim_total = 0.0;
        for i in 0..batch {
            let (img, sim) = self
                .model
                .sample_distributed(
                    &self.cluster,
                    self.algo,
                    self.degrees,
                    7 + i as u64,
                    self.steps,
                )
                .expect("sampling failed");
            self.images.lock().unwrap().push(img);
            sim_total += sim;
        }
        *self.wall.lock().unwrap() += t0.elapsed().as_secs_f64();
        // batched requests share the step loop on real hardware; model
        // sequential here, report the simulated aggregate
        sim_total
    }
}

// NumericService does not plan (it serves one fixed mesh); the empty
// Planner impl opts into the scheduler's plan-agnostic defaults.
impl Planner for NumericService {}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nreq = args.usize_or("requests", 6)?;
    let steps = args.usize_or("steps", 3)?;
    let algo = SpAlgo::from_name(args.str_or("algo", "swiftfusion"))
        .ok_or_else(|| anyhow::anyhow!("unknown algo"))?;

    let rt = Runtime::load_default()?;
    let model = DiTModel::new(rt.handle(), "small4")?;
    let cluster = ClusterSpec::new(2, 2);
    let degrees = SpDegrees::swiftfusion_default(&cluster, model.cfg.h);
    println!(
        "serving {nreq} image requests on a simulated 2x2 cluster ({}, U{}R{}, {} steps)",
        algo.name(),
        degrees.pu,
        degrees.pr,
        steps
    );

    // The request workload: one entry matching the small4 model shape.
    let workload = Workload {
        name: "small4-image",
        shape: AttnShape::new(model.cfg.b, model.cfg.l, model.cfg.h, model.cfg.d),
        layers: model.cfg.depth,
        steps,
        cfg_evals: 1,
    };
    // bursty arrivals: all requests in the first second
    let requests: Vec<Request> = (0..nreq)
        .map(|i| Request {
            id: i as u64,
            workload: workload.clone(),
            arrival: i as f64 * 0.1,
            seed: 100 + i as u64,
        })
        .collect();

    let svc = NumericService {
        model,
        cluster,
        algo,
        degrees,
        steps,
        images: Mutex::new(Vec::new()),
        wall: Mutex::new(0.0),
    };
    let mut router = Router::new(2, 2, 1, algo);
    let report = serve(
        &mut router,
        BatchPolicy { max_batch: 2, window: 0.5 },
        requests,
        &svc,
    );

    let mut metrics = report.metrics;
    print!("{}", metrics.report());
    let images = svc.images.lock().unwrap();
    println!(
        "generated {} images (all finite: {}), total wall compute {}",
        images.len(),
        images.iter().all(|i| i.is_finite()),
        swiftfusion::util::stats::fmt_time(*svc.wall.lock().unwrap())
    );
    anyhow::ensure!(images.len() >= nreq, "every request must yield an image");
    println!("serve_images OK");
    Ok(())
}
