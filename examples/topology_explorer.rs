//! Topology explorer: where does topology-aware scheduling stop paying
//! off? Sweeps the inter-machine bandwidth from commodity ethernet up to
//! NVSwitch parity and reports the USP/TAS/SwiftFusion ordering at each
//! point — making the paper's premise (§3 Challenge 1: the intra/inter
//! gap drives the design) quantitative.
//!
//!     cargo run --release --example topology_explorer [--machines 4]

use swiftfusion::config::ClusterSpec;
use swiftfusion::coordinator::engine::SimService;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::cli::Args;
use swiftfusion::util::stats::fmt_time;
use swiftfusion::workload::Workload;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("machines", 4)?;
    let w = Workload::cogvideo_20s();
    println!(
        "sweep: inter-machine bandwidth vs per-layer latency ({} machines x 8, {})",
        n, w.name
    );
    println!(
        "{:<22}{:>12}{:>12}{:>12}{:>14}",
        "inter-BW (GB/s/mach)", "usp", "tas", "swiftfusion", "SFU speedup"
    );

    // 12.5 GB/s (100 GbE) up to 300 GB/s (NVSwitch parity)
    for bw_gb in [12.5, 25.0, 50.0, 100.0, 200.0, 300.0] {
        let mut cluster = ClusterSpec::new(n, 8);
        cluster.net.inter_bw = bw_gb * 1e9;
        let t = |algo: SpAlgo| SimService::new(cluster.clone(), algo).layer_time(&w, 1);
        let (usp, tas, sfu) = (t(SpAlgo::Usp), t(SpAlgo::Tas), t(SpAlgo::SwiftFusion));
        println!(
            "{:<22}{:>12}{:>12}{:>12}{:>13.2}x",
            format!("{bw_gb}"),
            fmt_time(usp),
            fmt_time(tas),
            fmt_time(sfu),
            usp / sfu
        );
    }
    println!(
        "\nreading: the wider the intra/inter gap (left side), the bigger the\n\
         SwiftFusion win; at parity (right side) topology-awareness stops mattering."
    );
    Ok(())
}
