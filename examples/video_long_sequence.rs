//! Long-video workload at paper scale: one DiT sampling step of
//! CogVideoX-40s (≈326k tokens) on the paper's 4×8 A100 testbed,
//! comparing USP / TAS / SwiftFusion on the calibrated timing model —
//! the scenario the paper's introduction motivates (activations too big
//! for one GPU, inter-machine communication the bottleneck).
//!
//!     cargo run --release --example video_long_sequence

use swiftfusion::analysis;
use swiftfusion::config::{ClusterSpec, SpDegrees};
use swiftfusion::coordinator::engine::SimService;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::{fmt_bytes, fmt_time};
use swiftfusion::workload::Workload;

fn main() {
    let cluster = ClusterSpec::paper_testbed(); // 4 machines x 8 A100
    let w = Workload::cogvideo_40s();
    println!(
        "CogVideoX-40s: L={} tokens, H={}, D={}, {} layers x {} steps",
        w.shape.l, w.shape.h, w.shape.d, w.layers, w.steps
    );

    // memory check: why single-GPU fails (the paper's §2.1 motivation)
    let act_one_gpu = analysis::activation_bytes(SpAlgo::SwiftFusion, &w.shape, 1);
    println!(
        "single-GPU activations/layer: {} (A100 capacity {}) -> sequence parallelism required",
        fmt_bytes(act_one_gpu),
        fmt_bytes(cluster.gpu.mem_capacity)
    );

    println!("\nper-sampling-step latency on 4x8 (calibrated timing model):");
    let mut base = None;
    for algo in [SpAlgo::Usp, SpAlgo::Tas, SpAlgo::SwiftFusion] {
        let svc = SimService::new(cluster.clone(), algo);
        let layer = svc.layer_time(&w, 1);
        let step = layer * w.layers as f64;
        if algo == SpAlgo::Usp {
            base = Some(step);
        }
        let speed = base.map(|b| format!("{:.2}x vs USP", b / step)).unwrap_or_default();
        println!(
            "  {:<12} layer {:>10}  step {:>10}  full video {:>10}  {}",
            algo.name(),
            fmt_time(layer),
            fmt_time(step),
            fmt_time(step * w.steps as f64),
            speed
        );
    }

    // Appendix-D volumes: why SwiftFusion wins here
    println!("\ninter-machine volume per GPU (one attention layer):");
    let p = cluster.total_gpus();
    for (algo, pu) in [
        (SpAlgo::Usp, swiftfusion::config::gcd(cluster.gpus_per_machine, w.shape.h)),
        (SpAlgo::SwiftFusion, swiftfusion::config::gcd(p, w.shape.h)),
    ] {
        let deg = SpDegrees::new(pu, p / pu);
        let v = analysis::inter_volume(algo, &w.shape, 4, 8, deg);
        println!("  {:<12} (U{}R{})  {}", algo.name(), deg.pu, deg.pr, fmt_bytes(v * 4.0));
    }
}
