//! Quickstart: load the AOT artifacts, run a full image generation on a
//! single device, then the same generation distributed over a simulated
//! 2×2 GPU cluster with SwiftFusion — and check they agree.
//!
//!     make artifacts && cargo run --release --example quickstart

use swiftfusion::config::{ClusterSpec, SpDegrees};
use swiftfusion::model::DiTModel;
use swiftfusion::runtime::Runtime;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_time;

fn main() -> anyhow::Result<()> {
    // 1. Load the runtime (PJRT CPU client + artifact manifest).
    let rt = Runtime::load_default()?;
    println!("loaded {} artifacts", rt.manifest().artifacts.len());

    // 2. Pick the small validation DiT and generate one image,
    //    single-device: noise -> 6 DDIM steps -> toy VAE decode.
    let model = DiTModel::new(rt.handle(), "small4")?;
    let t0 = std::time::Instant::now();
    let img = model.sample_single(42, 6)?;
    println!(
        "single-device generation: {} tokens -> {:?} pixels in {}",
        model.cfg.l,
        img.shape(),
        fmt_time(t0.elapsed().as_secs_f64())
    );

    // 3. Same generation, distributed over 2 machines x 2 GPUs with
    //    SwiftFusion (Algorithm 1): real tensors cross rank threads, all
    //    attention tiles run through the Pallas artifact.
    let cluster = ClusterSpec::new(2, 2);
    let t0 = std::time::Instant::now();
    let (img_dist, sim_gpu_time) =
        model.sample_distributed(&cluster, SpAlgo::SwiftFusion, SpDegrees::new(2, 2), 42, 6)?;
    println!(
        "distributed generation (2x2, swiftfusion): wall {}, simulated GPU time {}",
        fmt_time(t0.elapsed().as_secs_f64()),
        fmt_time(sim_gpu_time)
    );

    // 4. The distributed engine must reproduce the single-device image.
    let diff = img.max_abs_diff(&img_dist);
    println!("max |single - distributed| = {diff:.2e}");
    anyhow::ensure!(diff < 1e-3, "distributed sampling diverged");

    // 5. Write the image as a PPM for inspection.
    let path = std::env::temp_dir().join("swiftfusion_quickstart.ppm");
    write_ppm(&img, &path)?;
    println!("wrote {}", path.display());
    println!("quickstart OK");
    Ok(())
}

/// Dump the [B, L, 12] patch tensor as an RGB PPM (2x2 patches per token,
/// tokens arranged in a square grid).
fn write_ppm(img: &swiftfusion::Tensor, path: &std::path::Path) -> anyhow::Result<()> {
    let l = img.shape()[1];
    let grid = (l as f64).sqrt() as usize;
    let side = grid * 2;
    let mut data = vec![0u8; side * side * 3];
    for token in 0..grid * grid {
        let (ty, tx) = (token / grid, token % grid);
        for py in 0..2 {
            for px in 0..2 {
                for ch in 0..3 {
                    let v = img.at(&[0, token, (py * 2 + px) * 3 + ch]);
                    let (y, x) = (ty * 2 + py, tx * 2 + px);
                    data[(y * side + x) * 3 + ch] = (v * 255.0) as u8;
                }
            }
        }
    }
    let mut out = format!("P6\n{side} {side}\n255\n").into_bytes();
    out.extend_from_slice(&data);
    std::fs::write(path, out)?;
    Ok(())
}
