//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset of the API this workspace uses: [`Error`] (an
//! opaque, context-carrying error), `Result<T>` with a defaulted error
//! type, the `anyhow!` / `bail!` / `ensure!` macros, and the [`Context`]
//! extension trait. Like real anyhow, `Error` deliberately does NOT
//! implement `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion to exist.
//!
//! `Display` prints the outermost message only; `{:#}` (alternate) prints
//! the whole cause chain separated by `: `, matching anyhow's behaviour.

use std::fmt;

/// Opaque error: a message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Self { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut cur = Some(self);
        std::iter::from_fn(move || {
            let e = cur?;
            cur = e.source.as_deref();
            Some(e)
        })
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        self.chain().last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, e) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut rest = self.source.as_deref();
        if rest.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = rest {
            write!(f, "\n    {}", e.msg)?;
            rest = e.source.as_deref();
        }
        Ok(())
    }
}

// The blanket conversion that makes `?` work on any std error. Error
// itself is not a std error, so this cannot conflict with the identity.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            out = Some(match out {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        out.expect("at least one message")
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result`s whose error converts into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest.json");
        assert_eq!(e.to_string(), "reading manifest.json");
        let alt = format!("{e:#}");
        assert!(alt.contains("reading manifest.json"));
        assert!(alt.contains("no such file"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("no such file"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert!(f(11).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::io::Error> = Ok(5);
        let mut called = false;
        let got = ok
            .with_context(|| {
                called = true;
                "never built"
            })
            .unwrap();
        assert_eq!(got, 5);
        assert!(!called, "context closure must not run on Ok");
    }
}
