//! Offline stub of the `xla` (xla_extension) PJRT bindings.
//!
//! This container has no network access and no prebuilt xla_extension, so
//! this crate mirrors the API surface `swiftfusion::runtime` uses and
//! reports PJRT as unavailable: [`PjRtClient::cpu`] returns an error, and
//! the runtime's service thread degrades gracefully (every artifact call
//! fails with a clear message; artifact-dependent tests skip).
//!
//! Swapping this path dependency for the real bindings re-enables the
//! numeric PJRT path with zero changes to the engine — the types and
//! signatures below match the subset of xla_extension 0.5.x in use.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// `AVAILABLE` lets callers gate artifact-dependent work at compile time:
/// the real bindings export `true`.
pub const AVAILABLE: bool = false;

/// Error type mirroring `xla::Error` (only constructed by the stub).
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Self {
        Self {
            msg: format!(
                "{what}: PJRT is unavailable (built with the offline xla stub; \
                 swap rust/vendor/xla for the real xla_extension bindings)"
            ),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (stub carries the data so pure-host round trips work).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Element types a [`Literal`] can be read back as (f32 only here).
pub trait NativeType: Sized {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

impl Literal {
    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], dims: vec![] }
    }

    pub fn vec1(v: &[f32]) -> Self {
        Self { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error {
                msg: format!("reshape {:?} on {} elements", dims, self.data.len()),
            });
        }
        Ok(Self { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub: never successfully constructed).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(Error::unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. The stub's constructor always fails; the engine's service
/// thread catches this and fails artifact calls with the message.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("offline xla stub"));
    }

    #[test]
    fn literal_roundtrip_works_on_host() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(Literal::vec1(&[1.0]).reshape(&[3]).is_err());
    }
}
