//! Figure 10 — ablation: USP → +topology-aware scheduling (TAS) →
//! +Torus Attention over NCCL → +one-sided (full SwiftFusion), per
//! workload, one sampling step on 4×8.
//!
//! Expected shape (paper Appendix B): TAS alone gives ~1.27x; Torus adds
//! most for the long-sequence video workloads (comm volume large enough
//! to matter); one-sided adds most for the image workloads (where the
//! sync/SM overheads dominate the smaller transfers).
//!
//! Run: `cargo bench --bench fig10_ablation`

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::config::ClusterSpec;
use swiftfusion::coordinator::engine::SimService;
use swiftfusion::sp::SpAlgo;
use swiftfusion::workload::Workload;

fn main() {
    let mut run = BenchRun::from_env("fig10_ablation");
    let cluster = ClusterSpec::paper_testbed();
    let variants = [
        ("usp", SpAlgo::Usp),
        ("+tas", SpAlgo::Tas),
        ("+torus(nccl)", SpAlgo::TorusNccl),
        ("+one-sided (sfu)", SpAlgo::SwiftFusion),
    ];
    let mut series: Vec<Series> = variants
        .iter()
        .map(|(name, _)| Series::new(*name))
        .collect();
    // smoke: one image + one video workload keep every ablation column
    let workloads = if run.smoke() {
        vec![Workload::flux_3072(), Workload::cogvideo_20s()]
    } else {
        Workload::paper_suite()
    };
    for w in workloads {
        for (i, (_, algo)) in variants.iter().enumerate() {
            let svc = SimService::new(cluster.clone(), *algo);
            let step = svc.layer_time(&w, 1) * w.layers as f64;
            series[i].push(w.name.to_string(), step);
        }
    }
    run.table(
        "Fig 10: ablation — one sampling step on 4x8, per workload",
        &series,
        Some("usp"),
    );
    println!(
        "\nreading: every row should order usp >= +tas >= +torus(nccl) >= sfu;\n\
         torus helps most on cogvideox (long L), one-sided most on flux."
    );
    run.finish().expect("write BENCH_fig10_ablation.json");
}
