//! Quality-elastic serving headline (PR 8): approximate inference modes
//! as a scheduler dimension. A single pod takes a video burst arriving
//! far faster than it can serve; under `--quality-floor` every batch
//! that lands on the backlogged pod degrades to the cheapest
//! [`QualityMode`] whose score clears the floor, while an idle pod still
//! serves exact — so the floored run must clear the burst *strictly*
//! faster than the same run forced to full quality, and every completion
//! must have served at or above the floor.
//!
//! Asserted:
//! 1. both runs complete the whole burst with zero rejections;
//! 2. every mode in the floored run's quality histogram scores >= the
//!    floor (the admission contract);
//! 3. the floored horizon is strictly below the forced-full horizon
//!    (`backlog_clear_speedup` > 1 in the JSON artifact).
//!
//! Run: `cargo bench --bench fig_quality_elastic`. `--smoke` shrinks the
//! burst for CI; the workload is the cfg-video pair shrunk to 2 layers x
//! 2 steps (the serve-test convention) so the timing simulations stay
//! fast — the quality admission flow is what is being measured.

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::config::QualityMode;
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{ServeConfig, ServeSession};
use swiftfusion::sp::SpAlgo;
use swiftfusion::workload::{Request, Workload};

/// The floor the headline run serves under: admits the whole ladder, so
/// backlogged batches degrade all the way to `steps/2` — which on a CFG
/// video also drops the second guidance branch (the distillation
/// arithmetic in `Workload::evals_under`).
const FLOOR: f64 = 0.5;

fn video_burst(n: usize) -> Vec<Request> {
    let mut w = Workload::cfg_video_96k();
    w.layers = 2;
    w.steps = 2;
    (0..n)
        .map(|i| Request {
            id: i as u64,
            workload: w.clone(),
            arrival: i as f64 * 0.05,
            seed: i as u64,
        })
        .collect()
}

/// One serving run on a 2x8 pod: `floor` = None forces full quality.
fn serve_burst(floor: Option<f64>, n: usize) -> ServeReport {
    let mut router = Router::new(2, 8, 1, SpAlgo::SwiftFusion);
    let mut config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto);
    config = match floor {
        Some(f) => config.quality_floor(f),
        None => config.quality(QualityMode::Full),
    };
    let svc = config
        .sim_service(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion)
        .expect("auto planner on the 2x8 pod");
    ServeSession::new(config, &svc).run(&mut router, video_burst(n))
}

fn main() {
    let mut run = BenchRun::from_env("fig_quality_elastic");
    let n = if run.smoke() { 6 } else { 16 };
    println!("fig_quality_elastic: {n}-request video burst on one 2x8 pod,");
    println!("forced full quality vs --quality-floor {FLOOR}\n");

    let full = serve_burst(None, n);
    let floored = serve_burst(Some(FLOOR), n);

    assert_eq!(full.metrics.completed(), n, "forced-full run must serve the burst");
    assert_eq!(floored.metrics.completed(), n, "floored run must serve the burst");
    assert!(full.rejected.is_empty() && floored.rejected.is_empty());

    // the admission contract: nothing served below the floor
    let allowed: Vec<String> = QualityMode::ladder()
        .iter()
        .filter(|q| q.score() >= FLOOR)
        .map(|q| q.label())
        .collect();
    for (mode, count) in &floored.quality_histogram {
        println!("  floored run served {count:>3} request(s) at quality '{mode}'");
        assert!(
            allowed.contains(mode),
            "mode '{mode}' served below the {FLOOR} floor (allowed: {allowed:?})"
        );
    }
    assert!(
        floored.quality_histogram.len() >= 2,
        "the backlog must flip at least one batch off full quality: {:?}",
        floored.quality_histogram
    );

    let speedup = full.metrics.horizon / floored.metrics.horizon;
    println!(
        "\n  horizon: forced full {:.3} s -> floored {:.3} s ({speedup:.2}x faster)",
        full.metrics.horizon, floored.metrics.horizon
    );
    assert!(
        floored.metrics.horizon < full.metrics.horizon,
        "the floored pod must clear the burst strictly faster: \
         {} vs {}",
        floored.metrics.horizon,
        full.metrics.horizon
    );

    let mut series = vec![Series::new("forced full"), Series::new("floored")];
    series[0].push("burst horizon s", full.metrics.horizon);
    series[1].push("burst horizon s", floored.metrics.horizon);
    for (mode, count) in &floored.quality_histogram {
        series[1].push(format!("served {mode}"), *count as f64);
    }
    run.table(
        "fig_quality_elastic: video burst, forced full vs quality floor (2x8 pod)",
        &series,
        None,
    );
    run.note("quality_histogram", floored.quality_histogram.len() as f64);
    run.note("backlog_clear_speedup", speedup);
    run.note("floored_horizon", floored.metrics.horizon);
    run.note("full_horizon", full.metrics.horizon);
    run.finish().expect("write BENCH_fig_quality_elastic.json");
}
