//! PipeFusion sweep on the 4×8-A100 testbed: sp-only vs pp×sp vs
//! cfg×pp×sp, per paper workload.
//!
//! Latency is the *executable* timing-mode makespan of one attention
//! layer under the plan — group-scoped SP schedules on carved sub-meshes
//! for the non-pipelined plans, the displaced patch pipeline
//! (`sp::pipefusion`) for `pp_degree > 1` — scaled to a full generation.
//! Expected shape: the long CFG video workloads gain most from adding
//! the pp dimension because a one-machine pipeline stage pays zero
//! inter-machine all-to-all (the per-patch activation hops are far
//! smaller and overlap with compute); short distilled workloads are
//! latency-bound on the hops and stay with plain SP. The closed-form
//! chooser (`analysis::choose_spec`) is printed alongside so its ranking
//! can be compared with the executable model's.
//!
//! Run: `cargo bench --bench fig_pipefusion`

use swiftfusion::analysis;
use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::config::{ClusterSpec, ParallelSpec};
use swiftfusion::coordinator::engine::SimService;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_time;
use swiftfusion::workload::Workload;

/// The plans under comparison: (label, cfg_degree, pp_degree, replicas).
/// Stage SP degrees follow the gcd placement rule on the stage size.
const PLANS: [(&str, usize, usize, usize); 4] = [
    ("sp-only (cfg1 sp32)", 1, 1, 1),
    ("pp2 x sp16", 1, 2, 1),
    ("pp4 x sp8", 1, 4, 1),
    ("cfg2 x pp2 x sp8", 2, 2, 1),
];

fn spec_for(
    cluster: &ClusterSpec,
    cfg: usize,
    pp: usize,
    reps: usize,
    heads: usize,
) -> ParallelSpec {
    let stage = cluster.total_gpus() / (cfg * pp * reps);
    ParallelSpec::with_gcd_placement_pp(cfg, pp, reps, stage, heads)
}

fn main() {
    let cluster = ClusterSpec::paper_testbed();
    let algo = SpAlgo::SwiftFusion;
    let patches = analysis::DEFAULT_PATCHES;
    println!(
        "PipeFusion plan sweep on 4x8 A100 ({}, {patches} patches)",
        algo.name()
    );

    let mut run = BenchRun::from_env("fig_pipefusion");
    // smoke: one image + one video workload keep every plan column
    let workloads = if run.smoke() {
        vec![Workload::flux_3072(), Workload::cogvideo_20s()]
    } else {
        Workload::paper_suite()
    };
    let mut lat_series: Vec<Series> = PLANS.iter().map(|(l, _, _, _)| Series::new(*l)).collect();

    for w in workloads {
        for (i, (label, cfg, pp, reps)) in PLANS.iter().enumerate() {
            let spec = spec_for(&cluster, *cfg, *pp, *reps, w.shape.h);
            assert!(spec.validate(&cluster).is_ok(), "{label} invalid on 4x8");
            let svc =
                SimService::with_plan(cluster.clone(), algo, spec).expect("validated spec");
            // one full generation at batch 1 under this plan
            let gen = svc.plan_layer_time(&spec, &w, 1) * w.layers as f64 * w.steps as f64;
            lat_series[i].push(w.name, gen);
        }
        let picked = analysis::choose_spec(&cluster, algo, &w.shape, w.cfg_evals, 1);
        println!("  {:<16} chooser (latency): {}", w.name, picked.label());
    }

    run.table(
        "fig_pipefusion: one full generation (batch 1), per plan",
        &lat_series,
        Some(PLANS[0].0),
    );

    // sanity lines the acceptance criterion reads off this bench: the
    // pipelined plans must beat sp-only on the long CFG video workloads
    for (i, (label, _, _, _)) in PLANS.iter().enumerate() {
        let video = lat_series[i]
            .points
            .iter()
            .find(|(x, _)| x == "cogvideox-20s")
            .map(|(_, y)| *y)
            .unwrap();
        println!("plan {label}: cogvideox-20s generation {}", fmt_time(video));
        run.note(&format!("cogvideox-20s/{label}"), video);
    }
    run.finish().expect("write BENCH_fig_pipefusion.json");
}
