//! Figure 9 — layerwise microbenchmarks: normalized single-attention-layer
//! latency of SwiftFusion vs USP across (a) sequence length × head dim
//! and (b) batch size × head dim, on 4×8.
//!
//! Expected shape (paper §5.3): SFU wins everywhere but the margin
//! *shrinks* with L (compute grows quadratically, comm linearly) and
//! *grows* with D (bigger tiles saturate the GPU better); no strong
//! batch-size trend.
//!
//! Run: `cargo bench --bench fig9_layerwise`

use swiftfusion::cluster::exec::{run_cluster, ExecMode};
use swiftfusion::comm::Buf;
use swiftfusion::config::{AttnShape, ClusterSpec, SpDegrees};
use swiftfusion::sp::{SpAlgo, SpParams};
use swiftfusion::bench::{BenchRun, Series};

const H: usize = 24;

fn layer_time(cluster: &ClusterSpec, algo: SpAlgo, shape: AttnShape) -> f64 {
    let p = cluster.total_gpus();
    let deg = match algo {
        SpAlgo::Usp => {
            let pu = swiftfusion::config::gcd(cluster.gpus_per_machine, shape.h);
            SpDegrees::new(pu, p / pu)
        }
        _ => SpDegrees::swiftfusion_default(cluster, shape.h),
    };
    let params = SpParams { shape, chunk: shape.l / p, mesh: algo.mesh(cluster, deg) };
    run_cluster(cluster, &ExecMode::Timing, |ctx| {
        let s = Buf::Shape(vec![shape.b, shape.l / p, shape.h, shape.d]);
        algo.run(ctx, &params, s.clone(), s.clone(), s);
    })
    .makespan()
}

fn main() {
    let mut run = BenchRun::from_env("fig9_layerwise");
    let cluster = ClusterSpec::paper_testbed();
    // smoke: one head dim, endpoint sequence lengths / batch sizes
    let dims: &[usize] = if run.smoke() { &[64] } else { &[32, 64, 128] };
    let lens: &[usize] = if run.smoke() { &[96, 192] } else { &[96, 128, 160, 192] };
    let batches: &[usize] = if run.smoke() { &[1, 4] } else { &[1, 2, 4] };

    // ---- Fig 9a: sequence length sweep per head dim ----
    for &d in dims {
        let mut usp = Series::new("usp");
        let mut sfu = Series::new("swiftfusion");
        for &l_k in lens {
            let l = l_k * 1024;
            let shape = AttnShape::new(1, l, H, d);
            let label = format!("L={l_k}k");
            usp.push(label.clone(), layer_time(&cluster, SpAlgo::Usp, shape));
            sfu.push(label, layer_time(&cluster, SpAlgo::SwiftFusion, shape));
        }
        run.table(
            &format!("Fig 9a: attention layer latency vs sequence length (D={d})"),
            &[usp, sfu],
            Some("usp"),
        );
    }

    // ---- Fig 9b: batch sweep per head dim ----
    for &d in dims {
        let mut usp = Series::new("usp");
        let mut sfu = Series::new("swiftfusion");
        for &b in batches {
            let shape = AttnShape::new(b, 96 * 1024, H, d);
            let label = format!("B={b}");
            usp.push(label.clone(), layer_time(&cluster, SpAlgo::Usp, shape));
            sfu.push(label, layer_time(&cluster, SpAlgo::SwiftFusion, shape));
        }
        run.table(
            &format!("Fig 9b: attention layer latency vs batch size (D={d})"),
            &[usp, sfu],
            Some("usp"),
        );
    }
    run.finish().expect("write BENCH_fig9_layerwise.json");
}
