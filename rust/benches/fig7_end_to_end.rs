//! Figure 7 — end-to-end latency + memory at each method's optimal
//! distributed configuration, for all four paper workloads and machine
//! counts M ∈ {1, 2, 3, 4} (×8 GPUs).
//!
//! Reported: one sampling-step latency (layers × per-layer makespan of
//! the executable schedule on the calibrated cluster model) for USP,
//! TAS, SFU, plus the per-GPU memory model. Expected shape (paper §5.2):
//! USP ≈ TAS at M=2 (TAS can lose), TAS wins ≥1.2x at M≥3, SFU adds
//! overlap on top; memory parity across methods.
//!
//! Run: `cargo bench --bench fig7_end_to_end`

use swiftfusion::analysis;
use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::config::ClusterSpec;
use swiftfusion::coordinator::engine::SimService;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_bytes;
use swiftfusion::workload::Workload;

fn main() {
    let mut run = BenchRun::from_env("fig7_end_to_end");
    // smoke: two workloads x the endpoint machine counts
    let workloads = if run.smoke() {
        vec![Workload::flux_3072(), Workload::cogvideo_20s()]
    } else {
        Workload::paper_suite()
    };
    let machines: &[usize] = if run.smoke() { &[1, 4] } else { &[1, 2, 3, 4] };
    for w in workloads {
        let mut usp = Series::new("usp");
        let mut tas = Series::new("tas");
        let mut sfu = Series::new("swiftfusion");
        for &m in machines {
            let cluster = ClusterSpec::new(m, 8);
            let step = |algo: SpAlgo| {
                let svc = SimService::new(cluster.clone(), algo);
                svc.layer_time(&w, 1) * w.layers as f64
            };
            let label = format!("M={m}");
            usp.push(label.clone(), step(SpAlgo::Usp));
            tas.push(label.clone(), step(SpAlgo::Tas));
            sfu.push(label, step(SpAlgo::SwiftFusion));
        }
        run.table(
            &format!("Fig 7: {} — one sampling-step latency", w.name),
            &[usp, tas, sfu],
            Some("usp"),
        );
    }

    println!("\n=== Fig 7 (memory): per-GPU activation+comm buffers at M=4 ===");
    println!("{:<16}{:>14}{:>14}{:>14}", "workload", "usp", "tas", "swiftfusion");
    for w in Workload::paper_suite() {
        let p = 32;
        let row: Vec<String> = [SpAlgo::Usp, SpAlgo::Tas, SpAlgo::SwiftFusion]
            .iter()
            .map(|a| fmt_bytes(analysis::activation_bytes(*a, &w.shape, p)))
            .collect();
        println!("{:<16}{:>14}{:>14}{:>14}", w.name, row[0], row[1], row[2]);
    }
    println!("(paper conclusion 4: SwiftFusion introduces no memory overhead vs USP)");
    run.finish().expect("write BENCH_fig7_end_to_end.json");
}
