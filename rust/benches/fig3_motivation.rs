//! Figure 3 — motivation.
//!
//! (a) Intra- vs inter-machine aggregated bandwidth across machine
//!     generations (the widening gap the design targets).
//! (b) USP latency breakdown (compute vs exposed communication) as the
//!     machine count grows: USP becomes communication-bound at 4
//!     machines — regenerated from the executable schedules, not from
//!     the closed forms.
//!
//! Run: `cargo bench --bench fig3_motivation`

use swiftfusion::bench::BenchRun;
use swiftfusion::cluster::exec::{run_cluster, ExecMode};
use swiftfusion::comm::Buf;
use swiftfusion::config::{ClusterSpec, NetSpec, SpDegrees};
use swiftfusion::sp::{SpAlgo, SpParams};
use swiftfusion::util::stats::{fmt_bytes, fmt_time};
use swiftfusion::workload::Workload;

fn main() {
    let mut run = BenchRun::from_env("fig3_motivation");
    fig3a();
    // the machine-count sweep: full [1, 2, 4], smoke drops to the
    // endpoints (the comm-bound trend needs only the extremes)
    let machines: &[usize] = if run.smoke() { &[1, 4] } else { &[1, 2, 4] };
    fig3b(&mut run, machines);
    run.finish().expect("write BENCH_fig3_motivation.json");
}

fn fig3a() {
    println!("=== Fig 3a: intra vs inter machine aggregated bandwidth ===");
    println!(
        "{:<28}{:>18}{:>18}{:>8}",
        "machine generation", "intra (GB/s/GPU)", "inter (GB/s/mach)", "ratio"
    );
    // (name, intra per-GPU one-direction, inter per machine) — public
    // specs for the generations Fig. 3a spans.
    let gens: &[(&str, f64, f64)] = &[
        ("DGX-1V (2017, 100G IB)", 150e9, 12.5e9),
        ("DGX-A100 (2020, 8x200G)", 300e9, 200e9 / 8.0 * 1.0),
        ("p4de+EFA (2022, 400G)", 300e9, 50e9),
        ("DGX-H100 (2023, 8x400G)", 450e9, 400e9 / 8.0 * 1.0),
    ];
    for (name, intra, inter) in gens {
        println!(
            "{:<28}{:>18}{:>18}{:>8.1}",
            name,
            format!("{}", fmt_bytes(*intra) + "/s"),
            format!("{}", fmt_bytes(*inter) + "/s"),
            intra / inter
        );
    }
    let net = NetSpec::p4de_efa();
    println!(
        "\n(model constants used everywhere else: intra {}/s, inter {}/s per machine)",
        fmt_bytes(net.intra_bw),
        fmt_bytes(net.inter_bw)
    );
}

fn fig3b(run: &mut BenchRun, machines: &[usize]) {
    println!("\n=== Fig 3b: USP latency breakdown vs machine count ===");
    let w = Workload::cogvideo_20s();
    println!(
        "one {} attention layer, M machines x 8 GPUs  (USP at its optimal U8R*)",
        w.name
    );
    println!(
        "{:<6}{:>12}{:>12}{:>12}{:>12}{:>10}",
        "M", "total", "compute", "comm", "sync", "comm%"
    );
    for &m in machines {
        let cluster = ClusterSpec::new(m, 8);
        let p = cluster.total_gpus();
        let pu = swiftfusion::config::gcd(8, w.shape.h);
        let shape = {
            let mut s = w.aligned_to(p * 64).shape;
            s.b = 1;
            s
        };
        let params = SpParams {
            shape,
            chunk: shape.l / p,
            mesh: SpAlgo::Usp.mesh(&cluster, SpDegrees::new(pu, p / pu)),
        };
        let run = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![shape.b, shape.l / p, shape.h, shape.d]);
            SpAlgo::Usp.run(ctx, &params, s.clone(), s.clone(), s);
        });
        let (c, wt, sy, _o) = run.mean_breakdown();
        let total = run.makespan();
        println!(
            "{:<6}{:>12}{:>12}{:>12}{:>12}{:>9.0}%",
            m,
            fmt_time(total),
            fmt_time(c),
            fmt_time(wt),
            fmt_time(sy),
            (wt + sy) / total * 100.0
        );
        run.note(&format!("usp_comm_fraction/M={m}"), (wt + sy) / total);
    }
    println!("(paper: USP becomes communication-bound by M=4 — the comm% column)");
}
