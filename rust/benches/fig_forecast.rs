//! Forecast-driven re-carving on the 4×8-A100 testbed: the phased
//! short-image / long-video trace served by one auto-planning pod under
//! reactive policies vs `RecarvePolicy::Forecast`.
//!
//! The motivating failure of *reactive* hysteresis: every phase
//! boundary serves `window` stale batches before the streak confirms
//! what the arrival trace already announced — the mix has shifted. The
//! forecast policy runs the same gain arithmetic, but a windowed EWMA
//! over observed arrivals ([`swiftfusion::analysis::EwmaForecaster`])
//! short-circuits the confirmation window as soon as the incoming class
//! dominates the predicted mix, so the re-carve lands at the *front* of
//! each phase shift. Expected shape: `forecast` strictly beats
//! `hysteresis` on completion horizon (it converts per-boundary stale
//! serves into proactive re-carves), while `never` serves every video
//! stale and trails far behind.
//!
//! Run: `cargo bench --bench fig_forecast` (add `-- --smoke` for the
//! CI-sized run; this sweep is already CI-sized, so `--smoke` only tags
//! the artifact).

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{ServeConfig, ServeSession};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_time;
use swiftfusion::workload::{phased_trace, Workload};

fn short_workload() -> Workload {
    Workload::short_image_4k()
}

fn long_workload() -> Workload {
    Workload::cfg_video_96k()
}

/// Dense short phases punctuated by window-sized video bursts — each
/// burst is exactly as long as the hysteresis confirmation window, the
/// worst case for a reactive policy: by the time the streak confirms,
/// the burst is half over and one video has already served stale. The
/// EWMA sees each shift at its first arrival.
fn mixed_trace() -> Vec<swiftfusion::workload::Request> {
    let short = short_workload();
    let long = long_workload();
    phased_trace(&[(&short, 8), (&long, 2), (&short, 8), (&long, 2)])
}

fn run_policy(policy: RecarvePolicy, forecast_window: Option<f64>) -> ServeReport {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let mut config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto)
        .recarve(policy);
    if let Some(w) = forecast_window {
        config = config.forecast_window(w);
    }
    ServeSession::new(config, &svc).run(&mut router, mixed_trace())
}

fn main() {
    let mut run = BenchRun::from_env("fig_forecast");
    let policies: [(&str, RecarvePolicy, Option<f64>); 4] = [
        ("never (frozen)", RecarvePolicy::Never, None),
        (
            "hysteresis 10%x2",
            RecarvePolicy::Hysteresis { threshold: 0.1, window: 2 },
            None,
        ),
        (
            "forecast 10%x2 ewma(1s)",
            RecarvePolicy::Forecast { threshold: 0.1, window: 2 },
            Some(1.0),
        ),
        ("free (pod-wide ideal)", RecarvePolicy::Free, None),
    ];
    println!(
        "forecast-driven re-carving on 4x8 A100: phased {} / {} trace (8+2 x 2 \
         phases), one auto-planned pod",
        short_workload().name,
        long_workload().name
    );

    let mut lat_series: Vec<Series> =
        policies.iter().map(|(l, _, _)| Series::new(*l)).collect();
    let mut reports = Vec::new();
    for (i, (_, policy, window)) in policies.iter().enumerate() {
        let mut report = run_policy(*policy, *window);
        for w in [short_workload(), long_workload()] {
            let mean = report
                .metrics
                .latency(w.name)
                .map(|s| s.mean())
                .unwrap_or(f64::NAN);
            lat_series[i].push(w.name, mean);
        }
        lat_series[i].push("horizon", report.metrics.horizon);
        reports.push(report);
    }
    run.table(
        "fig_forecast: mean latency per workload + horizon, per policy",
        &lat_series,
        Some(policies[0].0),
    );

    println!("\n=== fig_forecast: reactive vs proactive transitions ===");
    println!(
        "{:<26}{:>9}{:>11}{:>12}{:>12}",
        "policy", "recarves", "proactive", "drain", "re-setup"
    );
    for ((label, _, _), report) in policies.iter().zip(&reports) {
        let rc = &report.recarve;
        println!(
            "{:<26}{:>9}{:>11}{:>12}{:>12}",
            label,
            rc.recarve_count,
            rc.proactive_recarves,
            fmt_time(rc.drain_time),
            fmt_time(rc.setup_time)
        );
    }

    let horizon = |i: usize| reports[i].metrics.horizon;
    for (i, (label, _, _)) in policies.iter().enumerate() {
        run.note(&format!("horizon/{label}"), horizon(i));
    }
    let forecast = &reports[2];
    run.note("proactive_recarves", forecast.recarve.proactive_recarves as f64);
    run.note("forecast_speedup", horizon(1) / horizon(2));

    // sanity lines the acceptance criterion reads off this bench: every
    // request completes, the EWMA actually short-circuited at least one
    // confirmation window, and the proactive policy strictly beats the
    // reactive one on this trace
    for ((label, _, _), report) in policies.iter().zip(&reports) {
        assert_eq!(
            report.metrics.completed(),
            mixed_trace().len(),
            "{label} must complete the whole trace"
        );
    }
    assert!(
        forecast.recarve.proactive_recarves >= 1,
        "the phase shifts must fire at least one proactive re-carve"
    );
    assert!(
        horizon(2) < horizon(1),
        "forecast {} must strictly beat reactive hysteresis {}",
        horizon(2),
        horizon(1)
    );
    assert!(
        horizon(2) < horizon(0),
        "forecast {} must beat the frozen carve {}",
        horizon(2),
        horizon(0)
    );
    println!(
        "\nforecast beats reactive hysteresis by {:.2}x on this trace ({} vs {})",
        horizon(1) / horizon(2),
        fmt_time(horizon(2)),
        fmt_time(horizon(1))
    );
    run.finish().expect("write BENCH_fig_forecast.json");
}
