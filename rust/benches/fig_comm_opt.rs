//! Comm-layer optimization pass headline (PR 7): the three knobs —
//! contention-aware NIC chunk scheduling, inter-machine activation
//! compression, and CFG collective fusion — each measured on the paper's
//! 96k-token video shape on the 4×8 testbed, against the knob-off
//! baseline they are bit-identical to when disabled.
//!
//! 1. **Scheduling** (`NetSpec::nic_schedule`): one SwiftFusion torus
//!    layer in `ExecMode::Timing`, constant fair-share vs the per-NIC
//!    TDMA timeline. Asserted: the scheduled makespan is *strictly*
//!    lower (early slots land ~flows× sooner, queued chunks stop
//!    re-paying α; aggregate NIC throughput is conserved).
//! 2. **Compression** (`NetSpec::inter_compress`): the same layer at
//!    ratio 0.5. Asserted: measured inter wire bytes are exactly half
//!    the uncompressed run's (rel < 1e-9) — the same multiplier the
//!    analysis closed form charges, so `plan_step_cost` of an
//!    inter-bearing plan strictly drops while intra bytes are untouched.
//! 3. **Fusion** (`NetSpec::cfg_fuse`): a fusible cfg2 plan
//!    (machine-aligned 16-rank branch groups) through
//!    `hybrid_layer_makespan_traced`, fused vs plain. Asserted: the
//!    fused run prices > 0 transfers at the fused-pair rate and its
//!    makespan is strictly lower.
//!
//! Run: `cargo bench --bench fig_comm_opt`. The sweep is a handful of
//! Timing-mode layers and is already CI-sized, so `--smoke` only tags
//! the JSON artifact (the fig_partial_recarve convention).

use swiftfusion::analysis::plan_step_cost;
use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::cluster::exec::{run_in_world, ExecMode};
use swiftfusion::cluster::plan::ParallelPlan;
use swiftfusion::comm::{Buf, CommWorld, Traffic};
use swiftfusion::config::{AttnShape, ClusterSpec, ParallelSpec, SpDegrees};
use swiftfusion::sp::hybrid::hybrid_layer_makespan_traced;
use swiftfusion::sp::{SpAlgo, SpParams};

fn paper_shape() -> AttnShape {
    // the 96k-video acceptance config (paper §5: 96k tokens, 24 heads)
    AttnShape::new(1, 96 * 1024, 24, 64)
}

/// One SwiftFusion torus layer over the full 4×8 mesh in Timing mode;
/// returns (makespan, total traffic, NIC busy wire-seconds).
fn torus_layer(cluster: &ClusterSpec) -> (f64, Traffic, f64) {
    let shape = paper_shape();
    let p = cluster.total_gpus();
    let params = SpParams {
        shape,
        chunk: shape.l / p,
        mesh: SpAlgo::SwiftFusion.mesh(cluster, SpDegrees::swiftfusion_default(cluster, shape.h)),
    };
    let world = CommWorld::new(cluster.clone());
    let run = run_in_world(&world, &ExecMode::Timing, |ctx| {
        let s = Buf::Shape(vec![shape.b, shape.l / p, shape.h, shape.d]);
        SpAlgo::SwiftFusion.run(ctx, &params, s.clone(), s.clone(), s);
    });
    let busy: f64 = (0..p).map(|r| world.nic_busy_seconds(r)).sum();
    (run.makespan(), world.traffic_totals(), busy)
}

fn main() {
    let mut run = BenchRun::from_env("fig_comm_opt");
    let _smoke = run.smoke(); // Timing-mode sweep, already CI-sized
    let base = ClusterSpec::paper_testbed();
    let shape = paper_shape();
    println!(
        "fig_comm_opt: SwiftFusion torus, L={} H={} on 4x8; each knob vs",
        shape.l, shape.h
    );
    println!("the knob-off baseline it is bit-identical to when disabled\n");

    // ---- 1. contention-aware NIC chunk scheduling ----------------------
    let (t_const, tr_plain, busy_const) = torus_layer(&base);
    let mut sched = base.clone();
    sched.net.nic_schedule = true;
    let (t_sched, _, busy_sched) = torus_layer(&sched);
    println!(
        "  scheduling: constant fair-share {t_const:.6}s -> TDMA {t_sched:.6}s \
         ({:.2}% lower, NIC busy {busy_sched:.6}s)",
        (1.0 - t_sched / t_const) * 100.0
    );
    assert!(
        t_sched < t_const,
        "TDMA scheduling must strictly beat constant fair-share: \
         {t_sched} vs {t_const}"
    );
    assert_eq!(busy_const, 0.0, "constant mode must not touch the NIC timeline");
    assert!(busy_sched > 0.0, "scheduled mode must account NIC occupancy");

    // ---- 2. inter-machine activation compression -----------------------
    let ratio = 0.5;
    let mut comp = base.clone();
    comp.net.inter_compress = ratio;
    let (_, tr_comp, _) = torus_layer(&comp);
    let inter_plain = tr_plain.inter_in + tr_plain.inter_out;
    let inter_comp = tr_comp.inter_in + tr_comp.inter_out;
    let measured_ratio = inter_comp / inter_plain;
    println!(
        "  compression: inter wire {:.3} GB -> {:.3} GB (measured ratio {measured_ratio})",
        inter_plain / 1e9,
        inter_comp / 1e9
    );
    assert!(inter_plain > 0.0, "the torus layer must cross machines");
    assert!(
        (measured_ratio - ratio).abs() < 1e-9,
        "measured inter bytes must shrink by exactly the configured ratio: \
         {measured_ratio} vs {ratio}"
    );
    assert_eq!(
        tr_comp.intra_in, tr_plain.intra_in,
        "intra-machine bytes are never compressed"
    );
    // the closed form charges the same multiplier, so the chooser's cost
    // of an inter-bearing plan (16-rank groups = 2 machines each)
    // strictly drops under compression
    let inter_plan = ParallelSpec::with_gcd_placement(2, 1, 16, shape.h);
    let cost_plain = plan_step_cost(&base, SpAlgo::SwiftFusion, &shape, &inter_plan, 2);
    let cost_comp = plan_step_cost(&comp, SpAlgo::SwiftFusion, &shape, &inter_plan, 2);
    println!(
        "  closed form: plan_step_cost {cost_plain:.6}s -> {cost_comp:.6}s \
         ({:.2}% lower)",
        (1.0 - cost_comp / cost_plain) * 100.0
    );
    assert!(
        cost_comp < cost_plain,
        "the analysis closed form must see the compression saving: \
         {cost_comp} vs {cost_plain}"
    );

    // ---- 3. CFG collective fusion --------------------------------------
    let spec = ParallelSpec::new(2, 1, SpDegrees::new(8, 2));
    let chunk = shape.l / spec.ranks_per_group();
    let plan = ParallelPlan::build(&base, spec, SpAlgo::SwiftFusion).unwrap();
    let (t_plain, _) = hybrid_layer_makespan_traced(&plan, shape, chunk, 2);
    let mut fuse = base.clone();
    fuse.net.cfg_fuse = true;
    let fused_plan = ParallelPlan::build(&fuse, spec, SpAlgo::SwiftFusion).unwrap();
    assert!(fused_plan.cfg_fusible(), "cfg2 + machine-aligned groups must fuse");
    let (t_fused, stats) = hybrid_layer_makespan_traced(&fused_plan, shape, chunk, 2);
    println!(
        "  fusion: cfg2 layer {t_plain:.6}s -> {t_fused:.6}s \
         ({} transfers at the fused-pair rate)\n",
        stats.fused_transfers
    );
    assert!(
        stats.fused_transfers > 0,
        "a fusible plan must price inter transfers at the fused rate"
    );
    assert!(
        t_fused < t_plain,
        "fusing the CFG branch pair must strictly lower the makespan: \
         {t_fused} vs {t_plain}"
    );

    let mut series = vec![Series::new("baseline (knobs off)"), Series::new("comm-opt pass")];
    series[0].push("torus layer s", t_const);
    series[0].push("inter GB", inter_plain / 1e9);
    series[0].push("cfg2 layer s", t_plain);
    series[1].push("torus layer s", t_sched);
    series[1].push("inter GB", inter_comp / 1e9);
    series[1].push("cfg2 layer s", t_fused);
    run.table(
        "fig_comm_opt: each knob vs its knob-off baseline (96k video, 4x8)",
        &series,
        Some("baseline (knobs off)"),
    );
    run.note("inter_comm_time", busy_sched);
    run.note("sched_speedup", t_const / t_sched);
    run.note("compression_ratio", measured_ratio);
    run.note("fused_transfers", stats.fused_transfers as f64);
    run.finish().expect("write BENCH_fig_comm_opt.json");
}
