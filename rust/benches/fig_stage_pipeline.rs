//! Stage-pipeline headline (PR 9): a staged fleet — stage-class pods
//! joined by bounded inter-stage queues — against a monolithic-pod
//! fleet of the same 4x8 footprint on an interleaved image+video mix.
//!
//! The staged fleet decouples each request into its stage DAG
//! (text-encode -> diffusion -> VAE decode): the diffusion class keeps
//! two pods on the DiT step loop while a dedicated sp-only pod decodes
//! patch-parallel (xDiT Parallel VAE), so request n's denoising runs
//! concurrently with request n-1's decode. The monolithic fleet serves
//! every request end-to-end on one pod — same total machines, same
//! closed-form pricing (the stage `time_share`s partition the
//! monolithic cost exactly), no free work.
//!
//! Asserted:
//! 1. both fleets complete the whole mix with zero rejections;
//! 2. the staged fleet's mean e2e latency is *strictly* below the
//!    monolithic fleet's (`e2e_speedup` > 1 in the JSON artifact);
//! 3. diffusion/decode execution actually overlapped
//!    (`overlap_fraction` > 0) — the win is pipelining, not pricing.
//!
//! Run: `cargo bench --bench fig_stage_pipeline`. `--smoke` shrinks the
//! mix for CI; workloads are the serve-test pair shrunk to 2 layers x
//! 2 steps so the timing simulations stay fast.

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{ServeConfig, ServeSession};
use swiftfusion::coordinator::stages::{StagePlacement, StagePolicy};
use swiftfusion::sp::SpAlgo;
use swiftfusion::workload::{Request, Workload};

/// Interleaved image+video mix, one arrival every 50 ms — tighter than
/// a video's staged span, so consecutive videos occupy different
/// stages concurrently.
fn mixed_trace(n: usize) -> Vec<Request> {
    let mut img = Workload::short_image_4k();
    img.layers = 2;
    img.steps = 2;
    let mut vid = Workload::cfg_video_96k();
    vid.layers = 2;
    vid.steps = 2;
    (0..n)
        .map(|i| Request {
            id: i as u64,
            workload: if i % 2 == 0 { img.clone() } else { vid.clone() },
            arrival: i as f64 * 0.05,
            seed: i as u64,
        })
        .collect()
}

/// One serving run on the 4x8 fleet carved into four 1x8 pods:
/// `staged` selects the stage pipeline (1 encode / 2 diffusion /
/// 1 decode pod), otherwise each pod serves whole requests.
fn serve_mix(staged: bool, n: usize) -> ServeReport {
    let mut router = Router::new(4, 8, 4, SpAlgo::SwiftFusion);
    let mut config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto);
    if staged {
        config = config.stages(StagePolicy::new(StagePlacement::balanced(4)));
    }
    let svc = config
        .sim_service(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion)
        .expect("auto planner on the 1x8 pod");
    ServeSession::new(config, &svc).run(&mut router, mixed_trace(n))
}

fn mean_e2e(report: &ServeReport) -> f64 {
    let total: f64 = report.completions.iter().map(|&(_, a, d)| d - a).sum();
    total / report.completions.len() as f64
}

fn main() {
    let mut run = BenchRun::from_env("fig_stage_pipeline");
    let n = if run.smoke() { 8 } else { 24 };
    println!("fig_stage_pipeline: {n}-request image+video mix on a 4x8 fleet,");
    println!("monolithic pods vs staged pipeline (enc1/dit2/vae1)\n");

    let mono = serve_mix(false, n);
    let staged = serve_mix(true, n);

    assert_eq!(mono.metrics.completed(), n, "monolithic fleet must serve the mix");
    assert_eq!(staged.metrics.completed(), n, "staged fleet must serve the mix");
    assert!(mono.rejected.is_empty() && staged.rejected.is_empty());

    let st = staged.stages.as_ref().expect("staged run reports its stages section");
    assert_eq!(
        st.dispatches.values().sum::<usize>(),
        3 * n,
        "every request crosses all three stages exactly once"
    );
    for (class, count) in &st.dispatches {
        println!("  staged fleet ran {count:>3} {class} dispatch(es)");
    }

    let overlap_fraction = st.overlap_time / staged.metrics.horizon;
    println!(
        "\n  diffusion/decode overlap: {:.4} s ({:.1}% of the {:.3} s horizon)",
        st.overlap_time,
        overlap_fraction * 100.0,
        staged.metrics.horizon
    );
    assert!(
        st.overlap_time > 0.0,
        "request n's diffusion never overlapped request n-1's decode"
    );

    let e2e_mono = mean_e2e(&mono);
    let e2e_staged = mean_e2e(&staged);
    let speedup = e2e_mono / e2e_staged;
    println!(
        "  mean e2e latency: monolithic {:.4} s -> staged {:.4} s ({speedup:.2}x)",
        e2e_mono, e2e_staged
    );
    assert!(
        e2e_staged < e2e_mono,
        "the staged fleet must strictly beat monolithic pods e2e: \
         {e2e_staged} vs {e2e_mono}"
    );

    let mut series = vec![Series::new("monolithic"), Series::new("staged")];
    series[0].push("mean e2e s", e2e_mono);
    series[1].push("mean e2e s", e2e_staged);
    series[0].push("horizon s", mono.metrics.horizon);
    series[1].push("horizon s", staged.metrics.horizon);
    run.table(
        "fig_stage_pipeline: image+video mix, monolithic pods vs staged fleet (4x8)",
        &series,
        None,
    );
    run.note("e2e_latency", e2e_staged);
    run.note("e2e_latency_monolithic", e2e_mono);
    run.note("e2e_speedup", speedup);
    run.note("overlap_fraction", overlap_fraction);
    run.note("overlap_time", st.overlap_time);
    run.finish().expect("write BENCH_fig_stage_pipeline.json");
}
