//! Figure 8 — performance across distributed configurations UxRy on 4
//! and 3 GPU machines (×8), CogVideoX workloads.
//!
//! Expected shape (paper §5.2): TAS and SFU beat USP at every config
//! (avg ~1.5x/1.6x, up to 2.5x/3.1x); larger U is better, except TAS at
//! U24R1 vs U12R2 where the non-overlapped all-to-all bites.
//!
//! Run: `cargo bench --bench fig8_configs`

use swiftfusion::cluster::exec::{run_cluster, ExecMode};
use swiftfusion::comm::Buf;
use swiftfusion::config::{ClusterSpec, SpDegrees};
use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::sp::{SpAlgo, SpParams};
use swiftfusion::workload::Workload;

fn layer_time(cluster: &ClusterSpec, algo: SpAlgo, deg: SpDegrees, w: &Workload) -> f64 {
    let p = cluster.total_gpus();
    let shape = w.aligned_to(p * 64).shape;
    let params = SpParams {
        shape,
        chunk: shape.l / p,
        mesh: algo.mesh(cluster, deg),
    };
    let run = run_cluster(cluster, &ExecMode::Timing, |ctx| {
        let s = Buf::Shape(vec![shape.b, shape.l / p, shape.h, shape.d]);
        algo.run(ctx, &params, s.clone(), s.clone(), s);
    });
    run.makespan()
}

fn sweep(run: &mut BenchRun, machines: usize, w: &Workload) {
    let cluster = ClusterSpec::new(machines, 8);
    let p = cluster.total_gpus();
    let h = w.shape.h;
    // all UxRy with U | H and U*R = P
    let configs: Vec<SpDegrees> = (1..=p)
        .filter(|u| p % u == 0 && h % u == 0 && *u >= p / 8)
        .map(|u| SpDegrees::new(u, p / u))
        .collect();
    let mut usp = Series::new("usp");
    let mut tas = Series::new("tas");
    let mut sfu = Series::new("swiftfusion");
    for deg in configs {
        let label = format!("U{}R{}", deg.pu, deg.pr);
        usp.push(label.clone(), layer_time(&cluster, SpAlgo::Usp, deg, w));
        tas.push(label.clone(), layer_time(&cluster, SpAlgo::Tas, deg, w));
        sfu.push(label, layer_time(&cluster, SpAlgo::SwiftFusion, deg, w));
    }
    run.table(
        &format!(
            "Fig 8: {} on {} machines x 8 — per-layer latency across UxRy",
            w.name, machines
        ),
        &[usp, tas, sfu],
        Some("usp"),
    );
}

fn main() {
    let mut run = BenchRun::from_env("fig8_configs");
    sweep(&mut run, 4, &Workload::cogvideo_20s());
    if !run.smoke() {
        // smoke keeps the 4-machine sweep only (the paper's headline row)
        sweep(&mut run, 3, &Workload::cogvideo_40s());
    }
    run.finish().expect("write BENCH_fig8_configs.json");
}
