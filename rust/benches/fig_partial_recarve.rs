//! Group-granular (partial) re-carving on the 4×8-A100 testbed: a mixed
//! short-image / long-video trace served by one auto-planning pod under
//! pod-wide policies vs `RecarvePolicy::Partial`.
//!
//! The motivating failure of pod-wide re-carving: a single long CFG
//! video freezes the whole pod's plan — every transition must wait for
//! the **pod-wide drain barrier**, so while a stale video grinds on one
//! group, nothing can re-carve and later arrivals queue behind it.
//! `partial` splits instead: the busy machines keep serving under the
//! (narrowed) old carve while the idle machines re-carve immediately —
//! no drain — and the pod runs **two carve generations at once** (videos
//! on a 3-machine CFG×pp carve, shorts on the surviving one-machine
//! group) until it re-unifies during a lull. Expected shape:
//! `partial` strictly beats pod-wide `hysteresis` on horizon (it pays
//! staleness once instead of per phase boundary, drains nothing, and
//! overlaps the two traffic modes), while `never` serves every video
//! stale and trails far behind.
//!
//! Run: `cargo bench --bench fig_partial_recarve` (add `-- --smoke` for
//! the CI-sized run; this sweep is already CI-sized, so `--smoke` only
//! tags the artifact).

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{ServeConfig, ServeSession};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_time;
use swiftfusion::workload::{phased_trace, Workload};

fn short_workload() -> Workload {
    Workload::short_image_4k()
}

fn long_workload() -> Workload {
    Workload::cfg_video_96k()
}

/// Dense short phases punctuated by window-sized video bursts — the
/// mixed traffic a pod-wide drain barrier handles worst: each burst
/// forces pod-wide hysteresis to serve a video stale (streak = window)
/// and then re-carve through the drain, twice per cycle, while the
/// partial policy splits once at the first burst and never serves stale
/// again.
fn mixed_trace() -> Vec<swiftfusion::workload::Request> {
    let short = short_workload();
    let long = long_workload();
    phased_trace(&[(&short, 8), (&long, 2), (&short, 8), (&long, 2)])
}

fn run_policy(policy: RecarvePolicy) -> ServeReport {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto)
        .recarve(policy);
    ServeSession::new(config, &svc).run(&mut router, mixed_trace())
}

fn main() {
    let mut run = BenchRun::from_env("fig_partial_recarve");
    let policies: [(&str, RecarvePolicy); 4] = [
        ("never (frozen)", RecarvePolicy::Never),
        (
            "hysteresis 10%x2",
            RecarvePolicy::Hysteresis { threshold: 0.1, window: 2 },
        ),
        (
            "partial 10%x2",
            RecarvePolicy::Partial { threshold: 0.1, window: 2 },
        ),
        ("free (pod-wide ideal)", RecarvePolicy::Free),
    ];
    println!(
        "partial re-carving on 4x8 A100: mixed {} / {} trace (8+2 x 2 phases), one \
         auto-planned pod",
        short_workload().name,
        long_workload().name
    );

    let mut lat_series: Vec<Series> =
        policies.iter().map(|(l, _)| Series::new(*l)).collect();
    let mut reports = Vec::new();
    for (i, (_, policy)) in policies.iter().enumerate() {
        let mut report = run_policy(*policy);
        for w in [short_workload(), long_workload()] {
            let mean = report
                .metrics
                .latency(w.name)
                .map(|s| s.mean())
                .unwrap_or(f64::NAN);
            lat_series[i].push(w.name, mean);
        }
        lat_series[i].push("horizon", report.metrics.horizon);
        reports.push(report);
    }
    run.table(
        "fig_partial_recarve: mean latency per workload + horizon, per policy",
        &lat_series,
        Some(policies[0].0),
    );

    println!("\n=== fig_partial_recarve: what each policy paid / split ===");
    println!(
        "{:<22}{:>9}{:>8}{:>8}{:>12}{:>12}",
        "policy", "recarves", "splits", "merges", "drain", "re-setup"
    );
    for ((label, _), report) in policies.iter().zip(&reports) {
        let rc = &report.recarve;
        println!(
            "{:<22}{:>9}{:>8}{:>8}{:>12}{:>12}",
            label,
            rc.recarve_count,
            rc.partial_splits,
            rc.merges,
            fmt_time(rc.drain_time),
            fmt_time(rc.setup_time)
        );
    }
    let partial = &reports[2];
    for (pod, g) in &partial.recarve.group_epochs {
        println!(
            "partial: pod {pod} side generation {}: {} on machines [{}, {}), opened {}, \
             served {}",
            g.index,
            g.label(),
            g.base_machine,
            g.base_machine + g.machines,
            fmt_time(g.started_at),
            g.served
        );
    }

    let horizon = |i: usize| reports[i].metrics.horizon;
    for (i, (label, _)) in policies.iter().enumerate() {
        run.note(&format!("horizon/{label}"), horizon(i));
    }
    run.note("partial_splits", partial.recarve.partial_splits as f64);
    run.note(
        "speedup_partial_vs_hysteresis",
        horizon(1) / horizon(2),
    );

    // sanity lines the acceptance criterion reads off this bench: every
    // request completes, the mixed trace actually fires a split, and
    // group-granular re-carving strictly beats the pod-wide drain
    // barrier on this trace
    for ((label, _), report) in policies.iter().zip(&reports) {
        assert_eq!(
            report.metrics.completed(),
            mixed_trace().len(),
            "{label} must complete the whole trace"
        );
    }
    assert!(
        partial.recarve.partial_splits >= 1,
        "the video burst must split the pod"
    );
    assert_eq!(
        partial.recarve.drain_time, 0.0,
        "group-granular barriers never drain"
    );
    assert!(
        horizon(2) < horizon(1),
        "partial {} must strictly beat pod-wide hysteresis {}",
        horizon(2),
        horizon(1)
    );
    assert!(
        horizon(2) < horizon(0),
        "partial {} must beat the frozen carve {}",
        horizon(2),
        horizon(0)
    );
    println!(
        "\npartial beats pod-wide hysteresis by {:.2}x on this trace ({} vs {})",
        horizon(1) / horizon(2),
        fmt_time(horizon(2)),
        fmt_time(horizon(1))
    );
    run.finish().expect("write BENCH_fig_partial_recarve.json");
}
