//! Dynamic re-carving sweep on the 4×8-A100 testbed: a bimodal
//! short-image ↔ long-video trace served by one auto-planning pod under
//! each [`RecarvePolicy`].
//!
//! The trace alternates phases of short distilled image requests (whose
//! chosen plan stays on one machine) and long CFG video requests (whose
//! chosen plan is CFG- and pipeline-parallel across the pod). A frozen
//! pod (`never`) keeps its admission-time carve and serves every video
//! phase stale; `hysteresis` waits for the configured streak of
//! predicted-gain dispatches, then drains the pod, pays the modeled
//! re-setup, and re-carves — the expected shape is `free` (the unpaid
//! idealization) ≤ `hysteresis` ≈ `on-idle`-when-idle ≪ `never`.
//! Latency rows are per-workload means; the epoch columns show what each
//! policy paid for adaptivity.
//!
//! Run: `cargo bench --bench fig_recarve`

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{serve, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_time;
use swiftfusion::workload::{bimodal_trace, Workload};

/// The bimodal pair: [`Workload::short_image_4k`] pins a deliberately
/// video-hostile one-machine carve; [`Workload::cfg_video_96k`] wants
/// CFG × pipeline parallelism across the whole pod. Under `--smoke` the
/// workloads shrink to 2 layers × 2 steps and the trace to 3 × 6 — the
/// exact configuration the engine integration tests already prove the
/// policy ordering on, so the sanity asserts below stay valid.
fn short_workload(smoke: bool) -> Workload {
    let mut w = Workload::short_image_4k();
    if smoke {
        w.layers = 2;
        w.steps = 2;
    }
    w
}

fn long_workload(smoke: bool) -> Workload {
    let mut w = Workload::cfg_video_96k();
    if smoke {
        w.layers = 2;
        w.steps = 2;
    }
    w
}

fn run_policy(policy: RecarvePolicy, smoke: bool) -> ServeReport {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    if smoke {
        router.set_recarve_with_setup(policy, 0.01);
    } else {
        router.set_recarve(policy);
    }
    let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let (phases, per_phase) = if smoke { (3, 6) } else { (4, 8) };
    let reqs = bimodal_trace(
        &short_workload(smoke),
        &long_workload(smoke),
        phases,
        per_phase,
    );
    serve(
        &mut router,
        BatchPolicy { max_batch: 1, window: 0.0 },
        reqs,
        &svc,
    )
}

fn main() {
    let mut run = BenchRun::from_env("fig_recarve");
    let smoke = run.smoke();
    let policies: [(&str, RecarvePolicy); 4] = [
        ("never (frozen)", RecarvePolicy::Never),
        ("on-idle", RecarvePolicy::OnIdle),
        (
            "hysteresis 10%x2",
            RecarvePolicy::Hysteresis { threshold: 0.1, window: 2 },
        ),
        ("free (idealized)", RecarvePolicy::Free),
    ];
    println!(
        "dynamic re-carving on 4x8 A100: bimodal {} <-> {} trace, one auto-planned pod",
        short_workload(smoke).name,
        long_workload(smoke).name
    );

    let mut lat_series: Vec<Series> =
        policies.iter().map(|(l, _)| Series::new(*l)).collect();
    let mut reports = Vec::new();
    for (i, (_, policy)) in policies.iter().enumerate() {
        let mut report = run_policy(*policy, smoke);
        for w in [short_workload(smoke), long_workload(smoke)] {
            let mean = report
                .metrics
                .latency(w.name)
                .map(|s| s.mean())
                .unwrap_or(f64::NAN);
            lat_series[i].push(w.name, mean);
        }
        lat_series[i].push("horizon", report.metrics.horizon);
        reports.push(report);
    }

    run.table(
        "fig_recarve: mean latency per workload + serving horizon, per policy",
        &lat_series,
        Some(policies[0].0),
    );

    println!("\n=== fig_recarve: what each policy paid for adaptivity ===");
    println!(
        "{:<20}{:>10}{:>10}{:>14}{:>14}",
        "policy", "recarves", "epochs", "drain", "re-setup"
    );
    for ((label, _), report) in policies.iter().zip(&reports) {
        let rc = &report.recarve;
        println!(
            "{:<20}{:>10}{:>10}{:>14}{:>14}",
            label,
            rc.recarve_count,
            rc.epochs.len(),
            fmt_time(rc.drain_time),
            fmt_time(rc.setup_time)
        );
    }

    // sanity lines the acceptance criterion reads off this bench: the
    // hysteresis policy must beat the frozen carve on bimodal traffic,
    // and the unpaid idealization bounds it from below
    let horizon = |i: usize| reports[i].metrics.horizon;
    for (i, (label, _)) in policies.iter().enumerate() {
        run.note(&format!("horizon/{label}"), horizon(i));
    }
    assert!(
        horizon(2) < horizon(0),
        "hysteresis {} must beat frozen {}",
        horizon(2),
        horizon(0)
    );
    assert!(
        horizon(3) <= horizon(2),
        "free {} bounds hysteresis {} from below",
        horizon(3),
        horizon(2)
    );
    println!(
        "\nhysteresis beats the frozen carve by {:.2}x on this trace ({} vs {})",
        horizon(0) / horizon(2),
        fmt_time(horizon(2)),
        fmt_time(horizon(0))
    );
    run.note("speedup_hysteresis_vs_frozen", horizon(0) / horizon(2));
    run.finish().expect("write BENCH_fig_recarve.json");
}
