//! Fleet-scale scheduler throughput: tens of pods, 10^5 requests,
//! events/sec of the indexed scheduler path vs the pre-PR linear path.
//!
//! Both [`SchedulerMode`]s replay the same trace to bit-identical
//! reports (asserted below); they differ only in per-event cost:
//!
//! * **linear** — naive binary event heap, every dispatch re-prices
//!   every pod through the service model (`O(P)` model calls through a
//!   mutex-guarded string-keyed cache);
//! * **indexed** — indexed event heap, memoized pricing
//!   (`PriceCache`), and `free_at`-pruned earliest-finish selection
//!   that typically evaluates one or two pods per dispatch.
//!
//! The headline figure is **events/sec** (arrivals + dispatches +
//! completions + the flush, per wall-clock second — the convention
//! `benches/README.md` documents), and the assertion is the indexed
//! path's speedup over linear on the same trace.
//!
//! Run: `cargo bench --bench fig_fleet_scale` (full: 64 pods, 120k
//! requests) or with `--smoke` (CI: 16 pods, 8k requests).

use std::sync::Arc;
use std::time::Instant;

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{
    EarliestFinish, SchedulerMode, ServeConfig, ServeSession,
};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::json::to_string;
use swiftfusion::workload::{Request, Workload};

/// Deterministic two-workload arrival stream at 100 req/s — saturating
/// for the fleet, so pod timelines spread out and earliest-finish has
/// real work to do on every dispatch.
fn trace(n: usize) -> Vec<Request> {
    let ws = [Workload::short_image_4k(), Workload::flux_3072()];
    (0..n)
        .map(|i| Request {
            id: i as u64,
            workload: ws[i % 2].clone(),
            arrival: i as f64 * 0.01,
            seed: i as u64,
        })
        .collect()
}

fn run_mode(mode: SchedulerMode, pods: usize, n: usize) -> (ServeReport, f64) {
    // one machine of 8 GPUs per pod: every pod shares one footprint, so
    // a single auto-planning service model prices the whole fleet
    let mut router = Router::new(pods, 8, pods, SpAlgo::SwiftFusion);
    let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto)
        .dispatch(Arc::new(EarliestFinish))
        .scheduler(mode);
    let reqs = trace(n);
    let t0 = Instant::now();
    let report = ServeSession::new(config, &svc).run(&mut router, reqs);
    (report, t0.elapsed().as_secs_f64().max(1e-9))
}

fn main() {
    let mut run = BenchRun::from_env("fig_fleet_scale");
    let smoke = run.smoke();
    // floors sit far (~10x) below expected throughput so they catch an
    // accidental return to O(P)-per-event behaviour, not machine noise
    let (pods, n, min_speedup, min_events_per_sec) = if smoke {
        (16, 8_000, 1.2, 10_000.0)
    } else {
        (64, 120_000, 5.0, 25_000.0)
    };
    println!("fig_fleet_scale: {pods} pods (1x8 each), {n} requests, earliest-finish");
    println!("dispatch; linear (pre-PR reference) vs indexed scheduler\n");

    let (lin, lin_wall) = run_mode(SchedulerMode::Linear, pods, n);
    let (idx, idx_wall) = run_mode(SchedulerMode::Indexed, pods, n);

    // the two modes are semantics-preserving: same completions, same
    // virtual horizon (bit-for-bit), same event count, same report JSON
    assert_eq!(lin.metrics.completed() + lin.rejected.len(), n);
    assert_eq!(lin.metrics.completed(), idx.metrics.completed());
    assert_eq!(
        lin.metrics.horizon.to_bits(),
        idx.metrics.horizon.to_bits(),
        "virtual horizons must match bit-for-bit"
    );
    assert_eq!(lin.events, idx.events);
    assert_eq!(
        to_string(&lin.to_json()),
        to_string(&idx.to_json()),
        "reports must be bit-identical across scheduler modes"
    );
    assert!(lin.events >= 2 * n as u64, "every request arrives and dispatches");

    let eps_lin = lin.events as f64 / lin_wall;
    let eps_idx = idx.events as f64 / idx_wall;
    let speedup = eps_idx / eps_lin;
    println!(
        "  linear   {:>9} events in {:>8.3}s  ->  {:>12.0} events/sec",
        lin.events, lin_wall, eps_lin
    );
    println!(
        "  indexed  {:>9} events in {:>8.3}s  ->  {:>12.0} events/sec",
        idx.events, idx_wall, eps_idx
    );
    println!("\nindexed scheduler: {speedup:.2}x the linear path's events/sec");

    let mut series = vec![Series::new("linear (pre-PR)"), Series::new("indexed")];
    series[0].push("events/sec", eps_lin);
    series[0].push("wall s", lin_wall);
    series[1].push("events/sec", eps_idx);
    series[1].push("wall s", idx_wall);
    run.table(
        "fig_fleet_scale: scheduler events/sec, linear vs indexed",
        &series,
        Some("linear (pre-PR)"),
    );
    run.note("events", lin.events as f64);
    run.note("events_per_sec", eps_idx);
    run.note("events_per_sec_linear", eps_lin);
    run.note("speedup", speedup);

    assert!(
        speedup >= min_speedup,
        "indexed scheduler must be >= {min_speedup}x the linear path \
         (got {speedup:.2}x: {eps_idx:.0} vs {eps_lin:.0} events/sec)"
    );
    assert!(
        eps_idx >= min_events_per_sec,
        "indexed scheduler must process >= {min_events_per_sec} events/sec \
         (got {eps_idx:.0})"
    );
    run.finish().expect("write BENCH_fig_fleet_scale.json");
}
