//! Hybrid CFG×SP plan sweep on fixed hardware: modeled per-generation
//! latency and saturated throughput of distinct `ParallelSpec`s for each
//! paper workload on the 4×8-A100 testbed.
//!
//! Latency is the *executable* timing-mode makespan of one attention
//! layer under the plan (group-scoped schedules on carved sub-meshes),
//! scaled to a full generation; throughput assumes every replica group
//! is kept busy. Expected shape: CFG workloads (CogVideoX) gain from
//! `cfg_degree=2` because the branch groups never touch the
//! inter-machine fabric for each other; distilled workloads (Flux) have
//! nothing to branch-split, so replicas or the full mesh win depending
//! on sequence length. The closed-form chooser (`analysis::choose_spec`)
//! is printed alongside so its ranking can be compared with the
//! executable model's.
//!
//! Run: `cargo bench --bench fig_hybrid`

use swiftfusion::analysis;
use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::config::{ClusterSpec, ParallelSpec};
use swiftfusion::coordinator::engine::SimService;
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_time;
use swiftfusion::workload::Workload;

/// The plans under comparison: (label, cfg_degree, batch_replicas).
/// Group SP degrees follow the gcd placement rule on the group size.
const PLANS: [(&str, usize, usize); 4] = [
    ("cfg1 rep1 sp32", 1, 1),
    ("cfg2 rep1 sp16", 2, 1),
    ("cfg2 rep2 sp8", 2, 2),
    ("cfg1 rep4 sp8", 1, 4),
];

fn spec_for(cluster: &ClusterSpec, cfg: usize, reps: usize, heads: usize) -> ParallelSpec {
    ParallelSpec::with_gcd_placement(cfg, reps, cluster.total_gpus() / (cfg * reps), heads)
}

fn main() {
    let mut run = BenchRun::from_env("fig_hybrid");
    let cluster = ClusterSpec::paper_testbed();
    let algo = SpAlgo::SwiftFusion;
    println!("hybrid CFG x SP plan sweep on 4x8 A100 ({})", algo.name());
    // smoke: one image + one video workload keep every plan column
    let workloads = if run.smoke() {
        vec![Workload::flux_3072(), Workload::cogvideo_20s()]
    } else {
        Workload::paper_suite()
    };

    // One series per plan; rows are workloads (matches print_table).
    let mut lat_series: Vec<Series> = PLANS.iter().map(|(l, _, _)| Series::new(*l)).collect();
    let mut thr_rows: Vec<(String, Vec<f64>)> = Vec::new();

    for w in workloads {
        let mut thr = Vec::new();
        for (i, (label, cfg, reps)) in PLANS.iter().enumerate() {
            let spec = spec_for(&cluster, *cfg, *reps, w.shape.h);
            assert!(spec.validate(&cluster).is_ok(), "{label} invalid on 4x8");
            let svc =
                SimService::with_plan(cluster.clone(), algo, spec).expect("validated spec");
            // one full generation at batch 1 under this plan
            let gen =
                svc.plan_layer_time(&spec, &w, 1) * w.layers as f64 * w.steps as f64;
            lat_series[i].push(w.name, gen);
            thr.push(spec.batch_replicas as f64 / gen);
        }
        thr_rows.push((w.name.to_string(), thr));

        let picked = analysis::choose_spec(&cluster, algo, &w.shape, w.cfg_evals, 1);
        println!("  {:<16} chooser (latency): {}", w.name, picked.label());
    }

    run.table(
        "fig_hybrid: one full generation (batch 1), per plan",
        &lat_series,
        Some(PLANS[0].0),
    );

    println!("\n=== fig_hybrid: saturated throughput (req/s, all replica groups busy) ===");
    print!("{:<18}", "workload");
    for (label, _, _) in PLANS {
        print!("{label:>18}");
    }
    println!();
    for (name, thr) in &thr_rows {
        print!("{name:<18}");
        for t in thr {
            print!("{:>18}", format!("{t:.4}"));
        }
        println!();
    }

    // sanity lines the acceptance criterion reads off this bench
    for (i, (label, _, _)) in PLANS.iter().enumerate() {
        let video = lat_series[i]
            .points
            .iter()
            .find(|(x, _)| x == "cogvideox-20s")
            .map(|(_, y)| *y)
            .unwrap();
        println!("plan {label}: cogvideox-20s generation {}", fmt_time(video));
        run.note(&format!("cogvideox-20s/{label}"), video);
    }
    run.finish().expect("write BENCH_fig_hybrid.json");
}
