//! `ServeSession` scheduler demos on the 4×8-A100 testbed: the two new
//! scheduler clients the coordinator API redesign shipped, each against
//! its PR-3 baseline.
//!
//! **Replica co-batching** — a saturated short-image burst lands on the
//! auto-planner's 4-replica carve (`cfg1 x pp1 x rep4 x U8R1`). The
//! baseline queues each closed batch on one replica group; co-batching
//! scatters it across all four (each group serves `⌈B/R⌉` requests
//! concurrently), so the burst drains ~4× faster at bounded per-request
//! latency.
//!
//! **Cross-pod re-balancing** — a drifting pod-mix trace (short images
//! giving way to sparse long CFG videos) on a fleet of two 2-machine
//! pods (8 GPUs per machine). The frozen fleet serves every video on a
//! 16-GPU pod; the `gain` policy migrates the idle pod's machine toward
//! the video pod (2+2 → 3+1), whose 24-GPU footprint affords a
//! one-machine-stage pipeline carve (16 patches) no 16-GPU pod can
//! hold.
//!
//! Run: `cargo bench --bench fig_serve_session`

use std::sync::Arc;

use swiftfusion::bench::{BenchRun, Series};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, ServeReport, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{
    EarliestFinish, RebalancePolicy, ServeConfig, ServeSession, SimFleet,
};
use swiftfusion::sp::SpAlgo;
use swiftfusion::util::stats::fmt_time;
use swiftfusion::workload::{Request, Workload};

fn burst(w: &Workload, n: usize, spacing: f64) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i as u64,
            workload: w.clone(),
            arrival: i as f64 * spacing,
            seed: i as u64,
        })
        .collect()
}

fn run_cobatch(co_batch: bool, smoke: bool) -> ServeReport {
    let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
    let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 8, window: 1.0 })
        .plan(PlanPolicy::Auto)
        .co_batch(co_batch);
    // smoke: the 16-request burst the integration test proves the
    // co-batching win on; full: the 32-request figure sweep
    let n = if smoke { 16 } else { 32 };
    ServeSession::new(config, &svc).run(&mut router, burst(&Workload::short_image_4k(), n, 0.1))
}

/// Short-image phase (1 Hz), then sparse long CFG videos (spaced far
/// beyond their service time, so the fleet always has an idle donor).
fn drifting_trace() -> Vec<Request> {
    let mut reqs = burst(&Workload::short_image_4k(), 8, 1.0);
    for i in 0..6u64 {
        let id = reqs.len() as u64;
        reqs.push(Request {
            id,
            workload: Workload::cfg_video_96k(),
            arrival: 8.0 + 200.0 + i as f64 * 200.0,
            seed: id,
        });
    }
    reqs
}

fn run_rebalance(policy: RebalancePolicy) -> (ServeReport, Vec<usize>) {
    let mut router = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
    let fleet = SimFleet::auto(SpAlgo::SwiftFusion, 16);
    let config = ServeConfig::new()
        .batch(BatchPolicy { max_batch: 1, window: 0.0 })
        .plan(PlanPolicy::Auto)
        .patches(16)
        .dispatch(Arc::new(EarliestFinish))
        .rebalance(policy);
    let report = ServeSession::with_fleet(config, &fleet).run(&mut router, drifting_trace());
    let machines = router.pods.iter().map(|p| p.cluster.machines).collect();
    (report, machines)
}

fn main() {
    let mut run = BenchRun::from_env("fig_serve_session");
    let smoke = run.smoke();
    // --- replica co-batching ------------------------------------------------
    println!("fig_serve_session (1/2): replica co-batching, short-image burst");
    println!("on one auto-planned 4x8 pod (rep4 carve), max_batch=8\n");
    let mut series = vec![Series::new("one group (PR-3)"), Series::new("co-batched")];
    let mut horizons = Vec::new();
    for (i, co) in [false, true].into_iter().enumerate() {
        let mut report = run_cobatch(co, smoke);
        let name = Workload::short_image_4k().name;
        let mean = report.metrics.latency(name).map(|s| s.mean()).unwrap_or(f64::NAN);
        series[i].push("mean latency", mean);
        series[i].push("horizon", report.metrics.horizon);
        series[i].push("req/s", report.metrics.throughput());
        println!(
            "  co-batch={:<5} horizon {:>10}  mean latency {:>10}  co-batched dispatches {}",
            co,
            fmt_time(report.metrics.horizon),
            fmt_time(mean),
            report.co_batched
        );
        horizons.push(report.metrics.horizon);
    }
    run.table(
        "fig_serve_session: short-image burst, one group vs co-batched",
        &series,
        Some("one group (PR-3)"),
    );
    run.note("cobatch_speedup", horizons[0] / horizons[1]);
    assert!(
        horizons[1] < horizons[0],
        "co-batching {} must beat the one-group baseline {}",
        horizons[1],
        horizons[0]
    );

    // --- cross-pod re-balancing ---------------------------------------------
    println!("\nfig_serve_session (2/2): cross-pod re-balancing, drifting short->video");
    println!("mix on two 2-machine pods (4x8 GPUs), earliest-finish dispatch\n");
    let (frozen, frozen_machines) = run_rebalance(RebalancePolicy::Never);
    let (adaptive, adaptive_machines) =
        run_rebalance(RebalancePolicy::Gain { threshold: 0.1, window: 2 });
    let video = Workload::cfg_video_96k().name;
    let mut rows = Vec::new();
    for (label, mut report, machines) in [
        ("never (frozen fleet)", frozen, frozen_machines),
        ("gain 10%x2", adaptive, adaptive_machines),
    ] {
        let mean = report.metrics.latency(video).map(|s| s.mean()).unwrap_or(f64::NAN);
        println!(
            "  {label:<22} pods {machines:?}  video mean {:>10}  horizon {:>10}  migrations {}",
            fmt_time(mean),
            fmt_time(report.metrics.horizon),
            report.rebalances.len()
        );
        for ev in &report.rebalances {
            println!(
                "    t={:>10}: machine pod {} -> pod {} (now {} / {})",
                fmt_time(ev.at),
                ev.from_pod,
                ev.to_pod,
                ev.from_machines,
                ev.to_machines
            );
        }
        rows.push((mean, report.metrics.horizon, report.rebalances.len()));
    }
    assert!(rows[1].2 >= 1, "the drift must fire a migration");
    assert!(
        rows[1].0 < rows[0].0,
        "re-balanced video latency {} must beat the frozen fleet {}",
        rows[1].0,
        rows[0].0
    );
    assert!(rows[1].1 < rows[0].1, "and the fleet finishes sooner");
    println!(
        "\nre-balancing serves videos {:.2}x faster than the frozen fleet ({} vs {})",
        rows[0].0 / rows[1].0,
        fmt_time(rows[1].0),
        fmt_time(rows[0].0)
    );
    run.note("rebalance_video_speedup", rows[0].0 / rows[1].0);
    run.finish().expect("write BENCH_fig_serve_session.json");
}
