//! Figure 12 — kernel microbenchmark: the fused multi-QKV attention
//! kernel (Algorithm 2's Pallas analog: carried (O',l,m) state +
//! finalize-on-last) vs the single-QKV flash-attention path, measured
//! end-to-end through PJRT on the real artifacts.
//!
//! Expected shape (paper Appendix C): the multi-tensor/merging capability
//! costs ~nothing over the plain kernel at equal total work. Our measure:
//! chained `attn_partial` calls + finalize vs one `attn_full` call.
//!
//! Run: `make artifacts && cargo bench --bench fig12_kernel`

use swiftfusion::bench::{report, BenchRun, Bencher};
use swiftfusion::runtime::Runtime;
use swiftfusion::tensor::Tensor;

fn main() {
    let mut run = BenchRun::from_env("fig12_kernel");
    let Some(rt) = Runtime::load_default_if_available() else {
        println!("fig12_kernel: PJRT/artifacts unavailable — nothing to measure");
        // still emit the artifact (exit 0) so the CI smoke job can tell
        // a clean skip from a runtime panic
        run.note("skipped_no_pjrt", 1.0);
        run.finish().expect("write BENCH_fig12_kernel.json");
        return;
    };
    let h = rt.handle();
    println!("=== Fig 12: multi-QKV kernel vs single-QKV flash attention ===");
    let bencher = if run.smoke() { Bencher::new(1, 3) } else { Bencher::new(3, 15) };

    for cfg_name in ["small4", "small8"] {
        let c = rt.manifest().config(cfg_name).unwrap().clone();
        let (b, l, hh, d, lc) = (c.b, c.l, c.h, c.d, c.chunk);
        let q = Tensor::random(&[b, l, hh, d], 1);
        let k = Tensor::random(&[b, l, hh, d], 2);
        let v = Tensor::random(&[b, l, hh, d], 3);
        h.precompile(&[
            &format!("attn_full_{cfg_name}"),
            &format!("attn_partial_{cfg_name}_h{hh}"),
            &format!("attn_finalize_{cfg_name}_h{hh}"),
        ])
        .unwrap();

        // single-QKV baseline (the "FlashAttention-2" path)
        let mut s = bencher.run(|| {
            let out = h
                .call(
                    &format!("attn_full_{cfg_name}"),
                    &[q.clone(), k.clone(), v.clone()],
                )
                .unwrap();
            swiftfusion::bench::black_box(out);
        });
        report(&format!("{cfg_name}: attn_full (single QKV, L={l})"), &mut s);

        // multi-QKV path: q tiles x kv chunks through the carry kernel
        let nq = l / lc;
        let nkv = l / lc;
        let q_tiles: Vec<Tensor> =
            (0..nq).map(|i| q.slice(1, i * lc, (i + 1) * lc).unwrap()).collect();
        let kv_tiles: Vec<(Tensor, Tensor)> = (0..nkv)
            .map(|i| {
                (
                    k.slice(1, i * lc, (i + 1) * lc).unwrap(),
                    v.slice(1, i * lc, (i + 1) * lc).unwrap(),
                )
            })
            .collect();
        let mut s = bencher.run(|| {
            for qt in &q_tiles {
                let mut o = Tensor::zeros(&[b, lc, hh, d]);
                let mut lacc = Tensor::zeros(&[b, hh, lc]);
                let mut m = Tensor::neg_inf(&[b, hh, lc]);
                for (kt, vt) in &kv_tiles {
                    let out = h
                        .call(
                            &format!("attn_partial_{cfg_name}_h{hh}"),
                            &[qt.clone(), kt.clone(), vt.clone(), o, lacc, m],
                        )
                        .unwrap();
                    let mut it = out.into_iter();
                    o = it.next().unwrap();
                    lacc = it.next().unwrap();
                    m = it.next().unwrap();
                }
                let fin = h
                    .call(&format!("attn_finalize_{cfg_name}_h{hh}"), &[o, lacc])
                    .unwrap();
                swiftfusion::bench::black_box(fin);
            }
        });
        report(
            &format!("{cfg_name}: multi-QKV chain ({nq}x{nkv} tiles + finalize)"),
            &mut s,
        );

        // §Perf L3-1: the carry-chain fast path — same tiles, state kept
        // service-side as XLA literals (one roundtrip per q tile).
        let mut s = bencher.run(|| {
            for qt in &q_tiles {
                let st = (
                    Tensor::zeros(&[b, lc, hh, d]),
                    Tensor::zeros(&[b, hh, lc]),
                    Tensor::neg_inf(&[b, hh, lc]),
                );
                let out = h
                    .call_attn_chain(
                        &format!("attn_partial_{cfg_name}_h{hh}"),
                        qt,
                        kv_tiles.clone(),
                        st,
                    )
                    .unwrap();
                let fin = h
                    .call(
                        &format!("attn_finalize_{cfg_name}_h{hh}"),
                        &[out[0].clone(), out[1].clone()],
                    )
                    .unwrap();
                swiftfusion::bench::black_box(fin);
            }
        });
        report(
            &format!("{cfg_name}: multi-QKV fused chain (perf path)"),
            &mut s,
        );
        println!();
    }
    println!(
        "reading: the multi-QKV chain does the same total FLOPs; its overhead over\n\
         attn_full is per-call dispatch (the paper's fused CUDA kernel removes\n\
         exactly this, Fig 12 showing parity with FlashAttention-2)."
    );
    run.finish().expect("write BENCH_fig12_kernel.json");
}
