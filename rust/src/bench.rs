//! Bench harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with summary statistics, plus table
//! printers used by the per-figure bench binaries (`rust/benches/fig*.rs`)
//! so their output mirrors the rows/series of the paper's tables and
//! figures. `cargo bench` runs these binaries with `harness = false`.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::cli::Args;
use crate::util::json::{to_string, Json};
use crate::util::stats::{fmt_time, Summary};

/// Measured wall-clock runner for real code paths (PJRT execution, the
/// coordinator hot loop). For *simulated* latencies (paper-scale figures)
/// use [`Series`] directly with model outputs.
pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 10 }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Self { warmup_iters, iters }
    }

    /// Time `f`, returning per-iteration seconds.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        s
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept here so benches don't import std::hint everywhere).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One labelled series of (x-label, value) points — a figure line.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(String, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: impl Into<String>, y: f64) {
        self.points.push((x.into(), y));
    }
}

/// Print a figure-style table: rows = x-labels, one column per series,
/// with optional normalization against a baseline series (the paper plots
/// latency normalized to USP).
pub fn print_table(title: &str, series: &[Series], normalize_to: Option<&str>) {
    println!("\n=== {title} ===");
    if series.is_empty() {
        return;
    }
    let base = normalize_to.and_then(|n| series.iter().find(|s| s.name == n));
    // header
    print!("{:<22}", "x");
    for s in series {
        print!("{:>16}", s.name);
    }
    if base.is_some() {
        for s in series {
            print!("{:>14}", format!("{}/base", s.name));
        }
    }
    println!();
    let nrows = series.iter().map(|s| s.points.len()).max().unwrap_or(0);
    for row in 0..nrows {
        let label = series
            .iter()
            .find_map(|s| s.points.get(row).map(|(x, _)| x.clone()))
            .unwrap_or_default();
        print!("{label:<22}");
        for s in series {
            match s.points.get(row) {
                Some((_, y)) => print!("{:>16}", fmt_time(*y)),
                None => print!("{:>16}", "-"),
            }
        }
        if let Some(b) = base {
            let by = b.points.get(row).map(|(_, y)| *y);
            for s in series {
                let ratio = match (s.points.get(row), by) {
                    (Some((_, y)), Some(by)) if *y > 0.0 => {
                        format!("{:.2}x", by / y)
                    }
                    _ => "-".into(),
                };
                print!("{ratio:>14}");
            }
        }
        println!();
    }
}

/// Shared conventions of the `fig_*` bench binaries: the `--smoke` CLI
/// flag (CI-sized sweeps — CI *runs* every bench, it does not just build
/// them) and the `BENCH_<name>.json` artifact each bench emits so
/// runtime panics and perf-trajectory gaps cannot hide behind a
/// successful build. Usage:
///
/// ```no_run
/// use swiftfusion::bench::{BenchRun, Series};
/// let mut run = BenchRun::from_env("fig_example");
/// let sweep = if run.smoke() { 2 } else { 8 };
/// let series: Vec<Series> = Vec::new(); // ... measure `sweep` points ...
/// run.table("example sweep", &series, None);
/// run.note("speedup", 1.25);
/// run.finish().expect("write BENCH_fig_example.json");
/// # let _ = sweep;
/// ```
pub struct BenchRun {
    name: &'static str,
    smoke: bool,
    tables: Vec<(String, Vec<Series>)>,
    notes: BTreeMap<String, f64>,
}

impl BenchRun {
    /// Parse the bench CLI (`--smoke`; cargo's own `--bench` flag is
    /// ignored). `name` keys the JSON artifact: `BENCH_<name>.json`.
    pub fn from_env(name: &'static str) -> Self {
        let args = Args::from_env();
        let smoke = args.has("smoke");
        if smoke {
            println!("[{name}] --smoke: CI-sized sweep");
        }
        Self { name, smoke, tables: Vec::new(), notes: BTreeMap::new() }
    }

    /// A constructor for tests (no process CLI involved).
    pub fn new(name: &'static str, smoke: bool) -> Self {
        Self { name, smoke, tables: Vec::new(), notes: BTreeMap::new() }
    }

    /// Is this a `--smoke` (CI-sized) run?
    pub fn smoke(&self) -> bool {
        self.smoke
    }

    /// [`print_table`] that also records the series into the JSON
    /// artifact.
    pub fn table(&mut self, title: &str, series: &[Series], normalize_to: Option<&str>) {
        print_table(title, series, normalize_to);
        self.tables.push((title.to_string(), series.to_vec()));
    }

    /// Record a headline scalar (a horizon, a speedup) into the JSON
    /// artifact without printing.
    pub fn note(&mut self, key: &str, value: f64) {
        self.notes.insert(key.to_string(), value);
    }

    /// The artifact as a JSON value (`{bench, smoke, tables, notes}`).
    pub fn to_json(&self) -> Json {
        let tables = Json::Arr(
            self.tables
                .iter()
                .map(|(title, series)| {
                    let series = Json::Arr(
                        series
                            .iter()
                            .map(|s| {
                                let points = Json::Arr(
                                    s.points
                                        .iter()
                                        .map(|(x, y)| {
                                            Json::Arr(vec![
                                                Json::Str(x.clone()),
                                                Json::Num(*y),
                                            ])
                                        })
                                        .collect(),
                                );
                                let mut o = BTreeMap::new();
                                o.insert("name".to_string(), Json::Str(s.name.clone()));
                                o.insert("points".to_string(), points);
                                Json::Obj(o)
                            })
                            .collect(),
                    );
                    let mut o = BTreeMap::new();
                    o.insert("title".to_string(), Json::Str(title.clone()));
                    o.insert("series".to_string(), series);
                    Json::Obj(o)
                })
                .collect(),
        );
        let notes = Json::Obj(
            self.notes
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect(),
        );
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str(self.name.to_string()));
        root.insert("smoke".to_string(), Json::Bool(self.smoke));
        root.insert("tables".to_string(), tables);
        root.insert("notes".to_string(), notes);
        Json::Obj(root)
    }

    /// Write `BENCH_<name>.json` into the current directory (the CI
    /// bench-smoke job uploads these as workflow artifacts) and return
    /// the path. Call last.
    pub fn finish(&self) -> std::io::Result<String> {
        let path = format!("BENCH_{}.json", self.name);
        std::fs::write(&path, to_string(&self.to_json()))?;
        println!("[{}] wrote {path}", self.name);
        Ok(path)
    }
}

/// Print a Summary as a one-line bench result.
pub fn report(name: &str, s: &mut Summary) {
    println!(
        "{name:<48} mean {:>12}  p50 {:>12}  min {:>12}  max {:>12}  (n={})",
        fmt_time(s.mean()),
        fmt_time(s.p50()),
        fmt_time(s.min()),
        fmt_time(s.max()),
        s.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_counts_iterations() {
        let mut n = 0;
        let b = Bencher::new(2, 5);
        let s = b.run(|| n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn series_accumulates() {
        let mut s = Series::new("usp");
        s.push("M=2", 1.0);
        s.push("M=4", 2.0);
        assert_eq!(s.points.len(), 2);
    }

    #[test]
    fn bench_run_records_tables_and_notes_as_json() {
        let mut run = BenchRun::new("fig_test", true);
        assert!(run.smoke());
        let mut s = Series::new("usp");
        s.push("M=2", 2.0e-3);
        run.table("sweep", &[s], None);
        run.note("speedup", 1.5);
        let json = to_string(&run.to_json());
        assert!(json.contains("\"bench\":\"fig_test\""), "{json}");
        assert!(json.contains("\"smoke\":true"), "{json}");
        assert!(json.contains("\"title\":\"sweep\""), "{json}");
        assert!(json.contains("\"name\":\"usp\""), "{json}");
        assert!(json.contains("[\"M=2\",0.002]"), "{json}");
        assert!(json.contains("\"speedup\":1.5"), "{json}");
        // the artifact round-trips through the JSON parser
        assert!(Json::parse(&json).is_ok());
    }

    #[test]
    fn table_prints_without_panic() {
        let mut a = Series::new("usp");
        a.push("M=2", 2.0e-3);
        a.push("M=4", 4.0e-3);
        let mut b = Series::new("sfu");
        b.push("M=2", 1.5e-3);
        b.push("M=4", 2.0e-3);
        print_table("test", &[a, b], Some("usp"));
    }
}
