//! Bench harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with summary statistics, plus table
//! printers used by the per-figure bench binaries (`rust/benches/fig*.rs`)
//! so their output mirrors the rows/series of the paper's tables and
//! figures. `cargo bench` runs these binaries with `harness = false`.

use std::time::Instant;

use crate::util::stats::{fmt_time, Summary};

/// Measured wall-clock runner for real code paths (PJRT execution, the
/// coordinator hot loop). For *simulated* latencies (paper-scale figures)
/// use [`Series`] directly with model outputs.
pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, iters: 10 }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Self { warmup_iters, iters }
    }

    /// Time `f`, returning per-iteration seconds.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        s
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept here so benches don't import std::hint everywhere).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One labelled series of (x-label, value) points — a figure line.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(String, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: impl Into<String>, y: f64) {
        self.points.push((x.into(), y));
    }
}

/// Print a figure-style table: rows = x-labels, one column per series,
/// with optional normalization against a baseline series (the paper plots
/// latency normalized to USP).
pub fn print_table(title: &str, series: &[Series], normalize_to: Option<&str>) {
    println!("\n=== {title} ===");
    if series.is_empty() {
        return;
    }
    let base = normalize_to.and_then(|n| series.iter().find(|s| s.name == n));
    // header
    print!("{:<22}", "x");
    for s in series {
        print!("{:>16}", s.name);
    }
    if base.is_some() {
        for s in series {
            print!("{:>14}", format!("{}/base", s.name));
        }
    }
    println!();
    let nrows = series.iter().map(|s| s.points.len()).max().unwrap_or(0);
    for row in 0..nrows {
        let label = series
            .iter()
            .find_map(|s| s.points.get(row).map(|(x, _)| x.clone()))
            .unwrap_or_default();
        print!("{label:<22}");
        for s in series {
            match s.points.get(row) {
                Some((_, y)) => print!("{:>16}", fmt_time(*y)),
                None => print!("{:>16}", "-"),
            }
        }
        if let Some(b) = base {
            let by = b.points.get(row).map(|(_, y)| *y);
            for s in series {
                let ratio = match (s.points.get(row), by) {
                    (Some((_, y)), Some(by)) if *y > 0.0 => {
                        format!("{:.2}x", by / y)
                    }
                    _ => "-".into(),
                };
                print!("{ratio:>14}");
            }
        }
        println!();
    }
}

/// Print a Summary as a one-line bench result.
pub fn report(name: &str, s: &mut Summary) {
    println!(
        "{name:<48} mean {:>12}  p50 {:>12}  min {:>12}  max {:>12}  (n={})",
        fmt_time(s.mean()),
        fmt_time(s.p50()),
        fmt_time(s.min()),
        fmt_time(s.max()),
        s.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_counts_iterations() {
        let mut n = 0;
        let b = Bencher::new(2, 5);
        let s = b.run(|| n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn series_accumulates() {
        let mut s = Series::new("usp");
        s.push("M=2", 1.0);
        s.push("M=4", 2.0);
        assert_eq!(s.points.len(), 2);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut a = Series::new("usp");
        a.push("M=2", 2.0e-3);
        a.push("M=4", 4.0e-3);
        let mut b = Series::new("sfu");
        b.push("M=2", 1.5e-3);
        b.push("M=4", 2.0e-3);
        print_table("test", &[a, b], Some("usp"));
    }
}
