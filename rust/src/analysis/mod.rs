//! Analytical models from the paper: Appendix-D inter-machine
//! communication volumes, Lemma D.1, and memory/roofline estimates.
//!
//! These closed forms serve three purposes: (1) they regenerate the
//! motivation numbers (Fig. 3); (2) property tests check Lemma D.1
//! (`V_USP ≥ V_SFU` for `2 ≤ M ≤ P_u ≤ N`); and (3) integration tests
//! cross-validate them against the *measured* byte counters of the
//! threaded simulator ([`crate::comm::CommWorld::traffic`]) — the
//! formulas and the executable schedules must agree.

use crate::config::{AttnShape, ClusterSpec, ParallelSpec, SpDegrees};
use crate::sp::SpAlgo;

/// Inter-machine communication volume **per GPU, in elements**, for USP
/// on N machines × M GPUs with degrees (P_u, P_r). Paper Eq. (4)/(5).
///
/// USP places Ulysses intra-machine; Ring crosses machines whenever
/// `P_r > 1` spans them.
pub fn v_usp(shape: &AttnShape, n: usize, _m: usize, deg: SpDegrees) -> f64 {
    let blhd = shape.blhd() as f64;
    let nn = n as f64;
    let pr = deg.pr as f64;
    if n == 1 {
        return 0.0;
    }
    if deg.pr >= n {
        // Eq. (4): ring crosses machines on every hop that leaves a
        // machine; with P_r >= N the ring spans all N machines and the
        // KV blocks are BLHD/P_r each (2 tensors, P_r - 1 steps), of
        // which the fraction crossing machines is (N-1)/N per full loop.
        // The paper states the aggregate as 2·(N−1)·BLHD/N.
        2.0 * (nn - 1.0) * blhd / nn
    } else {
        // Eq. (5): Ring handles P_r of the inter dimension, Ulysses the
        // remaining N/P_r.
        let npr = nn / pr;
        (2.0 * (pr - 1.0) * npr + 4.0 * (npr - 1.0) / npr) * blhd / nn
    }
}

/// Inter-machine volume per GPU for SwiftFusion/TAS (Ulysses inter,
/// Ring intra). Paper Eq. (6)/(7).
pub fn v_sfu(shape: &AttnShape, n: usize, _m: usize, deg: SpDegrees) -> f64 {
    let blhd = shape.blhd() as f64;
    let nn = n as f64;
    let pu = deg.pu as f64;
    if n == 1 {
        return 0.0;
    }
    if deg.pu >= n {
        // Eq. (6): all-to-all over N machines, 4 tensors.
        4.0 * (nn - 1.0) / nn * blhd / nn
    } else {
        // Eq. (7): Ulysses covers P_u of the inter dimension; Ring covers
        // the remaining N/P_u across machines.
        let npu = nn / pu;
        (2.0 * (npu - 1.0) + 4.0 * (pu - 1.0) / pu * npu) * blhd / nn
    }
}

/// Inter-machine volume per GPU for pure Ring over the whole mesh.
pub fn v_ring(shape: &AttnShape, n: usize, m: usize) -> f64 {
    v_usp(shape, n, m, SpDegrees::new(1, n * m))
}

/// Inter-machine volume per GPU for pure mesh-wide Ulysses.
pub fn v_ulysses(shape: &AttnShape, n: usize, m: usize) -> f64 {
    v_sfu(shape, n, m, SpDegrees::new(n * m, 1))
}

/// Volume for a named algorithm (bench convenience).
pub fn inter_volume(algo: SpAlgo, shape: &AttnShape, n: usize, m: usize, deg: SpDegrees) -> f64 {
    match algo {
        SpAlgo::Ring => v_ring(shape, n, m),
        SpAlgo::Ulysses => v_ulysses(shape, n, m),
        SpAlgo::Usp => v_usp(shape, n, m, deg),
        SpAlgo::Tas | SpAlgo::TorusNccl | SpAlgo::SwiftFusion => v_sfu(shape, n, m, deg),
    }
}

/// Lemma D.1's `V_diff = (V_USP − V_SFU) / (BLHD/N)` in closed form.
pub fn lemma_d1_vdiff(n: usize, m: usize, pu: usize) -> f64 {
    let (nn, mm, p) = (n as f64, m as f64, pu as f64);
    4.0 * nn / (p * p) - (4.0 * mm + 6.0 * nn) / p - 2.0 * p / mm + 2.0 * nn + 6.0
}

/// Per-GPU activation memory (bytes) for one attention layer under a
/// given algorithm — the Fig. 7 memory-consumption model. All methods
/// hold their Q/K/V/O shards plus at most one communication copy of each
/// (Algorithm 1 uses exactly one buf clone per tensor; USP's NCCL path
/// stages the same).
pub fn activation_bytes(algo: SpAlgo, shape: &AttnShape, total_ranks: usize) -> f64 {
    let shard = shape.bytes_per_tensor() / total_ranks as f64;
    let base = 4.0 * shard; // Q, K, V, O shards
    let copies = match algo {
        // Ring keeps two in-flight KV blocks (current + receiving)
        SpAlgo::Ring => 4.0 * shard / 4.0 * 4.0,
        // one copy buffer of Q, K, V, O (paper §5.2 conclusion 4)
        _ => 4.0 * shard,
    };
    base + copies
}

/// Attention compute time for the full layer on one GPU (roofline).
pub fn compute_time(shape: &AttnShape, cluster: &ClusterSpec, total_ranks: usize) -> f64 {
    let flops = shape.attention_flops() / total_ranks as f64;
    let bytes = 4.0 * shape.bytes_per_tensor() / total_ranks as f64;
    cluster.gpu.tile_time(flops, bytes)
}

// ---------------------------------------------------------------------------
// Hybrid CFG×SP plan cost model
// ---------------------------------------------------------------------------

/// Closed-form per-step attention latency estimate (seconds) of a hybrid
/// plan: `evals × (compute + inter-comm + intra-comm)` where
/// `evals = ceil(cfg_evals / cfg_degree)` is how many guidance branches
/// each group runs sequentially. `shape` is the *per-branch* shape with
/// the per-replica batch; `batch_replicas` does not change this latency
/// (it adds independent groups), only throughput — see
/// [`choose_spec`]. The terms reuse the Appendix-D volume formulas on the
/// group's sub-geometry, so the model and the executable schedules agree
/// in ordering (cross-checked by `rust/tests/sp_property.rs`).
pub fn plan_step_cost(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    spec: &ParallelSpec,
    cfg_evals: usize,
) -> f64 {
    let group = spec.ranks_per_group();
    let m = cluster.gpus_per_machine;
    // group sub-geometry: whole machines per group, or a machine slice
    let (n_g, m_g) = if group >= m { (group / m, m) } else { (1, group) };
    let evals = cfg_evals.div_ceil(spec.cfg_degree.max(1)) as f64;

    let comp = compute_time(shape, cluster, group);
    let inter_elems = inter_volume(algo, shape, n_g, m_g, spec.sp);
    let inter = if n_g > 1 {
        cluster.net.inter_lat + inter_elems * 4.0 / cluster.net.inter_bw_per_flow(m_g)
    } else {
        0.0
    };
    // intra term: the group moves ~4 shard-sized tensors over NVSwitch
    // (Q/K/V in, O out) regardless of algorithm
    let intra = cluster.net.intra_lat
        + 4.0 * shape.bytes_per_tensor() / group as f64 / cluster.net.intra_bw;
    evals * (comp + inter + intra)
}

/// All structurally valid hybrid specs for a cluster/head count, each
/// group's SP degrees set by the paper's gcd placement rule. Covers
/// `cfg_degree ∈ {1, 2}` × every machine-aligned replica count.
pub fn enumerate_specs(cluster: &ClusterSpec, heads: usize) -> Vec<ParallelSpec> {
    let total = cluster.total_gpus();
    let mut out = Vec::new();
    for cfg in [1usize, 2] {
        if total % cfg != 0 {
            continue;
        }
        let per_branch = total / cfg;
        for reps in 1..=per_branch {
            if per_branch % reps != 0 {
                continue;
            }
            let group = per_branch / reps;
            let spec = ParallelSpec::with_gcd_placement(cfg, reps, group, heads);
            if spec.validate(cluster).is_ok() {
                out.push(spec);
            }
        }
    }
    out
}

/// Pick the spec minimizing modeled *service* cost for a request of
/// `shape` when `queue_depth` same-sized requests are waiting: batch
/// replicas beyond the queue depth idle (no work to fill them), so the
/// effective cost is `step latency / min(batch_replicas, queue_depth)`.
/// `queue_depth = 1` therefore optimizes pure latency. Deterministic:
/// ties break toward fewer groups (larger SP meshes).
pub fn choose_spec(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    cfg_evals: usize,
    queue_depth: usize,
) -> ParallelSpec {
    let mut specs = enumerate_specs(cluster, shape.h);
    // stable order: fewest groups first so equal costs prefer big meshes
    specs.sort_by_key(|s| (s.groups(), s.cfg_degree));
    let mut best: Option<(f64, ParallelSpec)> = None;
    for spec in specs {
        let useful = spec.batch_replicas.min(queue_depth.max(1)) as f64;
        let cost = plan_step_cost(cluster, algo, shape, &spec, cfg_evals) / useful;
        match best {
            Some((b, _)) if b <= cost => {}
            _ => best = Some((cost, spec)),
        }
    }
    best.map(|(_, s)| s)
        .unwrap_or_else(|| ParallelSpec::single(cluster, shape.h))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn shape() -> AttnShape {
        AttnShape::new(1, 96_000, 24, 64)
    }

    #[test]
    fn single_machine_volumes_are_zero() {
        assert_eq!(v_usp(&shape(), 1, 8, SpDegrees::new(8, 1)), 0.0);
        assert_eq!(v_sfu(&shape(), 1, 8, SpDegrees::new(8, 1)), 0.0);
    }

    #[test]
    fn paper_testbed_sfu_below_usp() {
        // N=4, M=8, H=24: USP (P_u=8 intra, P_r=4) vs SFU (gcd rule P_u=8).
        let s = shape();
        let usp = v_usp(&s, 4, 8, SpDegrees::new(8, 4));
        let sfu = v_sfu(&s, 4, 8, SpDegrees::new(8, 4));
        assert!(sfu < usp, "sfu {sfu} < usp {usp}");
        // the ratio drives the paper's ~1.3-1.8x speedups
        assert!(usp / sfu > 1.5, "ratio {}", usp / sfu);
    }

    #[test]
    fn two_machine_parity() {
        // §4.2: at P_u = 2 Ulysses and Ring volumes coincide (BLHD each);
        // SwiftFusion has no advantage (TAS can even lose, Fig. 7 M=2).
        let s = shape();
        let usp = v_usp(&s, 2, 8, SpDegrees::new(8, 2));
        let sfu = v_sfu(&s, 2, 8, SpDegrees::new(8, 2));
        // both are ~BLHD-level; SFU no worse
        assert!(sfu <= usp * 1.01, "sfu {sfu} usp {usp}");
    }

    #[test]
    fn ring_volume_constant_ulysses_shrinks() {
        let s = shape();
        let r4 = v_ring(&s, 4, 8);
        let r8 = v_ring(&s, 8, 8);
        // ring: 2(N-1)/N·BLHD grows (towards 2·BLHD)
        assert!(r8 > r4);
        let u4 = v_ulysses(&s, 4, 8);
        let u8 = v_ulysses(&s, 8, 8);
        // ulysses: 4(N-1)/N²·BLHD shrinks
        assert!(u8 < u4);
    }

    #[test]
    fn lemma_d1_closed_form_nonnegative() {
        for n in 2..=16 {
            for m in 2..=8 {
                for pu in m..=n {
                    let v = lemma_d1_vdiff(n, m, pu);
                    assert!(
                        v >= -1e-9,
                        "lemma violated at N={n} M={m} Pu={pu}: {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_lemma_d1_matches_volume_formulas() {
        // V_diff computed from the Eq. (5)/(7) formulas must equal the
        // closed form, and be >= 0, for the lemma's precondition
        // P_r = N·M/P_u <= N (i.e. P_u >= M) and P_u <= N.
        prop::run(60, |g| {
            let n = g.int(2, 12);
            let m = g.int(2, 6);
            if m > n {
                return;
            }
            // valid meshes only: P_u must divide N·M (else P_r = N·M/P_u
            // is not integral and the closed form doesn't apply)
            let cands: Vec<usize> =
                (m..=n).filter(|pu| (n * m) % pu == 0).collect();
            if cands.is_empty() {
                return;
            }
            let pu = *g.choose(&cands);
            let s = AttnShape::new(1, 4096, 24, 32);
            let unit = s.blhd() as f64 / n as f64;
            let usp = v_usp(&s, n, m, SpDegrees::new(pu, n * m / pu));
            let sfu = v_sfu(&s, n, m, SpDegrees::new(pu, n * m / pu));
            let vdiff_formulas = (usp - sfu) / unit;
            let vdiff_closed = lemma_d1_vdiff(n, m, pu);
            assert!(
                (vdiff_formulas - vdiff_closed).abs() < 1e-6,
                "N={n} M={m} Pu={pu}: {vdiff_formulas} vs {vdiff_closed}"
            );
            assert!(vdiff_closed >= -1e-9, "lemma: N={n} M={m} Pu={pu}");
        });
    }

    #[test]
    fn memory_model_sfu_not_worse_than_usp() {
        let s = shape();
        let usp = activation_bytes(SpAlgo::Usp, &s, 32);
        let sfu = activation_bytes(SpAlgo::SwiftFusion, &s, 32);
        assert!(sfu <= usp * 1.01, "Fig. 7: SFU memory ~ USP memory");
    }

    #[test]
    fn compute_time_scales_inversely_with_ranks() {
        let s = shape();
        let c = ClusterSpec::paper_testbed();
        let t8 = compute_time(&s, &c, 8);
        let t32 = compute_time(&s, &c, 32);
        assert!(t32 < t8 / 3.0);
    }

    #[test]
    fn enumerate_specs_are_valid_and_cover_cfg_modes() {
        let c = ClusterSpec::paper_testbed();
        let specs = enumerate_specs(&c, 24);
        assert!(!specs.is_empty());
        for s in &specs {
            assert!(s.validate(&c).is_ok(), "{s:?}");
        }
        assert!(specs.iter().any(|s| s.cfg_degree == 1));
        assert!(specs.iter().any(|s| s.cfg_degree == 2));
        assert!(specs.iter().any(|s| s.batch_replicas > 1));
    }

    #[test]
    fn cfg_parallel_wins_for_guided_long_sequences() {
        // CFG workloads (2 evals) on comm-bound shapes: running branches
        // concurrently on halves must model cheaper than sequentially on
        // the full mesh.
        let c = ClusterSpec::paper_testbed();
        let s = shape();
        let full = ParallelSpec::new(1, 1, SpDegrees::new(8, 4));
        let halves = ParallelSpec::new(2, 1, SpDegrees::new(8, 2));
        let t_full = plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &full, 2);
        let t_half = plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &halves, 2);
        assert!(t_half < t_full, "cfg2 {t_half} vs cfg1 {t_full}");
        // ...and the auto-chooser finds a CFG-parallel plan
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &s, 2, 1);
        assert_eq!(picked.cfg_degree, 2, "{picked:?}");
    }

    #[test]
    fn non_guided_workloads_keep_the_full_mesh() {
        // With a single eval there is no branch to parallelize: halving
        // the mesh only halves the compute power.
        let c = ClusterSpec::paper_testbed();
        let s = shape();
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &s, 1, 1);
        assert_eq!(picked.cfg_degree, 1, "{picked:?}");
        assert_eq!(picked.batch_replicas, 1, "{picked:?}");
    }

    #[test]
    fn deep_queues_favor_batch_replicas() {
        // Short sequences under heavy load: replicating beats sharding
        // one small request over 32 GPUs.
        let c = ClusterSpec::paper_testbed();
        let small = AttnShape::new(1, 4096, 24, 64);
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &small, 1, 32);
        assert!(
            picked.batch_replicas > 1,
            "deep queue should replicate: {picked:?}"
        );
        // and a short request should never be sharded across machines —
        // the inter-machine volume dwarfs its compute
        let shallow = choose_spec(&c, SpAlgo::SwiftFusion, &small, 1, 1);
        assert!(
            shallow.ranks_per_group() <= c.gpus_per_machine,
            "small request stays on one machine: {shallow:?}"
        );
    }
}
