//! Analytical models from the paper: Appendix-D inter-machine
//! communication volumes, Lemma D.1, and memory/roofline estimates.
//!
//! These closed forms serve three purposes: (1) they regenerate the
//! motivation numbers (Fig. 3); (2) property tests check Lemma D.1
//! (`V_USP ≥ V_SFU` for `2 ≤ M ≤ P_u ≤ N`); and (3) integration tests
//! cross-validate them against the *measured* byte counters of the
//! threaded simulator ([`crate::comm::CommWorld::traffic`]) — the
//! formulas and the executable schedules must agree.

use crate::config::{AttnShape, ClusterSpec, ParallelSpec, QualityMode, SpDegrees};
use crate::sp::SpAlgo;
use crate::workload::{StageClass, StageShape, Workload};

/// Inter-machine communication volume **per GPU, in elements**, for USP
/// on N machines × M GPUs with degrees (P_u, P_r). Paper Eq. (4)/(5).
///
/// USP places Ulysses intra-machine; Ring crosses machines whenever
/// `P_r > 1` spans them.
pub fn v_usp(shape: &AttnShape, n: usize, _m: usize, deg: SpDegrees) -> f64 {
    let blhd = shape.blhd() as f64;
    let nn = n as f64;
    let pr = deg.pr as f64;
    if n == 1 {
        return 0.0;
    }
    if deg.pr >= n {
        // Eq. (4): ring crosses machines on every hop that leaves a
        // machine; with P_r >= N the ring spans all N machines and the
        // KV blocks are BLHD/P_r each (2 tensors, P_r - 1 steps), of
        // which the fraction crossing machines is (N-1)/N per full loop.
        // The paper states the aggregate as 2·(N−1)·BLHD/N.
        2.0 * (nn - 1.0) * blhd / nn
    } else {
        // Eq. (5): Ring handles P_r of the inter dimension, Ulysses the
        // remaining N/P_r.
        let npr = nn / pr;
        (2.0 * (pr - 1.0) * npr + 4.0 * (npr - 1.0) / npr) * blhd / nn
    }
}

/// Inter-machine volume per GPU for SwiftFusion/TAS (Ulysses inter,
/// Ring intra). Paper Eq. (6)/(7).
pub fn v_sfu(shape: &AttnShape, n: usize, _m: usize, deg: SpDegrees) -> f64 {
    let blhd = shape.blhd() as f64;
    let nn = n as f64;
    let pu = deg.pu as f64;
    if n == 1 {
        return 0.0;
    }
    if deg.pu >= n {
        // Eq. (6): all-to-all over N machines, 4 tensors.
        4.0 * (nn - 1.0) / nn * blhd / nn
    } else {
        // Eq. (7): Ulysses covers P_u of the inter dimension; Ring covers
        // the remaining N/P_u across machines.
        let npu = nn / pu;
        (2.0 * (npu - 1.0) + 4.0 * (pu - 1.0) / pu * npu) * blhd / nn
    }
}

/// Inter-machine volume per GPU for pure Ring over the whole mesh.
pub fn v_ring(shape: &AttnShape, n: usize, m: usize) -> f64 {
    v_usp(shape, n, m, SpDegrees::new(1, n * m))
}

/// Inter-machine volume per GPU for pure mesh-wide Ulysses.
pub fn v_ulysses(shape: &AttnShape, n: usize, m: usize) -> f64 {
    v_sfu(shape, n, m, SpDegrees::new(n * m, 1))
}

/// Volume for a named algorithm (bench convenience).
pub fn inter_volume(algo: SpAlgo, shape: &AttnShape, n: usize, m: usize, deg: SpDegrees) -> f64 {
    match algo {
        SpAlgo::Ring => v_ring(shape, n, m),
        SpAlgo::Ulysses => v_ulysses(shape, n, m),
        SpAlgo::Usp => v_usp(shape, n, m, deg),
        SpAlgo::Tas | SpAlgo::TorusNccl | SpAlgo::SwiftFusion => v_sfu(shape, n, m, deg),
        // displaced steady state allgathers ONE fresh activation tensor
        // per step (the layer input doubles as K and V), half of Ring's
        // two-tensor KV rotation — and off the critical path besides
        // (the transfer overlaps compute; plan_step_cost_quality models
        // that part).
        SpAlgo::DisplacedPatch => v_ring(shape, n, m) / 2.0,
    }
}

/// Lemma D.1's `V_diff = (V_USP − V_SFU) / (BLHD/N)` in closed form.
pub fn lemma_d1_vdiff(n: usize, m: usize, pu: usize) -> f64 {
    let (nn, mm, p) = (n as f64, m as f64, pu as f64);
    4.0 * nn / (p * p) - (4.0 * mm + 6.0 * nn) / p - 2.0 * p / mm + 2.0 * nn + 6.0
}

/// Per-GPU activation memory (bytes) for one attention layer under a
/// given algorithm — the Fig. 7 memory-consumption model. All methods
/// hold their Q/K/V/O shards plus at most one communication copy of each
/// (Algorithm 1 uses exactly one buf clone per tensor; USP's NCCL path
/// stages the same).
pub fn activation_bytes(algo: SpAlgo, shape: &AttnShape, total_ranks: usize) -> f64 {
    let shard = shape.bytes_per_tensor() / total_ranks as f64;
    let base = 4.0 * shard; // Q, K, V, O shards
    let copies = match algo {
        // Ring keeps two in-flight KV blocks (current + receiving)
        SpAlgo::Ring => 4.0 * shard / 4.0 * 4.0,
        // one copy buffer of Q, K, V, O (paper §5.2 conclusion 4)
        _ => 4.0 * shard,
    };
    base + copies
}

/// Attention compute time for the full layer on one GPU (roofline).
pub fn compute_time(shape: &AttnShape, cluster: &ClusterSpec, total_ranks: usize) -> f64 {
    let flops = shape.attention_flops() / total_ranks as f64;
    let bytes = 4.0 * shape.bytes_per_tensor() / total_ranks as f64;
    cluster.gpu.tile_time(flops, bytes)
}

// ---------------------------------------------------------------------------
// Hybrid CFG×PP×SP plan cost model
// ---------------------------------------------------------------------------

/// Default patch count for the displaced patch pipeline (PipeFusion's
/// `M`): enough patches to keep the bubble fraction `(pp−1)/(pp·M)`
/// small without making the per-patch inter-stage transfers
/// latency-bound.
pub const DEFAULT_PATCHES: usize = 4;

/// Closed-form per-layer attention latency estimate (seconds) of a
/// hybrid plan with [`DEFAULT_PATCHES`] pipeline patches; see
/// [`plan_step_cost_patches`].
pub fn plan_step_cost(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    spec: &ParallelSpec,
    cfg_evals: usize,
) -> f64 {
    plan_step_cost_patches(cluster, algo, shape, spec, cfg_evals, DEFAULT_PATCHES)
}

/// Closed-form per-layer attention latency estimate (seconds) of a
/// hybrid plan: `evals × stage-layer terms`, where
/// `evals = ceil(cfg_evals / cfg_degree)` is how many guidance branches
/// each group runs sequentially and the SP compute/comm terms are taken
/// on the *stage* sub-geometry (the stage is the SP mesh). `shape` is
/// the *per-branch* shape with the per-replica batch; `batch_replicas`
/// does not change this latency (it adds independent groups), only
/// throughput — see [`choose_spec`].
///
/// For `pp_degree > 1` the pipeline terms follow PipeFusion: the layers
/// are spread over `pp` stages, so the per-layer wall time is the stage
/// layer time divided by `pp`, inflated by the pipeline-fill bubble —
/// `(pp−1)/(pp·patches)` of the stage layer time — plus the exposed part
/// of the per-patch inter-stage α–β activation transfer
/// (`B·L/M·H·D` elements per patch, independent of the SP degree),
/// overlapped against one patch's compute. The SP comm terms shrink to
/// the stage geometry, which is the whole point: a stage that fits in a
/// machine pays **zero** inter-machine all-to-all.
///
/// The comm-layer optimization knobs ([`crate::config::NetSpec`]) enter
/// here exactly as the executable schedules price them: inter-machine
/// byte terms scale by `inter_compress`, and a fusible CFG pair
/// (`cfg_fuse`, two branches, machine-aligned groups) halves the inter
/// α — so [`choose_spec`] can pick a different plan when compression or
/// fusion changes which candidate is cheapest.
pub fn plan_step_cost_patches(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    spec: &ParallelSpec,
    cfg_evals: usize,
    patches: usize,
) -> f64 {
    plan_step_cost_quality(cluster, algo, shape, spec, cfg_evals, patches, QualityMode::Full)
}

/// [`plan_step_cost_patches`] with the quality dimension priced in —
/// the staleness/approximation term that lets the chooser and the
/// admission knob trade quality against latency. `QualityMode::Full`
/// reproduces [`plan_step_cost_patches`] bit-for-bit (the degraded
/// adjustments below multiply by exactly 1.0 and pick the same branch
/// arms), so every existing caller and pinned golden is unaffected.
///
/// The degraded modes price as their executable schedules behave:
/// - `Displaced` ([`crate::sp::displaced`]): the fresh-patch allgather
///   runs *after* the step's attention and only feeds the next step, so
///   the inter byte term leaves the critical path — only the
///   non-overlappable per-transfer α survives. Wire bytes (for the
///   byte *counters*, not this latency) also halve via
///   [`QualityMode::wire_compress`].
/// - `FastAttn { keep_ratio }`: each query tile attends `keep_ratio` of
///   the KV tiles, so the attention compute term scales by
///   `keep_ratio`; the KV exchange is unchanged (the window is decided
///   after the allgather).
/// - `ReducedSteps`: the per-layer, per-eval cost is *unchanged* — the
///   saving is fewer evals per generation, priced end-to-end by
///   [`quality_time_factor`] / [`Workload::evals_under`].
#[allow(clippy::too_many_arguments)]
pub fn plan_step_cost_quality(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    spec: &ParallelSpec,
    cfg_evals: usize,
    patches: usize,
    quality: QualityMode,
) -> f64 {
    let stage = spec.ranks_per_stage();
    let m = cluster.gpus_per_machine;
    // stage sub-geometry: whole machines per stage, or a machine slice
    let (n_g, m_g) = if stage >= m { (stage / m, m) } else { (1, stage) };
    let evals = cfg_evals.div_ceil(spec.cfg_degree.max(1)) as f64;

    let comp = match quality {
        // windowed attention: each q tile touches keep_ratio of the KV
        QualityMode::FastAttn { keep_ratio } => {
            compute_time(shape, cluster, stage) * keep_ratio
        }
        _ => compute_time(shape, cluster, stage),
    };
    let inter_elems = inter_volume(algo, shape, n_g, m_g, spec.sp);
    // comm-layer optimization pass, mirrored from `comm::CommWorld` so
    // the chooser sees the same savings the schedules measure: inter
    // hops ship `inter_compress` of their payload bytes, and a fusible
    // CFG pair (cfg_fuse on, exactly two branches, machine-aligned
    // groups — `ParallelPlan::cfg_fusible`) pays half the per-transfer α
    // per-batch quality compression stacks on the pod-level knob
    // (both 1.0 under Full, so the Full path is untouched)
    let wire = cluster.net.inter_compress * quality.wire_compress();
    let fused =
        cluster.net.cfg_fuse && spec.cfg_degree == 2 && spec.ranks_per_group() % m == 0;
    let alpha = if fused { cluster.net.inter_lat * 0.5 } else { cluster.net.inter_lat };
    let inter = if n_g > 1 {
        match quality {
            // the displaced fresh-patch allgather feeds the *next* step,
            // so its bytes overlap this step's compute; only α is exposed
            QualityMode::Displaced => alpha,
            _ => alpha + inter_elems * 4.0 * wire / cluster.net.inter_bw_per_flow(m_g),
        }
    } else {
        0.0
    };
    // intra term: the stage moves ~4 shard-sized tensors over NVSwitch
    // (Q/K/V in, O out) regardless of algorithm
    let intra = cluster.net.intra_lat
        + 4.0 * shape.bytes_per_tensor() / stage as f64 / cluster.net.intra_bw;
    let stage_layer = comp + inter + intra;

    let pp = spec.pp_degree.max(1);
    if pp == 1 {
        return evals * stage_layer;
    }

    // --- pipeline terms -------------------------------------------------
    let ppf = pp as f64;
    let mm = patches.max(1) as f64;
    // per-patch inter-stage activation hop: one [B, L/M, H, D] tensor,
    // split across the stage's ranks (rank j streams to rank j of the
    // next stage); inter-machine iff the group spans machines.
    let per_rank_patch_bytes = shape.bytes_per_tensor() / mm / stage as f64;
    let hop = if spec.ranks_per_group() > m {
        alpha + per_rank_patch_bytes * wire / cluster.net.inter_bw_per_flow(m_g)
    } else {
        cluster.net.intra_lat + per_rank_patch_bytes / cluster.net.intra_bw
    };
    // the hop overlaps the next patch's compute on the stage; only the
    // excess is exposed, once per patch per stage boundary
    let per_patch_compute = stage_layer / mm;
    let hop_exposed = (hop - per_patch_compute).max(0.0);
    // pipelined block of pp one-layer stages over M patches:
    //   (M + pp − 1) · (stage_layer/M + exposed hop)
    // divided by pp for the per-layer equivalent; the (pp−1)/(pp·M)
    // bubble is the first term's inflation over stage_layer/pp.
    let per_layer =
        stage_layer / ppf * (1.0 + (ppf - 1.0) / mm) + (mm + ppf - 1.0) * hop_exposed / ppf;
    evals * per_layer
}

/// Modeled end-to-end service-time multiplier of serving a whole
/// generation of `workload` under `quality`, relative to `Full` — the
/// factor the scheduler applies to its (memoized, quality-agnostic)
/// service-duration estimate at dispatch time.
///
/// - `Full` is 1.0 by definition.
/// - `Displaced` is [`DISPLACED_TIME_FACTOR`]: the per-step saving from
///   taking the inter all-to-all off the critical path
///   ([`plan_step_cost_quality`]'s α-only inter term plus fp16 wire
///   bytes), averaged over the paper-testbed plan mix.
/// - `FastAttn { keep_ratio }` keeps `keep_ratio` of the attention
///   flops but all of the KV exchange and the non-attention layer work:
///   `0.25 + 0.75·keep_ratio` (attention is ~3/4 of a long-sequence DiT
///   step's time, the regime where the scheduler degrades).
/// - `ReducedSteps` is exact arithmetic: the eval count under
///   distillation over the full eval count
///   ([`Workload::evals_under`]).
pub fn quality_time_factor(workload: &Workload, quality: QualityMode) -> f64 {
    match quality {
        QualityMode::Full => 1.0,
        QualityMode::Displaced => DISPLACED_TIME_FACTOR,
        QualityMode::FastAttn { keep_ratio } => 0.25 + 0.75 * keep_ratio,
        QualityMode::ReducedSteps { .. } => {
            workload.evals_under(quality) as f64 / workload.total_evals().max(1) as f64
        }
    }
}

/// Per-step speedup of displaced patch parallelism over exact serving:
/// the one-step-stale schedule hides the inter-machine byte term behind
/// compute and ships fresh patches at fp16, leaving the exposed α and
/// the full-KV attention — about 15 % of a comm-bound step's time
/// saved on the paper testbed's chosen plans.
pub const DISPLACED_TIME_FACTOR: f64 = 0.85;

/// Predicted fractional per-step improvement of re-carving a pod from
/// plan `from` to plan `to` for a workload of `shape`:
/// `1 − cost(to) / cost(from)` under [`plan_step_cost_patches`].
/// Positive when the move helps (`0.1` = 10 % cheaper per step),
/// negative when it hurts. This is the prediction the hysteresis
/// re-carving policy
/// ([`crate::cluster::recarve::RecarvePolicy::Hysteresis`]) compares
/// against its threshold: using the same closed form as
/// [`choose_spec`] keeps the drain/re-plan decision consistent with the
/// admission-time planner.
pub fn recarve_gain(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    cfg_evals: usize,
    patches: usize,
    from: &ParallelSpec,
    to: &ParallelSpec,
) -> f64 {
    let c_from = plan_step_cost_patches(cluster, algo, shape, from, cfg_evals, patches);
    let c_to = plan_step_cost_patches(cluster, algo, shape, to, cfg_evals, patches);
    if !(c_from.is_finite() && c_from > 0.0) {
        return 0.0;
    }
    1.0 - c_to / c_from
}

/// Predicted fractional per-step improvement of a **group-granular**
/// (partial) re-carve: serving `shape` on the best plan the chooser
/// finds for the pod's `idle_machines` idle machines *now*, instead of
/// serving it stale under the pod's live carve `from`
/// (`1 − cost(best sub-plan on the idle subset) / cost(from on the full
/// pod)`). Positive when splitting helps despite the smaller footprint —
/// the gate [`crate::cluster::recarve::RecarvePolicy::Partial`]'s split
/// decision compares against its threshold, so the drain-free split uses
/// the same closed form as pod-wide admission and re-carving. Unlike
/// [`recarve_gain`] there is no drain term to amortize: the idle subset
/// re-carves immediately, which is exactly why a *smaller* carve can
/// still win while a long request pins the rest of the pod.
pub fn partial_recarve_gain(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    cfg_evals: usize,
    patches: usize,
    idle_machines: usize,
    from: &ParallelSpec,
) -> f64 {
    if idle_machines == 0 || idle_machines > cluster.machines {
        return 0.0;
    }
    let sub = cluster.resized(idle_machines);
    let best = choose_spec_with_patches(&sub, algo, shape, cfg_evals, 1, patches);
    let c_from = plan_step_cost_patches(cluster, algo, shape, from, cfg_evals, patches);
    let c_to = plan_step_cost_patches(&sub, algo, shape, &best, cfg_evals, patches);
    if !(c_from.is_finite() && c_from > 0.0) {
        return 0.0;
    }
    1.0 - c_to / c_from
}

/// Predicted fractional per-step improvement of serving `shape` on a
/// pod whose footprint changes from `from` to `to` (cross-pod
/// re-balancing, [`crate::coordinator::router::Router::rebalance_machine`]):
/// each footprint is scored by the best plan [`choose_spec_with_patches`]
/// finds on it, then compared like [`recarve_gain`] —
/// `1 − cost(to) / cost(from)`, positive when the bigger (or better-
/// shaped) pod helps. This is the prediction
/// [`crate::coordinator::session::RebalancePolicy::Gain`] compares
/// against its threshold, so the fleet-level migration decision uses the
/// same closed form as per-pod admission and re-carving.
pub fn rebalance_gain(
    from: &ClusterSpec,
    to: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    cfg_evals: usize,
    patches: usize,
) -> f64 {
    let best = |c: &ClusterSpec| {
        let spec = choose_spec_with_patches(c, algo, shape, cfg_evals, 1, patches);
        plan_step_cost_patches(c, algo, shape, &spec, cfg_evals, patches)
    };
    let c_from = best(from);
    let c_to = best(to);
    if !(c_from.is_finite() && c_from > 0.0) {
        return 0.0;
    }
    1.0 - c_to / c_from
}

/// All structurally valid hybrid specs for a cluster/head count, each
/// stage's SP degrees set by the paper's gcd placement rule. Covers
/// `cfg_degree ∈ {1, 2}` × every machine-aligned pipeline depth ×
/// replica count.
pub fn enumerate_specs(cluster: &ClusterSpec, heads: usize) -> Vec<ParallelSpec> {
    let total = cluster.total_gpus();
    let mut out = Vec::new();
    for cfg in [1usize, 2] {
        if total % cfg != 0 {
            continue;
        }
        let per_branch = total / cfg;
        for pp in 1..=per_branch {
            if per_branch % pp != 0 {
                continue;
            }
            let per_pipe = per_branch / pp;
            for reps in 1..=per_pipe {
                if per_pipe % reps != 0 {
                    continue;
                }
                let stage = per_pipe / reps;
                let spec = ParallelSpec::with_gcd_placement_pp(cfg, pp, reps, stage, heads);
                if spec.validate(cluster).is_ok() {
                    out.push(spec);
                }
            }
        }
    }
    out
}

/// The total order used to break cost ties: ascending degrees prefer
/// fewer groups / shallower pipelines (larger SP meshes).
fn spec_sort_key(s: &ParallelSpec) -> (usize, usize, usize, usize, usize) {
    (s.cfg_degree, s.pp_degree, s.batch_replicas, s.sp.pu, s.sp.pr)
}

/// [`choose_spec_with_patches`] at the [`DEFAULT_PATCHES`] patch count.
pub fn choose_spec(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    cfg_evals: usize,
    queue_depth: usize,
) -> ParallelSpec {
    choose_spec_with_patches(cluster, algo, shape, cfg_evals, queue_depth, DEFAULT_PATCHES)
}

/// Pick the spec minimizing modeled *service* cost for a request of
/// `shape` when `queue_depth` same-sized requests are waiting: batch
/// replicas beyond the queue depth idle (no work to fill them), so the
/// effective cost is `step latency / min(batch_replicas, queue_depth)`.
/// `queue_depth = 1` therefore optimizes pure latency.
///
/// Deterministic by construction: every candidate is scored, then the
/// whole list is ordered by `(cost, spec key)` before the argmin — the
/// choice can never depend on platform float quirks breaking ties or on
/// container iteration order.
pub fn choose_spec_with_patches(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    cfg_evals: usize,
    queue_depth: usize,
    patches: usize,
) -> ParallelSpec {
    let mut scored: Vec<(f64, ParallelSpec)> = enumerate_specs(cluster, shape.h)
        .into_iter()
        .map(|spec| {
            let useful = spec.batch_replicas.min(queue_depth.max(1)) as f64;
            let cost =
                plan_step_cost_patches(cluster, algo, shape, &spec, cfg_evals, patches)
                    / useful;
            (cost, spec)
        })
        .collect();
    scored.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| spec_sort_key(&a.1).cmp(&spec_sort_key(&b.1)))
    });
    scored
        .into_iter()
        .next()
        .map(|(_, s)| s)
        .unwrap_or_else(|| ParallelSpec::single(cluster, shape.h))
}

/// Patch counts [`choose_patches`] searches over. Powers of two up to
/// 32: beyond that the per-patch transfers on the testbed are pure
/// α-latency and the bubble saving is already < 3 %.
pub const PATCH_CANDIDATES: [usize; 6] = [1, 2, 4, 8, 16, 32];

/// Argmin over the pipeline patch count `M` for one workload shape: the
/// closed form in [`plan_step_cost_patches`] trades the pipeline-fill
/// bubble `(pp−1)/(pp·M)` (shrinks with M) against the exposed part of
/// the per-patch inter-stage hop (grows with M once a patch's compute
/// no longer covers the hop α). For each candidate M the *best spec at
/// that M* is priced — patch count and plan are chosen jointly, exactly
/// like the serving path uses them. Deterministic: ties break toward
/// the smaller M (fewer, larger transfers).
pub fn choose_patches(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    shape: &AttnShape,
    cfg_evals: usize,
) -> usize {
    let mut best: Option<(f64, usize)> = None;
    for &m in &PATCH_CANDIDATES {
        let spec = choose_spec_with_patches(cluster, algo, shape, cfg_evals, 1, m);
        let cost = plan_step_cost_patches(cluster, algo, shape, &spec, cfg_evals, m);
        let better = match best {
            None => true,
            Some((c, _)) => cost < c,
        };
        if better {
            best = Some((cost, m));
        }
    }
    best.map_or(DEFAULT_PATCHES, |(_, m)| m)
}

/// xDiT Parallel-VAE closed form (arxiv 2411.01738): the decode runs
/// patch-parallel across `ranks` sp-only workers, each patch boundary
/// paying one halo-exchange `hop`. `ranks <= 1` reproduces the serial
/// time exactly — the anchor that keeps a staged fleet's total priced
/// work equal to the monolithic fleet's.
pub fn vae_decode_time(serial: f64, ranks: usize, patches: usize, hop: f64) -> f64 {
    if ranks <= 1 {
        return serial;
    }
    serial / ranks as f64 + patches.saturating_sub(1) as f64 * hop
}

/// The carve a stage-class pod runs: the diffusion stage uses the full
/// hybrid chooser (it *is* the paper's plan space), while the encode
/// and decode stages are sp-only — one mesh, no guidance split, no
/// layer pipeline (xDiT decodes patch-parallel over a flat mesh; a
/// prompt encoder has nothing to pipeline) — so enumeration is
/// restricted to `cfg = pp = 1` candidates before the usual
/// deterministic `(cost, key)` argmin.
pub fn stage_spec(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    stage: &StageShape,
    patches: usize,
) -> ParallelSpec {
    if stage.class == StageClass::Diffusion {
        return choose_spec_with_patches(cluster, algo, &stage.shape, stage.cfg_evals, 1, patches);
    }
    let mut scored: Vec<(f64, ParallelSpec)> = enumerate_specs(cluster, stage.shape.h)
        .into_iter()
        .filter(|s| s.cfg_degree == 1 && s.pp_degree == 1)
        .map(|spec| {
            let cost =
                plan_step_cost_patches(cluster, algo, &stage.shape, &spec, stage.cfg_evals, patches);
            (cost, spec)
        })
        .collect();
    scored.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| spec_sort_key(&a.1).cmp(&spec_sort_key(&b.1)))
    });
    scored
        .into_iter()
        .next()
        .map(|(_, s)| s)
        .unwrap_or_else(|| ParallelSpec::single(cluster, stage.shape.h))
}

/// Closed-form service time of one stage of `workload` on a pod of
/// `cluster`: the stage's [`crate::workload::StageShape::time_share`]
/// of the closed-form monolithic request cost, with the VAE stage's
/// patch-parallel speedup ([`vae_decode_time`]) applied on top. This is
/// the pricing [`choose_stage_placement`] sizes stage-class pods with —
/// the same arithmetic, so placement and dispatch agree on where time
/// goes.
pub fn stage_service_time(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    workload: &Workload,
    class: StageClass,
    patches: usize,
) -> f64 {
    let spec =
        choose_spec_with_patches(cluster, algo, &workload.shape, workload.cfg_evals, 1, patches);
    let step = plan_step_cost_patches(cluster, algo, &workload.shape, &spec, workload.cfg_evals, patches);
    // `effective_layers` weights uneven per-layer DiT block costs when
    // the workload declares them ([`Workload::layer_costs`]); uniform
    // workloads reduce to the plain layer count bit-for-bit.
    let mono = step * workload.effective_layers() * workload.steps as f64;
    let stage = &workload.stage_shapes()[class.index()];
    let serial = stage.time_share * mono;
    if class != StageClass::VaeDecode {
        return serial;
    }
    let carve = stage_spec(cluster, algo, stage, patches);
    let ranks = carve.ranks_per_group().max(1);
    // per-patch halo: neighbouring patch rows over NVSwitch
    let hop = cluster.net.intra_lat
        + stage.shape.bytes_per_tensor() / patches.max(1) as f64 / cluster.net.intra_bw;
    vae_decode_time(serial, ranks, patches, hop)
}

/// Size the stage-class pod partition for a fleet of `num_pods` equal
/// pods serving `mix` (workload, weight) traffic: pods are allocated
/// proportionally to each class's aggregate closed-form service time
/// ([`stage_service_time`] × weight), with every class floored at one
/// pod. The encoder is always a single pod — its share is orders of
/// magnitude below the others — and the remainder splits between
/// diffusion and decode by largest share. Returns pods per class in
/// [`StageClass::ALL`] order; requires `num_pods >= 3`.
pub fn choose_stage_placement(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    mix: &[(&Workload, usize)],
    patches: usize,
    num_pods: usize,
) -> [usize; 3] {
    assert!(num_pods >= 3, "a staged fleet needs one pod per stage class");
    let time = |class: StageClass| -> f64 {
        mix.iter()
            .map(|&(w, n)| n as f64 * stage_service_time(cluster, algo, w, class, patches))
            .sum()
    };
    let t_diff = time(StageClass::Diffusion);
    let t_dec = time(StageClass::VaeDecode);
    let rest = num_pods - 1;
    let frac = if t_diff + t_dec > 0.0 { t_diff / (t_diff + t_dec) } else { 0.5 };
    let diff = ((rest as f64 * frac).round() as usize).clamp(1, rest - 1);
    [1, diff, rest - diff]
}

// ---------------------------------------------------------------------------
// Arrival-mix forecasting
// ---------------------------------------------------------------------------

/// A windowed per-workload-class arrival-mix forecaster: observes the
/// request trace as it arrives and predicts what share of near-future
/// traffic each class will be. The scheduler reads the prediction at
/// decision time (via `PolicyCtx::forecast_share`) to act *ahead* of a
/// mix shift — proactive re-carves
/// ([`crate::cluster::recarve::RecarvePolicy::Forecast`]) and
/// cost-gated side-carve absorption — instead of waiting for a
/// hysteresis window to confirm what the trace already announced.
///
/// Object-safe by design (the session stores a `Box<dyn Forecaster>`),
/// with [`EwmaForecaster`] as the default implementation.
pub trait Forecaster {
    /// Record one arrival of workload class `class` at virtual time
    /// `at`. Observations must be fed in non-decreasing time order
    /// (the serving loop's arrival order).
    fn observe(&mut self, class: &'static str, at: f64);

    /// Predicted share of the arrival mix belonging to `class` at
    /// virtual time `at` (in `[0, 1]`; `0.0` before any observation).
    fn share(&self, class: &str, at: f64) -> f64;

    /// Display name of the forecasting scheme.
    fn name(&self) -> &'static str {
        "ewma"
    }
}

/// Continuous-time exponential moving average of per-class arrival
/// rates: each observed arrival adds `1/tau` to its class's rate after
/// decaying every class by `exp(-dt/tau)`, so a class that stops
/// arriving fades with time constant `tau` (the *window*, in virtual
/// seconds) and a class that starts arriving at rate `r` converges to
/// rate `r`. The predicted mix share is the class's rate over the sum
/// — scale-free, so absolute traffic intensity cancels out.
#[derive(Debug, Clone)]
pub struct EwmaForecaster {
    /// Decay time constant (virtual seconds).
    tau: f64,
    /// Per-class decayed arrival rates, keyed by workload name.
    /// BTreeMap for deterministic iteration (reports, debugging).
    rates: std::collections::BTreeMap<&'static str, f64>,
    /// Time of the last observation (rates are decayed to this point).
    last: f64,
}

impl EwmaForecaster {
    /// A forecaster with decay window `window` virtual seconds
    /// (clamped below at a small epsilon so a zero window degrades to
    /// "only the latest arrival counts" rather than dividing by zero).
    pub fn new(window: f64) -> Self {
        Self {
            tau: window.max(1e-9),
            rates: std::collections::BTreeMap::new(),
            last: 0.0,
        }
    }

    /// Decay every class's rate from `self.last` to `at`.
    fn decay_to(&mut self, at: f64) {
        let dt = (at - self.last).max(0.0);
        if dt > 0.0 {
            let f = (-dt / self.tau).exp();
            for rate in self.rates.values_mut() {
                *rate *= f;
            }
        }
        self.last = self.last.max(at);
    }
}

impl Forecaster for EwmaForecaster {
    fn observe(&mut self, class: &'static str, at: f64) {
        self.decay_to(at);
        *self.rates.entry(class).or_insert(0.0) += 1.0 / self.tau;
    }

    fn share(&self, class: &str, at: f64) -> f64 {
        // decay is uniform across classes, so the *share* at any
        // `at >= last` equals the share at `last` — no mutation needed
        let _ = at;
        let total: f64 = self.rates.values().sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.rates.get(class).copied().unwrap_or(0.0) / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn shape() -> AttnShape {
        AttnShape::new(1, 96_000, 24, 64)
    }

    #[test]
    fn single_machine_volumes_are_zero() {
        assert_eq!(v_usp(&shape(), 1, 8, SpDegrees::new(8, 1)), 0.0);
        assert_eq!(v_sfu(&shape(), 1, 8, SpDegrees::new(8, 1)), 0.0);
    }

    #[test]
    fn choose_patches_pins_the_testbed_argmin() {
        // ROADMAP 4b: the bubble (pp−1)/(pp·M) vs per-patch hop argmin
        // on the 4×8 paper testbed. The CFG video picks a pipelined
        // plan, so the patch count matters; the argmin is pinned so a
        // cost-model change that silently shifts it fails loudly.
        let cluster = ClusterSpec::paper_testbed();
        let w = Workload::cfg_video_96k();
        let m = choose_patches(&cluster, SpAlgo::SwiftFusion, &w.shape, w.cfg_evals);
        assert!(PATCH_CANDIDATES.contains(&m));
        let best_cost = {
            let spec =
                choose_spec_with_patches(&cluster, SpAlgo::SwiftFusion, &w.shape, w.cfg_evals, 1, m);
            plan_step_cost_patches(&cluster, SpAlgo::SwiftFusion, &w.shape, &spec, w.cfg_evals, m)
        };
        for &cand in &PATCH_CANDIDATES {
            let spec = choose_spec_with_patches(
                &cluster,
                SpAlgo::SwiftFusion,
                &w.shape,
                w.cfg_evals,
                1,
                cand,
            );
            let cost = plan_step_cost_patches(
                &cluster,
                SpAlgo::SwiftFusion,
                &w.shape,
                &spec,
                w.cfg_evals,
                cand,
            );
            assert!(cost >= best_cost, "M={cand} beats the argmin M={m}");
        }
        assert_eq!(m, 32, "pinned testbed argmin (update only with the cost model)");
    }

    #[test]
    fn stage_pricing_partitions_the_monolithic_cost() {
        let cluster = ClusterSpec::paper_testbed();
        let algo = SpAlgo::SwiftFusion;
        let w = Workload::cfg_video_96k();
        let spec = choose_spec_with_patches(&cluster, algo, &w.shape, w.cfg_evals, 1, 4);
        let mono = plan_step_cost_patches(&cluster, algo, &w.shape, &spec, w.cfg_evals, 4)
            * w.layers as f64
            * w.steps as f64;
        // serial stage times (decode un-sped: ranks=1 anchor) sum to mono
        let serial: f64 = w
            .stage_shapes()
            .iter()
            .map(|s| s.time_share * mono)
            .sum();
        assert!((serial - mono).abs() / mono < 1e-12);
        // the priced decode stage is strictly faster than its serial
        // share (the xDiT patch-parallel carve) but never free
        let dec = stage_service_time(&cluster, algo, &w, StageClass::VaeDecode, 4);
        let dec_serial = w.stage_shapes()[StageClass::VaeDecode.index()].time_share * mono;
        assert!(dec < dec_serial, "{dec} vs serial {dec_serial}");
        assert!(dec > 0.0);
        // encode + diffusion price at exactly their shares
        let enc = stage_service_time(&cluster, algo, &w, StageClass::TextEncode, 4);
        assert!(enc < dec, "the encoder is the cheap stage");
    }

    #[test]
    fn stage_placement_tracks_the_mix() {
        let cluster = ClusterSpec::paper_testbed();
        let algo = SpAlgo::SwiftFusion;
        // few-step workloads make decode a big share → video-heavy mixes
        // grow the VAE class relative to image-heavy ones
        let mut img = Workload::short_image_4k();
        img.layers = 2;
        img.steps = 2;
        let mut vid = Workload::cfg_video_96k();
        vid.layers = 2;
        vid.steps = 2;
        let video_heavy = choose_stage_placement(&cluster, algo, &[(&img, 1), (&vid, 9)], 4, 8);
        let image_heavy = choose_stage_placement(&cluster, algo, &[(&img, 9), (&vid, 1)], 4, 8);
        for p in [video_heavy, image_heavy] {
            assert_eq!(p.iter().sum::<usize>(), 8);
            assert!(p.iter().all(|&n| n >= 1), "{p:?}");
        }
        assert_eq!(video_heavy[0], 1, "the encoder never needs more than one pod");
        assert!(
            video_heavy[2] >= image_heavy[2],
            "video-heavy grows the VAE class: {video_heavy:?} vs {image_heavy:?}"
        );
    }

    #[test]
    fn layer_costs_shift_stage_pricing_and_placement() {
        let cluster = ClusterSpec::paper_testbed();
        let algo = SpAlgo::SwiftFusion;
        // the shrunk few-step video where decode is a big share — the
        // regime where cost-weighting the diffusion depth moves pods
        let mut vid = Workload::cfg_video_96k();
        vid.layers = 2;
        vid.steps = 2;
        // heavy DiT blocks (8x an average block each): the diffusion
        // stage's absolute priced time grows, decode's stays put (its
        // work is per-token, not per-layer)
        let heavy = vid.clone().with_layer_costs(vec![8.0, 8.0]);
        let diff_u = stage_service_time(&cluster, algo, &vid, StageClass::Diffusion, 4);
        let diff_h = stage_service_time(&cluster, algo, &heavy, StageClass::Diffusion, 4);
        assert!(diff_h > diff_u, "{diff_h} !> {diff_u}");
        let dec_u = stage_service_time(&cluster, algo, &vid, StageClass::VaeDecode, 4);
        let dec_h = stage_service_time(&cluster, algo, &heavy, StageClass::VaeDecode, 4);
        assert!(
            (dec_h - dec_u).abs() / dec_u < 1e-12,
            "decode work is layer-independent: {dec_h} vs {dec_u}"
        );
        // and the placement chooser moves pods from decode to diffusion
        let uniform = choose_stage_placement(&cluster, algo, &[(&vid, 8)], 4, 8);
        let weighted = choose_stage_placement(&cluster, algo, &[(&heavy, 8)], 4, 8);
        assert_eq!(weighted.iter().sum::<usize>(), 8);
        assert!(
            weighted[1] > uniform[1],
            "cost-weighted layers grow the diffusion class: {weighted:?} vs {uniform:?}"
        );
        // uniform unit costs are the identity on pricing
        let unit = vid.clone().with_layer_costs(vec![1.0, 1.0]);
        let diff_unit = stage_service_time(&cluster, algo, &unit, StageClass::Diffusion, 4);
        assert_eq!(diff_unit.to_bits(), diff_u.to_bits(), "bit-identical when uniform");
    }

    #[test]
    fn paper_testbed_sfu_below_usp() {
        // N=4, M=8, H=24: USP (P_u=8 intra, P_r=4) vs SFU (gcd rule P_u=8).
        let s = shape();
        let usp = v_usp(&s, 4, 8, SpDegrees::new(8, 4));
        let sfu = v_sfu(&s, 4, 8, SpDegrees::new(8, 4));
        assert!(sfu < usp, "sfu {sfu} < usp {usp}");
        // the ratio drives the paper's ~1.3-1.8x speedups
        assert!(usp / sfu > 1.5, "ratio {}", usp / sfu);
    }

    #[test]
    fn two_machine_parity() {
        // §4.2: at P_u = 2 Ulysses and Ring volumes coincide (BLHD each);
        // SwiftFusion has no advantage (TAS can even lose, Fig. 7 M=2).
        let s = shape();
        let usp = v_usp(&s, 2, 8, SpDegrees::new(8, 2));
        let sfu = v_sfu(&s, 2, 8, SpDegrees::new(8, 2));
        // both are ~BLHD-level; SFU no worse
        assert!(sfu <= usp * 1.01, "sfu {sfu} usp {usp}");
    }

    #[test]
    fn ring_volume_constant_ulysses_shrinks() {
        let s = shape();
        let r4 = v_ring(&s, 4, 8);
        let r8 = v_ring(&s, 8, 8);
        // ring: 2(N-1)/N·BLHD grows (towards 2·BLHD)
        assert!(r8 > r4);
        let u4 = v_ulysses(&s, 4, 8);
        let u8 = v_ulysses(&s, 8, 8);
        // ulysses: 4(N-1)/N²·BLHD shrinks
        assert!(u8 < u4);
    }

    #[test]
    fn lemma_d1_closed_form_nonnegative() {
        for n in 2..=16 {
            for m in 2..=8 {
                for pu in m..=n {
                    let v = lemma_d1_vdiff(n, m, pu);
                    assert!(
                        v >= -1e-9,
                        "lemma violated at N={n} M={m} Pu={pu}: {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_lemma_d1_matches_volume_formulas() {
        // V_diff computed from the Eq. (5)/(7) formulas must equal the
        // closed form, and be >= 0, for the lemma's precondition
        // P_r = N·M/P_u <= N (i.e. P_u >= M) and P_u <= N.
        prop::run(60, |g| {
            let n = g.int(2, 12);
            let m = g.int(2, 6);
            if m > n {
                return;
            }
            // valid meshes only: P_u must divide N·M (else P_r = N·M/P_u
            // is not integral and the closed form doesn't apply)
            let cands: Vec<usize> =
                (m..=n).filter(|pu| (n * m) % pu == 0).collect();
            if cands.is_empty() {
                return;
            }
            let pu = *g.choose(&cands);
            let s = AttnShape::new(1, 4096, 24, 32);
            let unit = s.blhd() as f64 / n as f64;
            let usp = v_usp(&s, n, m, SpDegrees::new(pu, n * m / pu));
            let sfu = v_sfu(&s, n, m, SpDegrees::new(pu, n * m / pu));
            let vdiff_formulas = (usp - sfu) / unit;
            let vdiff_closed = lemma_d1_vdiff(n, m, pu);
            assert!(
                (vdiff_formulas - vdiff_closed).abs() < 1e-6,
                "N={n} M={m} Pu={pu}: {vdiff_formulas} vs {vdiff_closed}"
            );
            assert!(vdiff_closed >= -1e-9, "lemma: N={n} M={m} Pu={pu}");
        });
    }

    #[test]
    fn memory_model_sfu_not_worse_than_usp() {
        let s = shape();
        let usp = activation_bytes(SpAlgo::Usp, &s, 32);
        let sfu = activation_bytes(SpAlgo::SwiftFusion, &s, 32);
        assert!(sfu <= usp * 1.01, "Fig. 7: SFU memory ~ USP memory");
    }

    #[test]
    fn compute_time_scales_inversely_with_ranks() {
        let s = shape();
        let c = ClusterSpec::paper_testbed();
        let t8 = compute_time(&s, &c, 8);
        let t32 = compute_time(&s, &c, 32);
        assert!(t32 < t8 / 3.0);
    }

    #[test]
    fn enumerate_specs_are_valid_and_cover_cfg_modes() {
        let c = ClusterSpec::paper_testbed();
        let specs = enumerate_specs(&c, 24);
        assert!(!specs.is_empty());
        for s in &specs {
            assert!(s.validate(&c).is_ok(), "{s:?}");
        }
        assert!(specs.iter().any(|s| s.cfg_degree == 1));
        assert!(specs.iter().any(|s| s.cfg_degree == 2));
        assert!(specs.iter().any(|s| s.batch_replicas > 1));
        // the 3D plan space: pipelined candidates are enumerated too,
        // including composed cfg x pp x sp plans
        assert!(specs.iter().any(|s| s.pp_degree > 1));
        assert!(specs.iter().any(|s| s.cfg_degree == 2 && s.pp_degree == 2));
    }

    #[test]
    fn pipeline_chosen_for_long_sequence_multi_machine() {
        // CFG video on the 4x8 testbed: a pipelined plan keeps each
        // stage's SP inside one machine (zero inter-machine all-to-all)
        // and pays only the per-patch activation hops + bubble, so the
        // model must both rank it above the best non-pipelined plan and
        // have the chooser pick it.
        let c = ClusterSpec::paper_testbed();
        let s = shape(); // 96k tokens, 24 heads
        let pp_plan = ParallelSpec::with_gcd_placement_pp(2, 2, 1, 8, 24);
        let sp_plan = ParallelSpec::with_gcd_placement(2, 1, 16, 24);
        let t_pp = plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &pp_plan, 2);
        let t_sp = plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &sp_plan, 2);
        assert!(t_pp < t_sp, "pp2 {t_pp} must beat sp-only {t_sp}");
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &s, 2, 1);
        assert!(picked.pp_degree > 1, "chooser prefers a pipelined plan: {picked:?}");
        assert_eq!(picked.cfg_degree, 2, "CFG parallelism survives: {picked:?}");
    }

    #[test]
    fn comm_opt_knobs_reach_the_closed_form_and_flip_the_chooser() {
        // The comm-layer knobs must be visible to the planner, not just
        // the executable schedules. Three facts pin the wiring:
        let c = ClusterSpec::paper_testbed();
        let s = shape(); // 96k tokens, 24 heads
        // a 16-rank group spans two machines -> pays the inter all-to-all
        let inter_plan = ParallelSpec::with_gcd_placement(2, 1, 16, 24);
        // an 8-rank group fits one machine -> zero inter traffic
        let intra_plan = ParallelSpec::new(2, 2, SpDegrees::new(8, 1));
        let mut half = c.clone();
        half.net.inter_compress = 0.5;

        // (1) compression strictly cheapens inter-bearing plans and
        // leaves fully-intra plans *bit-identical* (off-path safety).
        let base = plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &inter_plan, 2);
        let compressed = plan_step_cost(&half, SpAlgo::SwiftFusion, &s, &inter_plan, 2);
        assert!(compressed < base, "compressed {compressed} vs {base}");
        assert_eq!(
            plan_step_cost(&half, SpAlgo::SwiftFusion, &s, &intra_plan, 2),
            plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &intra_plan, 2),
            "intra-only plans must not see the inter knob"
        );

        // (2) CFG fusion saves exactly the halved per-transfer alpha for
        // a fusible pair (cfg=2, machine-aligned group), once per eval.
        let mut fuse = c.clone();
        fuse.net.cfg_fuse = true;
        let fused = plan_step_cost(&fuse, SpAlgo::SwiftFusion, &s, &inter_plan, 2);
        let saved = base - fused;
        assert!(
            (saved - 0.5 * c.net.inter_lat).abs() < 1e-9,
            "fusion must halve alpha: saved {saved}"
        );

        // (3) the chooser flips: a 24k CFG video at 2 patches is served
        // unpipelined at full precision (the inter-machine activation
        // hop is too expensive), but 2x compression makes the deeper
        // cfg2 x pp2 pipeline the argmin. Margins are ~15-30% in the
        // closed form, so this pin is robust to small model changes.
        let mid = AttnShape::new(1, 24_000, 24, 64);
        let plain = choose_spec_with_patches(&c, SpAlgo::SwiftFusion, &mid, 2, 1, 2);
        let comp = choose_spec_with_patches(&half, SpAlgo::SwiftFusion, &mid, 2, 1, 2);
        assert_eq!(plain.label(), "cfg2 x pp1 x rep2 x U8R1", "{plain:?}");
        assert_eq!(comp.label(), "cfg2 x pp2 x rep1 x U8R1", "{comp:?}");
    }

    #[test]
    fn quality_pricing_reaches_the_closed_form() {
        use crate::workload::Workload;
        let c = ClusterSpec::paper_testbed();
        let s = shape(); // 96k tokens, 24 heads
        // a 16-rank group spans two machines -> pays the inter all-to-all
        let inter_plan = ParallelSpec::with_gcd_placement(2, 1, 16, 24);
        // an 8-rank group fits one machine -> zero inter traffic
        let intra_plan = ParallelSpec::new(2, 2, SpDegrees::new(8, 1));
        let cost = |spec: &ParallelSpec, q: QualityMode| {
            plan_step_cost_quality(&c, SpAlgo::SwiftFusion, &s, spec, 2, DEFAULT_PATCHES, q)
        };

        // (1) Full is bit-identical to the unpriced form — on every
        // candidate the chooser enumerates, not just hand-picked plans.
        for spec in enumerate_specs(&c, s.h) {
            assert_eq!(
                cost(&spec, QualityMode::Full),
                plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &spec, 2),
                "Full must not move {spec:?}"
            );
        }

        // (2) Displaced hides the inter byte term: strictly cheaper on an
        // inter-bearing plan, bit-identical on a fully-intra plan (no
        // inter term to hide).
        let full = cost(&inter_plan, QualityMode::Full);
        let disp = cost(&inter_plan, QualityMode::Displaced);
        assert!(disp < full, "displaced {disp} vs full {full}");
        assert_eq!(
            cost(&intra_plan, QualityMode::Displaced),
            cost(&intra_plan, QualityMode::Full),
            "no inter all-to-all to take off the critical path"
        );
        // exactly the byte term is saved (cfg2 runs its one eval's inter
        // all-to-all on the n_g=2, m_g=8 stage sub-geometry)
        let byte_term = full - disp;
        let elems = inter_volume(SpAlgo::SwiftFusion, &s, 2, 8, inter_plan.sp);
        let expect = elems * 4.0 / c.net.inter_bw_per_flow(8);
        assert!(
            (byte_term - expect).abs() < 1e-9 * expect,
            "displaced must save the byte term: {byte_term} vs {expect}"
        );

        // (3) FastAttn scales the compute term by keep_ratio: cheaper
        // everywhere, and on an intra-only plan the saving is exactly
        // half the compute time at keep_ratio = 0.5.
        let fa = QualityMode::FastAttn { keep_ratio: 0.5 };
        assert!(cost(&inter_plan, fa) < cost(&inter_plan, QualityMode::Full));
        // cfg2 runs one eval per group, so the saving is keep_ratio of
        // one eval's compute
        let intra_saved = cost(&intra_plan, QualityMode::Full) - cost(&intra_plan, fa);
        let comp = compute_time(&s, &c, intra_plan.ranks_per_stage());
        assert!(
            (intra_saved - 0.5 * comp).abs() < 1e-9 * comp,
            "fastattn must save keep_ratio of compute per eval: {intra_saved} vs {comp}"
        );

        // (4) ReducedSteps leaves the per-layer cost alone (its saving is
        // fewer evals, priced by quality_time_factor below).
        assert_eq!(
            cost(&inter_plan, QualityMode::ReducedSteps { factor: 2 }),
            cost(&inter_plan, QualityMode::Full)
        );

        // (5) the end-to-end factors: exact arithmetic for step
        // reduction, documented constants for the per-step modes, and
        // the admission ladder strictly cheapens for a CFG workload.
        let video = Workload::cfg_video_96k();
        let flux = Workload::flux_3072();
        assert_eq!(quality_time_factor(&video, QualityMode::Full), 1.0);
        assert_eq!(
            quality_time_factor(&video, QualityMode::Displaced),
            DISPLACED_TIME_FACTOR
        );
        assert_eq!(quality_time_factor(&video, fa), 0.625);
        assert_eq!(
            quality_time_factor(&video, QualityMode::ReducedSteps { factor: 2 }),
            0.25, // 25 evals of 100: halved steps AND folded uncond branch
        );
        assert_eq!(
            quality_time_factor(&flux, QualityMode::ReducedSteps { factor: 2 }),
            0.5, // already distilled: only the step halving remains
        );
        let ladder_factors: Vec<f64> = QualityMode::ladder()
            .iter()
            .map(|&q| quality_time_factor(&video, q))
            .collect();
        assert!(
            ladder_factors.windows(2).all(|w| w[0] > w[1]),
            "ladder must strictly cheapen: {ladder_factors:?}"
        );
        // scores strictly degrade down the ladder, from exactly 1.0
        let scores: Vec<f64> = QualityMode::ladder().iter().map(|q| q.score()).collect();
        assert_eq!(scores[0], 1.0);
        assert!(scores.windows(2).all(|w| w[0] > w[1]), "{scores:?}");
    }

    #[test]
    fn short_sequences_do_not_pipeline() {
        // Small requests are latency-bound on the per-patch hops: the
        // exposed transfers outweigh the saved all-to-all.
        let c = ClusterSpec::paper_testbed();
        let small = AttnShape::new(1, 4096, 24, 64);
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &small, 1, 1);
        assert_eq!(picked.pp_degree, 1, "{picked:?}");
    }

    #[test]
    fn choose_spec_is_deterministic_and_minimal() {
        // Regression for the (cost, key) ordering: the returned spec must
        // be the argmin of the scored candidate list under the total
        // order, recomputed independently here — and identical across
        // repeated calls.
        let c = ClusterSpec::paper_testbed();
        for (wshape, evals, queue) in [
            (shape(), 2, 1),
            (shape(), 1, 1),
            (AttnShape::new(1, 4096, 24, 64), 1, 32),
            (AttnShape::new(1, 163_200, 24, 64), 2, 4),
        ] {
            let picked = choose_spec(&c, SpAlgo::SwiftFusion, &wshape, evals, queue);
            let again = choose_spec(&c, SpAlgo::SwiftFusion, &wshape, evals, queue);
            assert_eq!(picked, again, "repeated calls must agree");
            let cost_of = |s: &ParallelSpec| {
                let useful = s.batch_replicas.min(queue) as f64;
                plan_step_cost(&c, SpAlgo::SwiftFusion, &wshape, s, evals) / useful
            };
            let picked_cost = cost_of(&picked);
            for cand in enumerate_specs(&c, wshape.h) {
                let cost = cost_of(&cand);
                assert!(
                    picked_cost < cost
                        || (picked_cost == cost
                            && spec_sort_key(&picked) <= spec_sort_key(&cand)),
                    "{picked:?} (cost {picked_cost}) not minimal vs {cand:?} (cost {cost})"
                );
            }
        }
    }

    #[test]
    fn recarve_gain_is_signed_and_consistent_with_the_chooser() {
        // Moving from a stale short-image carve to the plan the chooser
        // picks for a long CFG video must predict a substantial win; the
        // reverse move must predict a loss of the matching magnitude
        // (1 - 1/(1 - g)), and a no-op move predicts zero.
        let c = ClusterSpec::paper_testbed();
        let video = shape(); // 96k tokens, CFG
        let small = AttnShape::new(1, 4096, 24, 64);
        let video_plan = choose_spec(&c, SpAlgo::SwiftFusion, &video, 2, 1);
        let short_plan = choose_spec(&c, SpAlgo::SwiftFusion, &small, 1, 1);
        assert_ne!(video_plan, short_plan);
        let g = recarve_gain(
            &c,
            SpAlgo::SwiftFusion,
            &video,
            2,
            DEFAULT_PATCHES,
            &short_plan,
            &video_plan,
        );
        assert!(g > 0.2, "stale short carve must predict a large gain: {g}");
        let back = recarve_gain(
            &c,
            SpAlgo::SwiftFusion,
            &video,
            2,
            DEFAULT_PATCHES,
            &video_plan,
            &short_plan,
        );
        assert!(back < 0.0, "reverse move must predict a loss: {back}");
        let noop = recarve_gain(
            &c,
            SpAlgo::SwiftFusion,
            &video,
            2,
            DEFAULT_PATCHES,
            &video_plan,
            &video_plan,
        );
        assert!(noop.abs() < 1e-12);
        // by argmin-ness of the chooser, no move away from the chosen
        // plan can predict a positive gain
        for cand in enumerate_specs(&c, video.h) {
            let g = recarve_gain(
                &c,
                SpAlgo::SwiftFusion,
                &video,
                2,
                DEFAULT_PATCHES,
                &video_plan,
                &cand,
            );
            assert!(g <= 1e-12, "{cand:?} beats the chosen plan by {g}");
        }
    }

    #[test]
    fn partial_recarve_gain_predicts_the_split_trade() {
        // The motivating split: a long CFG video arrives while the pod
        // is pinned to a short-image carve (one-machine rep groups). The
        // 3-machine idle subset's best video plan must predict a large
        // win over serving the video stale; a 1-machine subset is weaker
        // but still beats the stale one-machine group (same footprint,
        // CFG-aware carve); the degenerate cases return 0.
        let c = ClusterSpec::paper_testbed();
        let video = shape(); // 96k tokens, 24 heads, CFG
        let small = AttnShape::new(1, 4096, 24, 64);
        let short_plan = choose_spec(&c, SpAlgo::SwiftFusion, &small, 1, 1);
        let g3 = partial_recarve_gain(
            &c,
            SpAlgo::SwiftFusion,
            &video,
            2,
            DEFAULT_PATCHES,
            3,
            &short_plan,
        );
        assert!(g3 > 0.2, "3-machine split must predict a substantial win: {g3}");
        let g1 = partial_recarve_gain(
            &c,
            SpAlgo::SwiftFusion,
            &video,
            2,
            DEFAULT_PATCHES,
            1,
            &short_plan,
        );
        assert!(g1 < g3, "fewer idle machines cannot predict more gain: {g1} vs {g3}");
        // moving off the *preferred* full-pod plan onto any subset is a
        // predicted loss — the split gate cannot fire on a happy pod
        let video_plan = choose_spec(&c, SpAlgo::SwiftFusion, &video, 2, 1);
        let off = partial_recarve_gain(
            &c,
            SpAlgo::SwiftFusion,
            &video,
            2,
            DEFAULT_PATCHES,
            3,
            &video_plan,
        );
        assert!(off < 0.0, "leaving the preferred plan must predict a loss: {off}");
        // degenerate subsets
        assert_eq!(
            partial_recarve_gain(
                &c,
                SpAlgo::SwiftFusion,
                &video,
                2,
                DEFAULT_PATCHES,
                0,
                &short_plan
            ),
            0.0
        );
        assert_eq!(
            partial_recarve_gain(
                &c,
                SpAlgo::SwiftFusion,
                &video,
                2,
                DEFAULT_PATCHES,
                9,
                &short_plan
            ),
            0.0
        );
    }

    #[test]
    fn rebalance_gain_predicts_when_a_machine_helps() {
        // Growing a 2-machine pod to 3 (8-GPU machines) unlocks a carve
        // the smaller pod cannot hold for the long CFG video: one-machine
        // pipeline stages over all three machines (cfg-combined pp3 x
        // sp8) — a ~25 % predicted win at 16 patches, where the pipeline
        // fill is well amortized. The short image is already served by a
        // one-machine carve that exists on both footprints, so the extra
        // machine buys it nothing.
        let from = ClusterSpec::new(2, 8);
        let to = ClusterSpec::new(3, 8);
        let patches = 16;
        let video = shape(); // 96k tokens, 24 heads, CFG
        let g = rebalance_gain(&from, &to, SpAlgo::SwiftFusion, &video, 2, patches);
        assert!(g > 0.1, "video gains from the third machine: {g}");
        let back = rebalance_gain(&to, &from, SpAlgo::SwiftFusion, &video, 2, patches);
        assert!(back < 0.0, "shrinking the pod must predict a loss: {back}");
        let small = AttnShape::new(1, 4096, 24, 64);
        let gs = rebalance_gain(&from, &to, SpAlgo::SwiftFusion, &small, 1, patches);
        assert!(
            gs.abs() < 0.05,
            "short images already fit a one-machine carve: {gs}"
        );
        let noop = rebalance_gain(&from, &from, SpAlgo::SwiftFusion, &video, 2, patches);
        assert!(noop.abs() < 1e-12, "{noop}");
        // at the default coarse patch count the pipeline-fill bubble
        // ((pp-1)/M of the stage layer) eats the whole win — the knob
        // matters, which is why ServeConfig carries it
        let coarse =
            rebalance_gain(&from, &to, SpAlgo::SwiftFusion, &video, 2, DEFAULT_PATCHES);
        assert!(coarse < g, "coarse patches amortize the fill worse: {coarse} vs {g}");
    }

    #[test]
    fn cfg_parallel_wins_for_guided_long_sequences() {
        // CFG workloads (2 evals) on comm-bound shapes: running branches
        // concurrently on halves must model cheaper than sequentially on
        // the full mesh.
        let c = ClusterSpec::paper_testbed();
        let s = shape();
        let full = ParallelSpec::new(1, 1, SpDegrees::new(8, 4));
        let halves = ParallelSpec::new(2, 1, SpDegrees::new(8, 2));
        let t_full = plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &full, 2);
        let t_half = plan_step_cost(&c, SpAlgo::SwiftFusion, &s, &halves, 2);
        assert!(t_half < t_full, "cfg2 {t_half} vs cfg1 {t_full}");
        // ...and the auto-chooser finds a CFG-parallel plan
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &s, 2, 1);
        assert_eq!(picked.cfg_degree, 2, "{picked:?}");
    }

    #[test]
    fn non_guided_workloads_keep_the_full_mesh() {
        // With a single eval there is no branch to parallelize: halving
        // the mesh only halves the compute power.
        let c = ClusterSpec::paper_testbed();
        let s = shape();
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &s, 1, 1);
        assert_eq!(picked.cfg_degree, 1, "{picked:?}");
        assert_eq!(picked.batch_replicas, 1, "{picked:?}");
    }

    #[test]
    fn deep_queues_favor_batch_replicas() {
        // Short sequences under heavy load: replicating beats sharding
        // one small request over 32 GPUs.
        let c = ClusterSpec::paper_testbed();
        let small = AttnShape::new(1, 4096, 24, 64);
        let picked = choose_spec(&c, SpAlgo::SwiftFusion, &small, 1, 32);
        assert!(
            picked.batch_replicas > 1,
            "deep queue should replicate: {picked:?}"
        );
        // and a short request should never be sharded across machines —
        // the inter-machine volume dwarfs its compute
        let shallow = choose_spec(&c, SpAlgo::SwiftFusion, &small, 1, 1);
        assert!(
            shallow.ranks_per_group() <= c.gpus_per_machine,
            "small request stays on one machine: {shallow:?}"
        );
    }

    // ---- arrival-mix forecasting ------------------------------------------

    #[test]
    fn ewma_empty_trace_predicts_nothing() {
        let f = EwmaForecaster::new(4.0);
        assert_eq!(f.share("short_image_4k", 0.0), 0.0);
        assert_eq!(f.share("short_image_4k", 100.0), 0.0);
    }

    #[test]
    fn ewma_step_response_tracks_a_phase_shift() {
        // one arrival/second of shorts, then the trace flips to videos:
        // the video share must cross dominance within ~a window of the
        // shift and keep climbing toward 1
        let mut f = EwmaForecaster::new(4.0);
        for t in 0..16 {
            f.observe("short_image_4k", t as f64);
        }
        assert!(
            f.share("short_image_4k", 15.0) > 0.99,
            "sustained single-class traffic saturates its share"
        );
        assert_eq!(f.share("cfg_video_96k", 15.0), 0.0);
        let mut crossed_at = None;
        for t in 16..40 {
            f.observe("cfg_video_96k", t as f64);
            let s = f.share("cfg_video_96k", t as f64);
            if crossed_at.is_none() && s >= 0.5 {
                crossed_at = Some(t);
            }
        }
        let crossed = crossed_at.expect("video share must reach dominance");
        assert!(
            (16..=16 + 5).contains(&crossed),
            "dominance within ~one window of the shift, got t={crossed}"
        );
        let late = f.share("cfg_video_96k", 39.0);
        assert!(late > 0.95, "old class fades to noise: {late}");
        // shares always partition the mix
        let sum = f.share("cfg_video_96k", 39.0) + f.share("short_image_4k", 39.0);
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ewma_window_sets_the_reaction_speed() {
        // the shorter the window, the sooner a phase shift dominates
        let cross = |window: f64| -> usize {
            let mut f = EwmaForecaster::new(window);
            for t in 0..32 {
                f.observe("short_image_4k", t as f64);
            }
            for t in 32..200 {
                f.observe("cfg_video_96k", t as f64);
                if f.share("cfg_video_96k", t as f64) >= 0.5 {
                    return t;
                }
            }
            panic!("video never dominated under window {window}");
        };
        let fast = cross(2.0);
        let slow = cross(16.0);
        assert!(
            fast < slow,
            "smaller window reacts sooner: {fast} !< {slow}"
        );
    }

    #[test]
    fn forecaster_trait_is_object_safe_and_named() {
        let mut f: Box<dyn Forecaster> = Box::new(EwmaForecaster::new(4.0));
        f.observe("flux_3072", 0.0);
        assert!(f.share("flux_3072", 0.0) > 0.99);
        assert_eq!(f.name(), "ewma");
    }
}
