//! # SwiftFusion — scalable sequence parallelism for distributed DiT inference
//!
//! Rust + JAX + Pallas reproduction of *"SwiftFusion: Scalable Sequence
//! Parallelism for Distributed Inference of Diffusion Transformers on GPUs"*
//! (ACM CAIS '26). Three-layer architecture:
//!
//! * **L1** — Pallas flash-attention kernel with softmax-state carry
//!   (`python/compile/kernels/`), the paper's Algorithm-2 analog, AOT-lowered
//!   to HLO text.
//! * **L2** — JAX DiT model split into pre-/post-attention stages
//!   (`python/compile/model.py`), lowered per validation config.
//! * **L3** — this crate: the distributed serving engine. It loads the AOT
//!   artifacts via PJRT ([`runtime`]), runs the paper's sequence-parallel
//!   attention algorithms ([`sp`]) over a simulated multi-machine GPU
//!   cluster ([`cluster`], [`comm`]), and serves DiT sampling requests
//!   through a router/batcher/scheduler ([`coordinator`]).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! engine is a self-contained binary.
//!
//! ## Hybrid parallelism: the CFG×PP×SP planner
//!
//! The paper scales one attention pass across one mesh. The serving
//! engine composes parallelism dimensions on top of that via
//! [`config::ParallelSpec`] / [`cluster::plan::ParallelPlan`] — a 3D
//! plan space of guidance branches × pipeline stages × SP meshes:
//!
//! ```text
//!             ClusterSpec (N machines × M GPUs)
//!                          │   ▲ per-pod plan *epochs*: drain → re-carve
//!                          │   │ (cluster::recarve, RecarvePolicy)
//!            ParallelPlan::build(spec, algo)           spec = {cfg_degree,
//!                          │                                   pp_degree,
//!          ┌───────────────┼────────────────┐                  batch_replicas,
//!          ▼               ▼                ▼                  sp: P_u × P_r}
//!    group 0 (cond)   group 1 (cond,    group k (uncond)   cfg_degree × batch_replicas
//!    [base, base+G)    replica 1) …      …                  contiguous, machine-aligned
//!          │               │                │               carves; G = pp·P_u·P_r ranks
//!     ┌────┴─────┐                                          each group split into
//!     ▼          ▼                                          pp_degree contiguous stages
//!  stage 0 …  stage pp-1                                    (Mesh2D::carved per stage);
//!  Mesh2D     Mesh2D       …                …               patches stream stage-to-
//!     │          │                                          stage with one-step-stale
//!     any SpAlgo inside each stage                          off-stage KV
//!    (ring/ulysses/torus/swiftfusion …)                     (sp::pipefusion)
//!          │               │                │
//!          └───────────────┴───────┬────────┘
//!                                  ▼
//!               guidance combine  ε = ε_u + s·(ε_c − ε_u)
//!                        (sp::hybrid)
//! ```
//!
//! Inside each carve the paper's §4.2 placement rules apply unchanged —
//! [`config::SpDegrees::swiftfusion_default`]'s gcd rule just sees the
//! stage as its "cluster" (P_u = gcd(stage, H)), and the torus/TAS
//! machine geometry is derived from the carve's actual machine
//! footprint. With `pp_degree > 1`, DiT layers are partitioned across a
//! group's stages and the latent sequence streams between them as
//! patches over the one-sided comm layer, with off-stage KV served from
//! the previous diffusion step's activations — PipeFusion's displaced
//! patch pipeline ([`sp::pipefusion`]; synchronous oracle-exact warm-up,
//! documented stale-KV tolerance afterwards). The [`analysis`] cost
//! model ([`analysis::choose_spec`]) trades SP degree against CFG-branch
//! groups, pipeline depth (bubble ≈ (pp−1)/(pp·patches), per-patch
//! inter-stage α–β hops overlapped with compute), and batch replicas per
//! request size; the [`coordinator`] resolves a plan per workload
//! (`--plan auto`) or runs a fixed one
//! (`--cfg-degree`/`--pp-degree`/`--patches`/`--batch-replicas`),
//! rejecting requests a plan cannot serve with typed, actionable errors
//! and reporting a per-plan request histogram from the serving output.
//!
//! Serving itself is an **event-driven scheduler**
//! ([`coordinator::session::ServeSession`]): a typed
//! [`coordinator::session::ServeConfig`] (batch policy, plan policy,
//! re-carving, dispatch, patches — one reproducible value, printed as
//! one `serve: …` line) drives arrival → batch-close → dispatch →
//! recarve-commit → completion events over the virtual clock. Cost and
//! planning are split traits ([`coordinator::CostModel`] /
//! [`coordinator::Planner`], composed back as
//! [`coordinator::ServiceModel`] by a blanket impl), dispatch is a
//! pluggable [`coordinator::session::DispatchPolicy`] (least-loaded
//! default, plan-aware earliest-finish), and the scheduler's first two
//! new clients are **replica co-batching** (`--co-batch`: a closed
//! batch scatters across its carve's batch-replica groups) and
//! **cross-pod re-balancing** (`--rebalance gain`: a fleet-level event
//! migrating an idle machine between pods when the workload mix
//! shifts, [`analysis::rebalance_gain`]-gated). The legacy `serve()`
//! entry point remains as a bit-for-bit shim over the session.
//!
//! A carve is no longer frozen for a pod's lifetime: serving is
//! *epoch-aware*. Each pod models its life as a sequence of plan epochs
//! ([`cluster::recarve`]) — when traffic shifts (short image bursts
//! giving way to long CFG video), the pod's
//! [`cluster::recarve::RecarvePolicy`] (`--recarve
//! never|on-idle|hysteresis|partial`, the gated policies driven by
//! [`analysis::recarve_gain`] over `--recarve-threshold`/`-window`) may
//! drain its in-flight groups, pay a modeled re-setup cost, and rebuild
//! the carved sub-meshes for the new plan. The drain barrier is
//! **group-granular** under `--recarve partial`: a busy pod *splits*
//! instead of draining — the machines carrying in-flight work keep
//! serving under the narrowed old carve while the idle machines
//! re-carve immediately ([`cluster::plan::ParallelPlan::build_subset`],
//! [`analysis::partial_recarve_gain`]-gated), the pod running two carve
//! generations concurrently until a lull re-unifies it; with
//! `--co-batch`, shards of one scattered batch may even span the
//! re-carve boundary. No request ever spans two carves, numerics stay
//! oracle-exact across both pod-wide and partial boundaries
//! (`rust/tests/sp_property.rs`), and the serving report carries the
//! epoch log, drain/setup totals, split/merge counts, and a per-carve
//! plan histogram. Epochs extend to *fleet* scope under cross-pod
//! re-balancing: migrating a machine resizes two pods at once, both
//! re-admitting footprint-sized carves behind the migration barrier.
//!
//! Above the per-pod plan space sits the **stage pipeline**
//! ([`coordinator::stages`], `--stages`): a request decomposes into its
//! linear stage DAG — text-encode → diffusion → VAE decode
//! ([`workload::StageClass`], [`workload::Workload::stage_shapes`]) —
//! and each stage class owns its own pods and carves
//! ([`coordinator::stages::StagePlacement`]; diffusion keeps the full
//! hybrid chooser, encode/decode run sp-only
//! [`analysis::stage_spec`] carves, the decode priced patch-parallel by
//! [`analysis::vae_decode_time`]). Requests flow between classes
//! through bounded inter-stage queues in the same deterministic
//! event order, so request *n*'s DiT steps overlap request *n−1*'s
//! decode, and `--rebalance gain` arbitrates machines *between stage
//! classes* under drifting load. `--patches auto`
//! ([`analysis::choose_patches`]) completes the picture by choosing the
//! pipeline patch count per workload with the same closed form.
//!
//! Numeric validation of all of this is hermetic: `ExecMode::HostNumeric`
//! backs the tile contract with in-process Algorithm-2 kernels
//! ([`sp::tiles::host`]), so `rust/tests/sp_property.rs` proves every
//! `SpAlgo` — including group-scoped runs on carved sub-meshes — equal to
//! the single-device guided-sampling oracle without PJRT or artifacts.
//!
//! ## Hardware substitution
//!
//! The paper evaluates on 4×8 A100s with NVSwitch + EFA. This environment
//! has neither, so the GPU cluster is *simulated*: every rank is a thread
//! exchanging **real tensors** (numerics are exact and validated against
//! the single-device oracle), while elapsed time is tracked by a calibrated
//! α–β network/compute model ([`comm`], [`cluster::clock`], [`analysis`]).
//! See DESIGN.md §2 for the substitution table and why figure *shapes*
//! survive, and `rust/ARCHITECTURE.md` for the paper-section → module map,
//! the 3D plan-space walkthrough, and the ExecMode matrix.

// Kernel-plumbing functions (ring/torus stages, tile ops) thread rank
// context + geometry + buffers + schedule knobs through flat argument
// lists on purpose — bundling them into structs would only obscure the
// correspondence with the paper's Algorithm 1/2 pseudocode.
#![allow(clippy::too_many_arguments)]

pub mod analysis;
pub mod bench;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod model;
pub mod runtime;
pub mod sp;
pub mod tensor;
pub mod util;
pub mod workload;

pub use config::ClusterSpec;
pub use tensor::Tensor;
