//! # SwiftFusion — scalable sequence parallelism for distributed DiT inference
//!
//! Rust + JAX + Pallas reproduction of *"SwiftFusion: Scalable Sequence
//! Parallelism for Distributed Inference of Diffusion Transformers on GPUs"*
//! (ACM CAIS '26). Three-layer architecture:
//!
//! * **L1** — Pallas flash-attention kernel with softmax-state carry
//!   (`python/compile/kernels/`), the paper's Algorithm-2 analog, AOT-lowered
//!   to HLO text.
//! * **L2** — JAX DiT model split into pre-/post-attention stages
//!   (`python/compile/model.py`), lowered per validation config.
//! * **L3** — this crate: the distributed serving engine. It loads the AOT
//!   artifacts via PJRT ([`runtime`]), runs the paper's sequence-parallel
//!   attention algorithms ([`sp`]) over a simulated multi-machine GPU
//!   cluster ([`cluster`], [`comm`]), and serves DiT sampling requests
//!   through a router/batcher/scheduler ([`coordinator`]).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! engine is a self-contained binary.
//!
//! ## Hardware substitution
//!
//! The paper evaluates on 4×8 A100s with NVSwitch + EFA. This environment
//! has neither, so the GPU cluster is *simulated*: every rank is a thread
//! exchanging **real tensors** (numerics are exact and validated against
//! the single-device oracle), while elapsed time is tracked by a calibrated
//! α–β network/compute model ([`cluster::netsim`], [`analysis`]). See
//! DESIGN.md §2 for the substitution table and why figure *shapes* survive.

pub mod analysis;
pub mod bench;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod model;
pub mod runtime;
pub mod sp;
pub mod tensor;
pub mod util;
pub mod workload;

pub use config::ClusterSpec;
pub use tensor::Tensor;
