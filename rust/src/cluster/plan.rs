//! Hybrid parallel plans: carve a cluster into CFG-branch / batch-replica
//! groups, each split into pipeline stages running group-scoped 2D SP
//! meshes — the 3D `cfg × pp × sp` plan space.
//!
//! The paper scales a *single* attention pass across one mesh; a serving
//! engine composes parallelism dimensions. A [`ParallelPlan`] partitions
//! the cluster's ranks into `cfg_degree × batch_replicas` contiguous,
//! machine-aligned groups, carves each group into `pp_degree` contiguous
//! pipeline *stages*, and gives every stage a carved [`Mesh2D`]
//! communicator, so any [`crate::sp::SpAlgo`] runs unchanged *inside* its
//! stage — collectives (rings, all-to-alls, barriers) are built from the
//! mesh's rank set and therefore never cross a partition boundary.
//!
//! With `cfg_degree == 2`, the conditional and unconditional guidance
//! branches of classifier-free-guidance sampling run concurrently on the
//! two halves (xDiT's CFG parallelism); their outputs are merged by the
//! guidance combine step (`crate::sp::hybrid`). With `pp_degree > 1`,
//! DiT layers are partitioned across the group's stages and the latent
//! sequence streams between them as patches — PipeFusion's displaced
//! patch pipeline (`crate::sp::pipefusion`). `batch_replicas` adds plain
//! data parallelism over requests beyond that.

use crate::cluster::Mesh2D;
use crate::config::{ClusterSpec, ParallelSpec, ParallelSpecError};
use crate::sp::SpAlgo;

/// Which guidance branch(es) a group computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchRole {
    /// `cfg_degree == 1`: the group runs both branches (sequentially).
    Both,
    /// The conditional (prompted) branch.
    Conditional,
    /// The unconditional (null-prompt) branch.
    Unconditional,
}

/// One carved replica group: a contiguous rank range split into
/// `pp_degree` pipeline stages, each a private SP sub-mesh.
#[derive(Debug, Clone)]
pub struct ParallelGroup {
    /// Group index in `[0, cfg_degree · batch_replicas)`, branch-major.
    pub index: usize,
    pub role: BranchRole,
    /// Batch-replica index within the branch.
    pub replica: usize,
    /// One carved SP sub-mesh per pipeline stage, in stage order.
    /// Length is the spec's `pp_degree`.
    pub stages: Vec<Mesh2D>,
}

impl ParallelGroup {
    /// The stage-0 communicator — the group's *only* mesh when
    /// `pp_degree == 1` (the non-pipelined SP paths use this directly).
    pub fn mesh(&self) -> &Mesh2D {
        &self.stages[0]
    }

    /// First absolute rank of the group.
    pub fn base(&self) -> usize {
        self.stages[0].base
    }

    /// Number of pipeline stages in this group.
    pub fn pp_degree(&self) -> usize {
        self.stages.len()
    }

    /// Total ranks of the group (all stages).
    pub fn len(&self) -> usize {
        self.stages.len() * self.stages[0].total()
    }

    /// A group always has at least one stage of at least one rank.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Does the group (any of its stages) own this absolute rank?
    pub fn contains(&self, rank: usize) -> bool {
        (self.base()..self.base() + self.len()).contains(&rank)
    }

    /// Absolute ranks of the group across all stages, ascending.
    pub fn ranks(&self) -> Vec<usize> {
        (self.base()..self.base() + self.len()).collect()
    }

    /// Group-relative index of an absolute rank.
    pub fn local_rank(&self, rank: usize) -> usize {
        debug_assert!(self.contains(rank), "rank {rank} outside group");
        rank - self.base()
    }

    /// Pipeline-stage index of an absolute rank (stages are contiguous
    /// and equal-sized, so this is a division).
    pub fn stage_of(&self, rank: usize) -> usize {
        debug_assert!(self.contains(rank), "rank {rank} outside group");
        (rank - self.base()) / self.stages[0].total()
    }

    /// The stage sub-mesh owning an absolute rank.
    pub fn stage_mesh(&self, rank: usize) -> &Mesh2D {
        &self.stages[self.stage_of(rank)]
    }
}

/// A validated partitioning of a cluster into SP groups.
#[derive(Debug, Clone)]
pub struct ParallelPlan {
    pub cluster: ClusterSpec,
    pub spec: ParallelSpec,
    pub algo: SpAlgo,
    pub groups: Vec<ParallelGroup>,
}

impl ParallelPlan {
    /// Validate `spec` against `cluster` and carve the groups. Groups are
    /// laid out branch-major: all conditional replicas first, then the
    /// unconditional ones (when `cfg_degree == 2`). Inside a group the
    /// `pp_degree` pipeline stages are contiguous, machine-aligned
    /// carves in stage order.
    pub fn build(
        cluster: &ClusterSpec,
        spec: ParallelSpec,
        algo: SpAlgo,
    ) -> Result<Self, ParallelSpecError> {
        spec.validate(cluster)?;
        let group_size = spec.ranks_per_group();
        let stage_size = spec.ranks_per_stage();
        let groups = (0..spec.groups())
            .map(|g| {
                let role = if spec.cfg_degree == 1 {
                    BranchRole::Both
                } else if g / spec.batch_replicas == 0 {
                    BranchRole::Conditional
                } else {
                    BranchRole::Unconditional
                };
                let base = g * group_size;
                let stages: Vec<Mesh2D> = (0..spec.pp_degree)
                    .map(|s| {
                        Mesh2D::carved(
                            cluster.clone(),
                            spec.sp,
                            algo.placement(),
                            base + s * stage_size,
                        )
                    })
                    .collect();
                ParallelGroup { index: g, role, replica: g % spec.batch_replicas, stages }
            })
            .collect();
        Ok(Self { cluster: cluster.clone(), spec, algo, groups })
    }

    /// The group owning an absolute rank (groups are contiguous and
    /// equal-sized, so this is a division).
    pub fn group_of(&self, rank: usize) -> &ParallelGroup {
        &self.groups[rank / self.spec.ranks_per_group()]
    }

    /// The group serving `(role, replica)`; for `cfg_degree == 1` pass
    /// the replica's `BranchRole::Both` group via either branch role.
    pub fn group_for(&self, role: BranchRole, replica: usize) -> &ParallelGroup {
        let branch = match (self.spec.cfg_degree, role) {
            (1, _) => 0,
            (_, BranchRole::Conditional | BranchRole::Both) => 0,
            (_, BranchRole::Unconditional) => 1,
        };
        &self.groups[branch * self.spec.batch_replicas + replica]
    }

    /// Groups computing the conditional branch (all groups at cfg 1).
    pub fn conditional_groups(&self) -> impl Iterator<Item = &ParallelGroup> {
        self.groups
            .iter()
            .filter(|g| matches!(g.role, BranchRole::Conditional | BranchRole::Both))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpDegrees;

    #[test]
    fn plan_partitions_every_rank_once() {
        let cluster = ClusterSpec::new(4, 8);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(8, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 4);
        let mut seen = vec![false; 32];
        for g in &plan.groups {
            for r in g.ranks() {
                assert!(!seen[r], "rank {r} in two groups");
                seen[r] = true;
                assert_eq!(plan.group_of(r).index, g.index);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn branch_major_layout_and_roles() {
        let cluster = ClusterSpec::new(2, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        assert_eq!(plan.groups[0].role, BranchRole::Conditional);
        assert_eq!(plan.groups[1].role, BranchRole::Conditional);
        assert_eq!(plan.groups[2].role, BranchRole::Unconditional);
        assert_eq!(plan.groups[3].role, BranchRole::Unconditional);
        assert_eq!(plan.groups[1].replica, 1);
        assert_eq!(plan.group_for(BranchRole::Unconditional, 1).index, 3);
        assert_eq!(plan.group_for(BranchRole::Conditional, 0).base(), 0);
        assert_eq!(plan.conditional_groups().count(), 2);
    }

    #[test]
    fn single_group_plan_covers_cluster() {
        let cluster = ClusterSpec::new(2, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(2, 2)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].role, BranchRole::Both);
        assert_eq!(plan.groups[0].ranks(), vec![0, 1, 2, 3]);
        // cfg 1: either role resolves to the only group
        assert_eq!(plan.group_for(BranchRole::Unconditional, 0).index, 0);
    }

    #[test]
    fn invalid_spec_propagates_typed_error() {
        let cluster = ClusterSpec::new(2, 2);
        let err = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(2, 2)),
            SpAlgo::SwiftFusion,
        )
        .unwrap_err();
        assert!(matches!(err, ParallelSpecError::SizeMismatch { .. }));
    }

    #[test]
    fn pipeline_stages_partition_each_group() {
        // cfg2 x pp2 x sp8 on the 4x8 testbed: two branch groups of 16,
        // each split into two machine-aligned 8-rank stages.
        let cluster = ClusterSpec::new(4, 8);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 2);
        let mut seen = vec![false; 32];
        for g in &plan.groups {
            assert_eq!(g.pp_degree(), 2);
            assert_eq!(g.len(), 16);
            assert_eq!(g.ranks().len(), 16);
            for (s, mesh) in g.stages.iter().enumerate() {
                // stages are contiguous, in order, and machine-aligned
                assert_eq!(mesh.base, g.base() + s * 8);
                assert_eq!(mesh.inter_machine_fraction(&mesh.ranks()), 0.0);
                for r in mesh.ranks() {
                    assert!(!seen[r], "rank {r} in two stages");
                    seen[r] = true;
                    assert_eq!(g.stage_of(r), s);
                    assert_eq!(g.stage_mesh(r).base, mesh.base);
                    assert_eq!(plan.group_of(r).index, g.index);
                    // stage collectives stay inside the stage carve
                    for peer in mesh.ulysses_group(r).into_iter().chain(mesh.ring_group(r)) {
                        assert!(mesh.contains(peer), "collective escaped the stage");
                    }
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
        // branch roles survive the pipeline split
        assert_eq!(plan.groups[0].role, BranchRole::Conditional);
        assert_eq!(plan.groups[1].role, BranchRole::Unconditional);
    }

    #[test]
    fn single_stage_groups_expose_their_mesh() {
        // pp = 1: stages == [mesh]; the legacy accessors keep working.
        let cluster = ClusterSpec::new(2, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        for g in &plan.groups {
            assert_eq!(g.pp_degree(), 1);
            assert_eq!(g.stages[0].base, g.mesh().base);
            assert_eq!(g.ranks(), g.mesh().ranks());
            for r in g.ranks() {
                assert_eq!(g.stage_of(r), 0);
                assert_eq!(g.local_rank(r), r - g.base());
            }
        }
    }

    #[test]
    fn group_meshes_never_share_ranks_with_neighbors() {
        let cluster = ClusterSpec::new(2, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(4, 1)),
            SpAlgo::Tas,
        )
        .unwrap();
        // each branch is exactly one machine here
        for g in &plan.groups {
            assert_eq!(g.mesh().inter_machine_fraction(&g.ranks()), 0.0);
            for r in g.ranks() {
                for peer in g.mesh().ulysses_group(r).into_iter().chain(g.mesh().ring_group(r)) {
                    assert!(g.mesh().contains(peer), "collective escaped the carve");
                }
            }
        }
    }
}
