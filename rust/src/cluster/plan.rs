//! Hybrid parallel plans: carve a cluster into CFG-branch / batch-replica
//! groups, each split into pipeline stages running group-scoped 2D SP
//! meshes — the 3D `cfg × pp × sp` plan space.
//!
//! The paper scales a *single* attention pass across one mesh; a serving
//! engine composes parallelism dimensions. A [`ParallelPlan`] partitions
//! the cluster's ranks into `cfg_degree × batch_replicas` contiguous,
//! machine-aligned groups, carves each group into `pp_degree` contiguous
//! pipeline *stages*, and gives every stage a carved [`Mesh2D`]
//! communicator, so any [`crate::sp::SpAlgo`] runs unchanged *inside* its
//! stage — collectives (rings, all-to-alls, barriers) are built from the
//! mesh's rank set and therefore never cross a partition boundary.
//!
//! With `cfg_degree == 2`, the conditional and unconditional guidance
//! branches of classifier-free-guidance sampling run concurrently on the
//! two halves (xDiT's CFG parallelism); their outputs are merged by the
//! guidance combine step (`crate::sp::hybrid`). With `pp_degree > 1`,
//! DiT layers are partitioned across the group's stages and the latent
//! sequence streams between them as patches — PipeFusion's displaced
//! patch pipeline (`crate::sp::pipefusion`). `batch_replicas` adds plain
//! data parallelism over requests beyond that.

use crate::cluster::Mesh2D;
use crate::config::{ClusterSpec, ParallelSpec, ParallelSpecError};
use crate::sp::SpAlgo;

/// Which guidance branch(es) a group computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchRole {
    /// `cfg_degree == 1`: the group runs both branches (sequentially).
    Both,
    /// The conditional (prompted) branch.
    Conditional,
    /// The unconditional (null-prompt) branch.
    Unconditional,
}

/// One carved replica group: a contiguous rank range split into
/// `pp_degree` pipeline stages, each a private SP sub-mesh.
#[derive(Debug, Clone)]
pub struct ParallelGroup {
    /// Group index in `[0, cfg_degree · batch_replicas)`, branch-major.
    pub index: usize,
    pub role: BranchRole,
    /// Batch-replica index within the branch.
    pub replica: usize,
    /// One carved SP sub-mesh per pipeline stage, in stage order.
    /// Length is the spec's `pp_degree`.
    pub stages: Vec<Mesh2D>,
}

impl ParallelGroup {
    /// The stage-0 communicator — the group's *only* mesh when
    /// `pp_degree == 1` (the non-pipelined SP paths use this directly).
    pub fn mesh(&self) -> &Mesh2D {
        &self.stages[0]
    }

    /// First absolute rank of the group.
    pub fn base(&self) -> usize {
        self.stages[0].base
    }

    /// Number of pipeline stages in this group.
    pub fn pp_degree(&self) -> usize {
        self.stages.len()
    }

    /// Total ranks of the group (all stages).
    pub fn len(&self) -> usize {
        self.stages.len() * self.stages[0].total()
    }

    /// A group always has at least one stage of at least one rank.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Does the group (any of its stages) own this absolute rank?
    pub fn contains(&self, rank: usize) -> bool {
        (self.base()..self.base() + self.len()).contains(&rank)
    }

    /// Absolute ranks of the group across all stages, ascending.
    pub fn ranks(&self) -> Vec<usize> {
        (self.base()..self.base() + self.len()).collect()
    }

    /// Group-relative index of an absolute rank.
    pub fn local_rank(&self, rank: usize) -> usize {
        debug_assert!(self.contains(rank), "rank {rank} outside group");
        rank - self.base()
    }

    /// Pipeline-stage index of an absolute rank (stages are contiguous
    /// and equal-sized, so this is a division).
    pub fn stage_of(&self, rank: usize) -> usize {
        debug_assert!(self.contains(rank), "rank {rank} outside group");
        (rank - self.base()) / self.stages[0].total()
    }

    /// The stage sub-mesh owning an absolute rank.
    pub fn stage_mesh(&self, rank: usize) -> &Mesh2D {
        &self.stages[self.stage_of(rank)]
    }
}

/// A validated partitioning of a cluster into SP groups.
///
/// A plan normally covers the whole cluster (`base_rank == 0`, the spec
/// tiles every GPU). [`Self::build_subset`] instead carves a
/// *contiguous, machine-aligned subset* of the cluster — the plan's
/// groups then live at `base_rank > 0` and the remaining ranks belong to
/// a different carve generation (group-granular re-carving,
/// [`crate::cluster::recarve`]). Executors skip ranks outside the plan
/// via [`Self::try_group_of`].
#[derive(Debug, Clone)]
pub struct ParallelPlan {
    pub cluster: ClusterSpec,
    pub spec: ParallelSpec,
    pub algo: SpAlgo,
    /// First absolute rank the plan covers (0 for whole-cluster plans).
    pub base_rank: usize,
    pub groups: Vec<ParallelGroup>,
}

impl ParallelPlan {
    /// Validate `spec` against `cluster` and carve the groups. Groups are
    /// laid out branch-major: all conditional replicas first, then the
    /// unconditional ones (when `cfg_degree == 2`). Inside a group the
    /// `pp_degree` pipeline stages are contiguous, machine-aligned
    /// carves in stage order.
    pub fn build(
        cluster: &ClusterSpec,
        spec: ParallelSpec,
        algo: SpAlgo,
    ) -> Result<Self, ParallelSpecError> {
        spec.validate(cluster)?;
        Ok(Self::carve(cluster, spec, algo, 0))
    }

    /// Carve `spec` onto a contiguous, machine-aligned *subset* of the
    /// cluster's machines starting at `base_machine` — the plan a
    /// group-granular re-carve builds for the idle machines of a pod
    /// while the busy generation keeps serving on the rest
    /// ([`crate::cluster::recarve::EpochTracker::split`]). The spec is
    /// validated against the subset footprint it tiles (whole machines),
    /// and the returned plan's meshes are *pod-absolute*: ranks run from
    /// `base_machine · gpus_per_machine`, so the two generations'
    /// collectives can never alias each other's ranks.
    pub fn build_subset(
        cluster: &ClusterSpec,
        spec: ParallelSpec,
        algo: SpAlgo,
        base_machine: usize,
    ) -> Result<Self, ParallelSpecError> {
        let m = cluster.gpus_per_machine;
        let ranks = spec.total_ranks();
        // The subset must be whole machines; validating against the
        // resized footprint reuses every alignment rule (and yields the
        // same actionable SizeMismatch when the spec does not tile it).
        let machines = if ranks % m == 0 { ranks / m } else { 0 };
        let sub = if machines >= 1 {
            cluster.resized(machines)
        } else {
            // sub-machine footprints cannot form a machine subset; let
            // validate() report the mismatch against a 1-machine slice
            cluster.resized(1)
        };
        spec.validate(&sub)?;
        if base_machine + machines > cluster.machines {
            return Err(ParallelSpecError::SubsetOutOfRange {
                base_machine,
                machines,
                pod_machines: cluster.machines,
            });
        }
        Ok(Self::carve(cluster, spec, algo, base_machine * m))
    }

    /// The shared carving tail: groups laid out from `base_rank`.
    fn carve(cluster: &ClusterSpec, spec: ParallelSpec, algo: SpAlgo, base_rank: usize) -> Self {
        let group_size = spec.ranks_per_group();
        let stage_size = spec.ranks_per_stage();
        let groups = (0..spec.groups())
            .map(|g| {
                let role = if spec.cfg_degree == 1 {
                    BranchRole::Both
                } else if g / spec.batch_replicas == 0 {
                    BranchRole::Conditional
                } else {
                    BranchRole::Unconditional
                };
                let base = base_rank + g * group_size;
                let stages: Vec<Mesh2D> = (0..spec.pp_degree)
                    .map(|s| {
                        Mesh2D::carved(
                            cluster.clone(),
                            spec.sp,
                            algo.placement(),
                            base + s * stage_size,
                        )
                    })
                    .collect();
                ParallelGroup { index: g, role, replica: g % spec.batch_replicas, stages }
            })
            .collect();
        Self { cluster: cluster.clone(), spec, algo, base_rank, groups }
    }

    /// Does the plan cover this absolute rank? Always true for
    /// whole-cluster plans; subset plans ([`Self::build_subset`]) own
    /// only their carve's contiguous rank range.
    pub fn contains(&self, rank: usize) -> bool {
        (self.base_rank..self.base_rank + self.spec.total_ranks()).contains(&rank)
    }

    /// The group owning an absolute rank (groups are contiguous and
    /// equal-sized, so this is a division). The rank must be covered by
    /// the plan; executors that may see out-of-plan ranks (a pod running
    /// two carve generations) use [`Self::try_group_of`] instead.
    pub fn group_of(&self, rank: usize) -> &ParallelGroup {
        debug_assert!(self.contains(rank), "rank {rank} outside the plan's carve");
        &self.groups[(rank - self.base_rank) / self.spec.ranks_per_group()]
    }

    /// [`Self::group_of`] for ranks that may be outside the plan's carve:
    /// `None` for ranks another generation owns.
    pub fn try_group_of(&self, rank: usize) -> Option<&ParallelGroup> {
        if self.contains(rank) {
            Some(&self.groups[(rank - self.base_rank) / self.spec.ranks_per_group()])
        } else {
            None
        }
    }

    /// The group serving `(role, replica)`; for `cfg_degree == 1` pass
    /// the replica's `BranchRole::Both` group via either branch role.
    pub fn group_for(&self, role: BranchRole, replica: usize) -> &ParallelGroup {
        let branch = match (self.spec.cfg_degree, role) {
            (1, _) => 0,
            (_, BranchRole::Conditional | BranchRole::Both) => 0,
            (_, BranchRole::Unconditional) => 1,
        };
        &self.groups[branch * self.spec.batch_replicas + replica]
    }

    /// Is this plan eligible for CFG collective fusion
    /// ([`crate::config::NetSpec::cfg_fuse`])? Requires exactly two
    /// guidance branches whose groups have *identical* collective
    /// footprints — guaranteed here by construction (all groups share
    /// one spec) — and machine-aligned groups, so the two branches'
    /// same-shape inter-machine transfers traverse *different* machine
    /// pairs in lockstep and can share one scheduled flow's handshake.
    /// A group smaller than a machine would put both branches on the
    /// same NIC and fusion would just rename contention.
    pub fn cfg_fusible(&self) -> bool {
        self.cluster.net.cfg_fuse
            && self.spec.cfg_degree == 2
            && self.spec.ranks_per_group() % self.cluster.gpus_per_machine == 0
    }

    /// Groups computing the conditional branch (all groups at cfg 1).
    pub fn conditional_groups(&self) -> impl Iterator<Item = &ParallelGroup> {
        self.groups
            .iter()
            .filter(|g| matches!(g.role, BranchRole::Conditional | BranchRole::Both))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpDegrees;

    #[test]
    fn plan_partitions_every_rank_once() {
        let cluster = ClusterSpec::new(4, 8);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(8, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 4);
        let mut seen = vec![false; 32];
        for g in &plan.groups {
            for r in g.ranks() {
                assert!(!seen[r], "rank {r} in two groups");
                seen[r] = true;
                assert_eq!(plan.group_of(r).index, g.index);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn branch_major_layout_and_roles() {
        let cluster = ClusterSpec::new(2, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        assert_eq!(plan.groups[0].role, BranchRole::Conditional);
        assert_eq!(plan.groups[1].role, BranchRole::Conditional);
        assert_eq!(plan.groups[2].role, BranchRole::Unconditional);
        assert_eq!(plan.groups[3].role, BranchRole::Unconditional);
        assert_eq!(plan.groups[1].replica, 1);
        assert_eq!(plan.group_for(BranchRole::Unconditional, 1).index, 3);
        assert_eq!(plan.group_for(BranchRole::Conditional, 0).base(), 0);
        assert_eq!(plan.conditional_groups().count(), 2);
    }

    #[test]
    fn single_group_plan_covers_cluster() {
        let cluster = ClusterSpec::new(2, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(2, 2)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].role, BranchRole::Both);
        assert_eq!(plan.groups[0].ranks(), vec![0, 1, 2, 3]);
        // cfg 1: either role resolves to the only group
        assert_eq!(plan.group_for(BranchRole::Unconditional, 0).index, 0);
    }

    #[test]
    fn invalid_spec_propagates_typed_error() {
        let cluster = ClusterSpec::new(2, 2);
        let err = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(2, 2)),
            SpAlgo::SwiftFusion,
        )
        .unwrap_err();
        assert!(matches!(err, ParallelSpecError::SizeMismatch { .. }));
    }

    #[test]
    fn pipeline_stages_partition_each_group() {
        // cfg2 x pp2 x sp8 on the 4x8 testbed: two branch groups of 16,
        // each split into two machine-aligned 8-rank stages.
        let cluster = ClusterSpec::new(4, 8);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 2);
        let mut seen = vec![false; 32];
        for g in &plan.groups {
            assert_eq!(g.pp_degree(), 2);
            assert_eq!(g.len(), 16);
            assert_eq!(g.ranks().len(), 16);
            for (s, mesh) in g.stages.iter().enumerate() {
                // stages are contiguous, in order, and machine-aligned
                assert_eq!(mesh.base, g.base() + s * 8);
                assert_eq!(mesh.inter_machine_fraction(&mesh.ranks()), 0.0);
                for r in mesh.ranks() {
                    assert!(!seen[r], "rank {r} in two stages");
                    seen[r] = true;
                    assert_eq!(g.stage_of(r), s);
                    assert_eq!(g.stage_mesh(r).base, mesh.base);
                    assert_eq!(plan.group_of(r).index, g.index);
                    // stage collectives stay inside the stage carve
                    for peer in mesh.ulysses_group(r).into_iter().chain(mesh.ring_group(r)) {
                        assert!(mesh.contains(peer), "collective escaped the stage");
                    }
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
        // branch roles survive the pipeline split
        assert_eq!(plan.groups[0].role, BranchRole::Conditional);
        assert_eq!(plan.groups[1].role, BranchRole::Unconditional);
    }

    #[test]
    fn single_stage_groups_expose_their_mesh() {
        // pp = 1: stages == [mesh]; the legacy accessors keep working.
        let cluster = ClusterSpec::new(2, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        for g in &plan.groups {
            assert_eq!(g.pp_degree(), 1);
            assert_eq!(g.stages[0].base, g.mesh().base);
            assert_eq!(g.ranks(), g.mesh().ranks());
            for r in g.ranks() {
                assert_eq!(g.stage_of(r), 0);
                assert_eq!(g.local_rank(r), r - g.base());
            }
        }
    }

    #[test]
    fn subset_plan_carves_only_its_machines() {
        // 4x8 pod: a 3-machine video carve on machines 1-3 while machine
        // 0 belongs to a different (busy) generation.
        let cluster = ClusterSpec::new(4, 8);
        let spec = ParallelSpec::new(2, 3, SpDegrees::new(4, 1));
        assert_eq!(spec.total_ranks(), 24);
        let plan = ParallelPlan::build_subset(&cluster, spec, SpAlgo::SwiftFusion, 1).unwrap();
        assert_eq!(plan.base_rank, 8);
        assert_eq!(plan.cluster.total_gpus(), 32, "the plan stays pod-absolute");
        // every covered rank maps to exactly one group; outside ranks to none
        for rank in 0..32 {
            match plan.try_group_of(rank) {
                Some(g) => {
                    assert!(plan.contains(rank));
                    assert!((8..32).contains(&rank), "rank {rank} outside the subset");
                    assert!(g.contains(rank));
                    // collectives stay inside the subset's carve
                    let mesh = g.stage_mesh(rank);
                    for peer in
                        mesh.ulysses_group(rank).into_iter().chain(mesh.ring_group(rank))
                    {
                        assert!((8..32).contains(&peer), "peer {peer} escaped the subset");
                    }
                }
                None => assert!(rank < 8, "rank {rank} should be covered"),
            }
        }
        // branch-major layout survives the offset: 3 conditional
        // replica groups (ranks 8..20), then 3 unconditional (20..32)
        assert_eq!(plan.groups.len(), 6);
        assert_eq!(plan.groups[0].base(), 8);
        assert_eq!(plan.groups[0].role, BranchRole::Conditional);
        assert_eq!(plan.groups[3].base(), 20);
        assert_eq!(plan.groups[3].role, BranchRole::Unconditional);
        assert_eq!(plan.group_of(9).index, 0);
        assert_eq!(plan.group_of(20).index, 3);
    }

    #[test]
    fn subset_plan_rejects_misfits() {
        let cluster = ClusterSpec::new(4, 8);
        // a spec tiling 2 machines cannot start at machine 3 (out of room)
        let spec = ParallelSpec::new(2, 1, SpDegrees::new(8, 1));
        assert!(ParallelPlan::build_subset(&cluster, spec, SpAlgo::SwiftFusion, 2).is_ok());
        let err =
            ParallelPlan::build_subset(&cluster, spec, SpAlgo::SwiftFusion, 3).unwrap_err();
        assert!(matches!(err, ParallelSpecError::SubsetOutOfRange { .. }));
        assert!(err.to_string().contains("exceeds the pod"), "{err}");
        // a sub-machine spec cannot form a machine subset
        let tiny = ParallelSpec::new(1, 1, SpDegrees::new(4, 1));
        let e = ParallelPlan::build_subset(&cluster, tiny, SpAlgo::SwiftFusion, 0).unwrap_err();
        assert!(matches!(e, ParallelSpecError::SizeMismatch { .. }));
        // whole-cluster builds still report base_rank 0 and contain all
        let full = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 4, SpDegrees::new(8, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(full.base_rank, 0);
        assert!(full.contains(0) && full.contains(31));
        assert!(full.try_group_of(31).is_some());
    }

    #[test]
    fn cfg_fusible_requires_knob_two_branches_and_alignment() {
        let mut cluster = ClusterSpec::new(4, 8);
        let spec = ParallelSpec::new(2, 2, SpDegrees::new(8, 1)); // groups of 8 = 1 machine
        let plan = ParallelPlan::build(&cluster, spec, SpAlgo::SwiftFusion).unwrap();
        assert!(!plan.cfg_fusible(), "knob off -> never fusible");
        cluster.net.cfg_fuse = true;
        let fusible = ParallelPlan::build(&cluster, spec, SpAlgo::SwiftFusion).unwrap();
        assert!(fusible.cfg_fusible());
        // cfg 1: no branch pair to fuse
        let solo = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 4, SpDegrees::new(8, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert!(!solo.cfg_fusible());
        // sub-machine groups: both branches share a NIC, not fusible
        let tiny = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 4, SpDegrees::new(4, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert!(!tiny.cfg_fusible());
    }

    #[test]
    fn group_meshes_never_share_ranks_with_neighbors() {
        let cluster = ClusterSpec::new(2, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(4, 1)),
            SpAlgo::Tas,
        )
        .unwrap();
        // each branch is exactly one machine here
        for g in &plan.groups {
            assert_eq!(g.mesh().inter_machine_fraction(&g.ranks()), 0.0);
            for r in g.ranks() {
                for peer in g.mesh().ulysses_group(r).into_iter().chain(g.mesh().ring_group(r)) {
                    assert!(g.mesh().contains(peer), "collective escaped the carve");
                }
            }
        }
    }
}
