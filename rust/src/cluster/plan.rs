//! Hybrid parallel plans: carve a cluster into CFG-branch / batch-replica
//! groups, each running a group-scoped 2D SP mesh.
//!
//! The paper scales a *single* attention pass across one mesh; a serving
//! engine composes parallelism dimensions. A [`ParallelPlan`] partitions
//! the cluster's ranks into `cfg_degree × batch_replicas` contiguous,
//! machine-aligned groups and gives each a carved [`Mesh2D`]
//! communicator, so any [`crate::sp::SpAlgo`] runs unchanged *inside* its
//! group — collectives (rings, all-to-alls, barriers) are built from the
//! mesh's rank set and therefore never cross a partition boundary.
//!
//! With `cfg_degree == 2`, the conditional and unconditional guidance
//! branches of classifier-free-guidance sampling run concurrently on the
//! two halves (xDiT's CFG parallelism); their outputs are merged by the
//! guidance combine step (`crate::sp::hybrid`). `batch_replicas` adds
//! plain data parallelism over requests beyond that.

use crate::cluster::Mesh2D;
use crate::config::{ClusterSpec, ParallelSpec, ParallelSpecError};
use crate::sp::SpAlgo;

/// Which guidance branch(es) a group computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchRole {
    /// `cfg_degree == 1`: the group runs both branches (sequentially).
    Both,
    /// The conditional (prompted) branch.
    Conditional,
    /// The unconditional (null-prompt) branch.
    Unconditional,
}

/// One carved replica group: a contiguous rank range with a private mesh.
#[derive(Debug, Clone)]
pub struct ParallelGroup {
    /// Group index in `[0, cfg_degree · batch_replicas)`, branch-major.
    pub index: usize,
    pub role: BranchRole,
    /// Batch-replica index within the branch.
    pub replica: usize,
    /// Group-scoped communicator (carved sub-mesh).
    pub mesh: Mesh2D,
}

impl ParallelGroup {
    /// First absolute rank of the group.
    pub fn base(&self) -> usize {
        self.mesh.base
    }

    /// Absolute ranks of the group, ascending.
    pub fn ranks(&self) -> Vec<usize> {
        self.mesh.ranks()
    }

    /// Group-relative index of an absolute rank.
    pub fn local_rank(&self, rank: usize) -> usize {
        debug_assert!(self.mesh.contains(rank), "rank {rank} outside group");
        rank - self.mesh.base
    }
}

/// A validated partitioning of a cluster into SP groups.
#[derive(Debug, Clone)]
pub struct ParallelPlan {
    pub cluster: ClusterSpec,
    pub spec: ParallelSpec,
    pub algo: SpAlgo,
    pub groups: Vec<ParallelGroup>,
}

impl ParallelPlan {
    /// Validate `spec` against `cluster` and carve the groups. Groups are
    /// laid out branch-major: all conditional replicas first, then the
    /// unconditional ones (when `cfg_degree == 2`).
    pub fn build(
        cluster: &ClusterSpec,
        spec: ParallelSpec,
        algo: SpAlgo,
    ) -> Result<Self, ParallelSpecError> {
        spec.validate(cluster)?;
        let size = spec.ranks_per_group();
        let groups = (0..spec.groups())
            .map(|g| {
                let role = if spec.cfg_degree == 1 {
                    BranchRole::Both
                } else if g / spec.batch_replicas == 0 {
                    BranchRole::Conditional
                } else {
                    BranchRole::Unconditional
                };
                ParallelGroup {
                    index: g,
                    role,
                    replica: g % spec.batch_replicas,
                    mesh: Mesh2D::carved(cluster.clone(), spec.sp, algo.placement(), g * size),
                }
            })
            .collect();
        Ok(Self { cluster: cluster.clone(), spec, algo, groups })
    }

    /// The group owning an absolute rank (groups are contiguous and
    /// equal-sized, so this is a division).
    pub fn group_of(&self, rank: usize) -> &ParallelGroup {
        &self.groups[rank / self.spec.ranks_per_group()]
    }

    /// The group serving `(role, replica)`; for `cfg_degree == 1` pass
    /// the replica's `BranchRole::Both` group via either branch role.
    pub fn group_for(&self, role: BranchRole, replica: usize) -> &ParallelGroup {
        let branch = match (self.spec.cfg_degree, role) {
            (1, _) => 0,
            (_, BranchRole::Conditional | BranchRole::Both) => 0,
            (_, BranchRole::Unconditional) => 1,
        };
        &self.groups[branch * self.spec.batch_replicas + replica]
    }

    /// Groups computing the conditional branch (all groups at cfg 1).
    pub fn conditional_groups(&self) -> impl Iterator<Item = &ParallelGroup> {
        self.groups
            .iter()
            .filter(|g| matches!(g.role, BranchRole::Conditional | BranchRole::Both))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpDegrees;

    #[test]
    fn plan_partitions_every_rank_once() {
        let cluster = ClusterSpec::new(4, 8);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(8, 1)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 4);
        let mut seen = vec![false; 32];
        for g in &plan.groups {
            for r in g.ranks() {
                assert!(!seen[r], "rank {r} in two groups");
                seen[r] = true;
                assert_eq!(plan.group_of(r).index, g.index);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn branch_major_layout_and_roles() {
        let cluster = ClusterSpec::new(2, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(2, 1)),
            SpAlgo::Ulysses,
        )
        .unwrap();
        assert_eq!(plan.groups[0].role, BranchRole::Conditional);
        assert_eq!(plan.groups[1].role, BranchRole::Conditional);
        assert_eq!(plan.groups[2].role, BranchRole::Unconditional);
        assert_eq!(plan.groups[3].role, BranchRole::Unconditional);
        assert_eq!(plan.groups[1].replica, 1);
        assert_eq!(plan.group_for(BranchRole::Unconditional, 1).index, 3);
        assert_eq!(plan.group_for(BranchRole::Conditional, 0).base(), 0);
        assert_eq!(plan.conditional_groups().count(), 2);
    }

    #[test]
    fn single_group_plan_covers_cluster() {
        let cluster = ClusterSpec::new(2, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(2, 2)),
            SpAlgo::SwiftFusion,
        )
        .unwrap();
        assert_eq!(plan.groups.len(), 1);
        assert_eq!(plan.groups[0].role, BranchRole::Both);
        assert_eq!(plan.groups[0].ranks(), vec![0, 1, 2, 3]);
        // cfg 1: either role resolves to the only group
        assert_eq!(plan.group_for(BranchRole::Unconditional, 0).index, 0);
    }

    #[test]
    fn invalid_spec_propagates_typed_error() {
        let cluster = ClusterSpec::new(2, 2);
        let err = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 2, SpDegrees::new(2, 2)),
            SpAlgo::SwiftFusion,
        )
        .unwrap_err();
        assert!(matches!(err, ParallelSpecError::SizeMismatch { .. }));
    }

    #[test]
    fn group_meshes_never_share_ranks_with_neighbors() {
        let cluster = ClusterSpec::new(2, 4);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(2, 1, SpDegrees::new(4, 1)),
            SpAlgo::Tas,
        )
        .unwrap();
        // each branch is exactly one machine here
        for g in &plan.groups {
            assert_eq!(g.mesh.inter_machine_fraction(&g.ranks()), 0.0);
            for r in g.ranks() {
                for peer in g.mesh.ulysses_group(r).into_iter().chain(g.mesh.ring_group(r)) {
                    assert!(g.mesh.contains(peer), "collective escaped the carve");
                }
            }
        }
    }
}
