//! Simulated GPU cluster: topology, 2D device mesh, per-rank clocks, and
//! the rank executor that runs SP algorithms as one thread per GPU.
//!
//! Ranks are numbered `machine * M + gpu` (M = GPUs per machine). The 2D
//! mesh assigns each rank an `(u, r)` coordinate — Ulysses × Ring process
//! groups (§4.2) — under one of two placements:
//!
//! * [`Placement::UlyssesIntra`] — USP: Ulysses groups are contiguous
//!   ranks (intra-machine when `P_u ≤ M`), Ring groups stride across
//!   machines. `rank = r * P_u + u`.
//! * [`Placement::UlyssesInter`] — SwiftFusion/TAS: Ring groups are
//!   contiguous ranks (intra-machine when `P_r ≤ M`), Ulysses groups
//!   stride across machines. `rank = u * P_r + r`.

pub mod clock;
pub mod exec;
pub mod plan;
pub mod recarve;

use crate::config::{ClusterSpec, SpDegrees};

/// How the `P_u × P_r` mesh is laid onto physical ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// USP (§2.2): Ulysses intra-machine, Ring inter-machine.
    UlyssesIntra,
    /// SwiftFusion/TAS (§4.2): Ulysses inter-machine, Ring intra-machine.
    UlyssesInter,
}

/// A concrete 2D device mesh over a cluster — either the whole cluster
/// ([`Mesh2D::new`], `base == 0`) or a *carved sub-mesh*: a contiguous
/// rank range `[base, base + P_u·P_r)` operated as its own 2D mesh
/// ([`Mesh2D::carved`]). Carved meshes are how the hybrid CFG×SP planner
/// ([`plan`]) gives each replica group a private communicator: every
/// group method below returns absolute cluster ranks inside the carve,
/// so collectives built from them can never cross a partition boundary.
#[derive(Debug, Clone)]
pub struct Mesh2D {
    pub cluster: ClusterSpec,
    pub degrees: SpDegrees,
    pub placement: Placement,
    /// First absolute rank of this mesh (0 for a full-cluster mesh).
    pub base: usize,
}

impl Mesh2D {
    pub fn new(cluster: ClusterSpec, degrees: SpDegrees, placement: Placement) -> Self {
        assert_eq!(
            degrees.total(),
            cluster.total_gpus(),
            "mesh degrees must cover the cluster"
        );
        Self { cluster, degrees, placement, base: 0 }
    }

    /// A sub-mesh over ranks `[base, base + degrees.total())` of `cluster`.
    pub fn carved(
        cluster: ClusterSpec,
        degrees: SpDegrees,
        placement: Placement,
        base: usize,
    ) -> Self {
        assert!(
            base + degrees.total() <= cluster.total_gpus(),
            "carve [{base}, {}) exceeds cluster of {} GPUs",
            base + degrees.total(),
            cluster.total_gpus()
        );
        Self { cluster, degrees, placement, base }
    }

    pub fn total(&self) -> usize {
        self.degrees.total()
    }

    /// All absolute ranks of this mesh, ascending.
    pub fn ranks(&self) -> Vec<usize> {
        (self.base..self.base + self.total()).collect()
    }

    /// Does this mesh contain the absolute rank?
    pub fn contains(&self, rank: usize) -> bool {
        (self.base..self.base + self.total()).contains(&rank)
    }

    /// (u, r) coordinate of an absolute rank.
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        debug_assert!(self.contains(rank), "rank {rank} outside mesh");
        let local = rank - self.base;
        match self.placement {
            Placement::UlyssesIntra => (local % self.degrees.pu, local / self.degrees.pu),
            Placement::UlyssesInter => (local / self.degrees.pr, local % self.degrees.pr),
        }
    }

    /// Absolute rank at (u, r).
    pub fn rank_at(&self, u: usize, r: usize) -> usize {
        debug_assert!(u < self.degrees.pu && r < self.degrees.pr);
        self.base
            + match self.placement {
                Placement::UlyssesIntra => r * self.degrees.pu + u,
                Placement::UlyssesInter => u * self.degrees.pr + r,
            }
    }

    /// All ranks sharing this rank's Ulysses group (varying u, fixed r).
    pub fn ulysses_group(&self, rank: usize) -> Vec<usize> {
        let (_, r) = self.coords(rank);
        (0..self.degrees.pu).map(|u| self.rank_at(u, r)).collect()
    }

    /// All ranks sharing this rank's Ring group (fixed u, varying r).
    pub fn ring_group(&self, rank: usize) -> Vec<usize> {
        let (u, _) = self.coords(rank);
        (0..self.degrees.pr).map(|r| self.rank_at(u, r)).collect()
    }

    /// Fraction of a group's pairwise links that cross machines — used by
    /// tests to assert the topology-awareness claims.
    pub fn inter_machine_fraction(&self, group: &[usize]) -> f64 {
        let mut inter = 0usize;
        let mut total = 0usize;
        for (i, &a) in group.iter().enumerate() {
            for &b in &group[i + 1..] {
                total += 1;
                if !self.cluster.same_machine(a, b) {
                    inter += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            inter as f64 / total as f64
        }
    }

    /// Torus factorization of the Ulysses group (§4.3): the group is split
    /// into `N` *torus* stages across machines × `P_u / N` intra-machine
    /// Ulysses sub-groups. Returns (torus index t, intra index u') for
    /// `rank` given `n` torus stages. Requires `n | P_u`.
    pub fn torus_coords(&self, rank: usize, n: usize) -> (usize, usize) {
        assert_eq!(self.degrees.pu % n, 0, "N must divide P_u");
        let (u, _) = self.coords(rank);
        let pu_prime = self.degrees.pu / n;
        match self.placement {
            // UlyssesInter: u strides across machines; consecutive u's with
            // the same u / (P_u/N) share a machine block.
            Placement::UlyssesInter => (u / pu_prime, u % pu_prime),
            Placement::UlyssesIntra => (u % n, u / n),
        }
    }

    /// Ranks in this rank's torus group (fixed u', r; varying torus index).
    pub fn torus_group(&self, rank: usize, n: usize) -> Vec<usize> {
        let (_, r) = self.coords(rank);
        let (_, uprime) = self.torus_coords(rank, n);
        let pu_prime = self.degrees.pu / n;
        (0..n)
            .map(|t| {
                let u = match self.placement {
                    Placement::UlyssesInter => t * pu_prime + uprime,
                    Placement::UlyssesIntra => uprime * n + t,
                };
                self.rank_at(u, r)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::util::prop;

    fn mesh(n: usize, m: usize, pu: usize, pr: usize, p: Placement) -> Mesh2D {
        Mesh2D::new(ClusterSpec::new(n, m), SpDegrees::new(pu, pr), p)
    }

    #[test]
    fn coords_roundtrip_both_placements() {
        for placement in [Placement::UlyssesIntra, Placement::UlyssesInter] {
            let me = mesh(2, 4, 4, 2, placement);
            for rank in 0..8 {
                let (u, r) = me.coords(rank);
                assert_eq!(me.rank_at(u, r), rank, "{placement:?} rank {rank}");
            }
        }
    }

    #[test]
    fn usp_ulysses_groups_are_intra_machine() {
        // USP on 2 machines x 4 GPUs with P_u=4: every Ulysses group must
        // live inside one machine (uses NVSwitch), Ring spans machines.
        let me = mesh(2, 4, 4, 2, Placement::UlyssesIntra);
        for rank in 0..8 {
            let ug = me.ulysses_group(rank);
            assert_eq!(me.inter_machine_fraction(&ug), 0.0, "ulysses {ug:?}");
            let rg = me.ring_group(rank);
            assert!(me.inter_machine_fraction(&rg) > 0.0, "ring {rg:?}");
        }
    }

    #[test]
    fn swiftfusion_ring_groups_are_intra_machine() {
        // SwiftFusion inverts the mapping (§4.2): Ring intra, Ulysses inter.
        let me = mesh(2, 4, 2, 4, Placement::UlyssesInter);
        for rank in 0..8 {
            let rg = me.ring_group(rank);
            assert_eq!(me.inter_machine_fraction(&rg), 0.0, "ring {rg:?}");
            let ug = me.ulysses_group(rank);
            assert!(me.inter_machine_fraction(&ug) > 0.0, "ulysses {ug:?}");
        }
    }

    #[test]
    fn groups_contain_self_and_are_consistent() {
        let me = mesh(2, 2, 2, 2, Placement::UlyssesInter);
        for rank in 0..4 {
            assert!(me.ulysses_group(rank).contains(&rank));
            assert!(me.ring_group(rank).contains(&rank));
            // group membership is symmetric
            for &peer in &me.ulysses_group(rank) {
                assert_eq!(me.ulysses_group(peer), me.ulysses_group(rank));
            }
        }
    }

    #[test]
    fn torus_coords_partition_ulysses_group() {
        // P_u = 4 over N = 2 machines: torus degree 2, intra-ulysses 2.
        let me = mesh(2, 4, 4, 2, Placement::UlyssesInter);
        for rank in 0..8 {
            let (t, up) = me.torus_coords(rank, 2);
            assert!(t < 2 && up < 2);
            let tg = me.torus_group(rank, 2);
            assert_eq!(tg.len(), 2);
            assert!(tg.contains(&rank));
            // each torus step crosses a machine boundary in UlyssesInter
            assert!(me.inter_machine_fraction(&tg) > 0.0, "{tg:?}");
        }
    }

    #[test]
    fn torus_groups_cover_ulysses_group() {
        let me = mesh(2, 4, 4, 2, Placement::UlyssesInter);
        let ug = me.ulysses_group(0);
        for &r in &ug {
            let tg = me.torus_group(r, 2);
            for t in tg {
                assert!(ug.contains(&t), "torus member {t} outside ulysses group {ug:?}");
            }
        }
    }

    #[test]
    fn carved_mesh_is_group_scoped() {
        // 2x4 cluster carved into two 2x2 sub-meshes at base 0 and 4: all
        // groups must stay inside their carve.
        let cluster = ClusterSpec::new(2, 4);
        for base in [0usize, 4] {
            let me = Mesh2D::carved(
                cluster.clone(),
                SpDegrees::new(2, 2),
                Placement::UlyssesInter,
                base,
            );
            assert_eq!(me.ranks(), (base..base + 4).collect::<Vec<_>>());
            for rank in me.ranks() {
                assert!(me.contains(rank));
                let (u, r) = me.coords(rank);
                assert_eq!(me.rank_at(u, r), rank, "base {base} rank {rank}");
                for peer in me.ulysses_group(rank).into_iter().chain(me.ring_group(rank)) {
                    assert!(
                        (base..base + 4).contains(&peer),
                        "group member {peer} escaped carve at base {base}"
                    );
                }
            }
        }
        // the two carves are disjoint and cover the cluster
        let a = Mesh2D::carved(cluster.clone(), SpDegrees::new(2, 2), Placement::UlyssesInter, 0);
        let b = Mesh2D::carved(cluster, SpDegrees::new(2, 2), Placement::UlyssesInter, 4);
        for r in a.ranks() {
            assert!(!b.contains(r));
        }
    }

    #[test]
    #[should_panic(expected = "exceeds cluster")]
    fn carve_past_cluster_end_panics() {
        let cluster = ClusterSpec::new(1, 4);
        Mesh2D::carved(cluster, SpDegrees::new(2, 1), Placement::UlyssesInter, 3);
    }

    #[test]
    fn prop_mesh_bijection() {
        prop::run(40, |g| {
            let n = g.int(1, 4);
            let m = *g.choose(&[1usize, 2, 4]);
            let total = n * m;
            let divs: Vec<usize> = (1..=total).filter(|d| total % d == 0).collect();
            let pu = *g.choose(&divs);
            let pr = total / pu;
            let placement = if g.bool() {
                Placement::UlyssesIntra
            } else {
                Placement::UlyssesInter
            };
            let me = mesh(n, m, pu, pr, placement);
            let mut seen = vec![false; total];
            for u in 0..pu {
                for r in 0..pr {
                    let rank = me.rank_at(u, r);
                    assert!(!seen[rank], "rank {rank} assigned twice");
                    seen[rank] = true;
                    assert_eq!(me.coords(rank), (u, r));
                }
            }
            assert!(seen.iter().all(|&s| s));
        });
    }
}
