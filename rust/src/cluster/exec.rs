//! Rank executor: run a per-rank closure on one thread per simulated GPU.
//!
//! Each closure receives a [`RankCtx`] bundling the rank id, the shared
//! [`CommWorld`], the rank's virtual [`RankClock`], and the execution
//! mode: **Numeric** (real tensors through the PJRT artifacts) or
//! **Timing** (shape-only buffers at paper scale). The SP algorithms in
//! [`crate::sp`] are written once against this context and run unchanged
//! in both modes.

use std::sync::Arc;

use crate::cluster::clock::{RankClock, TimeKind};
use crate::comm::{Buf, CommWorld, Event, GetHandle, SendHandle};
use crate::config::ClusterSpec;
use crate::runtime::{ConfigMeta, RuntimeHandle};

/// Execution mode for a cluster run.
#[derive(Clone)]
pub enum ExecMode {
    /// Real numerics via the AOT artifacts of `cfg`.
    Numeric { rt: RuntimeHandle, cfg: Arc<ConfigMeta> },
    /// Real numerics via the in-process host tile kernels
    /// (`crate::sp::tiles::host`): exact f32 flash-attention math with no
    /// PJRT dependency. Same dataflow and clock accounting as `Numeric`;
    /// only the tile backend differs. This is what the property suite
    /// (`rust/tests/sp_property.rs`) runs, so numeric validation works in
    /// hermetic/offline environments.
    HostNumeric,
    /// Shape-only buffers; only the virtual clocks matter.
    Timing,
}

impl ExecMode {
    /// True when buffers carry real tensor data (either tile backend).
    pub fn is_numeric(&self) -> bool {
        matches!(self, ExecMode::Numeric { .. } | ExecMode::HostNumeric)
    }
}

/// Per-rank execution context handed to SP algorithms.
pub struct RankCtx<'w> {
    pub rank: usize,
    pub world: &'w CommWorld,
    pub clock: RankClock,
    pub mode: ExecMode,
    /// One-sided window epoch. Every expose/put/get slot is silently
    /// prefixed with the epoch, so successive collectives (e.g. the
    /// attention of consecutive DiT blocks) can never read a stale
    /// window from an earlier layer. Bump with [`Self::next_epoch`]
    /// between collectives that reuse slot names.
    pub epoch: u64,
}

impl<'w> RankCtx<'w> {
    pub fn cluster(&self) -> &ClusterSpec {
        &self.world.cluster
    }

    /// Advance the clock by a compute span. (SM contention from kernel-
    /// based two-sided transfers is charged on the *transfer* side — see
    /// `CommWorld::wait_recv` — since it scales with transfer activity.)
    pub fn compute(&mut self, seconds: f64) {
        self.clock.advance(seconds, TimeKind::Compute);
    }

    /// The NIC flow count an SP collective over `ranks` should charge
    /// per inter-machine transfer from this rank's machine. Legacy
    /// (constant fair-share) mode keeps the historic worst case — every
    /// GPU of the machine contends — so existing schedules price
    /// bit-identically. Scheduled mode
    /// ([`crate::config::NetSpec::nic_schedule`]) counts the flows that
    /// can *actually* collide: the collective's own ranks on this
    /// machine (a ring subset with one rank per machine stops paying
    /// for seven phantom neighbours).
    pub fn nic_flows(&self, ranks: &[usize]) -> usize {
        let m = self.cluster().gpus_per_machine;
        if !self.cluster().net.nic_schedule {
            return m;
        }
        let mine = self.cluster().machine_of(self.rank);
        ranks
            .iter()
            .filter(|&&r| self.cluster().machine_of(r) == mine)
            .count()
            .clamp(1, m)
    }

    /// Cost model for one attention tile `[B, lq, g, D] x [B, lk, g, D]`.
    pub fn attn_tile_time(&self, b: usize, lq: usize, lk: usize, g: usize, d: usize) -> f64 {
        let flops = 4.0 * b as f64 * lq as f64 * lk as f64 * g as f64 * d as f64;
        // bytes: read q, k, v tiles + state, write state (f32)
        let bytes = (b * g * d * (lq + 2 * lk) + 2 * b * g * lq) as f64 * 4.0 * 2.0;
        self.cluster().gpu.tile_time(flops, bytes)
    }

    /// Execute an AOT artifact (numeric mode only) — used by the model
    /// stage driver; SP algorithms go through [`crate::sp::tiles`].
    pub fn call_artifact(&mut self, name: &str, inputs: &[Buf]) -> anyhow::Result<Vec<Buf>> {
        match &self.mode {
            ExecMode::Numeric { rt, .. } => {
                let tensors: Vec<_> = inputs.iter().map(|b| b.tensor().clone()).collect();
                let out = rt.call(name, &tensors)?;
                Ok(out.into_iter().map(Buf::Real).collect())
            }
            ExecMode::HostNumeric => {
                anyhow::bail!("call_artifact('{name}') in host-numeric mode: model-stage \
                               artifacts need the PJRT runtime")
            }
            ExecMode::Timing => anyhow::bail!("call_artifact in timing mode"),
        }
    }

    // ---- comm sugar (delegates to CommWorld with this rank's clock) ----

    pub fn isend(&mut self, dst: usize, tag: &str, buf: Buf) -> SendHandle {
        self.world.isend(&mut self.clock, self.rank, dst, tag, buf)
    }

    pub fn wait_recv(&mut self, src: usize, tag: &str, flows: usize) -> Buf {
        self.world
            .wait_recv(&mut self.clock, src, self.rank, tag, flows)
    }

    /// Post a receive early (NCCL irecv): the transfer progresses in the
    /// background; `wait_get` the handle after overlapped compute.
    pub fn irecv(&mut self, src: usize, tag: &str, flows: usize) -> GetHandle {
        self.world
            .irecv(&mut self.clock, src, self.rank, tag, flows)
    }

    pub fn wait_send(&mut self, h: SendHandle) {
        self.world.wait_send(&mut self.clock, h)
    }

    /// Advance the window epoch (call between collectives; all ranks
    /// must do so in lockstep, which the layer structure guarantees).
    pub fn next_epoch(&mut self) {
        self.epoch += 1;
    }

    fn scoped(&self, slot: &str) -> String {
        format!("e{}.{slot}", self.epoch)
    }

    pub fn expose(&mut self, slot: &str, buf: Buf) {
        let s = self.scoped(slot);
        self.world.expose(&self.clock, self.rank, &s, buf)
    }

    pub fn put(&mut self, dst: usize, slot: &str, buf: Buf, flows: usize) -> Event {
        let s = self.scoped(slot);
        self.world
            .put(&mut self.clock, self.rank, dst, &s, buf, flows)
    }

    pub fn get(&mut self, src: usize, slot: &str, flows: usize) -> GetHandle {
        let s = self.scoped(slot);
        self.world.get(&mut self.clock, self.rank, src, &s, flows)
    }

    pub fn wait_get(&mut self, h: GetHandle) -> Buf {
        self.world.wait_get(&mut self.clock, h)
    }

    pub fn wait_event(&mut self, ev: Event) {
        self.world.wait_event(&mut self.clock, ev)
    }

    pub fn barrier(&mut self, group: &[usize]) {
        self.world.barrier(&mut self.clock, group)
    }

    pub fn barrier_all(&mut self) {
        let all: Vec<usize> = (0..self.cluster().total_gpus()).collect();
        self.world.barrier(&mut self.clock, &all)
    }
}

/// Result of one cluster run: per-rank outputs and final clocks.
pub struct ClusterRun<R> {
    pub outputs: Vec<R>,
    pub clocks: Vec<RankClock>,
}

impl<R> ClusterRun<R> {
    /// Makespan: the max of all rank clocks (end-to-end latency of the
    /// collective operation — what the paper's figures plot).
    pub fn makespan(&self) -> f64 {
        self.clocks.iter().map(|c| c.now).fold(0.0, f64::max)
    }

    /// Aggregated (compute, comm_wait, sync, overhead) across ranks,
    /// averaged — the Fig. 3b breakdown.
    pub fn mean_breakdown(&self) -> (f64, f64, f64, f64) {
        let n = self.clocks.len().max(1) as f64;
        let mut acc = (0.0, 0.0, 0.0, 0.0);
        for c in &self.clocks {
            let b = c.breakdown();
            acc.0 += b.0 / n;
            acc.1 += b.1 / n;
            acc.2 += b.2 / n;
            acc.3 += b.3 / n;
        }
        acc
    }
}

/// Run `f` once per rank on its own thread against a fresh [`CommWorld`].
pub fn run_cluster<R, F>(cluster: &ClusterSpec, mode: &ExecMode, f: F) -> ClusterRun<R>
where
    R: Send,
    F: Fn(&mut RankCtx) -> R + Sync,
{
    let world = CommWorld::new(cluster.clone());
    run_in_world(&world, mode, f)
}

/// Run against an existing world (lets callers inspect window memory or
/// chain multiple collectives in one world).
pub fn run_in_world<R, F>(world: &CommWorld, mode: &ExecMode, f: F) -> ClusterRun<R>
where
    R: Send,
    F: Fn(&mut RankCtx) -> R + Sync,
{
    let n = world.cluster.total_gpus();
    let fref = &f;
    let results = crate::util::pool::scoped_run(
        (0..n)
            .map(|rank| {
                let mode = mode.clone();
                move || {
                    let mut ctx =
                        RankCtx { rank, world, clock: RankClock::new(), mode, epoch: 0 };
                    let out = fref(&mut ctx);
                    (out, ctx.clock)
                }
            })
            .collect::<Vec<_>>(),
    );
    let mut outputs = Vec::with_capacity(n);
    let mut clocks = Vec::with_capacity(n);
    for (o, c) in results {
        outputs.push(o);
        clocks.push(c);
    }
    ClusterRun { outputs, clocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;

    #[test]
    fn run_cluster_all_ranks_execute() {
        let c = ClusterSpec::new(2, 2);
        let run = run_cluster(&c, &ExecMode::Timing, |ctx| ctx.rank * 10);
        assert_eq!(run.outputs, vec![0, 10, 20, 30]);
        assert_eq!(run.clocks.len(), 4);
    }

    #[test]
    fn makespan_is_max_clock() {
        let c = ClusterSpec::new(1, 3);
        let run = run_cluster(&c, &ExecMode::Timing, |ctx| {
            ctx.compute(ctx.rank as f64 * 0.5);
        });
        assert!((run.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn two_sided_transfer_pays_sm_tax() {
        // Same bytes, same link: the two-sided (NCCL-kernel) transfer
        // must be slower than the one-sided (driver-copy) pull by the SM
        // tax plus the rendezvous penalty.
        let c = ClusterSpec::new(1, 2);
        let bytes = 64.0 * 1024.0 * 1024.0;
        let base = c.net.intra_lat + bytes / c.net.intra_bw;
        let two = run_cluster(&c, &ExecMode::Timing, |ctx| {
            if ctx.rank == 0 {
                let h = ctx.isend(1, "x", Buf::Shape(vec![16 * 1024 * 1024]));
                ctx.wait_send(h);
                0.0
            } else {
                ctx.wait_recv(0, "x", 1);
                ctx.clock.now
            }
        })
        .outputs[1];
        let one = run_cluster(&c, &ExecMode::Timing, |ctx| {
            if ctx.rank == 0 {
                ctx.expose("x", Buf::Shape(vec![16 * 1024 * 1024]));
                0.0
            } else {
                let h = ctx.get(0, "x", 1);
                ctx.wait_get(h);
                ctx.clock.now
            }
        })
        .outputs[1];
        assert!(two > one, "two-sided {two} must exceed one-sided {one}");
        assert!(two >= base * (1.0 + c.net.sm_tax), "{two} vs base {base}");
    }

    #[test]
    fn ring_exchange_through_ctx() {
        // Each rank pushes a token to its ring successor's window, then
        // reads its own window to find its predecessor's token.
        let c = ClusterSpec::new(2, 2);
        let run = run_cluster(&c, &ExecMode::Timing, |ctx| {
            let n = ctx.cluster().total_gpus();
            let next = (ctx.rank + 1) % n;
            let prev = (ctx.rank + n - 1) % n;
            ctx.put(next, "tok", Buf::Shape(vec![ctx.rank + 1]), 1);
            let h = ctx.get(ctx.rank, "tok", 1);
            let got = ctx.wait_get(h);
            assert_eq!(got.shape(), &[prev + 1]);
            got.shape()[0]
        });
        assert_eq!(run.outputs, vec![4, 1, 2, 3]);
    }

    #[test]
    fn attn_tile_time_monotone() {
        let c = ClusterSpec::new(1, 1);
        let w = CommWorld::new(c);
        let ctx = RankCtx {
            rank: 0,
            world: &w,
            clock: RankClock::new(),
            mode: ExecMode::Timing,
            epoch: 0,
        };
        let small = ctx.attn_tile_time(1, 128, 128, 1, 64);
        let big = ctx.attn_tile_time(1, 4096, 4096, 1, 64);
        assert!(big > small);
        // launch overhead is a floor
        assert!(small >= ctx.cluster().gpu.launch_overhead);
    }
}
