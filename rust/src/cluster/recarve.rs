//! Dynamic re-carving of live pods: plan *epochs*, drain barriers, and
//! the policies that decide when a pod trades its current carve for a
//! better one.
//!
//! The hybrid planner ([`crate::cluster::plan`]) freezes one
//! [`crate::cluster::plan::ParallelPlan`] when a pod admits a request
//! stream. That is the right call while traffic is homogeneous — but a
//! serving pod sees traffic *shift* (short image bursts giving way to
//! long CFG video, and back), and the plan
//! [`crate::analysis::choose_spec`] would pick for the new mix can differ
//! from the one the pod is carved into. This module models a pod as a
//! sequence of **plan epochs**:
//!
//! ```text
//!   epoch 0                  epoch 1                    epoch 2
//!   cfg1 x rep4 x U8R1  →→   cfg2 x pp2 x U8R1    →→    cfg1 x rep4 x U8R1
//!   [-- requests --]|drain|setup|[--- requests ---]|drain|setup|[- requests -]
//! ```
//!
//! Each epoch owns one `ParallelSpec`; transitioning requires **draining**
//! the in-flight groups (no request ever spans two carves — the old
//! epoch's batches run to completion behind the drain barrier), then
//! paying a modeled **re-setup** cost ([`resetup_cost`]) for tearing down
//! and rebuilding the carved [`crate::cluster::Mesh2D`] sub-meshes and
//! pipeline stages, before the first batch of the new epoch can start.
//!
//! When to pay that cost is a policy question — re-carving on every
//! preference flip thrashes, never re-carving serves long sequences with
//! a stale carve. [`RecarvePolicy`] covers the spectrum, and
//! [`EpochTracker`] is the per-pod state machine the epoch-aware router
//! ([`crate::coordinator::router`]) and serving loop
//! ([`crate::coordinator::engine::serve`]) drive. The numerics are
//! unaffected by construction: every epoch's plan is rebuilt from its
//! validated spec ([`EpochTracker::carved_plan`]), and
//! `rust/tests/sp_property.rs` proves oracle-exactness on both sides of
//! an epoch boundary, including a pipelined (`pp > 1`) to non-pipelined
//! transition.

use crate::cluster::plan::ParallelPlan;
use crate::config::{ClusterSpec, ParallelSpec};
use crate::sp::SpAlgo;

/// When a pod may trade its current carve for the plan the cost model
/// prefers for the traffic it is actually seeing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RecarvePolicy {
    /// The pre-recarve idealization (and the default, so existing serving
    /// paths are unchanged): adopt the preferred plan on every dispatch
    /// with **zero** modeled transition cost. This is what the planner
    /// implicitly assumed before epochs existed — useful as an upper
    /// bound on what any real policy can achieve.
    Free,
    /// Freeze the admission-time carve for the pod's lifetime. Requests
    /// preferring a different plan are served under the stale carve —
    /// the static-plan baseline `benches/fig_recarve.rs` compares
    /// against, and (for a fixed-plan service) exactly the pre-epoch
    /// serving behaviour. One exception: a carve that cannot serve a
    /// request *at all* still yields via [`EpochTracker::force`] —
    /// that transition is dictated by physics, not preference.
    Never,
    /// Re-carve only when the pod is idle at dispatch time (the drain
    /// barrier is free); under backlog the pod keeps its carve. Cheap
    /// and safe, but a saturated pod never gets to adapt.
    OnIdle,
    /// Re-carve once the cost model predicts at least `threshold`
    /// fractional per-step improvement (`0.1` = 10 %, via
    /// [`crate::analysis::recarve_gain`]) for `window` *consecutive*
    /// dispatches on the pod. The window is the hysteresis: alternating
    /// short/long traffic resets the streak before it fires, so the pod
    /// never thrashes between carves, while a sustained shift clears the
    /// window and pays the drain + re-setup once.
    Hysteresis {
        /// Minimum predicted fractional gain (e.g. `0.1` for 10 %).
        threshold: f64,
        /// Consecutive gainful dispatches required before re-carving.
        window: usize,
    },
    /// Group-granular re-carving: gated exactly like
    /// [`Self::Hysteresis`], but when the policy fires on a *busy* pod it
    /// does not wait for the pod-wide drain barrier. Instead the pod
    /// **splits** into two carve generations: the machines carrying the
    /// in-flight batch keep serving under the (narrowed) old carve,
    /// while the idle machines re-carve immediately into the plan the
    /// cost model prefers for their footprint
    /// ([`EpochTracker::split`] → [`PartialRecarve`]). The pod
    /// re-unifies — merging the side generation back and re-admitting a
    /// full-footprint carve — the first time both generations are idle
    /// at a dispatch ([`EpochTracker::merge`]). On an idle pod the
    /// policy degenerates to plain hysteresis (the drain is free, so a
    /// pod-wide transition is strictly better than a split).
    Partial {
        /// Minimum predicted fractional gain (e.g. `0.1` for 10 %).
        threshold: f64,
        /// Consecutive gainful dispatches required before re-carving.
        window: usize,
    },
    /// Forecast-driven re-carving: gated by the same `recarve_gain`
    /// arithmetic as [`Self::Hysteresis`], but the confirmation window
    /// is short-circuited when the arrival-mix forecaster
    /// ([`crate::analysis::Forecaster`]) already predicts the incoming
    /// workload class *dominates* the near-future mix
    /// ([`FORECAST_DOMINANCE`]): one gainful dispatch suffices, so the
    /// pod re-carves during the lull at the front of a phase shift
    /// instead of serving `window` requests stale first. When the
    /// forecast is silent (no dominant class, or no forecaster
    /// configured) the policy degrades to plain hysteresis — it never
    /// fires *later* than [`Self::Hysteresis`] with the same
    /// `threshold`/`window`.
    Forecast {
        /// Minimum predicted fractional gain (e.g. `0.1` for 10 %).
        threshold: f64,
        /// Hysteresis fallback window when the forecast is silent.
        window: usize,
    },
}

/// Forecast share above which an incoming workload class counts as
/// *dominating* the predicted arrival mix — the proactive trigger of
/// [`RecarvePolicy::Forecast`]. A strict majority: two-class traffic
/// cannot have both classes proactive at once.
pub const FORECAST_DOMINANCE: f64 = 0.5;

/// Forecast share below which a drained side carve's workload class
/// counts as *gone* from the predicted arrival mix — the cost-gate of
/// the forecast-driven absorb ([`EpochTracker::absorb_side`]): a
/// main-busy pod re-unifies a drained side generation only when the
/// forecaster says the side's class will not return, so the pod never
/// pays a merge it would immediately have to split back out of.
pub const FORECAST_ABSORB_EPS: f64 = 0.05;

impl RecarvePolicy {
    /// Does this policy read the modeled gain prediction passed to
    /// [`EpochTracker::on_dispatch`]? Callers use this to skip computing
    /// [`crate::analysis::recarve_gain`] for policies that ignore it —
    /// keep it in sync when adding a gain-driven policy variant.
    pub fn wants_gain(&self) -> bool {
        matches!(
            self,
            Self::Hysteresis { .. } | Self::Partial { .. } | Self::Forecast { .. }
        )
    }

    /// Parse a CLI policy name; `threshold`/`window` feed the
    /// hysteresis, partial, and forecast variants and are ignored by
    /// the others.
    pub fn from_name(name: &str, threshold: f64, window: usize) -> Option<Self> {
        match name {
            "free" => Some(Self::Free),
            "never" => Some(Self::Never),
            "on-idle" => Some(Self::OnIdle),
            "hysteresis" => Some(Self::Hysteresis { threshold, window }),
            "partial" => Some(Self::Partial { threshold, window }),
            "forecast" => Some(Self::Forecast { threshold, window }),
            _ => None,
        }
    }
}

impl std::fmt::Display for RecarvePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Free => write!(f, "free"),
            Self::Never => write!(f, "never"),
            Self::OnIdle => write!(f, "on-idle"),
            Self::Hysteresis { threshold, window } => {
                write!(f, "hysteresis({:.0}% x {window})", threshold * 100.0)
            }
            Self::Partial { threshold, window } => {
                write!(f, "partial({:.0}% x {window})", threshold * 100.0)
            }
            Self::Forecast { threshold, window } => {
                write!(f, "forecast({:.0}% x {window})", threshold * 100.0)
            }
        }
    }
}

/// The one view every per-dispatch policy decision reads: clock,
/// backlog, the plan preference, the modeled gain of adopting it, and
/// the forecaster's opinion of the incoming class — instead of the
/// ad-hoc positional argument lists the [`EpochTracker::on_dispatch`]
/// and `DispatchPolicy::pick` call sites grew across PRs 3–9. Built
/// with [`PolicyCtx::at`] plus chainable setters; fields a caller does
/// not know stay at their cheap defaults (`None` / `0`), and policies
/// that do not read a field never observe the difference (the knob-off
/// goldens are byte-identical by construction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyCtx {
    /// Virtual time the batch is ready to start.
    pub ready: f64,
    /// Virtual time the pod's in-flight work drains.
    pub free_at: f64,
    /// The plan the service model would carve for this batch's
    /// workload (`None` for unplanned models).
    pub preferred: Option<ParallelSpec>,
    /// Predicted fractional per-step improvement of moving from the
    /// current carve to `preferred`
    /// ([`crate::analysis::recarve_gain`]); only gain-driven policies
    /// read it ([`RecarvePolicy::wants_gain`]), so callers may leave
    /// it `None` for the others.
    pub gain: Option<f64>,
    /// The forecaster's predicted arrival-mix share of the incoming
    /// batch's workload class (`None` when no forecaster is
    /// configured); read by [`RecarvePolicy::Forecast`].
    pub forecast_share: Option<f64>,
    /// Requests queued behind this batch at decision time.
    pub backlog: usize,
}

impl PolicyCtx {
    /// The minimal context: the two clocks every policy reads.
    pub fn at(ready: f64, free_at: f64) -> Self {
        Self {
            ready,
            free_at,
            preferred: None,
            gain: None,
            forecast_share: None,
            backlog: 0,
        }
    }

    /// Attach the service model's preferred plan.
    pub fn preferred(mut self, spec: impl Into<Option<ParallelSpec>>) -> Self {
        self.preferred = spec.into();
        self
    }

    /// Attach the modeled re-carve gain.
    pub fn gain(mut self, gain: impl Into<Option<f64>>) -> Self {
        self.gain = gain.into();
        self
    }

    /// Attach the forecast share of the incoming workload class.
    pub fn forecast_share(mut self, share: impl Into<Option<f64>>) -> Self {
        self.forecast_share = share.into();
        self
    }

    /// Attach the queue depth behind this batch.
    pub fn backlog(mut self, backlog: usize) -> Self {
        self.backlog = backlog;
        self
    }
}

/// One plan epoch of a pod: a half-open span of virtual time during
/// which the pod is carved into one fixed plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanEpoch {
    /// Epoch index within the pod (0 = admission-time carve).
    pub index: usize,
    /// The epoch's hybrid spec; `None` for service models that do not
    /// plan (legacy single-mesh serving).
    pub plan: Option<ParallelSpec>,
    /// Virtual time the epoch became serveable (after the previous
    /// epoch's drain and this epoch's re-setup).
    pub started_at: f64,
    /// Requests served inside this epoch.
    pub served: usize,
}

impl PlanEpoch {
    /// Stable display key, matching the serving report's plan histogram:
    /// the spec's [`ParallelSpec::label`], or `single-mesh` for
    /// unplanned epochs.
    pub fn label(&self) -> String {
        self.plan
            .map_or_else(|| "single-mesh".to_string(), |s| s.label())
    }
}

/// Outcome of one dispatch-time policy decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    /// The carve the batch must be served under (the new plan if
    /// `recarved`, otherwise the — possibly stale — current one).
    pub carve: Option<ParallelSpec>,
    /// Whether an epoch boundary was crossed at this dispatch.
    pub recarved: bool,
    /// Seconds the batch waited on the drain barrier (previous epoch's
    /// in-flight work running to completion). Zero unless `recarved`.
    pub drain: f64,
    /// Re-setup seconds charged to the pod timeline. Zero unless
    /// `recarved` (and always zero under [`RecarvePolicy::Free`]).
    pub setup: f64,
    /// [`RecarvePolicy::Partial`] fired on a busy pod: the carve is kept
    /// (no pod-wide transition) and the caller should attempt a
    /// group-granular split ([`EpochTracker::split`]) — or fall back to
    /// a forced pod-wide transition when no machine-aligned split
    /// exists. Always false for every other policy.
    pub split_pending: bool,
}

impl Transition {
    fn keep(carve: Option<ParallelSpec>) -> Self {
        Self { carve, recarved: false, drain: 0.0, setup: 0.0, split_pending: false }
    }
}

/// One **group-granular** epoch: a side carve generation opened by a
/// partial re-carve on the idle machine subset of a busy pod
/// ([`EpochTracker::split`]). The pod-wide [`PlanEpoch`] log keeps
/// tracking the main generation; these entries record what the split-off
/// subset ran, where it lived, and when (if ever) it merged back.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupEpoch {
    /// Side-generation ordinal within the pod (0 = first split).
    pub index: usize,
    /// First machine of the subset (machine offset within the pod).
    pub base_machine: usize,
    /// Machine footprint of the subset.
    pub machines: usize,
    /// The subset's carve (sized for `machines`, not the whole pod).
    pub plan: Option<ParallelSpec>,
    /// Virtual time the subset became serveable (split + re-setup).
    pub started_at: f64,
    /// Requests served by this generation.
    pub served: usize,
    /// Virtual time the generation merged back into the pod-wide carve;
    /// `None` while live (or when a fleet resize dissolved it).
    pub merged_at: Option<f64>,
}

impl GroupEpoch {
    /// Stable display key, matching [`PlanEpoch::label`].
    pub fn label(&self) -> String {
        self.plan
            .map_or_else(|| "single-mesh".to_string(), |s| s.label())
    }
}

/// The live side generation of a split pod: its carve, machine
/// footprint, and its own serving timeline (`free_at`), independent of
/// the main generation's — the two generations serve concurrently.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SideCarve {
    /// The subset's carve (sized for `machines` whole machines).
    pub plan: Option<ParallelSpec>,
    /// First machine of the subset within the pod.
    pub base_machine: usize,
    /// Machine footprint of the subset.
    pub machines: usize,
    /// Virtual time this generation's in-flight work drains.
    pub free_at: f64,
    /// Index into [`EpochTracker::group_epochs`] for served attribution.
    epoch: usize,
}

/// Outcome of a group-granular (partial) re-carve: what the busy
/// generation narrowed to, what the idle subset re-carved into, and what
/// the split cost. Unlike a pod-wide [`Transition`] there is **no
/// drain** — the whole point is that only already-idle machines re-carve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartialRecarve {
    /// The busy generation's carve, narrowed to its in-flight machine
    /// footprint ([`ParallelSpec::narrowed_to_machines`]).
    pub narrowed: Option<ParallelSpec>,
    /// The idle subset's new carve.
    pub side: Option<ParallelSpec>,
    /// First machine of the side subset within the pod.
    pub base_machine: usize,
    /// Machine footprint of the side subset.
    pub machines: usize,
    /// Re-setup seconds the side generation paid before opening.
    pub setup: f64,
}

/// Modeled cost (seconds) of tearing down and rebuilding a pod's carved
/// sub-meshes at an epoch boundary: a host-side re-plan constant plus,
/// per log₂(P) communicator stage, a pod-wide barrier and the
/// window/communicator re-registration that one-sided libraries pay when
/// the symmetric heap is re-laid-out. Deliberately of NCCL/NVSHMEM
/// re-init magnitude (tens of milliseconds on a 32-GPU pod) — small next
/// to a video generation, ruinous if paid on every request, which is
/// exactly the trade the [`RecarvePolicy`] variants navigate.
pub fn resetup_cost(cluster: &ClusterSpec) -> f64 {
    /// Host-side cost of validating the spec and rebuilding the
    /// `ParallelPlan` / schedule state.
    const REPLAN_HOST: f64 = 5e-3;
    /// Per-log-stage communicator + window re-registration.
    const COMM_INIT: f64 = 4e-3;
    let p = cluster.total_gpus() as f64;
    let stages = p.log2().ceil().max(1.0);
    REPLAN_HOST + stages * (cluster.net.barrier_lat + COMM_INIT)
}

/// Per-pod epoch state machine: the current carve, the hysteresis
/// streak, and the epoch/drain observability the serving report
/// aggregates. Driven by the serving loop once per batch dispatch.
#[derive(Debug, Clone)]
pub struct EpochTracker {
    /// The pod's re-carving policy.
    pub policy: RecarvePolicy,
    /// Seconds charged per epoch transition (see [`resetup_cost`]).
    pub setup_cost: f64,
    /// False until the first dispatch adopts the admission-time carve.
    started: bool,
    carve: Option<ParallelSpec>,
    /// Consecutive gainful dispatches (hysteresis state).
    streak: usize,
    epochs: Vec<PlanEpoch>,
    recarve_count: usize,
    drain_time: f64,
    setup_time: f64,
    /// Epoch transitions fired by the forecast short-circuit *before*
    /// the hysteresis fallback window would have confirmed them.
    proactive_recarves: usize,
    /// Live side generation of a split pod ([`RecarvePolicy::Partial`]).
    side: Option<SideCarve>,
    /// Workload class the live side generation was opened for — what
    /// the forecast-gated absorb ([`Self::absorb_side`]) checks
    /// against the predicted mix.
    side_class: Option<&'static str>,
    /// Log of every side generation opened on this pod, in order.
    group_epochs: Vec<GroupEpoch>,
    partial_splits: usize,
    merges: usize,
    /// In-flight batches on the main generation, as `(done_at,
    /// replica_groups_occupied)` — a co-batched batch scatters shards
    /// across every replica group of the carve, so one busy replica's
    /// footprint undercounts it ([`Self::busy_replicas`]).
    inflight: Vec<(f64, usize)>,
}

impl EpochTracker {
    pub fn new(policy: RecarvePolicy, setup_cost: f64) -> Self {
        Self {
            policy,
            setup_cost,
            started: false,
            carve: None,
            streak: 0,
            epochs: Vec::new(),
            recarve_count: 0,
            drain_time: 0.0,
            setup_time: 0.0,
            proactive_recarves: 0,
            side: None,
            side_class: None,
            group_epochs: Vec::new(),
            partial_splits: 0,
            merges: 0,
            inflight: Vec::new(),
        }
    }

    /// The pod's current carve (`None` before the first dispatch, or for
    /// models that do not plan).
    pub fn carve(&self) -> Option<ParallelSpec> {
        self.carve
    }

    /// All epochs so far, in order; the last one is live.
    pub fn epochs(&self) -> &[PlanEpoch] {
        &self.epochs
    }

    /// Epoch transitions paid so far (the admission-time carve is not a
    /// transition).
    pub fn recarve_count(&self) -> usize {
        self.recarve_count
    }

    /// Total seconds epoch-opening batches waited on drain barriers.
    pub fn drain_time(&self) -> f64 {
        self.drain_time
    }

    /// Total re-setup seconds charged to the pod's timeline.
    pub fn setup_time(&self) -> f64 {
        self.setup_time
    }

    /// Epoch transitions the forecast short-circuit fired *ahead* of
    /// the hysteresis fallback window (always 0 for other policies).
    pub fn proactive_recarves(&self) -> usize {
        self.proactive_recarves
    }

    /// Workload class the live side generation was opened for
    /// (`None` when unsplit or unrecorded).
    pub fn side_class(&self) -> Option<&'static str> {
        self.side_class
    }

    /// Record the workload class the live side generation serves, for
    /// the forecast-gated absorb check ([`Self::absorb_side`]).
    pub fn note_side_class(&mut self, class: &'static str) {
        if self.side.is_some() {
            self.side_class = Some(class);
        }
    }

    /// Is the pod currently running two carve generations?
    pub fn is_split(&self) -> bool {
        self.side.is_some()
    }

    /// The live side generation, if the pod is split.
    pub fn side(&self) -> Option<&SideCarve> {
        self.side.as_ref()
    }

    /// The side generation's carve (`None` when unsplit).
    pub fn side_carve(&self) -> Option<ParallelSpec> {
        self.side.and_then(|s| s.plan)
    }

    /// When the side generation's in-flight work drains (`None` when
    /// unsplit).
    pub fn side_free_at(&self) -> Option<f64> {
        self.side.map(|s| s.free_at)
    }

    /// Every side generation this pod ever opened, in order; a live one
    /// (if any) is the last entry with `merged_at == None`.
    pub fn group_epochs(&self) -> &[GroupEpoch] {
        &self.group_epochs
    }

    /// Group-granular splits performed so far.
    pub fn partial_splits(&self) -> usize {
        self.partial_splits
    }

    /// Side generations merged back so far.
    pub fn merges(&self) -> usize {
        self.merges
    }

    /// Rebuild the current epoch's carved [`ParallelPlan`] — the step a
    /// real pod performs after the drain barrier: fresh `Mesh2D`
    /// sub-meshes and pipeline stages from the validated spec. `None`
    /// when the pod has no hybrid carve (single-mesh serving) *or* when
    /// the carve does not validate against `cluster` (a mismatched
    /// service model); the serving path models the latter as
    /// unserveable rather than panicking, and this accessor mirrors
    /// that posture.
    pub fn carved_plan(&self, cluster: &ClusterSpec, algo: SpAlgo) -> Option<ParallelPlan> {
        self.carve
            .and_then(|spec| ParallelPlan::build(cluster, spec, algo).ok())
    }

    /// Decide (and apply) the epoch transition for one batch dispatch,
    /// reading every decision input from one [`PolicyCtx`] view
    /// (clock, preference, modeled gain, forecast share, backlog).
    /// Callers that do not run a gain-driven policy may leave
    /// `ctx.gain` unset ([`RecarvePolicy::wants_gain`]); only
    /// [`RecarvePolicy::Forecast`] reads `ctx.forecast_share`.
    ///
    /// The first dispatch adopts `ctx.preferred` as the admission-time
    /// carve (epoch 0) at no cost. Afterwards a transition happens only
    /// when the preference differs from the current carve *and* the
    /// policy fires; the returned [`Transition`] carries the carve to
    /// serve under plus the drain/setup accounting the caller must
    /// commit to the pod's timeline
    /// ([`crate::coordinator::router::Router::commit_recarve`]).
    pub fn on_dispatch(&mut self, ctx: &PolicyCtx) -> Transition {
        let (ready_at, free_at, preferred) = (ctx.ready, ctx.free_at, ctx.preferred);
        if !self.started {
            self.started = true;
            self.carve = preferred;
            self.epochs.push(PlanEpoch {
                // 0 on the true first dispatch; after a fleet-scope
                // resize ([`Self::resize_reset`]) re-admission continues
                // the pod's epoch numbering
                index: self.epochs.len(),
                plan: preferred,
                started_at: ready_at.max(free_at),
                served: 0,
            });
            return Transition::keep(preferred);
        }
        if self.carve == preferred {
            self.streak = 0;
            return Transition::keep(self.carve);
        }
        let recarve = match self.policy {
            RecarvePolicy::Free => true,
            RecarvePolicy::Never => false,
            RecarvePolicy::OnIdle => free_at <= ready_at,
            RecarvePolicy::Hysteresis { threshold, window } => {
                if ctx.gain.is_some_and(|g| g >= threshold) {
                    self.streak += 1;
                } else {
                    self.streak = 0;
                }
                self.streak >= window.max(1)
            }
            RecarvePolicy::Partial { threshold, window } => {
                if ctx.gain.is_some_and(|g| g >= threshold) {
                    self.streak += 1;
                } else {
                    self.streak = 0;
                }
                if self.streak < window.max(1) {
                    false
                } else if free_at <= ready_at {
                    // idle pod: the drain barrier is free, so a plain
                    // pod-wide transition beats splitting
                    true
                } else {
                    // busy pod: keep the carve and ask the caller to
                    // split off the idle machines ([`Self::split`])
                    let mut t = Transition::keep(self.carve);
                    t.split_pending = true;
                    return t;
                }
            }
            RecarvePolicy::Forecast { threshold, window } => {
                if ctx.gain.is_some_and(|g| g >= threshold) {
                    self.streak += 1;
                } else {
                    self.streak = 0;
                }
                let confirmed = self.streak >= window.max(1);
                // the proactive short-circuit: one gainful dispatch is
                // enough when the forecaster already predicts the
                // incoming class dominates the near-future mix — the
                // re-carve lands at the front of the phase shift
                let predicted = self.streak >= 1
                    && ctx
                        .forecast_share
                        .is_some_and(|s| s >= FORECAST_DOMINANCE);
                if predicted && !confirmed {
                    self.proactive_recarves += 1;
                }
                confirmed || predicted
            }
        };
        if !recarve {
            return Transition::keep(self.carve);
        }
        self.transition(ready_at, free_at, preferred)
    }

    /// Force an epoch transition regardless of policy. The serving loop
    /// uses this when the live carve **cannot serve** a batch at all
    /// (e.g. a patch-pipeline granularity larger than the request's
    /// sequence): the re-carve is dictated by physics, not preference,
    /// so even [`RecarvePolicy::Never`] yields. The transition is paid
    /// for like any other (drain + re-setup).
    pub fn force(
        &mut self,
        ready_at: f64,
        free_at: f64,
        preferred: Option<ParallelSpec>,
    ) -> Transition {
        if !self.started || self.carve == preferred {
            return self.on_dispatch(&PolicyCtx::at(ready_at, free_at).preferred(preferred));
        }
        self.transition(ready_at, free_at, preferred)
    }

    /// The shared transition tail: bookkeeping + the new epoch.
    fn transition(
        &mut self,
        ready_at: f64,
        free_at: f64,
        preferred: Option<ParallelSpec>,
    ) -> Transition {
        self.streak = 0;
        self.recarve_count += 1;
        // Free models the pre-epoch idealization: the switch is
        // instantaneous and unpaid. Real policies drain in-flight work
        // and pay the re-setup before the new epoch opens.
        let (drain, setup) = if matches!(self.policy, RecarvePolicy::Free) {
            (0.0, 0.0)
        } else {
            ((free_at - ready_at).max(0.0), self.setup_cost)
        };
        self.drain_time += drain;
        self.setup_time += setup;
        // the drain barrier retires all in-flight work with the old carve
        self.inflight.clear();
        self.carve = preferred;
        self.epochs.push(PlanEpoch {
            index: self.epochs.len(),
            plan: preferred,
            // the true open time: the previous epoch's in-flight work
            // finishes at free_at even under the unpaid Free policy
            // (whose drain is recorded as zero), then setup is paid
            started_at: ready_at.max(free_at) + setup,
            served: 0,
        });
        Transition { carve: preferred, recarved: true, drain, setup, split_pending: false }
    }

    /// Group-granular (partial) re-carve of a **busy** pod: the machines
    /// carrying the in-flight batch keep serving under `narrowed` (the
    /// live carve restricted to their footprint,
    /// [`ParallelSpec::narrowed_to_machines`]) while the `machines` idle
    /// machines starting at `base_machine` immediately re-carve into
    /// `side_plan` — no drain barrier, only the side's re-setup cost.
    /// The pod then runs **two carve generations at once**: the main
    /// generation keeps the pod timeline, the side generation gets its
    /// own ([`Self::dispatch_side`]), and the pod re-unifies via
    /// [`Self::merge`] the first time both are idle.
    ///
    /// The caller (the scheduler,
    /// [`crate::coordinator::session::ServeSession`]) is responsible for
    /// the machine-footprint accounting: `narrowed` and `side_plan` must
    /// each tile their whole-machine subset
    /// ([`crate::cluster::plan::ParallelPlan::build_subset`] enforces
    /// alignment when the sub-meshes are actually built).
    pub fn split(
        &mut self,
        ready_at: f64,
        narrowed: Option<ParallelSpec>,
        side_plan: Option<ParallelSpec>,
        base_machine: usize,
        machines: usize,
    ) -> PartialRecarve {
        debug_assert!(
            self.side.is_none(),
            "a pod holds at most two carve generations; merge before re-splitting"
        );
        self.streak = 0;
        self.partial_splits += 1;
        let setup = self.setup_cost;
        self.setup_time += setup;
        // the busy generation narrows: its in-flight work continues
        // untouched, but future dispatches price (and log) the carve it
        // actually still holds. Occupancy restarts against the narrowed
        // carve's replica groups (a split pod never re-splits anyway).
        self.inflight.clear();
        self.carve = narrowed;
        self.epochs.push(PlanEpoch {
            index: self.epochs.len(),
            plan: narrowed,
            started_at: ready_at,
            served: 0,
        });
        let epoch = self.group_epochs.len();
        self.group_epochs.push(GroupEpoch {
            index: epoch,
            base_machine,
            machines,
            plan: side_plan,
            started_at: ready_at + setup,
            served: 0,
            merged_at: None,
        });
        self.side = Some(SideCarve {
            plan: side_plan,
            base_machine,
            machines,
            free_at: ready_at + setup,
            epoch,
        });
        PartialRecarve { narrowed, side: side_plan, base_machine, machines, setup }
    }

    /// Commit a batch to the side generation's timeline: service starts
    /// when both the side is free and the batch is ready. Returns
    /// `(start, done)`.
    pub fn dispatch_side(&mut self, ready_at: f64, service: f64) -> (f64, f64) {
        let s = self.side.as_mut().expect("dispatch_side on an unsplit pod");
        let start = s.free_at.max(ready_at);
        let done = start + service;
        s.free_at = done;
        (start, done)
    }

    /// Attribute `n` served requests to the live side generation.
    pub fn record_side_served(&mut self, n: usize) {
        if let Some(s) = &self.side {
            self.group_epochs[s.epoch].served += n;
        }
    }

    /// Re-unify a split pod: both generations are idle, so the side
    /// merges back and the pod re-admits a full-footprint carve on its
    /// next dispatch (adopted free, like [`Self::resize_reset`] — the
    /// merge barrier's re-setup, returned here, is the paid part; the
    /// caller charges it to the pod timeline via
    /// [`crate::coordinator::router::Router::commit_recarve`]).
    pub fn merge(&mut self, at: f64) -> f64 {
        let s = self.side.take().expect("merge on an unsplit pod");
        self.side_class = None;
        self.group_epochs[s.epoch].merged_at = Some(at);
        self.merges += 1;
        let setup = self.setup_cost;
        self.setup_time += setup;
        self.started = false;
        self.carve = None;
        self.streak = 0;
        self.inflight.clear();
        setup
    }

    /// Cost-gated early re-unification of a split pod: the side
    /// generation has drained and the forecaster says its traffic
    /// class won't return, so the **main-busy** pod absorbs the side's
    /// machines now instead of waiting for the fully-idle merge
    /// barrier ([`Self::merge`]). Unlike `merge`, the main generation
    /// is untouched — its carve, epoch, streak, and in-flight work all
    /// survive (the absorbed machines simply rejoin the pod footprint
    /// at the next pod-wide re-carve) — so only the side's teardown
    /// re-setup, returned here, is charged; the caller commits it to
    /// the pod timeline like any other transition cost.
    pub fn absorb_side(&mut self, at: f64) -> f64 {
        let s = self.side.take().expect("absorb_side on an unsplit pod");
        self.side_class = None;
        self.group_epochs[s.epoch].merged_at = Some(at);
        self.merges += 1;
        let setup = self.setup_cost;
        self.setup_time += setup;
        setup
    }

    /// Fleet-scope epoch boundary: the pod's machine footprint changed
    /// (cross-pod re-balancing,
    /// [`crate::coordinator::router::Router::rebalance_machine`]), so the
    /// live carve is obsolete no matter what the policy says. Closes the
    /// current epoch; the next dispatch re-admits — it adopts the
    /// model's preferred plan for the *new* footprint as a fresh
    /// admission-time carve at no further cost, because the migration
    /// barrier already charged drain + re-setup to the pod's timeline.
    /// Not counted in [`Self::recarve_count`] (that counts per-pod
    /// policy transitions; fleet events are reported separately).
    pub fn resize_reset(&mut self) {
        self.started = false;
        self.carve = None;
        self.streak = 0;
        self.inflight.clear();
        // a live side generation is dissolved by the footprint change
        // (its epoch log entry stays, with `merged_at` left `None`)
        self.side = None;
        self.side_class = None;
    }

    /// Attribute `n` served requests to the live epoch.
    pub fn record_served(&mut self, n: usize) {
        if let Some(e) = self.epochs.last_mut() {
            e.served += n;
        }
    }

    /// Record a batch committed to the main generation at virtual time
    /// `now`, running until `until` and occupying `replicas` replica
    /// groups of the live carve (1 for an ordinary batch; the full
    /// scatter width for a co-batched one). Expired entries are retired
    /// on the way in, so the log stays O(in-flight).
    pub fn note_inflight(&mut self, now: f64, until: f64, replicas: usize) {
        self.inflight.retain(|&(u, _)| u > now);
        self.inflight.push((until, replicas));
    }

    /// Replica groups of the live carve still serving at virtual time
    /// `now` — the occupancy a partial re-carve must treat as busy
    /// footprint. Main-generation dispatches are sequential, so the max
    /// over live entries is the one batch actually running.
    pub fn busy_replicas(&self, now: f64) -> usize {
        self.inflight
            .iter()
            .filter(|&&(u, _)| u > now)
            .map(|&(_, r)| r)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpDegrees;

    fn spec_a() -> ParallelSpec {
        ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
    }

    fn spec_b() -> ParallelSpec {
        ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
    }

    #[test]
    fn first_dispatch_adopts_admission_carve_for_free() {
        for policy in [
            RecarvePolicy::Free,
            RecarvePolicy::Never,
            RecarvePolicy::OnIdle,
            RecarvePolicy::Hysteresis { threshold: 0.1, window: 2 },
        ] {
            let mut t = EpochTracker::new(policy, 0.03);
            let tr = t.on_dispatch(&PolicyCtx::at(1.0, 0.0).preferred(spec_a()));
            assert!(!tr.recarved, "{policy:?}");
            assert_eq!(tr.carve, Some(spec_a()));
            assert_eq!((tr.drain, tr.setup), (0.0, 0.0));
            assert_eq!(t.epochs().len(), 1);
            assert_eq!(t.epochs()[0].index, 0);
            assert_eq!(t.recarve_count(), 0);
        }
    }

    #[test]
    fn never_serves_stale_under_the_admission_carve() {
        let mut t = EpochTracker::new(RecarvePolicy::Never, 0.03);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        let tr = t.on_dispatch(&PolicyCtx::at(1.0, 5.0).preferred(spec_b()).gain(0.9));
        assert!(!tr.recarved);
        assert_eq!(tr.carve, Some(spec_a()), "stale carve kept");
        assert_eq!(t.epochs().len(), 1);
        assert_eq!(t.recarve_count(), 0);
    }

    #[test]
    fn free_adopts_every_preference_at_zero_cost() {
        let mut t = EpochTracker::new(RecarvePolicy::Free, 0.03);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        let tr = t.on_dispatch(&PolicyCtx::at(1.0, 9.0).preferred(spec_b()));
        assert!(tr.recarved);
        assert_eq!(tr.carve, Some(spec_b()));
        assert_eq!((tr.drain, tr.setup), (0.0, 0.0), "free = unpaid");
        assert_eq!(t.setup_time(), 0.0);
        assert_eq!(t.recarve_count(), 1);
        assert_eq!(t.epochs().len(), 2);
    }

    #[test]
    fn on_idle_recarves_only_when_drained() {
        let mut t = EpochTracker::new(RecarvePolicy::OnIdle, 0.03);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        // pod busy until t=5, batch ready at t=1: keep the carve
        let busy = t.on_dispatch(&PolicyCtx::at(1.0, 5.0).preferred(spec_b()));
        assert!(!busy.recarved);
        // pod idle: re-carve, drain free, setup charged
        let idle = t.on_dispatch(&PolicyCtx::at(6.0, 5.0).preferred(spec_b()));
        assert!(idle.recarved);
        assert_eq!(idle.drain, 0.0);
        assert_eq!(idle.setup, 0.03);
        assert_eq!(t.carve(), Some(spec_b()));
    }

    #[test]
    fn hysteresis_needs_a_sustained_gain_streak() {
        let mut t =
            EpochTracker::new(RecarvePolicy::Hysteresis { threshold: 0.2, window: 2 }, 0.03);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        // gainful once, then below threshold: streak resets
        assert!(!t.on_dispatch(&PolicyCtx::at(1.0, 2.0).preferred(spec_b()).gain(0.5)).recarved);
        assert!(!t.on_dispatch(&PolicyCtx::at(2.0, 3.0).preferred(spec_b()).gain(0.1)).recarved);
        // a dispatch already on the preferred plan also resets the streak
        assert!(!t.on_dispatch(&PolicyCtx::at(3.0, 4.0).preferred(spec_b()).gain(0.5)).recarved);
        assert!(!t.on_dispatch(&PolicyCtx::at(4.0, 5.0).preferred(spec_a())).recarved);
        // two consecutive gainful dispatches: the second one fires
        assert!(!t.on_dispatch(&PolicyCtx::at(5.0, 8.0).preferred(spec_b()).gain(0.5)).recarved);
        let fire = t.on_dispatch(&PolicyCtx::at(6.0, 8.0).preferred(spec_b()).gain(0.5));
        assert!(fire.recarved);
        // drain = in-flight work (until t=8) minus readiness (t=6)
        assert_eq!(fire.drain, 2.0);
        assert_eq!(fire.setup, 0.03);
        assert_eq!(t.drain_time(), 2.0);
        assert_eq!(t.setup_time(), 0.03);
        // the new epoch opens after drain + setup
        assert_eq!(t.epochs()[1].started_at, 6.0 + 2.0 + 0.03);
        assert_eq!(t.epochs()[1].plan, Some(spec_b()));
    }

    #[test]
    fn force_overrides_never_and_invalid_carves_yield_no_plan() {
        let mut t = EpochTracker::new(RecarvePolicy::Never, 0.1);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        // the policy says keep; physics (an unserveable carve) says go
        let f = t.force(2.0, 5.0, Some(spec_b()));
        assert!(f.recarved);
        assert_eq!(f.drain, 3.0);
        assert_eq!(f.setup, 0.1);
        assert_eq!(t.carve(), Some(spec_b()));
        assert_eq!(t.recarve_count(), 1);
        // forcing onto the current carve is a no-op
        let same = t.force(6.0, 5.0, Some(spec_b()));
        assert!(!same.recarved);
        assert_eq!(t.recarve_count(), 1);
        // a carve that does not validate against the given cluster
        // yields None (modeled as unserveable), never a panic
        let tiny = ClusterSpec::new(1, 2);
        assert!(t.carved_plan(&tiny, SpAlgo::SwiftFusion).is_none());
    }

    #[test]
    fn unplanned_models_stay_in_one_epoch() {
        let mut t = EpochTracker::new(RecarvePolicy::Free, 0.03);
        for i in 0..4 {
            let tr = t.on_dispatch(&PolicyCtx::at(i as f64, 0.0));
            assert!(!tr.recarved);
            assert_eq!(tr.carve, None);
            t.record_served(1);
        }
        assert_eq!(t.epochs().len(), 1);
        assert_eq!(t.epochs()[0].served, 4);
        assert_eq!(t.epochs()[0].label(), "single-mesh");
    }

    #[test]
    fn resize_reset_reopens_admission_for_free() {
        let mut t = EpochTracker::new(RecarvePolicy::Never, 0.1);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        t.record_served(2);
        t.resize_reset();
        assert!(t.carve().is_none(), "carve obsolete after the resize");
        // next dispatch re-admits the (new-footprint) preferred plan at
        // no cost, even under Never — the migration barrier already paid
        let tr = t.on_dispatch(&PolicyCtx::at(3.0, 1.0).preferred(spec_b()));
        assert!(!tr.recarved);
        assert_eq!(tr.carve, Some(spec_b()));
        assert_eq!((tr.drain, tr.setup), (0.0, 0.0));
        assert_eq!(t.recarve_count(), 0, "fleet resets are not policy transitions");
        assert_eq!(t.epochs().len(), 2, "but they do open a new epoch");
        assert_eq!(t.epochs()[1].plan, Some(spec_b()));
        assert_eq!(t.epochs()[0].served, 2, "the closed epoch keeps its log");
    }

    #[test]
    fn carved_plan_rebuilds_the_epoch_mesh() {
        let cluster = ClusterSpec::new(4, 8);
        let mut t = EpochTracker::new(RecarvePolicy::Free, 0.0);
        assert!(t.carved_plan(&cluster, SpAlgo::SwiftFusion).is_none());
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_b()));
        let plan = t.carved_plan(&cluster, SpAlgo::SwiftFusion).unwrap();
        assert_eq!(plan.spec, spec_b());
        assert_eq!(plan.groups.len(), 2);
        assert_eq!(plan.groups[0].pp_degree(), 2);
    }

    #[test]
    fn resetup_cost_is_milliseconds_scale_and_grows_with_pod_size() {
        let small = resetup_cost(&ClusterSpec::new(1, 2));
        let big = resetup_cost(&ClusterSpec::new(4, 8));
        assert!(small > 1e-3 && big < 1.0, "{small} .. {big}");
        assert!(big > small);
    }

    #[test]
    fn policy_names_round_trip() {
        assert_eq!(
            RecarvePolicy::from_name("never", 0.0, 0),
            Some(RecarvePolicy::Never)
        );
        assert_eq!(RecarvePolicy::from_name("free", 0.0, 0), Some(RecarvePolicy::Free));
        assert_eq!(
            RecarvePolicy::from_name("on-idle", 0.0, 0),
            Some(RecarvePolicy::OnIdle)
        );
        assert_eq!(
            RecarvePolicy::from_name("hysteresis", 0.25, 3),
            Some(RecarvePolicy::Hysteresis { threshold: 0.25, window: 3 })
        );
        assert_eq!(
            RecarvePolicy::from_name("partial", 0.1, 2),
            Some(RecarvePolicy::Partial { threshold: 0.1, window: 2 })
        );
        assert_eq!(
            RecarvePolicy::from_name("forecast", 0.1, 2),
            Some(RecarvePolicy::Forecast { threshold: 0.1, window: 2 })
        );
        assert_eq!(RecarvePolicy::from_name("sometimes", 0.0, 0), None);
        assert!(RecarvePolicy::Hysteresis { threshold: 0.1, window: 2 }.wants_gain());
        assert!(RecarvePolicy::Partial { threshold: 0.1, window: 2 }.wants_gain());
        assert!(RecarvePolicy::Forecast { threshold: 0.1, window: 2 }.wants_gain());
        assert!(!RecarvePolicy::Never.wants_gain());
        assert!(!RecarvePolicy::Free.wants_gain());
        assert!(!RecarvePolicy::OnIdle.wants_gain());
        assert_eq!(RecarvePolicy::Never.to_string(), "never");
        assert!(RecarvePolicy::Hysteresis { threshold: 0.1, window: 2 }
            .to_string()
            .contains("10%"));
        assert!(RecarvePolicy::Partial { threshold: 0.1, window: 2 }
            .to_string()
            .starts_with("partial(10%"));
        assert!(RecarvePolicy::Forecast { threshold: 0.1, window: 2 }
            .to_string()
            .starts_with("forecast(10%"));
    }

    // ---- forecast-driven (proactive) re-carving --------------------------

    #[test]
    fn forecast_without_a_share_degrades_to_plain_hysteresis() {
        let policy = RecarvePolicy::Forecast { threshold: 0.2, window: 2 };
        let mut t = EpochTracker::new(policy, 0.03);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        // no forecast share in the ctx: the fallback window gates
        assert!(!t
            .on_dispatch(&PolicyCtx::at(1.0, 2.0).preferred(spec_b()).gain(0.5))
            .recarved);
        let fire = t.on_dispatch(&PolicyCtx::at(2.0, 3.0).preferred(spec_b()).gain(0.5));
        assert!(fire.recarved, "second gainful dispatch clears the window");
        assert_eq!(t.proactive_recarves(), 0, "nothing was ahead of the window");
    }

    #[test]
    fn forecast_dominance_short_circuits_the_window() {
        let policy = RecarvePolicy::Forecast { threshold: 0.2, window: 4 };
        let mut t = EpochTracker::new(policy, 0.03);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        // a sub-dominant share keeps the hysteresis gate
        let held = t.on_dispatch(
            &PolicyCtx::at(1.0, 2.0)
                .preferred(spec_b())
                .gain(0.5)
                .forecast_share(0.4),
        );
        assert!(!held.recarved);
        // a dominant predicted share fires on the very next gainful
        // dispatch, 2 dispatches ahead of the window-4 fallback
        let fire = t.on_dispatch(
            &PolicyCtx::at(2.0, 3.0)
                .preferred(spec_b())
                .gain(0.5)
                .forecast_share(0.8),
        );
        assert!(fire.recarved, "dominant forecast short-circuits");
        assert_eq!(t.proactive_recarves(), 1);
        assert_eq!(t.recarve_count(), 1);
        assert_eq!(t.carve(), Some(spec_b()));
        // a dominant share with a below-threshold gain never fires:
        // the forecast accelerates the gain gate, it does not replace it
        let quiet = t.on_dispatch(
            &PolicyCtx::at(3.0, 4.0)
                .preferred(spec_a())
                .gain(0.05)
                .forecast_share(0.9),
        );
        assert!(!quiet.recarved, "gain threshold still gates");
        assert_eq!(t.proactive_recarves(), 1);
    }

    // ---- forecast-gated side absorption ----------------------------------

    #[test]
    fn absorb_side_reunifies_without_touching_the_main_generation() {
        let mut t = partial_tracker(1);
        let narrowed = ParallelSpec::new(1, 1, SpDegrees::new(8, 1));
        t.split(2.0, Some(narrowed), Some(spec_b()), 1, 3);
        t.note_side_class("cfg_video_96k");
        assert_eq!(t.side_class(), Some("cfg_video_96k"));
        t.dispatch_side(2.0, 1.0);
        t.record_side_served(1);
        // main generation keeps serving (busy) while the side drains
        t.note_inflight(3.0, 9.0, 1);
        let setup = t.absorb_side(5.0);
        assert_eq!(setup, 0.25);
        assert!(!t.is_split());
        assert_eq!(t.side_class(), None);
        assert_eq!(t.merges(), 1, "an absorb is a (cost-gated) merge");
        assert_eq!(t.group_epochs()[0].merged_at, Some(5.0));
        assert_eq!(t.group_epochs()[0].served, 1);
        // unlike merge: the main generation survives untouched
        assert_eq!(t.carve(), Some(narrowed), "main carve kept");
        assert_eq!(t.busy_replicas(4.0), 1, "in-flight work kept");
        let tr = t.on_dispatch(&PolicyCtx::at(6.0, 9.0).preferred(narrowed));
        assert!(!tr.recarved, "no forced re-admission epoch");
        assert_eq!(t.epochs().len(), 2, "admission + narrowed epoch only");
    }

    // ---- group-granular (partial) re-carving -----------------------------

    fn partial_tracker(window: usize) -> EpochTracker {
        let policy = RecarvePolicy::Partial { threshold: 0.2, window };
        let mut t = EpochTracker::new(policy, 0.25);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        t
    }

    #[test]
    fn partial_on_an_idle_pod_transitions_pod_wide_like_hysteresis() {
        let mut t = partial_tracker(2);
        // one gainful dispatch: streak below window, carve kept
        let held = t.on_dispatch(&PolicyCtx::at(1.0, 0.5).preferred(spec_b()).gain(0.9));
        assert!(!held.recarved && !held.split_pending);
        // second gainful dispatch, pod idle: pod-wide transition fires
        let fire = t.on_dispatch(&PolicyCtx::at(2.0, 1.5).preferred(spec_b()).gain(0.9));
        assert!(fire.recarved, "idle pod degenerates to hysteresis");
        assert!(!fire.split_pending);
        assert_eq!((fire.drain, fire.setup), (0.0, 0.25));
        assert_eq!(t.carve(), Some(spec_b()));
        assert_eq!(t.recarve_count(), 1);
        assert!(!t.is_split());
        assert_eq!(t.partial_splits(), 0);
    }

    #[test]
    fn partial_on_a_busy_pod_requests_a_split() {
        let mut t = partial_tracker(1);
        // gainful dispatch on a busy pod (free_at > ready): no pod-wide
        // transition, the caller is asked to split
        let tr = t.on_dispatch(&PolicyCtx::at(1.0, 9.0).preferred(spec_b()).gain(0.9));
        assert!(tr.split_pending);
        assert!(!tr.recarved);
        assert_eq!(tr.carve, Some(spec_a()), "carve kept until the split");
        assert_eq!(t.recarve_count(), 0);
        // a below-threshold gain resets the streak and never asks
        let mut t2 = partial_tracker(1);
        let quiet = t2.on_dispatch(&PolicyCtx::at(1.0, 9.0).preferred(spec_b()).gain(0.1));
        assert!(!quiet.split_pending && !quiet.recarved);
    }

    #[test]
    fn split_opens_a_side_generation_with_its_own_timeline() {
        let mut t = partial_tracker(1);
        let narrowed = ParallelSpec::new(1, 1, SpDegrees::new(8, 1));
        let pr = t.split(2.0, Some(narrowed), Some(spec_b()), 1, 3);
        assert_eq!(
            pr,
            PartialRecarve {
                narrowed: Some(narrowed),
                side: Some(spec_b()),
                base_machine: 1,
                machines: 3,
                setup: 0.25,
            }
        );
        assert!(t.is_split());
        assert_eq!(t.carve(), Some(narrowed), "main generation narrowed");
        assert_eq!(t.side_carve(), Some(spec_b()));
        assert_eq!(t.side_free_at(), Some(2.25), "split + re-setup, no drain");
        assert_eq!(t.partial_splits(), 1);
        assert_eq!(t.recarve_count(), 0, "splits are not pod-wide transitions");
        assert_eq!(t.setup_time(), 0.25);
        assert_eq!(t.drain_time(), 0.0, "the whole point: no drain");
        // the main epoch log gained the narrowed epoch; the group log
        // gained the side generation
        assert_eq!(t.epochs().len(), 2);
        assert_eq!(t.epochs()[1].plan, Some(narrowed));
        assert_eq!(t.group_epochs().len(), 1);
        let ge = &t.group_epochs()[0];
        assert_eq!((ge.base_machine, ge.machines), (1, 3));
        assert_eq!(ge.plan, Some(spec_b()));
        assert_eq!(ge.started_at, 2.25);
        assert_eq!(ge.merged_at, None);
        assert_eq!(ge.label(), spec_b().label());

        // the side generation serves on its own timeline
        let (start, done) = t.dispatch_side(2.0, 1.0);
        assert_eq!((start, done), (2.25, 3.25));
        t.record_side_served(1);
        let (s2, d2) = t.dispatch_side(2.5, 1.0);
        assert_eq!((s2, d2), (3.25, 4.25), "side work queues on the side");
        t.record_side_served(1);
        assert_eq!(t.group_epochs()[0].served, 2);
        assert_eq!(t.epochs()[1].served, 0, "main epoch untouched by side work");
    }

    #[test]
    fn merge_reunifies_and_readmits_for_free() {
        let mut t = partial_tracker(1);
        let narrowed = ParallelSpec::new(1, 1, SpDegrees::new(8, 1));
        t.split(2.0, Some(narrowed), Some(spec_b()), 1, 3);
        t.dispatch_side(2.0, 1.0);
        t.record_side_served(1);
        let setup = t.merge(8.0);
        assert_eq!(setup, 0.25);
        assert!(!t.is_split());
        assert_eq!(t.merges(), 1);
        assert_eq!(t.setup_time(), 0.5, "split + merge each paid one re-setup");
        assert_eq!(t.group_epochs()[0].merged_at, Some(8.0));
        assert_eq!(t.group_epochs()[0].served, 1, "closed epoch keeps its log");
        assert!(t.carve().is_none(), "carve obsolete until re-admission");
        // next dispatch re-admits the preferred full-pod plan at no cost
        let tr = t.on_dispatch(&PolicyCtx::at(9.0, 8.0).preferred(spec_b()));
        assert!(!tr.recarved && !tr.split_pending);
        assert_eq!(tr.carve, Some(spec_b()));
        assert_eq!((tr.drain, tr.setup), (0.0, 0.0));
        assert_eq!(t.epochs().len(), 3, "re-admission opens a fresh pod-wide epoch");
    }

    #[test]
    fn resize_reset_dissolves_a_live_side_generation() {
        let mut t = partial_tracker(1);
        t.split(1.0, Some(spec_a()), Some(spec_b()), 1, 3);
        assert!(t.is_split());
        t.resize_reset();
        assert!(!t.is_split(), "footprint change dissolves the side");
        assert_eq!(t.group_epochs().len(), 1, "the log entry survives");
        assert_eq!(t.group_epochs()[0].merged_at, None);
        assert_eq!(t.merges(), 0, "a resize is not a merge");
    }

    // ---- in-flight replica-group occupancy -------------------------------

    #[test]
    fn inflight_occupancy_tracks_the_live_batch_footprint() {
        let mut t = EpochTracker::new(RecarvePolicy::Never, 0.1);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        assert_eq!(t.busy_replicas(0.0), 0, "idle pod occupies nothing");
        // a co-batched batch scatters across all 4 replica groups
        t.note_inflight(0.0, 4.0, 4);
        assert_eq!(t.busy_replicas(1.0), 4);
        assert_eq!(t.busy_replicas(4.0), 0, "retired at its completion time");
        // sequential dispatches: the later batch defines the footprint
        t.note_inflight(4.0, 6.0, 1);
        assert_eq!(t.busy_replicas(5.0), 1);
    }

    #[test]
    fn epoch_boundaries_clear_inflight_occupancy() {
        // a pod-wide transition drains all in-flight work
        let mut t = EpochTracker::new(RecarvePolicy::Free, 0.1);
        t.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec_a()));
        t.note_inflight(0.0, 10.0, 4);
        t.force(1.0, 10.0, Some(spec_b()));
        assert_eq!(t.busy_replicas(1.0), 0, "transition clears occupancy");
        // split, merge, and resize each reset the footprint log too
        let mut s = partial_tracker(1);
        s.note_inflight(0.5, 9.0, 4);
        s.split(1.0, Some(spec_a()), Some(spec_b()), 1, 3);
        assert_eq!(s.busy_replicas(1.0), 0, "split restarts occupancy");
        s.note_inflight(2.0, 9.0, 1);
        s.merge(9.5);
        assert_eq!(s.busy_replicas(3.0), 0, "merge clears occupancy");
        let mut r = partial_tracker(1);
        r.note_inflight(0.0, 9.0, 2);
        r.resize_reset();
        assert_eq!(r.busy_replicas(1.0), 0, "resize clears occupancy");
    }
}
