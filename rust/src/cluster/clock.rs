//! Per-rank simulated clocks.
//!
//! Each GPU rank owns a [`RankClock`]: a virtual-time counter advanced by
//! the compute cost model and the network cost model, *never* by wall
//! time. Transfers additionally serialize on the rank-local egress /
//! ingress queues (a GPU's NVLink egress and its NIC share are the
//! dominant serialization points; cross-rank contention is captured
//! statically via the caller-provided flow counts — see DESIGN.md §2).
//!
//! The clock also keeps a breakdown by [`TimeKind`], which regenerates the
//! paper's Figure 3b (compute vs exposed-communication split).

/// What a span of virtual time was spent on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TimeKind {
    /// Attention / model-stage computation.
    Compute,
    /// Blocked waiting for data (exposed, non-overlapped communication).
    CommWait,
    /// Two-sided rendezvous / barrier synchronization.
    Sync,
    /// Kernel-launch and transfer-issue overheads.
    Overhead,
}

/// Virtual clock + accounting for one rank.
#[derive(Debug, Clone, Default)]
pub struct RankClock {
    /// Current virtual time, seconds.
    pub now: f64,
    /// Egress queue: earliest time the next outgoing transfer can start.
    pub egress_free: f64,
    /// Ingress queue: earliest time the next incoming pull can start.
    pub ingress_free: f64,
    /// Number of in-flight two-sided transfers (SM-contention tracking).
    pub two_sided_inflight: usize,
    breakdown: [f64; 4],
    /// Recorded (start, end, kind) spans — the per-rank timeline behind
    /// `swiftfusion trace` (chrome://tracing export).
    spans: Vec<(f64, f64, TimeKind)>,
}

fn kind_idx(k: TimeKind) -> usize {
    match k {
        TimeKind::Compute => 0,
        TimeKind::CommWait => 1,
        TimeKind::Sync => 2,
        TimeKind::Overhead => 3,
    }
}

impl RankClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance the clock by `dt`, attributing it to `kind`.
    pub fn advance(&mut self, dt: f64, kind: TimeKind) {
        debug_assert!(dt >= 0.0, "negative advance {dt}");
        if dt > 0.0 {
            self.spans.push((self.now, self.now + dt, kind));
        }
        self.now += dt;
        self.breakdown[kind_idx(kind)] += dt;
    }

    /// Jump the clock forward to `t` (no-op if already past), attributing
    /// the waited span to `kind`.
    pub fn advance_to(&mut self, t: f64, kind: TimeKind) {
        if t > self.now {
            let dt = t - self.now;
            self.spans.push((self.now, t, kind));
            self.now = t;
            self.breakdown[kind_idx(kind)] += dt;
        }
    }

    /// The recorded timeline: (start, end, kind) spans in issue order.
    pub fn spans(&self) -> &[(f64, f64, TimeKind)] {
        &self.spans
    }

    /// Reserve the egress queue for a transfer of duration `dur` that may
    /// start no earlier than `earliest`; returns (start, done).
    pub fn reserve_egress(&mut self, earliest: f64, dur: f64) -> (f64, f64) {
        let start = earliest.max(self.egress_free);
        let done = start + dur;
        self.egress_free = done;
        (start, done)
    }

    /// Same for the ingress queue (pull-side serialization).
    pub fn reserve_ingress(&mut self, earliest: f64, dur: f64) -> (f64, f64) {
        let start = earliest.max(self.ingress_free);
        let done = start + dur;
        self.ingress_free = done;
        (start, done)
    }

    pub fn time_in(&self, kind: TimeKind) -> f64 {
        self.breakdown[kind_idx(kind)]
    }

    /// (compute, comm_wait, sync, overhead) split — the Fig. 3b quadruple.
    pub fn breakdown(&self) -> (f64, f64, f64, f64) {
        (
            self.breakdown[0],
            self.breakdown[1],
            self.breakdown[2],
            self.breakdown[3],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_accumulates_by_kind() {
        let mut c = RankClock::new();
        c.advance(1.0, TimeKind::Compute);
        c.advance(0.5, TimeKind::CommWait);
        c.advance(0.25, TimeKind::Compute);
        assert_eq!(c.now, 1.75);
        assert_eq!(c.time_in(TimeKind::Compute), 1.25);
        assert_eq!(c.time_in(TimeKind::CommWait), 0.5);
        assert_eq!(c.time_in(TimeKind::Sync), 0.0);
    }

    #[test]
    fn advance_to_only_moves_forward() {
        let mut c = RankClock::new();
        c.advance(2.0, TimeKind::Compute);
        c.advance_to(1.0, TimeKind::CommWait); // in the past: no-op
        assert_eq!(c.now, 2.0);
        assert_eq!(c.time_in(TimeKind::CommWait), 0.0);
        c.advance_to(3.0, TimeKind::CommWait);
        assert_eq!(c.now, 3.0);
        assert_eq!(c.time_in(TimeKind::CommWait), 1.0);
    }

    #[test]
    fn egress_serializes_transfers() {
        let mut c = RankClock::new();
        let (s1, d1) = c.reserve_egress(0.0, 1.0);
        let (s2, d2) = c.reserve_egress(0.0, 1.0);
        assert_eq!((s1, d1), (0.0, 1.0));
        assert_eq!((s2, d2), (1.0, 2.0)); // queued behind the first
        // a transfer that can only start later leaves a gap
        let (s3, d3) = c.reserve_egress(5.0, 1.0);
        assert_eq!((s3, d3), (5.0, 6.0));
    }

    #[test]
    fn ingress_independent_of_egress() {
        let mut c = RankClock::new();
        c.reserve_egress(0.0, 10.0);
        let (s, d) = c.reserve_ingress(0.0, 1.0);
        assert_eq!((s, d), (0.0, 1.0));
    }

    #[test]
    fn spans_cover_breakdown_exactly() {
        let mut c = RankClock::new();
        c.advance(1.0, TimeKind::Compute);
        c.advance_to(3.0, TimeKind::CommWait);
        c.advance_to(2.0, TimeKind::Sync); // past: no span
        let spans = c.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0], (0.0, 1.0, TimeKind::Compute));
        assert_eq!(spans[1], (1.0, 3.0, TimeKind::CommWait));
        let total: f64 = spans.iter().map(|(s, e, _)| e - s).sum();
        let b = c.breakdown();
        assert!((total - (b.0 + b.1 + b.2 + b.3)).abs() < 1e-12);
    }

    #[test]
    fn breakdown_tuple() {
        let mut c = RankClock::new();
        c.advance(1.0, TimeKind::Compute);
        c.advance(2.0, TimeKind::CommWait);
        c.advance(3.0, TimeKind::Sync);
        c.advance(4.0, TimeKind::Overhead);
        assert_eq!(c.breakdown(), (1.0, 2.0, 3.0, 4.0));
    }
}
