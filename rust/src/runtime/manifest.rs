//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust engine. Parsed from `artifacts/manifest.json` with the in-tree JSON
//! parser; every entry records exact input/output shapes so calls are
//! shape-checked at the API boundary instead of failing inside XLA.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One AOT-lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// One validation config (mirrors `model.VALIDATION_CONFIGS` in python).
#[derive(Debug, Clone)]
pub struct ConfigMeta {
    pub name: String,
    pub b: usize,
    pub l: usize,
    pub h: usize,
    pub d: usize,
    pub depth: usize,
    pub c_in: usize,
    pub mesh: usize,
    pub hidden: usize,
    pub chunk: usize,
    pub head_groups: Vec<usize>,
    pub seed: u64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: Vec<ConfigMeta>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn shape_list(v: &Json, what: &str) -> Result<Vec<Vec<usize>>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("{what}: expected array of shapes"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow!("{what}: expected shape array"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("{what}: bad dim")))
                .collect()
        })
        .collect()
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| anyhow!("config missing usize field '{key}'"))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let version = root.get("version").as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }

        let mut configs = Vec::new();
        for c in root.get("configs").as_arr().unwrap_or(&[]) {
            configs.push(ConfigMeta {
                name: c
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("config missing name"))?
                    .to_string(),
                b: req_usize(c, "b")?,
                l: req_usize(c, "l")?,
                h: req_usize(c, "h")?,
                d: req_usize(c, "d")?,
                depth: req_usize(c, "depth")?,
                c_in: req_usize(c, "c_in")?,
                mesh: req_usize(c, "mesh")?,
                hidden: req_usize(c, "hidden")?,
                chunk: req_usize(c, "chunk")?,
                head_groups: c
                    .get("head_groups")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|g| g.as_usize())
                    .collect(),
                seed: c.get("seed").as_i64().unwrap_or(0) as u64,
            });
        }

        let mut artifacts = BTreeMap::new();
        for a in root.get("artifacts").as_arr().unwrap_or(&[]) {
            let name = a
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let meta = ArtifactMeta {
                name: name.clone(),
                file: dir.join(
                    a.get("file")
                        .as_str()
                        .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
                ),
                inputs: shape_list(a.get("inputs"), &name)?,
                outputs: shape_list(a.get("outputs"), &name)?,
            };
            artifacts.insert(name, meta);
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Self { dir, configs, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    pub fn config(&self, name: &str) -> Result<&ConfigMeta> {
        self.configs
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow!("config '{name}' not in manifest"))
    }

    /// Default artifacts directory: `$SWIFTFUSION_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("SWIFTFUSION_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // Walk up from CWD looking for artifacts/manifest.json (tests run
        // from the crate root; binaries may run elsewhere).
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sfu_manifest_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const GOOD: &str = r#"{
      "version": 1,
      "configs": [{"name":"small4","b":1,"l":128,"h":4,"d":16,"depth":2,
                   "c_in":16,"mesh":4,"hidden":64,"chunk":32,
                   "head_groups":[1,2,4],"seed":1}],
      "artifacts": [{"name":"attn_full_small4","file":"attn_full_small4.hlo.txt",
                     "inputs":[[1,128,4,16],[1,128,4,16],[1,128,4,16]],
                     "outputs":[[1,128,4,16]]}]
    }"#;

    #[test]
    fn loads_good_manifest() {
        let d = tmpdir("good");
        write_manifest(&d, GOOD);
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.configs.len(), 1);
        let c = m.config("small4").unwrap();
        assert_eq!(c.chunk, 32);
        assert_eq!(c.head_groups, vec![1, 2, 4]);
        let a = m.artifact("attn_full_small4").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.outputs[0], vec![1, 128, 4, 16]);
    }

    #[test]
    fn missing_artifact_is_error() {
        let d = tmpdir("missing");
        write_manifest(&d, GOOD);
        let m = Manifest::load(&d).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let d = tmpdir("badver");
        write_manifest(&d, r#"{"version": 2, "artifacts": [{"name":"x","file":"x","inputs":[],"outputs":[]}]}"#);
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_empty() {
        let d = tmpdir("empty");
        write_manifest(&d, r#"{"version": 1, "configs": [], "artifacts": []}"#);
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn missing_file_is_context_error() {
        let d = tmpdir("nofile");
        let err = Manifest::load(&d).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
