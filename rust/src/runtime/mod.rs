//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), while the
//! simulated cluster runs one thread per GPU rank. The runtime therefore
//! owns the client on a dedicated **service thread**; [`RuntimeHandle`] is
//! a cheap `Clone + Send` handle that ships [`Tensor`] inputs over a
//! channel and receives outputs back. Executables are compiled lazily on
//! first call and cached (one compiled executable per artifact, as the
//! paper's engine keeps one CUDA graph per model variant).
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥0.5
//! emits 64-bit instruction ids in serialized protos that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py docstring).

pub mod manifest;

pub use manifest::{ArtifactMeta, ConfigMeta, Manifest};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

/// Aggregate execution counters (perf accounting; see EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub calls: AtomicU64,
    pub compile_ns: AtomicU64,
    pub execute_ns: AtomicU64,
}

enum Req {
    Call {
        name: String,
        inputs: Vec<Tensor>,
        resp: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    /// Hot-path fusion (§Perf): run a whole softmax-carry chain — one q
    /// tile against many KV tiles — on the service thread, keeping the
    /// (O', l, m) state as XLA literals between steps instead of paying
    /// a channel roundtrip + tensor conversion per tile.
    AttnChain {
        partial: String,
        q: Tensor,
        kvs: Vec<(Tensor, Tensor)>,
        state: Box<(Tensor, Tensor, Tensor)>,
        resp: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    Precompile {
        names: Vec<String>,
        resp: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// Cloneable, Send-able handle used by rank threads and the coordinator.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Req>,
    manifest: Arc<Manifest>,
    stats: Arc<RuntimeStats>,
}

/// Owns the service thread; dropping shuts it down.
pub struct Runtime {
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Runtime {
    /// Load the manifest from `dir` and start the PJRT service thread.
    pub fn load(dir: impl Into<std::path::PathBuf>) -> Result<Self> {
        let manifest = Arc::new(Manifest::load(dir.into())?);
        let stats = Arc::new(RuntimeStats::default());
        let (tx, rx) = mpsc::channel::<Req>();
        let m2 = Arc::clone(&manifest);
        let s2 = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(rx, m2, s2))
            .context("spawning pjrt service thread")?;
        Ok(Self {
            handle: RuntimeHandle { tx, manifest, stats },
            join: Some(join),
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Self> {
        Self::load(Manifest::default_dir())
    }

    /// Load the default artifacts if a real PJRT backend is linked and
    /// the manifest exists; `None` (with a stderr note) otherwise. This
    /// is what lets artifact-dependent integration tests *skip* instead
    /// of fail in offline builds (the vendored `xla` stub reports
    /// PJRT unavailable).
    pub fn load_default_if_available() -> Option<Self> {
        if !pjrt_available() {
            eprintln!("skipping: PJRT unavailable (offline xla stub linked)");
            return None;
        }
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!(
                "skipping: no artifacts at {} (run `make artifacts`)",
                dir.display()
            );
            return None;
        }
        match Self::load(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: artifact load failed: {e:#}");
                None
            }
        }
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.handle.manifest
    }

    pub fn stats(&self) -> &RuntimeStats {
        &self.handle.stats
    }
}

/// True when the linked `xla` crate has a real PJRT backend (false with
/// the offline stub vendored at `rust/vendor/xla`).
pub fn pjrt_available() -> bool {
    xla::AVAILABLE
}

impl Drop for Runtime {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `name` on `inputs`; shape-checked against the
    /// manifest before dispatch.
    pub fn call(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.call_owned(name, inputs.to_vec())
    }

    /// Like [`Self::call`] but takes ownership — the hot tile path uses
    /// this to avoid re-cloning the (large) carry-state tensors
    /// (§Perf L3-3).
    pub fn call_owned(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let meta = self.manifest.artifact(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.shape() != want.as_slice() {
                bail!(
                    "artifact '{name}' input {i}: shape {:?} != manifest {:?}",
                    t.shape(),
                    want
                );
            }
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Req::Call { name: name.to_string(), inputs, resp: rtx })
            .map_err(|_| anyhow!("pjrt service thread is gone"))?;
        rrx.recv().map_err(|_| anyhow!("pjrt service dropped response"))?
    }

    /// Execute a softmax-carry chain: `q` against each KV tile in turn,
    /// threading the (O', l, m) state through `partial` without
    /// round-tripping it to the caller (see `Req::AttnChain`). Returns
    /// the final [o, l, m].
    pub fn call_attn_chain(
        &self,
        partial: &str,
        q: &Tensor,
        kvs: Vec<(Tensor, Tensor)>,
        state: (Tensor, Tensor, Tensor),
    ) -> Result<Vec<Tensor>> {
        let meta = self.manifest.artifact(partial)?;
        if meta.inputs.len() != 6 {
            bail!("'{partial}' is not a carry-chain artifact");
        }
        for (k, v) in &kvs {
            if k.shape() != meta.inputs[1].as_slice() || v.shape() != meta.inputs[2].as_slice() {
                bail!(
                    "chain kv tile shape {:?}/{:?} != manifest {:?}",
                    k.shape(),
                    v.shape(),
                    meta.inputs[1]
                );
            }
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Req::AttnChain {
                partial: partial.to_string(),
                q: q.clone(),
                kvs,
                state: Box::new(state),
                resp: rtx,
            })
            .map_err(|_| anyhow!("pjrt service thread is gone"))?;
        rrx.recv().map_err(|_| anyhow!("pjrt service dropped response"))?
    }

    /// Compile a set of artifacts ahead of the hot path.
    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Req::Precompile {
                names: names.iter().map(|s| s.to_string()).collect(),
                resp: rtx,
            })
            .map_err(|_| anyhow!("pjrt service thread is gone"))?;
        rrx.recv().map_err(|_| anyhow!("pjrt service dropped response"))?
    }

    pub fn stats(&self) -> &RuntimeStats {
        &self.stats
    }
}

// ---------------------------------------------------------------------------
// Service thread
// ---------------------------------------------------------------------------

struct Service {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    stats: Arc<RuntimeStats>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

fn service_main(rx: mpsc::Receiver<Req>, manifest: Arc<Manifest>, stats: Arc<RuntimeStats>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the creation error.
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Call { resp, .. } | Req::AttnChain { resp, .. } => {
                        let _ = resp.send(Err(anyhow!("pjrt cpu client failed: {e:?}")));
                    }
                    Req::Precompile { resp, .. } => {
                        let _ = resp.send(Err(anyhow!("pjrt cpu client failed: {e:?}")));
                    }
                    Req::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut svc = Service { client, manifest, stats, cache: HashMap::new() };
    while let Ok(req) = rx.recv() {
        match req {
            Req::Call { name, inputs, resp } => {
                let _ = resp.send(svc.call(&name, &inputs));
            }
            Req::AttnChain { partial, q, kvs, state, resp } => {
                let _ = resp.send(svc.attn_chain(&partial, &q, &kvs, *state));
            }
            Req::Precompile { names, resp } => {
                let mut result = Ok(());
                for n in &names {
                    if let Err(e) = svc.ensure_compiled(n) {
                        result = Err(e);
                        break;
                    }
                }
                let _ = resp.send(result);
            }
            Req::Shutdown => break,
        }
    }
}

impl Service {
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        // Guard against elided weight constants: jax's as_hlo_text()
        // prints `constant({...})` unless print_large_constants=True, and
        // the text parser would silently zero them (model "runs", wrong).
        let text = std::fs::read_to_string(&meta.file)
            .map_err(|e| anyhow!("reading {}: {e}", meta.file.display()))?;
        if text.contains("constant({...})") {
            bail!(
                "artifact '{name}' has elided constants — regenerate with \
                 `make artifacts` (aot.py must print_large_constants)"
            );
        }
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .map_err(|e| anyhow!("loading {}: {e:?}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling '{name}': {e:?}"))?;
        self.stats
            .compile_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    fn call(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.ensure_compiled(name)?;
        let meta = self.manifest.artifact(name)?.clone();
        let exe = self.cache.get(name).expect("just compiled");

        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let bufs = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?;
        let out_lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output of '{name}': {e:?}"))?;
        self.stats
            .execute_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.calls.fetch_add(1, Ordering::Relaxed);

        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling output of '{name}': {e:?}"))?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, shape)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("reading output of '{name}': {e:?}"))?;
                Tensor::new(shape.clone(), data)
                    .map_err(|e| anyhow!("output of '{name}': {e}"))
            })
            .collect()
    }
}

impl Service {
    /// The carry-chain fast path: state stays as XLA literals across KV
    /// tiles; only the final (o, l, m) is converted back to tensors.
    fn attn_chain(
        &mut self,
        partial: &str,
        q: &Tensor,
        kvs: &[(Tensor, Tensor)],
        state: (Tensor, Tensor, Tensor),
    ) -> Result<Vec<Tensor>> {
        self.ensure_compiled(partial)?;
        let meta = self.manifest.artifact(partial)?.clone();
        let exe = self.cache.get(partial).expect("just compiled");

        let q_lit = tensor_to_literal(q)?;
        let mut o = tensor_to_literal(&state.0)?;
        let mut l = tensor_to_literal(&state.1)?;
        let mut m = tensor_to_literal(&state.2)?;
        let t0 = Instant::now();
        for (k, v) in kvs {
            let k_lit = tensor_to_literal(k)?;
            let v_lit = tensor_to_literal(v)?;
            let bufs = exe
                .execute::<&xla::Literal>(&[&q_lit, &k_lit, &v_lit, &o, &l, &m])
                .map_err(|e| anyhow!("chain step '{partial}': {e:?}"))?;
            let out = bufs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("chain fetch '{partial}': {e:?}"))?;
            let mut parts = out
                .to_tuple()
                .map_err(|e| anyhow!("chain untuple '{partial}': {e:?}"))?;
            anyhow::ensure!(parts.len() == 3, "carry chain expects 3 outputs");
            m = parts.pop().unwrap();
            l = parts.pop().unwrap();
            o = parts.pop().unwrap();
            self.stats.calls.fetch_add(1, Ordering::Relaxed);
        }
        self.stats
            .execute_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let shapes = &meta.outputs;
        let mut out = Vec::with_capacity(3);
        for (lit, shape) in [o, l, m].into_iter().zip(shapes) {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("chain output of '{partial}': {e:?}"))?;
            out.push(Tensor::new(shape.clone(), data)?);
        }
        Ok(out)
    }
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    if t.rank() == 0 {
        return Ok(xla::Literal::scalar(t.data()[0]));
    }
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .map_err(|e| anyhow!("building literal {:?}: {e:?}", t.shape()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime tests (against real artifacts) live in
    // rust/tests/runtime_artifacts.rs; here we cover the handle-side
    // validation logic which needs no artifacts on disk.

    fn fake_manifest() -> Arc<Manifest> {
        use std::collections::BTreeMap;
        let mut artifacts = BTreeMap::new();
        artifacts.insert(
            "f".to_string(),
            ArtifactMeta {
                name: "f".into(),
                file: "/nonexistent".into(),
                inputs: vec![vec![2, 2]],
                outputs: vec![vec![2, 2]],
            },
        );
        Arc::new(Manifest { dir: "/nonexistent".into(), configs: vec![], artifacts })
    }

    fn handle_with_dead_service() -> (RuntimeHandle, mpsc::Receiver<Req>) {
        let (tx, rx) = mpsc::channel();
        (
            RuntimeHandle {
                tx,
                manifest: fake_manifest(),
                stats: Arc::new(RuntimeStats::default()),
            },
            rx,
        )
    }

    #[test]
    fn call_rejects_wrong_arity() {
        let (h, _rx) = handle_with_dead_service();
        let err = h.call("f", &[]).unwrap_err();
        assert!(err.to_string().contains("expects 1 inputs"));
    }

    #[test]
    fn call_rejects_wrong_shape() {
        let (h, _rx) = handle_with_dead_service();
        let t = Tensor::zeros(&[3, 3]);
        let err = h.call("f", &[t]).unwrap_err();
        assert!(err.to_string().contains("shape"));
    }

    #[test]
    fn call_rejects_unknown_artifact() {
        let (h, _rx) = handle_with_dead_service();
        let err = h.call("nope", &[]).unwrap_err();
        assert!(err.to_string().contains("not in manifest"));
    }

    #[test]
    fn dead_service_is_reported() {
        let (h, rx) = handle_with_dead_service();
        drop(rx);
        let t = Tensor::zeros(&[2, 2]);
        let err = h.call("f", &[t]).unwrap_err();
        assert!(err.to_string().contains("service thread is gone"));
    }
}
