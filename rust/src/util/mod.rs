//! In-tree substrates replacing crates unavailable in this offline
//! environment (see Cargo.toml note): a JSON parser ([`json`]), a CLI
//! argument parser ([`cli`]), a deterministic PRNG + property-testing
//! harness ([`rng`], [`prop`]), summary statistics ([`stats`]), and a
//! scoped thread pool ([`pool`]).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
