//! Summary statistics for the bench harness and serving metrics:
//! [`Summary`] accumulates samples and answers mean/percentile/extreme
//! queries (sorting lazily on first percentile read), and the `fmt_*`
//! helpers render seconds/bytes with sensible units for table output.

/// Online summary of a sample set (latencies in seconds, volumes, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(samples: Vec<f64>) -> Self {
        Self { samples, sorted: false }
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    /// Percentile by linear interpolation; `q` in [0, 1].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.samples.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(0.5)
    }
    pub fn p95(&mut self) -> f64 {
        self.percentile(0.95)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(0.99)
    }
}

/// Format a duration in seconds with an auto-chosen unit.
pub fn fmt_time(secs: f64) -> String {
    if !secs.is_finite() {
        return format!("{secs}");
    }
    let a = secs.abs();
    if a >= 1.0 {
        format!("{secs:.3} s")
    } else if a >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if a >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format a byte count with an auto-chosen binary unit.
pub fn fmt_bytes(bytes: f64) -> String {
    let a = bytes.abs();
    if a >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", bytes / (1024.0 * 1024.0 * 1024.0))
    } else if a >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", bytes / (1024.0 * 1024.0))
    } else if a >= 1024.0 {
        format!("{:.2} KiB", bytes / 1024.0)
    } else {
        format!("{bytes:.0} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let s = Summary::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.stddev() - 1.2909944).abs() < 1e-5);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Summary::from_samples(vec![4.0, 1.0, 3.0, 2.0]);
        assert!((s.p50() - 2.5).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(1.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }

    #[test]
    fn add_resets_sort() {
        let mut s = Summary::new();
        s.add(5.0);
        assert_eq!(s.p50(), 5.0);
        s.add(1.0);
        assert!((s.p50() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(1.5), "1.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(3e-6), "3.000 µs");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_bytes(3.0 * 1024.0 * 1024.0), "3.00 MiB");
    }
}
