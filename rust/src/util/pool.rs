//! Small thread-pool + parallel-map helpers (tokio/rayon unavailable).
//!
//! The coordinator's engine loop and the rank executor use plain threads;
//! this module provides the shared helpers: `scoped_run` spawns one thread
//! per closure and joins them (propagating panics), and [`WorkQueue`] is a
//! simple MPMC queue for the serving engine's worker pool.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

/// Run one closure per element on its own scoped thread; returns outputs in
/// order. Panics from workers are re-raised on the caller thread.
pub fn scoped_run<T, F>(jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs
            .into_iter()
            .map(|job| s.spawn(job))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect()
    })
}

/// Blocking MPMC queue with shutdown. Used by the serving engine to feed
/// request batches to worker threads.
pub struct WorkQueue<T> {
    inner: Arc<QueueInner<T>>,
}

struct QueueInner<T> {
    queue: Mutex<QueueState<T>>,
    cond: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> Clone for WorkQueue<T> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Default for WorkQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WorkQueue<T> {
    pub fn new() -> Self {
        Self {
            inner: Arc::new(QueueInner {
                queue: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
                cond: Condvar::new(),
            }),
        }
    }

    /// Push an item; returns false if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut q = self.inner.queue.lock().unwrap();
        if q.closed {
            return false;
        }
        q.items.push_back(item);
        self.inner.cond.notify_one();
        true
    }

    /// Pop, blocking until an item is available or the queue is closed and
    /// drained (then `None`).
    pub fn pop(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = q.items.pop_front() {
                return Some(item);
            }
            if q.closed {
                return None;
            }
            q = self.inner.cond.wait(q).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.queue.lock().unwrap().items.pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue; blocked `pop`s drain remaining items then get None.
    pub fn close(&self) {
        self.inner.queue.lock().unwrap().closed = true;
        self.inner.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_run_preserves_order() {
        let jobs: Vec<_> = (0..8)
            .map(|i| move || i * 10)
            .collect();
        assert_eq!(scoped_run(jobs), vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn scoped_run_propagates_panics() {
        scoped_run(vec![|| panic!("boom")]);
    }

    #[test]
    fn queue_fifo() {
        let q = WorkQueue::new();
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn queue_close_drains_then_none() {
        let q = WorkQueue::new();
        q.push(7);
        q.close();
        assert!(!q.push(8));
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_cross_thread() {
        let q = WorkQueue::new();
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(x) = q2.pop() {
                got.push(x);
            }
            got
        });
        for i in 0..100 {
            q.push(i);
        }
        q.close();
        let got = h.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }
}
