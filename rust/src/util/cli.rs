//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Invalid(String, String, &'static str),
    /// Value outside a closed choice set (see [`Args::enum_or`]).
    InvalidChoice(String, String, &'static [&'static str]),
    /// Value rejected by a typed domain parser whose error already
    /// lists the valid spellings — the rendered message is carried
    /// verbatim (see [`Args::choice_or`]).
    Typed(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(k) => write!(f, "missing required argument --{k}"),
            CliError::Invalid(k, v, want) => {
                write!(f, "argument --{k} has invalid value '{v}': expected {want}")
            }
            CliError::InvalidChoice(k, v, allowed) => write!(
                f,
                "argument --{k} has invalid value '{v}': expected one of {}",
                allowed.join(", ")
            ),
            CliError::Typed(k, msg) => write!(f, "argument --{k} is invalid: {msg}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(body.to_string(), v);
                } else {
                    flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self { flags, positional }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(key.into(), v.into(), "usize")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(key.into(), v.into(), "f64")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(CliError::Invalid(key.into(), v.into(), "bool")),
        }
    }

    pub fn required(&self, key: &str) -> Result<&str, CliError> {
        self.get(key).ok_or_else(|| CliError::Missing(key.into()))
    }

    /// A flag constrained to a closed set of names (e.g.
    /// `--plan single|auto|fixed`): returns `default` when absent, the
    /// given value when it is one of `allowed`, and an actionable
    /// [`CliError::InvalidChoice`] listing the options otherwise.
    pub fn enum_or<'a>(
        &'a self,
        key: &str,
        default: &'a str,
        allowed: &'static [&'static str],
    ) -> Result<&'a str, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) if allowed.iter().any(|a| *a == v) => Ok(v),
            Some(v) => Err(CliError::InvalidChoice(key.into(), v.into(), allowed)),
        }
    }

    /// Like [`Self::enum_or`], but for parameterized choices validated
    /// by a typed domain parser (e.g.
    /// `QualityMode::from_name("fastattn:0.25")`, whose valid spellings
    /// are open-ended forms a `&'static` choice list cannot enumerate):
    /// returns `None` when the flag is absent, the parsed value when the
    /// parser accepts it, and the parser's own error — which lists the
    /// valid spellings — wrapped in [`CliError::Typed`] otherwise.
    pub fn choice_or<T, E: std::fmt::Display>(
        &self,
        key: &str,
        parse: impl Fn(&str) -> Result<T, E>,
    ) -> Result<Option<T>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => parse(v)
                .map(Some)
                .map_err(|e| CliError::Typed(key.into(), e.to_string())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("--alpha 3 --beta=4 --gamma");
        assert_eq!(a.get("alpha"), Some("3"));
        assert_eq!(a.get("beta"), Some("4"));
        assert_eq!(a.bool_or("gamma", false).unwrap(), true);
    }

    #[test]
    fn positional_mix() {
        let a = parse("serve --port 8080 trace.txt");
        assert_eq!(a.positional(), &["serve", "trace.txt"]);
        assert_eq!(a.usize_or("port", 0).unwrap(), 8080);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("--x 1");
        assert_eq!(a.usize_or("y", 9).unwrap(), 9);
        assert!(a.required("z").is_err());
        assert_eq!(a.required("x").unwrap(), "1");
    }

    #[test]
    fn invalid_types_error() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.f64_or("n", 0.0).is_err());
        assert!(a.bool_or("n", false).is_err());
    }

    #[test]
    fn enum_or_validates_against_the_choice_set() {
        let a = parse("--plan auto");
        assert_eq!(a.enum_or("plan", "single", &["single", "auto", "fixed"]).unwrap(), "auto");
        assert_eq!(a.enum_or("recarve", "free", &["free", "never"]).unwrap(), "free");
        let bad = parse("--plan sometimes");
        let err = bad.enum_or("plan", "single", &["single", "auto", "fixed"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("sometimes") && msg.contains("single, auto, fixed"), "{msg}");
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("--verbose");
        assert!(a.bool_or("verbose", false).unwrap());
    }

    /// Regression: a misspelled `--quality` must surface the typed
    /// parser's message (which names every valid spelling), not a bare
    /// failure.
    #[test]
    fn choice_or_surfaces_the_typed_parser_error() {
        use crate::config::QualityMode;
        let bad = parse("--quality fastatn");
        let err = bad.choice_or("quality", QualityMode::from_name).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--quality"), "{msg}");
        assert!(msg.contains("'fastatn'"), "{msg}");
        for form in QualityMode::NAME_FORMS {
            assert!(msg.contains(form), "{msg} missing {form}");
        }
        // absent flag → None; valid spelling → parsed value
        assert!(parse("").choice_or("quality", QualityMode::from_name).unwrap().is_none());
        assert_eq!(
            parse("--quality reduced:4")
                .choice_or("quality", QualityMode::from_name)
                .unwrap(),
            Some(QualityMode::ReducedSteps { factor: 4 })
        );
    }
}
