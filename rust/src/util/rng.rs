//! Deterministic PRNGs (the `rand` crate is unavailable offline).
//!
//! [`SplitMix64`] for cheap seeding/property tests; [`Pcg32`] for workload
//! generation (arrival processes, request sizes) where stream quality and
//! jumpability matter a bit more. Both are tiny, well-known generators.

/// SplitMix64 — 64-bit state, passes BigCrush when used as a seeder.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (n > 0), via rejection-free multiply-shift.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[-1, 1)` — handy for synthetic tensors.
    pub fn f32_sym(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Standard normal via Box–Muller (one value per call; wasteful but simple).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }
}

/// PCG-XSH-RR 32-bit output, 64-bit state.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut s = Self { state: 0, inc: (stream << 1) | 1 };
        s.next_u32();
        s.state = s.state.wrapping_add(seed);
        s.next_u32();
        s
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    pub fn f64(&mut self) -> f64 {
        self.next_u32() as f64 / u32::MAX as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_seed_sensitivity() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = SplitMix64::new(5);
        let lambda = 4.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn pcg_deterministic_and_stream_separated() {
        let mut a = Pcg32::new(9, 1);
        let mut b = Pcg32::new(9, 1);
        let mut c = Pcg32::new(9, 2);
        let av: Vec<u32> = (0..10).map(|_| a.next_u32()).collect();
        let bv: Vec<u32> = (0..10).map(|_| b.next_u32()).collect();
        let cv: Vec<u32> = (0..10).map(|_| c.next_u32()).collect();
        assert_eq!(av, bv);
        assert_ne!(av, cv);
    }
}
