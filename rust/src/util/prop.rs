//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `run(cases, |g| { ... })` calls the closure `cases` times with a
//! [`Gen`] handle seeded deterministically per case; assertion failures
//! report the failing case's seed so it can be replayed with
//! [`run_seed`]. No shrinking — cases are kept small instead.

use super::rng::SplitMix64;

/// Per-case generator handle.
pub struct Gen {
    pub rng: SplitMix64,
    pub seed: u64,
}

impl Gen {
    /// usize uniform in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.int(0, xs.len() - 1)]
    }

    /// f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vec of f32 in [-1, 1), length n.
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.f32_sym()).collect()
    }

    /// A divisor of `n`, uniformly among divisors.
    pub fn divisor(&mut self, n: usize) -> usize {
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        *self.choose(&divs)
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.int(0, i);
            p.swap(i, j);
        }
        p
    }
}

/// Run `f` for `cases` deterministic cases. Panics (with the case seed in
/// the message) on the first failing case.
pub fn run<F: FnMut(&mut Gen)>(cases: u64, mut f: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000u64.wrapping_add(case.wrapping_mul(0x9e3779b9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: SplitMix64::new(seed), seed };
            f(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (replay: run_seed({seed:#x})): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn run_seed<F: FnOnce(&mut Gen)>(seed: u64, f: F) {
    let mut g = Gen { rng: SplitMix64::new(seed), seed };
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        run(25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        run(10, |g| a.push(g.int(0, 1000)));
        run(10, |g| b.push(g.int(0, 1000)));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        run(10, |g| {
            let x = g.int(0, 100);
            assert!(x < 101); // always true
            if g.seed != 0 {
                panic!("intentional");
            }
        });
    }

    #[test]
    fn int_bounds_inclusive() {
        run(50, |g| {
            let x = g.int(3, 5);
            assert!((3..=5).contains(&x));
        });
    }

    #[test]
    fn divisor_divides() {
        run(50, |g| {
            let n = g.int(1, 48);
            let d = g.divisor(n);
            assert_eq!(n % d, 0);
        });
    }

    #[test]
    fn permutation_is_permutation() {
        run(30, |g| {
            let n = g.int(1, 20);
            let mut p = g.permutation(n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        });
    }
}
