//! Minimal JSON parser + writer (serde_json is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! parsed as f64 with integer accessors. This is enough for
//! `artifacts/manifest.json` and config files, and is fully unit-tested.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64()
            .and_then(|n| if n.fract() == 0.0 { Some(n as i64) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]`-style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }
    /// Array index access; Null when out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("short \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize a [`Json`] value (compact form, stable key order via BTreeMap).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").at(1).as_usize(), Some(2));
    }

    #[test]
    fn handles_whitespace_and_unicode() {
        let v = Json::parse(" { \"k\" : [ 1 , \"\\u0041\\n\\t✓\" ] } ").unwrap();
        assert_eq!(v.get("k").at(1).as_str(), Some("A\n\t✓"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn missing_key_is_null_not_panic() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope").get("deeper").at(3), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-7}"#;
        let v = Json::parse(src).unwrap();
        let s = to_string(&v);
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn usize_rejects_negative_and_fractional() {
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "version": 1,
          "configs": [{"name": "small4", "l": 128, "head_groups": [1,2,4]}],
          "artifacts": [{"name": "attn_partial_small4_h1",
                         "file": "attn_partial_small4_h1.hlo.txt",
                         "inputs": [[1,32,1,16],[1,1,32]],
                         "outputs": [[1,32,1,16]]}]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").as_usize(), Some(1));
        let a = v.get("artifacts").at(0);
        assert_eq!(a.get("inputs").at(0).as_arr().unwrap().len(), 4);
    }
}
