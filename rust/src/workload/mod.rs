//! Workloads: the paper's four evaluation targets (§5.1) plus the
//! patchify arithmetic that turns image/video requests into sequence
//! lengths, and a Poisson request-trace generator for the serving
//! benchmarks.

use crate::config::{AttnShape, QualityMode};
use crate::util::rng::SplitMix64;

/// Latent patchification arithmetic: pixels → VAE latents (8× spatial
/// downsample) → transformer tokens (patch×patch latent pixels each).
pub fn image_tokens(width: usize, height: usize, patch: usize) -> usize {
    let (lw, lh) = (width / 8, height / 8);
    (lw / patch) * (lh / patch)
}

/// Video: temporal 4× compression at `fps`, then per-frame image tokens.
pub fn video_tokens(
    width: usize,
    height: usize,
    seconds: usize,
    fps: usize,
    patch: usize,
) -> usize {
    let frames = (seconds * fps).div_ceil(4);
    frames * image_tokens(width, height, patch)
}

/// One stage of the request DAG every diffusion request walks:
/// text-encode → DiT denoising loop → VAE decode (PipeDiT's
/// task decomposition, arxiv 2511.12056). The serving layer
/// ([`crate::coordinator::stages`]) gives each class its own pods and
/// carves; the monolithic path folds all three into one service time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StageClass {
    /// Prompt encoding: tiny, sequence-short (a few hundred tokens).
    TextEncode,
    /// The denoising step loop — the stage the paper parallelizes.
    Diffusion,
    /// Latent → pixel decode: sp-only patch-parallel à la xDiT's
    /// Parallel VAE (arxiv 2411.01738), no step loop, no guidance.
    VaeDecode,
}

impl StageClass {
    /// Pipeline order of the linear stage DAG.
    pub const ALL: [StageClass; 3] =
        [StageClass::TextEncode, StageClass::Diffusion, StageClass::VaeDecode];

    pub fn name(&self) -> &'static str {
        match self {
            StageClass::TextEncode => "text-encode",
            StageClass::Diffusion => "diffusion",
            StageClass::VaeDecode => "vae-decode",
        }
    }

    /// Position in [`Self::ALL`] (the DAG is linear, so the index is
    /// the stage's pipeline depth).
    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// Sequence length of the text-encoder stage: one padded prompt.
pub const ENCODE_TOKENS: usize = 512;
/// Encoder work per prompt token, in DiT-layer-token equivalents.
const ENCODE_WORK_PER_TOKEN: f64 = 4.0;
/// VAE decode work per latent token, in DiT-layer-token equivalents —
/// the 8× spatial upsample makes decode a meaningful fraction of a
/// few-step generation, and negligible against a 28-step loop.
const DECODE_WORK_PER_TOKEN: f64 = 8.0;

/// Per-stage cost shape of one request: what the stage computes over
/// (`shape`/`layers`/`steps`/`cfg_evals`) plus the stage's share of the
/// *monolithic* request cost. Shares are derived from per-stage work in
/// a common unit (layer-token equivalents) and always sum to 1.0, so a
/// staged fleet and a monolithic fleet price the same total work — the
/// staged fleet wins by overlap and per-class carves, never by
/// dropping work.
#[derive(Debug, Clone, PartialEq)]
pub struct StageShape {
    pub class: StageClass,
    /// Attention shape the stage runs over (tokens matter: the VAE
    /// stage patch-parallelizes across them).
    pub shape: AttnShape,
    pub layers: usize,
    pub steps: usize,
    pub cfg_evals: usize,
    /// This stage's fraction of the monolithic request service time.
    pub time_share: f64,
}

/// One of the paper's evaluation workloads.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub name: &'static str,
    /// Attention shape of one DiT layer at this workload.
    pub shape: AttnShape,
    /// Number of transformer layers (end-to-end = layers × per-layer).
    pub layers: usize,
    /// Sampling steps for a full generation.
    pub steps: usize,
    /// Guidance branches per step: 1 for guidance-distilled models, 2 for
    /// classifier-free guidance (conditional + unconditional). CFG-
    /// parallel plans (`config::ParallelSpec::cfg_degree == 2`) run the
    /// two branches concurrently on disjoint device groups.
    pub cfg_evals: usize,
    /// Optional per-layer relative costs (one entry per layer, in units
    /// of an average DiT block). Real DiT stacks are not uniform —
    /// joint-attention blocks, token-refiner layers, and final-layer
    /// projections run heavier than the plain blocks — and pipeline
    /// stage boundaries should balance *cost*, not layer count. `None`
    /// (every preset) means uniform layers and reproduces the plain
    /// `layers` arithmetic bit-for-bit; see [`Self::effective_layers`].
    pub layer_costs: Option<Vec<f64>>,
}

impl Workload {
    /// Flux-12B (§5.1): 24 heads, D=128. 3072×3072 with patch 2 on the
    /// 8×-downsampled latent → (3072/8/2)² = 36 864 tokens. Flux-dev is
    /// guidance-distilled: one eval per step.
    pub fn flux_3072() -> Self {
        Self {
            name: "flux-3072",
            shape: AttnShape::new(1, image_tokens(3072, 3072, 2), 24, 128),
            layers: 19,
            steps: 28,
            cfg_evals: 1,
            layer_costs: None,
        }
    }

    /// Flux-12B at 4096×4096 → 65 536 tokens.
    pub fn flux_4096() -> Self {
        Self {
            name: "flux-4096",
            shape: AttnShape::new(1, image_tokens(4096, 4096, 2), 24, 128),
            layers: 19,
            steps: 28,
            cfg_evals: 1,
            layer_costs: None,
        }
    }

    /// CogVideoX-5B (§5.1): 24 heads, D=64, 768×1360 video at the
    /// model's 8 fps with 4× temporal VAE compression, patch 2 →
    /// 40 latent frames × 4080 tokens ≈ 163k tokens at 20 s. Samples
    /// with classifier-free guidance (two evals per step).
    pub fn cogvideo_20s() -> Self {
        Self {
            name: "cogvideox-20s",
            shape: AttnShape::new(1, video_tokens(1360, 768, 20, 8, 2), 24, 64),
            layers: 30,
            steps: 50,
            cfg_evals: 2,
            layer_costs: None,
        }
    }

    /// CogVideoX-5B, 40 s → ~326k tokens (the paper's longest workload;
    /// its Fig. 9 microbench sweeps 96k-192k separately).
    pub fn cogvideo_40s() -> Self {
        Self {
            name: "cogvideox-40s",
            shape: AttnShape::new(1, video_tokens(1360, 768, 40, 8, 2), 24, 64),
            layers: 30,
            steps: 50,
            cfg_evals: 2,
            layer_costs: None,
        }
    }

    /// Synthetic short distilled image request (4096 tokens, one
    /// guidance eval): small enough that the plan chooser keeps it on a
    /// single machine. Paired with [`Self::cfg_video_96k`] as the
    /// bimodal short ↔ long traffic shift the dynamic re-carving bench
    /// and tests drive (`benches/fig_recarve.rs`).
    pub fn short_image_4k() -> Self {
        Self {
            name: "short-image-4k",
            shape: AttnShape::new(1, 4096, 24, 64),
            layers: 19,
            steps: 28,
            cfg_evals: 1,
            layer_costs: None,
        }
    }

    /// Synthetic long CFG video request (96k tokens, two guidance
    /// evals, the Fig. 9 microbench scale): the plan chooser wants CFG ×
    /// pipeline parallelism across the whole pod for it — the other
    /// half of the [`Self::short_image_4k`] bimodal pair.
    pub fn cfg_video_96k() -> Self {
        Self {
            name: "cfg-video-96k",
            shape: AttnShape::new(1, 96_000, 24, 64),
            layers: 30,
            steps: 50,
            cfg_evals: 2,
            layer_costs: None,
        }
    }

    /// All four paper workloads (Fig. 7 / Fig. 10 x-axis).
    pub fn paper_suite() -> Vec<Workload> {
        vec![
            Self::flux_3072(),
            Self::flux_4096(),
            Self::cogvideo_20s(),
            Self::cogvideo_40s(),
        ]
    }

    /// Round the sequence length down to a multiple of `p` (SP divisibility;
    /// the paper pads/crops workloads the same way).
    pub fn aligned_to(&self, p: usize) -> Workload {
        let mut w = self.clone();
        w.shape.l -= w.shape.l % p;
        w
    }

    /// Total guidance evaluations of a full generation: `steps ×
    /// cfg_evals` — the unit the per-layer cost model multiplies out to
    /// end-to-end time.
    pub fn total_evals(&self) -> usize {
        self.steps * self.cfg_evals
    }

    /// Attach per-layer relative costs (see [`Self::layer_costs`]).
    /// `costs` must have exactly `layers` entries, all positive.
    pub fn with_layer_costs(mut self, costs: Vec<f64>) -> Self {
        assert_eq!(
            costs.len(),
            self.layers,
            "one cost per layer ({} layers)",
            self.layers
        );
        assert!(costs.iter().all(|&c| c > 0.0), "layer costs must be positive");
        self.layer_costs = Some(costs);
        self
    }

    /// The workload's depth in *cost* units: the sum of
    /// [`Self::layer_costs`] when provided, else `layers` — so uniform
    /// workloads (`None`, every preset) keep the plain `layers as f64`
    /// arithmetic bit-for-bit. Every closed form that multiplies by
    /// layer count ([`Self::stage_shapes`],
    /// [`crate::analysis::stage_service_time`]) goes through this, so
    /// stage shares and stage placement shift consistently when layer
    /// costs are declared.
    pub fn effective_layers(&self) -> f64 {
        match &self.layer_costs {
            Some(costs) => costs.iter().sum(),
            None => self.layers as f64,
        }
    }

    /// The linear stage DAG of one request: text-encode → diffusion →
    /// VAE decode, each with its own cost shape and a `time_share`
    /// decomposition of the monolithic request cost. Work per stage is
    /// measured in layer-token equivalents: the encoder runs one cheap
    /// pass over a padded prompt, the diffusion stage pays the full
    /// `tokens × layers × evals` step loop, and the VAE pays a
    /// per-token decode constant — so on few-step (distilled or
    /// test-shrunk) workloads decode is a large share worth hiding,
    /// while on a 28-step generation it is a few percent.
    pub fn stage_shapes(&self) -> [StageShape; 3] {
        let l = self.shape.l as f64;
        let w_enc = ENCODE_TOKENS as f64 * ENCODE_WORK_PER_TOKEN;
        // cost-weighted depth: uneven per-layer costs grow (or shrink)
        // the diffusion stage's share of the request; `None` reduces to
        // `layers as f64` exactly
        let w_diff = l * self.effective_layers() * self.total_evals() as f64;
        let w_dec = l * DECODE_WORK_PER_TOKEN;
        let total = w_enc + w_diff + w_dec;
        let enc_shape = AttnShape::new(self.shape.b, ENCODE_TOKENS, self.shape.h, self.shape.d);
        let flat = AttnShape::new(self.shape.b, self.shape.l, self.shape.h, self.shape.d);
        [
            StageShape {
                class: StageClass::TextEncode,
                shape: enc_shape,
                layers: 1,
                steps: 1,
                cfg_evals: 1,
                time_share: w_enc / total,
            },
            StageShape {
                class: StageClass::Diffusion,
                shape: self.shape,
                layers: self.layers,
                steps: self.steps,
                cfg_evals: self.cfg_evals,
                time_share: w_diff / total,
            },
            StageShape {
                class: StageClass::VaeDecode,
                shape: flat,
                layers: 1,
                steps: 1,
                cfg_evals: 1,
                time_share: w_dec / total,
            },
        ]
    }

    /// Total guidance evaluations under a [`QualityMode`].
    /// `ReducedSteps { factor }` is distilled few-step sampling: the
    /// step count divides by `factor`, and — guidance distillation —
    /// a CFG workload (`cfg_evals >= 2`) folds its unconditional branch
    /// into the student, dropping to one eval per step (the same
    /// distinction that separates Flux-distilled from CFG video in the
    /// presets). Every other mode keeps the step budget; its saving is
    /// per-step, priced by [`crate::analysis::quality_time_factor`].
    pub fn evals_under(&self, quality: QualityMode) -> usize {
        match quality {
            QualityMode::ReducedSteps { factor } => {
                let steps = (self.steps / factor.max(1)).max(1);
                let evals = if self.cfg_evals >= 2 { 1 } else { self.cfg_evals };
                steps * evals
            }
            _ => self.total_evals(),
        }
    }
}

/// One serving request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub workload: Workload,
    /// Arrival time (seconds, virtual).
    pub arrival: f64,
    pub seed: u64,
}

/// Deterministic alternating-phase trace: `phases` phases of
/// `per_phase` requests each, one arrival per second, even phases drawn
/// from `short` and odd phases from `long` — the sustained bimodal
/// traffic shift the dynamic re-carving policies
/// ([`crate::cluster::recarve`]) are designed to adapt to.
pub fn bimodal_trace(
    short: &Workload,
    long: &Workload,
    phases: usize,
    per_phase: usize,
) -> Vec<Request> {
    let spec: Vec<(&Workload, usize)> = (0..phases)
        .map(|p| (if p % 2 == 0 { short } else { long }, per_phase))
        .collect();
    phased_trace(&spec)
}

/// Deterministic phased trace with *asymmetric* phases: one arrival per
/// second, each `(workload, count)` phase in order — e.g. dense short
/// phases punctuated by small long-video bursts, the mixed traffic shape
/// group-granular re-carving (`benches/fig_partial_recarve.rs`) is built
/// for. [`bimodal_trace`] is the equal-phase special case.
pub fn phased_trace(phases: &[(&Workload, usize)]) -> Vec<Request> {
    let mut reqs = Vec::new();
    for &(w, count) in phases {
        for _ in 0..count {
            let id = reqs.len() as u64;
            reqs.push(Request { id, workload: w.clone(), arrival: id as f64, seed: id });
        }
    }
    reqs
}

/// Poisson-arrival trace over a workload mix.
pub struct TraceGen {
    rng: SplitMix64,
    rate: f64,
    mix: Vec<Workload>,
    now: f64,
    next_id: u64,
}

impl TraceGen {
    pub fn new(seed: u64, rate_per_sec: f64, mix: Vec<Workload>) -> Self {
        assert!(!mix.is_empty());
        Self { rng: SplitMix64::new(seed), rate: rate_per_sec, mix, now: 0.0, next_id: 0 }
    }

    pub fn next_request(&mut self) -> Request {
        self.now += self.rng.exp(self.rate);
        let w = self.mix[self.rng.below(self.mix.len() as u64) as usize].clone();
        let r = Request {
            id: self.next_id,
            workload: w,
            arrival: self.now,
            seed: self.rng.next_u64(),
        };
        self.next_id += 1;
        r
    }

    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patchify_arithmetic() {
        assert_eq!(image_tokens(3072, 3072, 2), 36_864);
        assert_eq!(image_tokens(4096, 4096, 2), 65_536);
        // 20s * 8fps / 4 = 40 frames, each (1360/8/2)*(768/8/2)=85*48=4080
        assert_eq!(video_tokens(1360, 768, 20, 8, 2), 40 * 4080);
    }

    #[test]
    fn paper_suite_matches_section_5() {
        let suite = Workload::paper_suite();
        assert_eq!(suite.len(), 4);
        for w in &suite {
            assert_eq!(w.shape.h, 24, "both models use 24 heads");
        }
        assert_eq!(suite[0].shape.d, 128); // Flux
        assert_eq!(suite[2].shape.d, 64); // CogVideoX
        // long-sequence regime: 40s is ~2x the 20s workload
        let l20 = Workload::cogvideo_20s().shape.l;
        let l40 = Workload::cogvideo_40s().shape.l;
        assert_eq!(l40, 2 * l20);
        assert!(l20 > 100_000, "{l20}");
        // guidance: Flux is distilled (1 eval), CogVideoX runs CFG (2)
        assert_eq!(suite[0].cfg_evals, 1);
        assert_eq!(suite[2].cfg_evals, 2);
    }

    #[test]
    fn alignment_preserves_divisibility() {
        let w = Workload::cogvideo_20s().aligned_to(32);
        assert_eq!(w.shape.l % 32, 0);
        assert!(w.shape.l <= Workload::cogvideo_20s().shape.l);
    }

    #[test]
    fn bimodal_pair_and_trace() {
        let s = Workload::short_image_4k();
        let l = Workload::cfg_video_96k();
        assert_eq!(s.cfg_evals, 1);
        assert_eq!(l.cfg_evals, 2);
        assert!(l.shape.l > 20 * s.shape.l, "the pair must be bimodal");
        let reqs = bimodal_trace(&s, &l, 3, 4);
        assert_eq!(reqs.len(), 12);
        // phases alternate short, long, short; 1 Hz arrivals, unique ids
        assert_eq!(reqs[0].workload.name, "short-image-4k");
        assert_eq!(reqs[4].workload.name, "cfg-video-96k");
        assert_eq!(reqs[8].workload.name, "short-image-4k");
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.arrival, i as f64);
        }
    }

    #[test]
    fn phased_trace_supports_asymmetric_phases() {
        let s = Workload::short_image_4k();
        let l = Workload::cfg_video_96k();
        let reqs = phased_trace(&[(&s, 3), (&l, 1), (&s, 2)]);
        assert_eq!(reqs.len(), 6);
        let names: Vec<&str> = reqs.iter().map(|r| r.workload.name).collect();
        assert_eq!(
            names,
            vec![s.name, s.name, s.name, l.name, s.name, s.name]
        );
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.arrival, i as f64, "one arrival per second");
        }
        // bimodal_trace is the equal-phase special case
        let a = bimodal_trace(&s, &l, 3, 4);
        let b = phased_trace(&[(&s, 4), (&l, 4), (&s, 4)]);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.id, x.arrival, x.workload.name), (y.id, y.arrival, y.workload.name));
        }
    }

    #[test]
    fn cfg_evals_and_reduced_steps_pin_the_distillation_arithmetic() {
        // Flux is already guidance-distilled: 28 steps x 1 eval. Reduced
        // sampling halves the step count and has no uncond branch to drop.
        let flux = Workload::flux_3072();
        assert_eq!((flux.steps, flux.cfg_evals), (28, 1));
        assert_eq!(flux.total_evals(), 28);
        assert_eq!(flux.evals_under(QualityMode::ReducedSteps { factor: 2 }), 14);
        // CFG video pays 2 evals per step: 50 x 2 = 100. Distillation at
        // factor 2 halves the steps AND folds the uncond branch: 25 x 1.
        let video = Workload::cfg_video_96k();
        assert_eq!((video.steps, video.cfg_evals), (50, 2));
        assert_eq!(video.total_evals(), 100);
        assert_eq!(video.evals_under(QualityMode::ReducedSteps { factor: 2 }), 25);
        // same arithmetic on the paper preset the serve benches use
        let cog = Workload::cogvideo_20s();
        assert_eq!(cog.total_evals(), 100);
        assert_eq!(cog.evals_under(QualityMode::ReducedSteps { factor: 5 }), 10);
        // non-step modes keep the eval budget; factor never rounds to 0
        assert_eq!(flux.evals_under(QualityMode::Full), 28);
        assert_eq!(flux.evals_under(QualityMode::Displaced), 28);
        assert_eq!(
            flux.evals_under(QualityMode::FastAttn { keep_ratio: 0.5 }),
            28
        );
        assert_eq!(
            flux.evals_under(QualityMode::ReducedSteps { factor: 100 }),
            1
        );
    }

    #[test]
    fn stage_shapes_decompose_the_request() {
        for w in Workload::paper_suite()
            .into_iter()
            .chain([Workload::short_image_4k(), Workload::cfg_video_96k()])
        {
            let stages = w.stage_shapes();
            // linear DAG in pipeline order
            let classes: Vec<StageClass> = stages.iter().map(|s| s.class).collect();
            assert_eq!(classes, StageClass::ALL.to_vec());
            // shares partition the monolithic cost exactly
            let total: f64 = stages.iter().map(|s| s.time_share).sum();
            assert!((total - 1.0).abs() < 1e-12, "{total}");
            assert!(stages.iter().all(|s| s.time_share > 0.0));
            // the diffusion stage is the existing step loop, untouched
            let diff = &stages[StageClass::Diffusion.index()];
            assert_eq!(diff.shape, w.shape);
            assert_eq!((diff.layers, diff.steps, diff.cfg_evals), (w.layers, w.steps, w.cfg_evals));
            // the encoder is tiny and sequence-short; no step loop on
            // either side stage
            let enc = &stages[StageClass::TextEncode.index()];
            assert_eq!(enc.shape.l, ENCODE_TOKENS);
            assert!(enc.time_share < 0.01, "{}", enc.time_share);
            let dec = &stages[StageClass::VaeDecode.index()];
            assert_eq!((enc.steps, dec.steps), (1, 1));
            assert_eq!(dec.shape.l, w.shape.l);
            // a full 28+-step loop dominates; decode is a few percent
            assert!(diff.time_share > 0.9, "{}", diff.time_share);
        }
        // on a few-step (test-shrunk) workload decode is a large share —
        // the regime where hiding it inside the diffusion loop pays
        let mut w = Workload::cfg_video_96k();
        w.layers = 2;
        w.steps = 2;
        let dec = w.stage_shapes()[StageClass::VaeDecode.index()].time_share;
        assert!(dec > 0.3, "{dec}");
    }

    #[test]
    fn layer_costs_weight_the_effective_depth() {
        let w = Workload::short_image_4k();
        // uniform (None) reduces to the plain layer count bit-for-bit
        assert_eq!(w.effective_layers(), w.layers as f64);
        // uniform costs of 1.0 are the identity too
        let uniform = w.clone().with_layer_costs(vec![1.0; w.layers]);
        assert_eq!(uniform.effective_layers(), w.layers as f64);
        assert_eq!(uniform.stage_shapes(), w.stage_shapes());
        // heavier blocks grow the effective depth and the diffusion
        // stage's share of the request
        let mut costs = vec![1.0; w.layers];
        costs[0] = 4.0; // a heavy joint-attention front block
        let heavy = w.clone().with_layer_costs(costs);
        assert_eq!(heavy.effective_layers(), w.layers as f64 + 3.0);
        let share = |wl: &Workload| wl.stage_shapes()[StageClass::Diffusion.index()].time_share;
        assert!(share(&heavy) > share(&w));
        // shares still partition the request exactly
        let total: f64 = heavy.stage_shapes().iter().map(|s| s.time_share).sum();
        assert!((total - 1.0).abs() < 1e-12, "{total}");
    }

    #[test]
    #[should_panic(expected = "one cost per layer")]
    fn layer_costs_must_match_the_layer_count() {
        let _ = Workload::short_image_4k().with_layer_costs(vec![1.0; 3]);
    }

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let mk = || TraceGen::new(7, 0.5, Workload::paper_suite()).take(50);
        let a = mk();
        let b = mk();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.workload.name, y.workload.name);
        }
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        // mean inter-arrival ~ 1/rate = 2s
        let mean = a.last().unwrap().arrival / 50.0;
        assert!((1.0..4.0).contains(&mean), "{mean}");
    }
}
