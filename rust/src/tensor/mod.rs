//! Host tensor type for the L3 engine.
//!
//! All request-path data is f32 (matching the AOT artifacts); tensors are
//! dense, row-major, and cheap to slice along the sequence (axis 1) and
//! head (axis 2) dimensions — the two axes sequence parallelism shards
//! (`[B, L, H, D]` layout throughout, as in the paper's Section 2.2).

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

#[derive(Debug, PartialEq)]
pub enum TensorError {
    ShapeMismatch { shape: Vec<usize>, expected: usize, got: usize },
    BadAxis { axis: usize, rank: usize },
    BadSplit { len: usize, parts: usize },
    BadRange { start: usize, end: usize, len: usize },
    BadConcat { axis: usize, a: Vec<usize>, b: Vec<usize> },
    BinaryShapeMismatch { a: Vec<usize>, b: Vec<usize> },
}

impl fmt::Display for TensorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TensorError::ShapeMismatch { shape, expected, got } => {
                write!(f, "shape {shape:?} implies {expected} elements, got {got}")
            }
            TensorError::BadAxis { axis, rank } => {
                write!(f, "axis {axis} out of range for rank-{rank} tensor")
            }
            TensorError::BadSplit { len, parts } => {
                write!(f, "cannot split axis of length {len} into {parts} equal parts")
            }
            TensorError::BadRange { start, end, len } => {
                write!(f, "range {start}..{end} out of bounds for axis of length {len}")
            }
            TensorError::BadConcat { axis, a, b } => {
                write!(f, "concat shapes incompatible at axis {axis}: {a:?} vs {b:?}")
            }
            TensorError::BinaryShapeMismatch { a, b } => {
                write!(f, "elementwise op needs equal shapes: {a:?} vs {b:?}")
            }
        }
    }
}

impl std::error::Error for TensorError {}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != data.len() {
            return Err(TensorError::ShapeMismatch { shape, expected, got: data.len() });
        }
        Ok(Self { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], value: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![value; n] }
    }

    /// Identity of the softmax-merge monoid wants m = -inf.
    pub fn neg_inf(shape: &[usize]) -> Self {
        Self::full(shape, f32::NEG_INFINITY)
    }

    /// Deterministic pseudo-random tensor in [-1, 1) (for synthetic inputs).
    pub fn random(shape: &[usize], seed: u64) -> Self {
        let mut rng = crate::util::rng::SplitMix64::new(seed);
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.f32_sym()).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn scalar(value: f32) -> Self {
        Self { shape: vec![], data: vec![value] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size of the tensor in bytes (f32) — what the network model charges.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != self.data.len() {
            return Err(TensorError::ShapeMismatch {
                shape,
                expected,
                got: self.data.len(),
            });
        }
        self.shape = shape;
        Ok(self)
    }

    fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Slice `start..end` along `axis` (copying).
    pub fn slice(&self, axis: usize, start: usize, end: usize) -> Result<Self, TensorError> {
        if axis >= self.shape.len() {
            return Err(TensorError::BadAxis { axis, rank: self.shape.len() });
        }
        let len = self.shape[axis];
        if start > end || end > len {
            return Err(TensorError::BadRange { start, end, len });
        }
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let new_len = end - start;
        let mut out = Vec::with_capacity(outer * new_len * inner);
        for o in 0..outer {
            let base = o * len * inner;
            out.extend_from_slice(&self.data[base + start * inner..base + end * inner]);
        }
        let mut shape = self.shape.clone();
        shape[axis] = new_len;
        Ok(Self { shape, data: out })
    }

    /// Split into `parts` equal chunks along `axis`.
    pub fn split(&self, axis: usize, parts: usize) -> Result<Vec<Self>, TensorError> {
        if axis >= self.shape.len() {
            return Err(TensorError::BadAxis { axis, rank: self.shape.len() });
        }
        let len = self.shape[axis];
        if parts == 0 || len % parts != 0 {
            return Err(TensorError::BadSplit { len, parts });
        }
        let step = len / parts;
        (0..parts)
            .map(|i| self.slice(axis, i * step, (i + 1) * step))
            .collect()
    }

    /// Concatenate along `axis`.
    pub fn concat(tensors: &[&Tensor], axis: usize) -> Result<Self, TensorError> {
        assert!(!tensors.is_empty(), "concat of zero tensors");
        let first = tensors[0];
        if axis >= first.shape.len() {
            return Err(TensorError::BadAxis { axis, rank: first.shape.len() });
        }
        let mut total_axis = 0;
        for t in tensors {
            if t.shape.len() != first.shape.len()
                || t.shape
                    .iter()
                    .zip(&first.shape)
                    .enumerate()
                    .any(|(i, (a, b))| i != axis && a != b)
            {
                return Err(TensorError::BadConcat {
                    axis,
                    a: first.shape.clone(),
                    b: t.shape.clone(),
                });
            }
            total_axis += t.shape[axis];
        }
        let outer: usize = first.shape[..axis].iter().product();
        let inner: usize = first.shape[axis + 1..].iter().product();
        let mut shape = first.shape.clone();
        shape[axis] = total_axis;
        let mut out = Vec::with_capacity(outer * total_axis * inner);
        for o in 0..outer {
            for t in tensors {
                let alen = t.shape[axis];
                let base = o * alen * inner;
                out.extend_from_slice(&t.data[base..base + alen * inner]);
            }
        }
        Ok(Self { shape, data: out })
    }

    /// Element access by multi-index (debug/test helper; row-major).
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        let flat: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[flat]
    }

    /// Max absolute difference; shapes must match.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "max_abs_diff shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// True if all elements within `atol + rtol*|b|` of `other`.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    fn zip_with(
        &self,
        other: &Tensor,
        f: impl Fn(f32, f32) -> f32,
    ) -> Result<Self, TensorError> {
        if self.shape != other.shape {
            return Err(TensorError::BinaryShapeMismatch {
                a: self.shape.clone(),
                b: other.shape.clone(),
            });
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| f(a, b))
            .collect();
        Ok(Self { shape: self.shape.clone(), data })
    }

    /// Elementwise sum (shapes must match).
    pub fn add(&self, other: &Tensor) -> Result<Self, TensorError> {
        self.zip_with(other, |a, b| a + b)
    }

    /// Elementwise difference (shapes must match).
    pub fn sub(&self, other: &Tensor) -> Result<Self, TensorError> {
        self.zip_with(other, |a, b| a - b)
    }

    /// Multiply every element by a scalar.
    pub fn scale(&self, s: f32) -> Self {
        Self {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| x * s).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn seq(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape.to_vec(), (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(matches!(
            Tensor::new(vec![2, 3], vec![0.0; 5]),
            Err(TensorError::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn slice_axis1() {
        // [1, 4, 2]: seq values 0..8
        let t = seq(&[1, 4, 2]);
        let s = t.slice(1, 1, 3).unwrap();
        assert_eq!(s.shape(), &[1, 2, 2]);
        assert_eq!(s.data(), &[2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn slice_axis2_strided() {
        // [1, 2, 3]: slicing the inner-but-one axis exercises strides
        let t = seq(&[1, 2, 3]);
        let s = t.slice(2, 0, 1).unwrap();
        assert_eq!(s.shape(), &[1, 2, 1]);
        assert_eq!(s.data(), &[0.0, 3.0]);
    }

    #[test]
    fn split_concat_roundtrip() {
        let t = seq(&[2, 8, 3]);
        let parts = t.split(1, 4).unwrap();
        assert_eq!(parts.len(), 4);
        let refs: Vec<&Tensor> = parts.iter().collect();
        let back = Tensor::concat(&refs, 1).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn concat_axis0() {
        let a = seq(&[1, 2]);
        let b = seq(&[2, 2]);
        let c = Tensor::concat(&[&a, &b], 0).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[0.0, 1.0, 0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn errors_are_typed() {
        let t = seq(&[2, 4]);
        assert!(matches!(t.slice(5, 0, 1), Err(TensorError::BadAxis { .. })));
        assert!(matches!(t.slice(1, 3, 2), Err(TensorError::BadRange { .. })));
        assert!(matches!(t.split(1, 3), Err(TensorError::BadSplit { .. })));
        let u = seq(&[3, 4]);
        assert!(matches!(
            Tensor::concat(&[&t, &u], 1),
            Err(TensorError::BadConcat { .. })
        ));
    }

    #[test]
    fn at_multiindex() {
        let t = seq(&[2, 3, 4]);
        assert_eq!(t.at(&[0, 0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2, 3]), 23.0);
        assert_eq!(t.at(&[1, 0, 2]), 14.0);
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::full(&[2, 2], 1.0);
        let mut b = a.clone();
        b.data[3] = 1.0005;
        assert!(a.allclose(&b, 1e-3, 0.0));
        assert!(!a.allclose(&b, 1e-5, 0.0));
        assert!((a.max_abs_diff(&b) - 0.0005).abs() < 1e-6);
    }

    #[test]
    fn random_is_deterministic() {
        let a = Tensor::random(&[4, 4], 9);
        let b = Tensor::random(&[4, 4], 9);
        let c = Tensor::random(&[4, 4], 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.data().iter().all(|x| (-1.0..1.0).contains(x)));
    }

    #[test]
    fn scalar_shape() {
        let s = Tensor::scalar(3.5);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes(), 4);
    }

    #[test]
    fn elementwise_ops() {
        let a = seq(&[2, 2]);
        let b = Tensor::full(&[2, 2], 1.0);
        assert_eq!(a.add(&b).unwrap().data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.sub(&b).unwrap().data(), &[-1.0, 0.0, 1.0, 2.0]);
        assert_eq!(a.scale(2.0).data(), &[0.0, 2.0, 4.0, 6.0]);
        let c = seq(&[4]);
        assert!(a.add(&c).is_err(), "shape mismatch must be rejected");
    }

    #[test]
    fn prop_split_concat_any_axis() {
        prop::run(40, |g| {
            let shape = vec![g.int(1, 3), g.int(2, 8), g.int(1, 4)];
            let t = Tensor::random(&shape, g.seed);
            let axis = g.int(0, 2);
            let parts_opts: Vec<usize> =
                (1..=shape[axis]).filter(|p| shape[axis] % p == 0).collect();
            let parts = *g.choose(&parts_opts);
            let split = t.split(axis, parts).unwrap();
            let refs: Vec<&Tensor> = split.iter().collect();
            let back = Tensor::concat(&refs, axis).unwrap();
            assert_eq!(back, t, "axis={axis} parts={parts}");
        });
    }

    #[test]
    fn prop_slice_matches_at() {
        prop::run(40, |g| {
            let shape = vec![g.int(1, 2), g.int(2, 6), g.int(1, 3)];
            let t = Tensor::random(&shape, g.seed ^ 1);
            let start = g.int(0, shape[1] - 1);
            let end = g.int(start + 1, shape[1]);
            let s = t.slice(1, start, end).unwrap();
            for b in 0..shape[0] {
                for l in 0..end - start {
                    for c in 0..shape[2] {
                        assert_eq!(s.at(&[b, l, c]), t.at(&[b, l + start, c]));
                    }
                }
            }
        });
    }
}
