//! DDIM sampling schedule — mirrors `python/compile/model.py::ddim_alphas`
//! (cosine alpha-bar, evenly spaced timesteps, deterministic sampler).
//! Keep the two implementations in sync; `python/tests/test_model.py`
//! and the tests below pin the same values.

/// Cosine ᾱ(t) (Nichol & Dhariwal), `total`-step convention.
pub fn alpha_bar(t: f64, total: f64) -> f64 {
    let x = (t / total + 0.008) / 1.008 * std::f64::consts::FRAC_PI_2;
    x.cos().powi(2)
}

/// The sampling schedule: `(t, abar_t, abar_prev)` triples from high t to
/// low. `abar_prev` of the last step is 1.0 (full reconstruction).
pub fn schedule(steps: usize) -> Vec<(i64, f64, f64)> {
    let total = 1000.0;
    let ts: Vec<i64> = (0..steps)
        .map(|i| 999 - (i * (1000 / steps)) as i64)
        .collect();
    let mut out = Vec::with_capacity(steps);
    for (i, &t) in ts.iter().enumerate() {
        let abar_t = alpha_bar(t as f64, total);
        let abar_prev = if i + 1 < ts.len() {
            alpha_bar(ts[i + 1] as f64, total)
        } else {
            1.0
        };
        out.push((t, abar_t, abar_prev));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_bar_bounds_and_monotonicity() {
        assert!((alpha_bar(0.0, 1000.0) - 1.0).abs() < 1e-3);
        assert!(alpha_bar(999.0, 1000.0) < 0.01);
        let mut prev = 2.0;
        for t in 0..1000 {
            let a = alpha_bar(t as f64, 1000.0);
            assert!(a <= prev + 1e-12, "abar must be non-increasing in t");
            assert!((0.0..=1.0).contains(&a));
            prev = a;
        }
    }

    #[test]
    fn schedule_descends_and_ends_at_one() {
        let s = schedule(10);
        assert_eq!(s.len(), 10);
        assert!(s.windows(2).all(|w| w[0].0 > w[1].0), "t descends");
        assert_eq!(s.last().unwrap().2, 1.0);
        // abar_prev of step i == abar_t of step i+1
        for w in s.windows(2) {
            assert!((w[0].2 - w[1].1).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_python_reference_values() {
        // pinned against python: model.ddim_alphas(10) first entry
        // t=999 -> abar ~ cos((0.999+0.008)/1.008 * pi/2)^2
        let (t, abar_t, _) = schedule(10)[0];
        assert_eq!(t, 999);
        let expect = ((999.0 / 1000.0 + 0.008) / 1.008 * std::f64::consts::FRAC_PI_2)
            .cos()
            .powi(2);
        assert!((abar_t - expect).abs() < 1e-12);
    }

    #[test]
    fn single_step_schedule() {
        let s = schedule(1);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].0, 999);
        assert_eq!(s[0].2, 1.0);
    }
}
