//! DiT model driver: orchestrates the AOT model-stage artifacts into
//! single-device and *distributed* forward passes, plus the DDIM sampler
//! (Figure 1's loop: noise → DiT steps → VAE decode).
//!
//! The distributed forward is where the paper's system integrates: every
//! non-attention stage (embed, qkv-proj, post-block, final) is pointwise
//! in the sequence dimension, so each rank runs the `_l{chunk}` variants
//! of the stage artifacts on its shard, and the attention in the middle
//! goes through whichever [`SpAlgo`] the engine selected.

pub mod sampler;

use std::sync::Arc;

use anyhow::Result;

use crate::cluster::exec::{run_cluster, ClusterRun, ExecMode, RankCtx};
use crate::comm::Buf;
use crate::config::{AttnShape, ClusterSpec, SpDegrees};
use crate::runtime::{ConfigMeta, RuntimeHandle};
use crate::sp::{SpAlgo, SpParams};
use crate::tensor::Tensor;

/// A loaded DiT instance (one validation config's artifact set).
#[derive(Clone)]
pub struct DiTModel {
    pub rt: RuntimeHandle,
    pub cfg: Arc<ConfigMeta>,
}

impl DiTModel {
    pub fn new(rt: RuntimeHandle, cfg_name: &str) -> Result<Self> {
        let cfg = Arc::new(rt.manifest().config(cfg_name)?.clone());
        Ok(Self { rt, cfg })
    }

    fn name(&self, stem: &str) -> String {
        format!("{stem}_{}", self.cfg.name)
    }

    fn name_l(&self, stem: &str, ls: usize) -> String {
        format!("{stem}_{}_l{ls}", self.cfg.name)
    }

    /// Fused single-device forward (the oracle): x `[B, L, c_in]`,
    /// t `[B]` → eps `[B, L, c_in]`.
    pub fn forward_single(&self, x: &Tensor, t: &Tensor) -> Result<Tensor> {
        let out = self
            .rt
            .call(&self.name("dit_forward"), &[x.clone(), t.clone()])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Stage-wise single-device forward (same numerics via split
    /// artifacts at Ls = L; used to validate stage composition).
    pub fn forward_stagewise(&self, x: &Tensor, t: &Tensor) -> Result<Tensor> {
        let l = self.cfg.l;
        let emb = self
            .rt
            .call(&self.name_l("dit_embed", l), &[x.clone(), t.clone()])?;
        let (mut h, c) = (emb[0].clone(), emb[1].clone());
        for i in 0..self.cfg.depth {
            let qkv = self.rt.call(
                &self.name_l(&format!("dit_block{i}_qkv"), l),
                &[h.clone(), c.clone()],
            )?;
            let attn = self.rt.call(
                &self.name("attn_full"),
                &[qkv[0].clone(), qkv[1].clone(), qkv[2].clone()],
            )?;
            h = self
                .rt
                .call(
                    &self.name_l(&format!("dit_block{i}_post"), l),
                    &[h, attn[0].clone(), c.clone()],
                )?
                .remove(0);
        }
        Ok(self
            .rt
            .call(&self.name_l("dit_final", l), &[h, c.clone()])?
            .remove(0))
    }

    /// One DDIM update through the artifact.
    pub fn ddim_step(
        &self,
        x: &Tensor,
        eps: &Tensor,
        abar_t: f64,
        abar_prev: f64,
    ) -> Result<Tensor> {
        Ok(self
            .rt
            .call(
                &self.name("ddim_step"),
                &[
                    x.clone(),
                    eps.clone(),
                    Tensor::scalar(abar_t as f32),
                    Tensor::scalar(abar_prev as f32),
                ],
            )?
            .remove(0))
    }

    /// VAE decode to pixel patches in [0, 1].
    pub fn decode(&self, x0: &Tensor) -> Result<Tensor> {
        Ok(self.rt.call(&self.name("vae_decode"), &[x0.clone()])?.remove(0))
    }

    /// Full single-device sampling loop: noise → x0 → pixels.
    pub fn sample_single(&self, seed: u64, steps: usize) -> Result<Tensor> {
        let mut x = Tensor::random(&[self.cfg.b, self.cfg.l, self.cfg.c_in], seed);
        for (t, abar_t, abar_prev) in sampler::schedule(steps) {
            let tt = Tensor::new(vec![self.cfg.b], vec![t as f32; self.cfg.b])?;
            let eps = self.forward_single(&x, &tt)?;
            x = self.ddim_step(&x, &eps, abar_t, abar_prev)?;
        }
        self.decode(&x)
    }

    /// Distributed forward of one DiT step on a simulated cluster: each
    /// rank owns the sequence shard `[B, chunk, ·]`, attention runs under
    /// `algo`. Returns per-rank eps shards + the run's virtual clocks.
    pub fn forward_distributed(
        &self,
        cluster: &ClusterSpec,
        algo: SpAlgo,
        degrees: SpDegrees,
        x: &Tensor,
        t: &Tensor,
    ) -> Result<(Tensor, ClusterRun<Tensor>)> {
        let total = cluster.total_gpus();
        anyhow::ensure!(
            total == self.cfg.mesh,
            "cluster {} ranks != config mesh {}",
            total,
            self.cfg.mesh
        );
        let params = SpParams {
            shape: AttnShape::new(self.cfg.b, self.cfg.l, self.cfg.h, self.cfg.d),
            chunk: self.cfg.chunk,
            mesh: algo.mesh(cluster, degrees),
        };
        let mode = ExecMode::Numeric { rt: self.rt.clone(), cfg: Arc::clone(&self.cfg) };
        let model = self.clone();
        let ls = self.cfg.chunk;
        let run = run_cluster(cluster, &mode, |ctx| {
            model
                .rank_forward(ctx, &params, algo, x, t, ls)
                .expect("rank forward failed")
        });
        let refs: Vec<&Tensor> = run.outputs.iter().collect();
        let eps = Tensor::concat(&refs, 1)?;
        Ok((eps, run))
    }

    /// Per-rank body of the distributed forward.
    fn rank_forward(
        &self,
        ctx: &mut RankCtx,
        params: &SpParams,
        algo: SpAlgo,
        x: &Tensor,
        t: &Tensor,
        ls: usize,
    ) -> Result<Tensor> {
        let r = ctx.rank;
        let xs = x.slice(1, r * ls, (r + 1) * ls)?;
        // model-stage compute cost: pointwise stages are memory-bound and
        // tiny next to attention; charge their byte traffic.
        let stage_cost = |ctx: &mut RankCtx, bytes: f64| {
            let t = ctx.cluster().gpu.tile_time(0.0, bytes);
            ctx.compute(t);
        };

        let emb = ctx.call_artifact(
            &self.name_l("dit_embed", ls),
            &[Buf::Real(xs.clone()), Buf::Real(t.clone())],
        )?;
        stage_cost(ctx, xs.bytes() as f64 * 2.0);
        let (mut h, c) = (emb[0].clone(), emb[1].clone());
        for i in 0..self.cfg.depth {
            let qkv = ctx.call_artifact(
                &self.name_l(&format!("dit_block{i}_qkv"), ls),
                &[h.clone(), c.clone()],
            )?;
            stage_cost(ctx, h.bytes() * 6.0);
            let (q, k, v) = (qkv[0].clone(), qkv[1].clone(), qkv[2].clone());
            // fresh one-sided window epoch per layer: blocks must never
            // pull a previous layer's exposed buffers
            ctx.next_epoch();
            let attn = algo.run(ctx, params, q, k, v);
            let out = ctx.call_artifact(
                &self.name_l(&format!("dit_block{i}_post"), ls),
                &[h.clone(), attn, c.clone()],
            )?;
            stage_cost(ctx, h.bytes() * 10.0);
            h = out.into_iter().next().unwrap();
        }
        let eps = ctx.call_artifact(&self.name_l("dit_final", ls), &[h, c])?;
        Ok(eps.into_iter().next().unwrap().into_tensor())
    }

    /// Distributed sampling loop (the serving engine's work unit): runs
    /// `steps` DiT evaluations + DDIM updates. Sampler math runs on the
    /// gathered eps (host-side, negligible cost). Returns decoded pixels
    /// and the total simulated GPU time across steps.
    pub fn sample_distributed(
        &self,
        cluster: &ClusterSpec,
        algo: SpAlgo,
        degrees: SpDegrees,
        seed: u64,
        steps: usize,
    ) -> Result<(Tensor, f64)> {
        let mut x = Tensor::random(&[self.cfg.b, self.cfg.l, self.cfg.c_in], seed);
        let mut sim_time = 0.0;
        for (t, abar_t, abar_prev) in sampler::schedule(steps) {
            let tt = Tensor::new(vec![self.cfg.b], vec![t as f32; self.cfg.b])?;
            let (eps, run) = self.forward_distributed(cluster, algo, degrees, &x, &tt)?;
            sim_time += run.makespan();
            x = self.ddim_step(&x, &eps, abar_t, abar_prev)?;
        }
        let img = self.decode(&x)?;
        Ok((img, sim_time))
    }
}

#[cfg(test)]
mod tests {
    // Numeric model tests need artifacts: rust/tests/model_distributed.rs.
    // Here: sampler schedule unit tests live in sampler.rs.
}
