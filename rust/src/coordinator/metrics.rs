//! Serving metrics: per-workload latency distributions + throughput.

use std::collections::BTreeMap;

use crate::util::stats::{fmt_time, Summary};

/// One finished request, as the scheduler's completion event carries it
/// — the typed record behind `ServeReport::completions` tuples.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: u64,
    /// Workload name (stable — workload names are `&'static`).
    pub workload: &'static str,
    /// Virtual arrival time of the request.
    pub arrival: f64,
    /// Virtual completion time.
    pub done: f64,
    /// Pod that served the request.
    pub pod: usize,
}

impl Completion {
    /// Request latency (completion − arrival).
    pub fn latency(&self) -> f64 {
        self.done - self.arrival
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    per_workload: BTreeMap<String, Summary>,
    completed: usize,
    /// Virtual (or wall) time of the last completion.
    pub horizon: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, workload: &str, latency: f64, completion: f64) {
        self.per_workload
            .entry(workload.to_string())
            .or_default()
            .add(latency);
        self.completed += 1;
        self.horizon = self.horizon.max(completion);
    }

    /// [`Self::record`] from a typed [`Completion`] event.
    pub fn observe(&mut self, c: &Completion) {
        self.record(c.workload, c.latency(), c.done);
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Requests per second over the serving horizon.
    pub fn throughput(&self) -> f64 {
        if self.horizon > 0.0 {
            self.completed as f64 / self.horizon
        } else {
            0.0
        }
    }

    pub fn latency(&mut self, workload: &str) -> Option<&mut Summary> {
        self.per_workload.get_mut(workload)
    }

    pub fn workloads(&self) -> Vec<String> {
        self.per_workload.keys().cloned().collect()
    }

    /// Human-readable report table.
    pub fn report(&mut self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "completed {} requests in {} ({:.3} req/s)\n",
            self.completed,
            fmt_time(self.horizon),
            self.throughput()
        ));
        out.push_str(&format!(
            "{:<16}{:>6}{:>14}{:>14}{:>14}{:>14}\n",
            "workload", "n", "mean", "p50", "p95", "max"
        ));
        let keys = self.workloads();
        for k in keys {
            let s = self.per_workload.get_mut(&k).unwrap();
            out.push_str(&format!(
                "{:<16}{:>6}{:>14}{:>14}{:>14}{:>14}\n",
                k,
                s.len(),
                fmt_time(s.mean()),
                fmt_time(s.p50()),
                fmt_time(s.p95()),
                fmt_time(s.max()),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.record("flux", 1.0, 10.0);
        m.record("flux", 3.0, 12.0);
        m.record("video", 5.0, 20.0);
        assert_eq!(m.completed(), 3);
        assert_eq!(m.horizon, 20.0);
        assert!((m.throughput() - 0.15).abs() < 1e-12);
        assert!((m.latency("flux").unwrap().mean() - 2.0).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("flux") && rep.contains("video"));
    }

    #[test]
    fn observe_matches_record() {
        let c = Completion { id: 3, workload: "flux", arrival: 1.5, done: 4.0, pod: 0 };
        assert_eq!(c.latency(), 2.5);
        let mut a = Metrics::new();
        a.observe(&c);
        let mut b = Metrics::new();
        b.record("flux", 2.5, 4.0);
        assert_eq!(a.completed(), b.completed());
        assert_eq!(a.horizon, b.horizon);
        assert_eq!(a.latency("flux").unwrap().mean(), b.latency("flux").unwrap().mean());
    }

    #[test]
    fn empty_metrics() {
        let mut m = Metrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert!(m.latency("x").is_none());
        assert!(m.report().contains("completed 0"));
    }
}
