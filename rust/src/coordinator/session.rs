//! The event-driven serving scheduler: a [`ServeSession`] built from a
//! typed [`ServeConfig`] drives **arrival → batch-close → dispatch →
//! recarve-commit → completion** events over the virtual clock.
//!
//! Before this redesign the serving loop was one hard-coded
//! batch → pick → dispatch path (a 150-line free function with an inner
//! closure); policies lived in scattered places — batch policy as a
//! `serve()` argument, plan policy + patches in `SimService`
//! constructors, re-carving in ad-hoc `Router` setters. [`ServeConfig`]
//! folds all of them into one reproducible value (see
//! [`ServeConfig::summary`]), and the explicit event loop makes dispatch
//! policy pluggable ([`DispatchPolicy`]) and leaves room for fleet-level
//! events. The redesign ships its first two new scheduler clients:
//!
//! * **replica co-batching** (`ServeConfig::co_batch`) — a closed
//!   batch is *scattered* across its carve's batch-replica groups (each
//!   group serves `⌈B/R⌉` requests concurrently, outputs gathered)
//!   instead of the whole batch queueing on one group;
//! * **cross-pod re-balancing** ([`RebalancePolicy`]) — a fleet-level
//!   event that migrates an idle machine between pods when the workload
//!   mix shifts, extending [`crate::cluster::recarve`] epochs from
//!   per-pod to fleet scope
//!   ([`crate::coordinator::router::Router::rebalance_machine`]).
//!
//! The legacy [`crate::coordinator::engine::serve`] entry point remains
//! as a thin shim over [`ServeSession`] and reproduces the pre-redesign
//! results bit-for-bit on the pinned goldens
//! (`rust/tests/serve_session.rs`, `rust/tests/recarve_serving.rs`);
//! the one deliberate observable change is that completions are
//! recorded in completion-time order (see
//! [`crate::coordinator::engine::ServeReport::completions`]).

use std::cell::RefCell;
use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeSet, BinaryHeap, HashMap};
use std::sync::{Arc, Mutex};

use crate::analysis::{EwmaForecaster, Forecaster};
use crate::cluster::recarve::{PolicyCtx, RecarvePolicy, FORECAST_ABSORB_EPS};
use crate::comm::CommStats;
use crate::config::{ClusterSpec, ParallelSpec, ParallelSpecError, QualityMode};
use crate::coordinator::batcher::{Batch, BatchPolicy, Batcher};
use crate::coordinator::engine::{PlanPolicy, RecarveReport, ServeReport, SimService};
use crate::coordinator::metrics::{Completion, Metrics};
use crate::coordinator::router::{RebalanceEvent, Router};
use crate::coordinator::schedule::{EventHeap, PriceCache};
use crate::coordinator::stages::{self, StagePolicy};
use crate::coordinator::{CostModel, Planner, ServiceModel};
use crate::sp::SpAlgo;
use crate::workload::{Request, StageClass, Workload};

// ---------------------------------------------------------------------------
// Dispatch policy
// ---------------------------------------------------------------------------

/// Pluggable "which pod serves this batch" policy. Decision inputs
/// arrive through one [`PolicyCtx`] view (clock, backlog, forecast —
/// the same struct the re-carve policies read, minus the pod-scoped
/// fields, which stay at their defaults at fleet scope) instead of the
/// ad-hoc argument list this trait grew across PRs 3–9; `est(pod,
/// batch)` is a service-time estimate on that pod (the pod-sized
/// model's live-carve time). Policies that only read queue state may
/// ignore both — `est` is never called unless the policy asks.
pub trait DispatchPolicy: Sync {
    /// Stable policy name for the effective-config line
    /// ([`ServeConfig::summary`]) and CLI parsing.
    fn name(&self) -> &'static str;

    /// Pick the pod for `batch`. Must be deterministic.
    fn pick(
        &self,
        router: &Router,
        batch: &Batch,
        ctx: &PolicyCtx,
        est: &dyn Fn(usize, &Batch) -> f64,
    ) -> usize;
}

/// The default (and the pre-redesign behaviour, `Router::pick`):
/// earliest-free pod, ties by lowest id.
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLoaded;

impl DispatchPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(
        &self,
        router: &Router,
        _batch: &Batch,
        _ctx: &PolicyCtx,
        _est: &dyn Fn(usize, &Batch) -> f64,
    ) -> usize {
        router.pick()
    }
}

/// Plan-aware dispatch: minimize the batch's predicted completion time
/// `max(free_at, ready) + est(pod, batch)` — with differently-sized pods
/// (cross-pod re-balancing) this routes long sequences to the pod whose
/// carve actually serves them fastest, where least-loaded is blind to
/// pod shape. Ties by lowest pod id.
#[derive(Debug, Clone, Copy, Default)]
pub struct EarliestFinish;

impl DispatchPolicy for EarliestFinish {
    fn name(&self) -> &'static str {
        "earliest-finish"
    }

    fn pick(
        &self,
        router: &Router,
        batch: &Batch,
        ctx: &PolicyCtx,
        est: &dyn Fn(usize, &Batch) -> f64,
    ) -> usize {
        let ready = ctx.ready;
        router
            .pods
            .iter()
            .map(|p| (p.id, p.free_at.max(ready) + est(p.id, batch)))
            .min_by(|(ia, a), (ib, b)| a.total_cmp(b).then(ia.cmp(ib)))
            .map(|(id, _)| id)
            .unwrap()
    }
}

/// Parse a dispatch policy by CLI name.
pub fn dispatch_policy_from_name(name: &str) -> Option<Arc<dyn DispatchPolicy>> {
    match name {
        "least-loaded" => Some(Arc::new(LeastLoaded)),
        "earliest-finish" => Some(Arc::new(EarliestFinish)),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Fleet scope: pod-sized models + re-balancing policy
// ---------------------------------------------------------------------------

/// Fleet-scope extension of the cost/plan pair: resolves a service model
/// *per pod footprint*. Cross-pod re-balancing changes pod sizes at
/// runtime, so a single cluster-bound model (like one `SimService`)
/// cannot price every pod; a `FleetModel` can.
pub trait FleetModel: Sync {
    /// The cost/plan model for a pod carved as `cluster`.
    fn model_for(&self, cluster: &ClusterSpec) -> Arc<dyn ServiceModel>;

    /// Fleet-wide comm observability: the per-footprint models'
    /// [`CostModel::comm_stats`] folded together, `None` when no model
    /// reports any (the comm-optimization pass is off everywhere).
    fn comm_stats(&self) -> Option<CommStats> {
        None
    }
}

/// [`FleetModel`] over auto-planning [`SimService`]s, one per distinct
/// pod footprint, built lazily and cached (the timing schedules behind
/// them are themselves cached per workload/batch/plan).
pub struct SimFleet {
    algo: SpAlgo,
    patches: usize,
    patches_auto: bool,
    models: Mutex<HashMap<(usize, usize), Arc<SimService>>>,
}

impl SimFleet {
    /// An auto-planning fleet: every footprint gets
    /// [`SimService::auto_plan`] with the given patch count.
    pub fn auto(algo: SpAlgo, patches: usize) -> Self {
        Self { algo, patches, patches_auto: false, models: Mutex::new(HashMap::new()) }
    }

    /// Choose the patch count per workload by the closed-form argmin on
    /// every footprint model (`--patches auto`).
    pub fn auto_patches(mut self) -> Self {
        self.patches_auto = true;
        self
    }
}

impl FleetModel for SimFleet {
    fn model_for(&self, cluster: &ClusterSpec) -> Arc<dyn ServiceModel> {
        let key = (cluster.machines, cluster.gpus_per_machine);
        let mut models = self.models.lock().unwrap();
        let model = models.entry(key).or_insert_with(|| {
            let mut svc = SimService::auto_plan(cluster.clone(), self.algo);
            svc.patches = self.patches;
            svc.patches_auto = self.patches_auto;
            Arc::new(svc)
        });
        let model: Arc<SimService> = Arc::clone(model);
        model
    }

    fn comm_stats(&self) -> Option<CommStats> {
        let models = self.models.lock().unwrap();
        let mut acc = CommStats::default();
        let mut any = false;
        for m in models.values() {
            if let Some(s) = m.comm_stats_if_active() {
                acc.absorb(&s);
                any = true;
            }
        }
        any.then_some(acc)
    }
}

/// When the fleet may migrate an idle machine between pods
/// ([`crate::coordinator::router::Router::rebalance_machine`]).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum RebalancePolicy {
    /// Pods keep their admission-time footprint (the pre-redesign
    /// behaviour, and the default).
    #[default]
    Never,
    /// Migrate one machine toward the dispatching pod when
    /// [`crate::analysis::rebalance_gain`] predicts at least `threshold`
    /// fractional per-step improvement from one more machine for
    /// `window` consecutive dispatches (fleet-scope hysteresis), and
    /// some other pod is idle with a machine to spare. Requires a
    /// [`FleetModel`] (pods change size); without one the policy is
    /// inert.
    Gain {
        /// Minimum predicted fractional gain (e.g. `0.1` for 10 %).
        threshold: f64,
        /// Consecutive gainful dispatches required before migrating.
        window: usize,
    },
}

impl RebalancePolicy {
    /// Parse a CLI policy name; `threshold`/`window` feed the gain
    /// variant and are ignored by `never`.
    pub fn from_name(name: &str, threshold: f64, window: usize) -> Option<Self> {
        match name {
            "never" => Some(Self::Never),
            "gain" => Some(Self::Gain { threshold, window }),
            _ => None,
        }
    }
}

impl std::fmt::Display for RebalancePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Never => write!(f, "never"),
            Self::Gain { threshold, window } => {
                write!(f, "gain({:.0}% x {window})", threshold * 100.0)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scheduler mode
// ---------------------------------------------------------------------------

/// Which data structures drive the event loop. Both modes are
/// *semantics-preserving*: they produce bit-identical reports on the
/// same trace (pinned by `tests/fleet_scale.rs`); they differ only in
/// asymptotic cost per event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// The reference path: naive binary event heap, linear pod scans,
    /// every dispatch re-priced through the service model. `O(P)` per
    /// dispatch — kept as the oracle the indexed path is compared
    /// against (and for bisecting scheduler bugs).
    Linear,
    /// The fleet-scale path (default): indexed event heap
    /// ([`crate::coordinator::schedule::EventHeap`]), memoized pricing
    /// ([`crate::coordinator::schedule::PriceCache`]), and `O(log P)`
    /// pod selection over the router's `free_at` index.
    Indexed,
}

impl SchedulerMode {
    /// Parse a CLI mode name.
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "linear" => Some(Self::Linear),
            "indexed" => Some(Self::Indexed),
            _ => None,
        }
    }
}

impl std::fmt::Display for SchedulerMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Linear => write!(f, "linear"),
            Self::Indexed => write!(f, "indexed"),
        }
    }
}

// ---------------------------------------------------------------------------
// Policy sub-configs
// ---------------------------------------------------------------------------

/// Re-carving knobs: the policy installed on every pod at run start and
/// the per-transition setup cost. Both `None` by default — the
/// legacy-shim posture that inherits whatever the router already has.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecarveCfg {
    /// Re-carving policy to install on every pod at run start; `None`
    /// (the default) inherits whatever the router already has.
    pub policy: Option<RecarvePolicy>,
    /// Per-transition re-setup seconds to install on every pod at run
    /// start; `None` keeps each pod's modeled
    /// [`crate::cluster::recarve::resetup_cost`].
    pub setup: Option<f64>,
}

/// Cross-pod machine migration knobs ([`RebalancePolicy::Never`] by
/// default — pods keep their admission-time footprint).
#[derive(Debug, Clone, Copy, Default)]
pub struct RebalanceCfg {
    /// When the fleet may migrate an idle machine between pods.
    pub policy: RebalancePolicy,
}

/// Quality-elastic serving knobs: the admission floor and the forced
/// mode. Both `None` by default, which serves everything exact and
/// leaves the report byte-identical to pre-quality output.
#[derive(Debug, Clone, Copy, Default)]
pub struct QualityCfg {
    /// Quality-elastic admission floor in (0, 1]: when set, a batch
    /// dispatched onto a backlogged pod degrades to the cheapest
    /// [`QualityMode`] whose [`QualityMode::score`] clears the floor
    /// (an idle pod always serves `Full`).
    pub floor: Option<f64>,
    /// Force one [`QualityMode`] for every batch, overriding the floor
    /// walk (`--quality` on the CLI).
    pub forced: Option<QualityMode>,
}

/// Stage-pipeline knobs: `None` (the default) keeps the monolithic
/// loop and its byte-identical goldens.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageCfg {
    /// Decoupled multi-stage pipeline: when set, the fleet is
    /// partitioned into stage-class pods and every request walks the
    /// text-encode → diffusion → VAE-decode DAG through bounded
    /// inter-stage queues ([`crate::coordinator::stages`]).
    pub policy: Option<StagePolicy>,
}

/// Arrival-mix forecasting knobs. Present (`ServeConfig::forecast` is
/// `Some`) ⇒ the session observes every admitted arrival through an
/// [`EwmaForecaster`] and feeds the predicted class shares to the
/// policy layer: [`RecarvePolicy::Forecast`]'s proactive trigger and
/// the cost-gated side-carve absorb
/// ([`crate::cluster::recarve::EpochTracker::absorb_side`]). Absent ⇒
/// no forecaster runs and every report stays byte-identical to the
/// pre-forecast output.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ForecastCfg {
    /// EWMA time constant in virtual seconds
    /// ([`EwmaForecaster::new`]): how far back the arrival mix is
    /// remembered — small windows react within a few arrivals, large
    /// ones smooth bursts out.
    pub window: f64,
}

impl Default for ForecastCfg {
    fn default() -> Self {
        Self { window: DEFAULT_FORECAST_WINDOW }
    }
}

/// Default [`ForecastCfg::window`]: long enough to smooth a one-off
/// stray arrival, short enough to flip the dominant class within a
/// handful of arrivals at one request per second.
pub const DEFAULT_FORECAST_WINDOW: f64 = 8.0;

// ---------------------------------------------------------------------------
// ServeConfig
// ---------------------------------------------------------------------------

/// Typed serving configuration — every knob of one serving run in one
/// value, where they used to be scattered across `serve()` arguments,
/// `SimService` constructors, and `Router` setters. Built with the
/// builder methods; [`Self::summary`] renders the effective config as
/// one line so any run is reproducible from its log.
///
/// Knobs are grouped into typed policy sub-structs ([`RecarveCfg`],
/// [`RebalanceCfg`], [`QualityCfg`], [`StageCfg`], [`ForecastCfg`])
/// rather than the ~20 loose fields they accreted as; the builder
/// methods keep their original names and signatures, so existing call
/// sites compile unchanged. [`Self::preset`] names three common
/// postures.
#[derive(Clone)]
pub struct ServeConfig {
    /// Batching policy (max batch size + batching window — how long
    /// the head request may wait for same-workload companions; distinct
    /// from replica *co*-batching, which is the `co_batch` flag).
    pub batch: BatchPolicy,
    /// Plan policy the service model is built from
    /// ([`Self::sim_service`]); informational for hand-built models.
    pub plan: PlanPolicy,
    /// Patch count for pipelined (`pp_degree > 1`) plans.
    pub patches: usize,
    /// Pick the pipeline patch count per workload by the closed-form
    /// argmin ([`crate::analysis::choose_patches`]) instead of the
    /// fixed [`Self::patches`] (`--patches auto` on the CLI). Off by
    /// default.
    pub patches_auto: bool,
    /// Which pod serves each batch ([`LeastLoaded`] by default).
    pub dispatch: Arc<dyn DispatchPolicy>,
    /// Replica co-batching: scatter a closed batch across its carve's
    /// batch-replica groups (service time of `⌈B/R⌉` per group) instead
    /// of queueing the whole batch on one group. Off by default (the
    /// pre-redesign behaviour).
    pub co_batch: bool,
    /// Scheduler data structures ([`SchedulerMode::Indexed`] by
    /// default; `Linear` keeps the naive reference path). Both modes
    /// produce bit-identical reports.
    pub scheduler: SchedulerMode,
    /// Per-pod re-carving knobs.
    pub recarve: RecarveCfg,
    /// Cross-pod machine migration knobs.
    pub rebalance: RebalanceCfg,
    /// Quality-elastic serving knobs.
    pub quality: QualityCfg,
    /// Stage-pipeline knobs.
    pub stages: StageCfg,
    /// Arrival-mix forecasting knobs; `None` (the default) runs no
    /// forecaster and keeps every report byte-identical.
    pub forecast: Option<ForecastCfg>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batch: BatchPolicy::default(),
            plan: PlanPolicy::SingleMesh,
            patches: crate::analysis::DEFAULT_PATCHES,
            patches_auto: false,
            dispatch: Arc::new(LeastLoaded),
            co_batch: false,
            scheduler: SchedulerMode::Indexed,
            recarve: RecarveCfg::default(),
            rebalance: RebalanceCfg::default(),
            quality: QualityCfg::default(),
            stages: StageCfg::default(),
            forecast: None,
        }
    }
}

impl ServeConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// A named configuration posture — the three most common knob
    /// bundles, spelled once:
    ///
    /// * `"throughput"` — saturate the fleet: auto planning, replica
    ///   co-batching, earliest-finish dispatch, group-granular
    ///   re-carving ([`RecarvePolicy::Partial`]) and gain-driven
    ///   machine re-balancing.
    /// * `"latency"` — immediate dispatch (batch of 1, zero window),
    ///   earliest-finish, and predictive re-carving
    ///   ([`RecarvePolicy::Forecast`] + the arrival-mix forecaster) so
    ///   carve transitions happen ahead of the mix instead of behind
    ///   it.
    /// * `"quality"` — auto planning with every batch pinned to
    ///   [`QualityMode::Full`]: no approximate mode is ever chosen, and
    ///   the quality histogram records the guarantee.
    ///
    /// Presets are plain starting points: chain further builder calls
    /// to override any knob. Panics on an unknown name (the CLI
    /// validates first).
    pub fn preset(name: &str) -> Self {
        let base = Self::new().plan(PlanPolicy::Auto).dispatch(Arc::new(EarliestFinish));
        match name {
            "throughput" => base
                .batch(BatchPolicy { max_batch: 8, window: 2.0 })
                .co_batch(true)
                .recarve(RecarvePolicy::Partial { threshold: 0.1, window: 2 })
                .rebalance(RebalancePolicy::Gain { threshold: 0.1, window: 2 }),
            "latency" => base
                .batch(BatchPolicy { max_batch: 1, window: 0.0 })
                .recarve(RecarvePolicy::Forecast { threshold: 0.1, window: 2 })
                .forecast_window(DEFAULT_FORECAST_WINDOW),
            "quality" => base.quality(QualityMode::Full),
            _ => panic!(
                "unknown preset '{name}' (expected throughput, latency, or quality)"
            ),
        }
    }

    /// Set the batching policy.
    pub fn batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }

    /// Set the plan policy ([`Self::sim_service`] builds from it).
    pub fn plan(mut self, plan: PlanPolicy) -> Self {
        self.plan = plan;
        self
    }

    /// Set the pipeline patch count.
    pub fn patches(mut self, patches: usize) -> Self {
        assert!(patches > 0, "patches must be >= 1");
        self.patches = patches;
        self
    }

    /// Install a re-carving policy on every pod at run start.
    pub fn recarve(mut self, policy: RecarvePolicy) -> Self {
        self.recarve.policy = Some(policy);
        self
    }

    /// Pin the per-transition re-setup cost (seconds) on every pod.
    pub fn recarve_setup(mut self, seconds: f64) -> Self {
        self.recarve.setup = Some(seconds);
        self
    }

    /// Set the dispatch policy.
    pub fn dispatch(mut self, policy: Arc<dyn DispatchPolicy>) -> Self {
        self.dispatch = policy;
        self
    }

    /// Enable/disable replica co-batching.
    pub fn co_batch(mut self, on: bool) -> Self {
        self.co_batch = on;
        self
    }

    /// Set the cross-pod re-balancing policy.
    pub fn rebalance(mut self, policy: RebalancePolicy) -> Self {
        self.rebalance.policy = policy;
        self
    }

    /// Select the scheduler data structures (indexed vs linear).
    pub fn scheduler(mut self, mode: SchedulerMode) -> Self {
        self.scheduler = mode;
        self
    }

    /// Set the quality-elastic admission floor (see
    /// [`QualityCfg::floor`]).
    pub fn quality_floor(mut self, floor: f64) -> Self {
        assert!(
            floor > 0.0 && floor <= 1.0,
            "quality floor must be in (0, 1], got {floor}"
        );
        self.quality.floor = Some(floor);
        self
    }

    /// Force one quality mode for every batch.
    pub fn quality(mut self, mode: QualityMode) -> Self {
        self.quality.forced = Some(mode);
        self
    }

    /// Turn the fleet into a decoupled stage pipeline (see
    /// [`StageCfg::policy`]).
    pub fn stages(mut self, policy: StagePolicy) -> Self {
        self.stages.policy = Some(policy);
        self
    }

    /// Choose the pipeline patch count per workload by the closed-form
    /// argmin instead of the fixed [`Self::patches`].
    pub fn patches_auto(mut self, on: bool) -> Self {
        self.patches_auto = on;
        self
    }

    /// Enable the arrival-mix forecaster with the given EWMA window
    /// (virtual seconds, see [`ForecastCfg::window`]).
    pub fn forecast_window(mut self, window: f64) -> Self {
        assert!(window > 0.0, "forecast window must be > 0, got {window}");
        self.forecast = Some(ForecastCfg { window });
        self
    }

    /// Build the timing-mode service model this config describes for one
    /// pod footprint — the constructor scatter
    /// (`SimService::{new, auto_plan, with_plan}` + `patches` field
    /// pokes) behind one call.
    pub fn sim_service(
        &self,
        cluster: ClusterSpec,
        algo: SpAlgo,
    ) -> Result<SimService, ParallelSpecError> {
        let mut svc = match &self.plan {
            PlanPolicy::SingleMesh => SimService::new(cluster, algo),
            PlanPolicy::Auto => SimService::auto_plan(cluster, algo),
            PlanPolicy::Fixed(spec) => SimService::with_plan(cluster, algo, *spec)?,
        };
        svc.patches = self.patches;
        svc.patches_auto = self.patches_auto;
        Ok(svc)
    }

    /// The effective-config line, e.g.
    /// `serve: batch=4x2s plan=auto patches=4 recarve=hysteresis(15% x 2)
    /// dispatch=least-loaded co-batch=off rebalance=never
    /// scheduler=indexed` — printed by the CLI so a run is reproducible
    /// from its log.
    pub fn summary(&self) -> String {
        let patches = if self.patches_auto {
            "auto".to_string()
        } else {
            self.patches.to_string()
        };
        let mut line = format!(
            "serve: batch={}x{}s plan={} patches={} recarve={} dispatch={} co-batch={} \
             rebalance={} scheduler={}",
            self.batch.max_batch,
            self.batch.window,
            self.plan,
            patches,
            self.recarve
                .policy
                .map_or_else(|| "inherit".to_string(), |p| p.to_string()),
            self.dispatch.name(),
            if self.co_batch { "on" } else { "off" },
            self.rebalance.policy,
            self.scheduler,
        );
        // optional knobs are appended only when set, so knob-off logs
        // (and the tests pinning them) are unchanged
        if let Some(q) = self.quality.forced {
            line.push_str(&format!(" quality={}", q.label()));
        }
        if let Some(f) = self.quality.floor {
            line.push_str(&format!(" quality-floor={f}"));
        }
        if let Some(s) = self.stages.policy {
            line.push_str(&format!(" stages={s}"));
        }
        if let Some(f) = self.forecast {
            line.push_str(&format!(" forecast=ewma({}s)", f.window));
        }
        line
    }
}

// ---------------------------------------------------------------------------
// ServeState — the named accumulation state of one run
// ---------------------------------------------------------------------------

/// Mutable accumulation state of one serving run — the six `&mut`
/// arguments the pre-redesign `serve_batch` closure threaded, as one
/// named struct the dispatch handler receives.
#[derive(Default)]
pub struct ServeState {
    pub metrics: Metrics,
    /// (request id, arrival, completion), in completion-event order.
    pub completions: Vec<(u64, f64, f64)>,
    /// (request id, reason) for admission- and dispatch-time rejections.
    pub rejected: Vec<(u64, String)>,
    /// Plan label served under → request count.
    pub plan_histogram: std::collections::BTreeMap<String, usize>,
    /// Quality mode served under → request count. Only populated when a
    /// quality knob ([`ServeConfig::quality_floor`] /
    /// [`ServeConfig::quality`]) is set; empty otherwise so the report
    /// stays byte-identical to pre-quality output.
    pub quality_histogram: std::collections::BTreeMap<String, usize>,
    /// Fleet-scope machine migrations, in commit order.
    pub rebalances: Vec<RebalanceEvent>,
    /// Dispatches whose batch was scattered across replica groups.
    pub co_batched: usize,
    /// Of `co_batched`, dispatches whose shards spanned both carve
    /// generations of a split pod (cross-epoch co-batching).
    pub co_batched_cross: usize,
    /// Scheduler events processed (arrivals, dispatches, completions,
    /// the flush) — the denominator of the fleet-scale bench's
    /// events/sec figure.
    pub events: u64,
    /// Comm counters of the run's pricing models, set by the session
    /// just before finalizing (None when the comm-opt pass is off).
    pub comm: Option<CommStats>,
}

impl ServeState {
    /// Finalize into a [`ServeReport`], snapshotting the pods' epoch
    /// logs (the tail of the pre-redesign `serve()`).
    pub fn into_report(self, router: &Router) -> ServeReport {
        let mut recarve = RecarveReport::default();
        for pod in &router.pods {
            let rc = &pod.recarver;
            recarve.recarve_count += rc.recarve_count();
            recarve.drain_time += rc.drain_time();
            recarve.setup_time += rc.setup_time();
            for e in rc.epochs() {
                *recarve.epoch_histogram.entry(e.label()).or_insert(0) += 1;
                recarve.epochs.push((pod.id, e.clone()));
            }
            recarve.partial_splits += rc.partial_splits();
            recarve.merges += rc.merges();
            recarve.proactive_recarves += rc.proactive_recarves();
            for e in rc.group_epochs() {
                recarve.group_epochs.push((pod.id, e.clone()));
            }
        }
        ServeReport {
            metrics: self.metrics,
            completions: self.completions,
            rejected: self.rejected,
            plan_histogram: self.plan_histogram,
            quality_histogram: self.quality_histogram,
            recarve,
            rebalances: self.rebalances,
            co_batched: self.co_batched,
            co_batched_cross: self.co_batched_cross,
            events: self.events,
            comm: self.comm,
            // the staged path sets this after finalizing; monolithic
            // runs never populate it
            stages: None,
        }
    }
}

// ---------------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------------

/// One scheduler event over the virtual clock.
enum Event {
    /// A request reaches the coordinator (admission + batching).
    Arrival(Request),
    /// The batcher closed a batch at this instant; dispatch it.
    Dispatch(Batch),
    /// A dispatched batch's requests finish service.
    Completion(Completion),
    /// End of trace: force-close everything still queued.
    Flush,
}

/// Heap entry: events process in `(time, seq)` order — seq is the
/// creation order, so same-instant events are FIFO and the loop is
/// deterministic (and, with the default config, reproduces the legacy
/// nested-loop order exactly).
struct Timed {
    at: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for Timed {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Timed {}
impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue behind the loop: the naive [`BinaryHeap`] of
/// [`Timed`] entries ([`SchedulerMode::Linear`]) or the indexed
/// [`EventHeap`] ([`SchedulerMode::Indexed`]). Both pop in identical
/// `(time, seq)` order — `EventHeap` encodes the same key pair through
/// [`crate::coordinator::schedule::time_key`] — so the two modes replay
/// a trace event-for-event.
enum Queue {
    Naive { heap: BinaryHeap<Timed>, seq: u64 },
    Indexed(EventHeap<Event>),
}

impl Queue {
    fn new(mode: SchedulerMode) -> Self {
        match mode {
            SchedulerMode::Linear => Queue::Naive { heap: BinaryHeap::new(), seq: 0 },
            SchedulerMode::Indexed => Queue::Indexed(EventHeap::new()),
        }
    }

    fn push(&mut self, at: f64, ev: Event) {
        match self {
            Queue::Naive { heap, seq } => {
                heap.push(Timed { at, seq: *seq, ev });
                *seq += 1;
            }
            Queue::Indexed(h) => h.push(at, ev),
        }
    }

    fn pop(&mut self) -> Option<(f64, Event)> {
        match self {
            Queue::Naive { heap, .. } => heap.pop().map(|t| (t.at, t.ev)),
            Queue::Indexed(h) => h.pop(),
        }
    }
}

/// Per-run scheduler working state the dispatch handler threads:
/// fleet-rebalance hysteresis streaks (grow and shrink sides), the set
/// of currently split pods (so indexed EarliestFinish can price them
/// outside the `free_at`-pruned scan), and the memoized pricing cache.
struct SchedState {
    /// Grow streaks, keyed by the *receiving* pod (mirroring the
    /// per-pod EpochTracker streak): a pod earns its extra machine with
    /// its own consecutive gainful dispatches, so two gainful pods
    /// cannot pool their streaks and interleaved traffic to other pods
    /// does not reset a pod's progress.
    grow_streaks: HashMap<usize, usize>,
    /// Shrink streaks, keyed by the *pressured* (small, queue-building)
    /// pod — the donor side of the symmetric trigger.
    pressure_streaks: HashMap<usize, usize>,
    /// Pods currently running two carve generations.
    split: BTreeSet<usize>,
    /// Memoized per-pod pricing (enabled in indexed mode only; the
    /// linear path re-prices every call, as before).
    price: RefCell<PriceCache>,
    /// Arrival-mix forecaster (the [`ServeConfig::forecast_window`]
    /// knob): observes every admitted arrival, and its predicted class
    /// shares feed the [`PolicyCtx`] every dispatch decision reads —
    /// the proactive [`RecarvePolicy::Forecast`] trigger and the
    /// cost-gated side-carve absorb. `None` when the knob is off, so
    /// knob-off runs never consult a forecast.
    forecaster: Option<Box<dyn Forecaster>>,
}

impl SchedState {
    fn new(config: &ServeConfig, router: &Router) -> Self {
        Self {
            grow_streaks: HashMap::new(),
            pressure_streaks: HashMap::new(),
            split: router
                .pods
                .iter()
                .filter(|p| p.recarver.is_split())
                .map(|p| p.id)
                .collect(),
            price: RefCell::new(PriceCache::new(matches!(
                config.scheduler,
                SchedulerMode::Indexed
            ))),
            forecaster: config
                .forecast
                .map(|f| Box::new(EwmaForecaster::new(f.window)) as Box<dyn Forecaster>),
        }
    }
}

/// Where the scheduler gets cost/plan models from: one shared model
/// (pods priced identically — the classic path), or a [`FleetModel`]
/// pricing each pod by its current footprint (required for cross-pod
/// re-balancing).
#[derive(Clone, Copy)]
enum ModelSource<'a> {
    Shared(&'a dyn ServiceModel),
    Fleet(&'a dyn FleetModel),
}

/// A resolved per-pod model (borrowed or fleet-owned).
enum PodModel<'a> {
    Shared(&'a dyn ServiceModel),
    Owned(Arc<dyn ServiceModel>),
}

impl PodModel<'_> {
    fn get(&self) -> &dyn ServiceModel {
        match self {
            PodModel::Shared(s) => *s,
            PodModel::Owned(a) => a.as_ref(),
        }
    }
}

impl<'a> ModelSource<'a> {
    fn for_pod(&self, cluster: &ClusterSpec) -> PodModel<'a> {
        match self {
            ModelSource::Shared(s) => PodModel::Shared(*s),
            ModelSource::Fleet(f) => PodModel::Owned(f.model_for(cluster)),
        }
    }

    /// Comm observability of the run's pricing models, for the report's
    /// additive `comm` section (None when the pass is off everywhere).
    fn comm_stats(&self) -> Option<CommStats> {
        match self {
            ModelSource::Shared(s) => s.comm_stats(),
            ModelSource::Fleet(f) => f.comm_stats(),
        }
    }

    /// Fleet-wide admission: a shared model speaks for every pod; with
    /// a fleet source a request is admitted when *any* pod's
    /// footprint-sized model admits it (footprints diverge after
    /// re-balancing — a workload only the big pod can serve must not be
    /// rejected because a small pod cannot). On rejection the first
    /// pod's reason is reported.
    fn admit(&self, router: &Router, workload: &Workload) -> Result<(), String> {
        match self {
            ModelSource::Shared(s) => s.admit(workload),
            ModelSource::Fleet(f) => {
                let mut first_err = None;
                for p in &router.pods {
                    match f.model_for(&p.cluster).admit(workload) {
                        Ok(()) => return Ok(()),
                        Err(e) => {
                            first_err.get_or_insert(e);
                        }
                    }
                }
                Err(first_err.unwrap_or_else(|| "router has no pods".to_string()))
            }
        }
    }
}

/// One serving run: a [`ServeConfig`], a model source, and the
/// event-driven scheduler that executes a request trace against a
/// [`Router`]. Construct with [`Self::new`] (one shared service model)
/// or [`Self::with_fleet`] (per-footprint models, enables cross-pod
/// re-balancing), then call [`Self::run`].
pub struct ServeSession<'a> {
    config: ServeConfig,
    source: ModelSource<'a>,
}

impl<'a> ServeSession<'a> {
    /// A session pricing every pod with one shared service model.
    pub fn new(config: ServeConfig, service: &'a dyn ServiceModel) -> Self {
        Self { config, source: ModelSource::Shared(service) }
    }

    /// A session pricing each pod by its current footprint — required
    /// for [`RebalancePolicy::Gain`] (pods change size at runtime).
    pub fn with_fleet(config: ServeConfig, fleet: &'a dyn FleetModel) -> Self {
        Self { config, source: ModelSource::Fleet(fleet) }
    }

    /// Execute `requests` (time-ordered) against `router`. Deterministic
    /// virtual time; every request ends as exactly one completion or one
    /// rejection in the report.
    pub fn run(self, router: &mut Router, requests: Vec<Request>) -> ServeReport {
        if let Some(policy) = self.config.recarve.policy {
            match self.config.recarve.setup {
                Some(s) => router.set_recarve_with_setup(policy, s),
                None => router.set_recarve(policy),
            }
        } else if let Some(s) = self.config.recarve.setup {
            for p in &mut router.pods {
                p.recarver.setup_cost = s;
            }
        }

        if let Some(policy) = self.config.stages.policy {
            return self.run_staged(router, requests, policy);
        }

        let mut state = ServeState::default();
        let mut batcher = Batcher::new(self.config.batch.clone());
        let mut sched = SchedState::new(&self.config, router);
        // Pods may have been mutated directly between runs (tests
        // pre-script timelines); re-derive the free_at index before
        // trusting it.
        router.rebuild_free_index();
        let mut queue = Queue::new(self.config.scheduler);
        for r in requests {
            queue.push(r.arrival, Event::Arrival(r));
        }
        queue.push(f64::INFINITY, Event::Flush);

        while let Some((at, ev)) = queue.pop() {
            state.events += 1;
            match ev {
                Event::Arrival(r) => {
                    if let Err(reason) = self.source.admit(router, &r.workload) {
                        state.rejected.push((r.id, reason));
                        continue;
                    }
                    // every admitted arrival updates the predicted mix
                    if let Some(f) = sched.forecaster.as_mut() {
                        f.observe(r.workload.name, at);
                    }
                    batcher.push(r);
                    // batch-close: sweep synchronously at the arrival
                    // instant (push-then-sweep, so a request arriving
                    // exactly at a window deadline joins the closing
                    // batch), dispatch as queued events
                    while let Some(batch) = batcher.pop_ready(at) {
                        queue.push(at, Event::Dispatch(batch));
                    }
                }
                Event::Dispatch(batch) => {
                    for c in self.dispatch_batch(router, batch, &mut state, &mut sched) {
                        queue.push(c.done, Event::Completion(c));
                    }
                }
                Event::Completion(c) => {
                    state.metrics.observe(&c);
                    state.completions.push((c.id, c.arrival, c.done));
                }
                Event::Flush => {
                    while let Some(batch) = batcher.pop_any() {
                        queue.push(at, Event::Dispatch(batch));
                    }
                }
            }
        }
        state.comm = self.source.comm_stats();
        state.into_report(router)
    }

    /// The staged path of [`Self::run`]: hand the trace to
    /// [`stages::run_staged`], pricing each stage as its
    /// [`crate::workload::StageShape::time_share`] of the configured
    /// cost model's monolithic service time on the serving pod's
    /// footprint — so staged and monolithic fleets price the same total
    /// work — with the VAE stage additionally patch-parallel
    /// ([`crate::analysis::vae_decode_time`]). The outcome folds into
    /// the regular [`ServeReport`] with the additive `stages` section.
    fn run_staged(
        self,
        router: &mut Router,
        requests: Vec<Request>,
        policy: StagePolicy,
    ) -> ServeReport {
        let source = self.source;
        let algo = router.pods.first().map_or(SpAlgo::SwiftFusion, |p| p.algo);
        let patches = self.config.patches;
        // Admission is checked against the fleet's *initial* footprints:
        // cross-class migrations only move machines between pods that
        // could already serve their class's stage.
        let clusters: Vec<ClusterSpec> =
            router.pods.iter().map(|p| p.cluster.clone()).collect();
        let mut stage_time = |cluster: &ClusterSpec, w: &Workload, class: StageClass| -> f64 {
            let mono = source.for_pod(cluster).get().service_time(w, 1);
            let stage = w.stage_shapes()[class.index()].clone();
            let serial = stage.time_share * mono;
            if class == StageClass::VaeDecode {
                let ranks = crate::analysis::stage_spec(cluster, algo, &stage, patches)
                    .ranks_per_group()
                    .max(1);
                let hop = cluster.net.intra_lat
                    + stage.shape.bytes_per_tensor() / patches.max(1) as f64
                        / cluster.net.intra_bw;
                crate::analysis::vae_decode_time(serial, ranks, patches, hop)
            } else {
                serial
            }
        };
        let mut admit = |w: &Workload| -> Result<(), String> {
            match source {
                ModelSource::Shared(s) => s.admit(w),
                ModelSource::Fleet(f) => {
                    let mut first_err = None;
                    for c in &clusters {
                        match f.model_for(c).admit(w) {
                            Ok(()) => return Ok(()),
                            Err(e) => {
                                first_err.get_or_insert(e);
                            }
                        }
                    }
                    Err(first_err.unwrap_or_else(|| "router has no pods".to_string()))
                }
            }
        };
        let outcome = stages::run_staged(
            router,
            requests,
            &policy,
            &self.config.rebalance.policy,
            algo,
            patches,
            &mut stage_time,
            &mut admit,
        );
        let state = ServeState {
            metrics: outcome.metrics,
            completions: outcome.completions,
            rejected: outcome.rejected,
            plan_histogram: outcome.plan_histogram,
            rebalances: outcome.rebalances,
            events: outcome.events,
            comm: source.comm_stats(),
            ..ServeState::default()
        };
        let mut report = state.into_report(router);
        report.stages = Some(outcome.report);
        report
    }

    /// The dispatch handler: pick a pod, run the fleet re-balancing and
    /// per-pod re-carving policies, commit the (possibly co-batched)
    /// service to the pod timeline. Returns one [`Completion`] per
    /// request (empty when the batch is rejected at dispatch).
    fn dispatch_batch(
        &self,
        router: &mut Router,
        batch: Batch,
        state: &mut ServeState,
        sched: &mut SchedState,
    ) -> Vec<Completion> {
        let workload = batch.requests[0].workload.clone();
        let ready = batch.ready_at();
        let source = self.source;
        // The forecaster's opinion of this batch's class, threaded to
        // every policy decision below through the shared PolicyCtx.
        let forecast_share = sched
            .forecaster
            .as_ref()
            .map(|f| f.share(workload.name, ready));
        let price_cell = &sched.price;
        // Plan-aware dispatch estimates price each pod by the carve it
        // will actually serve under: for pods whose policy can hold a
        // stale carve (anything but the free idealization), that is the
        // pod's *live* carve — a re-carve-averse pod no longer wins
        // dispatches on the strength of a preferred plan it will refuse
        // to adopt. Free-policy pods adopt the preferred plan at
        // dispatch, unpaid, so the preferred-plan estimate remains exact
        // for them. A split pod is priced generation-aware: each
        // generation is its own `(free_at, duration)` pair, and the
        // estimate is the earlier of the two finishes re-based onto the
        // pod's main timeline (`finish - max(main_free_at, ready)`), so
        // EarliestFinish sees the side generation's *own* availability.
        // That difference can make the estimate negative — the side may
        // start before the main timeline frees.
        let est = |pod: usize, b: &Batch| -> f64 {
            let p = &router.pods[pod];
            let fp = (p.cluster.machines, p.cluster.gpus_per_machine);
            let w = &b.requests[0].workload;
            let mut price = price_cell.borrow_mut();
            let live = if matches!(p.recarver.policy, RecarvePolicy::Free) {
                None
            } else {
                p.recarver.carve()
            };
            match live {
                None => price.service_time(fp, w, b.size(), || {
                    source.for_pod(&p.cluster).get().service_time(w, b.size())
                }),
                Some(c) => {
                    let t = price.service_time_under(fp, w, b.size(), Some(&c), || {
                        source
                            .for_pod(&p.cluster)
                            .get()
                            .service_time_under(w, b.size(), Some(&c))
                    });
                    match (p.recarver.side_carve(), p.recarver.side_free_at()) {
                        (Some(s), Some(side_free)) => {
                            let ts =
                                price.service_time_under(fp, w, b.size(), Some(&s), || {
                                    source
                                        .for_pod(&p.cluster)
                                        .get()
                                        .service_time_under(w, b.size(), Some(&s))
                                });
                            let ready = b.ready_at();
                            let fin = |free: f64, dur: f64| {
                                if dur.is_finite() {
                                    free.max(ready) + dur
                                } else {
                                    f64::INFINITY
                                }
                            };
                            fin(p.free_at, t).min(fin(side_free, ts)) - p.free_at.max(ready)
                        }
                        _ => t,
                    }
                }
            }
        };
        // The fleet-scope decision view: pod-scoped fields (free_at,
        // preferred, gain) stay at their defaults — no pod is chosen
        // yet.
        let fleet_ctx = PolicyCtx::at(ready, 0.0)
            .forecast_share(forecast_share)
            .backlog(batch.size());
        let pod = match self.config.scheduler {
            SchedulerMode::Linear => {
                self.config.dispatch.pick(router, &batch, &fleet_ctx, &est)
            }
            // O(log P)-flavored selection for the built-in policies:
            // least-loaded reads the front of the router's free_at
            // index; earliest-finish prunes its scan with it. Custom
            // policies keep their own pick.
            SchedulerMode::Indexed => match self.config.dispatch.name() {
                "least-loaded" => router.pick_indexed(),
                "earliest-finish" => {
                    pruned_earliest_finish(router, &batch, &est, &sched.split)
                }
                _ => self.config.dispatch.pick(router, &batch, &fleet_ctx, &est),
            },
        };

        // Fleet event: would one more machine pay off here, and is some
        // other pod idle enough to donate one? Symmetrically: is this
        // pod queueing behind a strictly bigger pod's leftovers and
        // should the big pod give a machine back?
        if let RebalancePolicy::Gain { threshold, window } = self.config.rebalance.policy {
            if matches!(self.source, ModelSource::Fleet(_)) {
                let mut migrated = false;
                let cur = router.pods[pod].cluster.clone();
                let grown = cur.resized(cur.machines + 1);
                let gain = crate::analysis::rebalance_gain(
                    &cur,
                    &grown,
                    router.pods[pod].algo,
                    &workload.shape,
                    workload.cfg_evals,
                    self.config.patches,
                );
                let streak = sched.grow_streaks.entry(pod).or_insert(0);
                if gain >= threshold {
                    *streak += 1;
                } else {
                    *streak = 0;
                }
                if *streak >= window.max(1) {
                    let donor = router
                        .pods
                        .iter()
                        .filter(|p| {
                            p.id != pod && p.free_at <= ready && p.cluster.machines >= 2
                        })
                        .min_by_key(|p| (Reverse(p.cluster.machines), p.id))
                        .map(|p| p.id);
                    if let Some(donor) = donor {
                        state.rebalances.push(router.rebalance_machine(donor, pod, ready));
                        sched.grow_streaks.clear();
                        sched.pressure_streaks.clear();
                        // rebalance_machine resize-resets both pods,
                        // dissolving any live split
                        sched.split.remove(&donor);
                        sched.split.remove(&pod);
                        migrated = true;
                    }
                }
                // Donor-side pressure (the shrink half): this pod keeps
                // receiving dispatches while already busy, and a
                // strictly bigger pod exists — the earlier grow
                // overshot for the current mix, so migrate a machine
                // back from the biggest pod. Unlike the grow trigger
                // (which only moves an *idle* machine, opportunistic by
                // design), shrink is a pressure valve and pays the
                // donor's drain.
                if !migrated {
                    let my_machines = router.pods[pod].cluster.machines;
                    let pressured = router.pods[pod].free_at > ready
                        && router.pods.iter().any(|p| p.cluster.machines > my_machines);
                    let ps = sched.pressure_streaks.entry(pod).or_insert(0);
                    if pressured {
                        *ps += 1;
                    } else {
                        *ps = 0;
                    }
                    if *ps >= window.max(1) {
                        let donor = router
                            .pods
                            .iter()
                            .filter(|p| {
                                p.id != pod
                                    && p.cluster.machines > my_machines
                                    && p.cluster.machines >= 2
                            })
                            .min_by_key(|p| (Reverse(p.cluster.machines), p.id))
                            .map(|p| p.id);
                        if let Some(donor) = donor {
                            state
                                .rebalances
                                .push(router.rebalance_machine(donor, pod, ready));
                            sched.grow_streaks.clear();
                            sched.pressure_streaks.clear();
                            sched.split.remove(&donor);
                            sched.split.remove(&pod);
                        }
                    }
                }
            }
        }

        // Footprint after any rebalance above — the pricing-cache key
        // half that, together with the workload class, identifies a
        // memoized service time.
        let fp = (
            router.pods[pod].cluster.machines,
            router.pods[pod].cluster.gpus_per_machine,
        );
        let model = self.source.for_pod(&router.pods[pod].cluster);
        let service = model.get();
        let preferred = service.plan_spec(&workload);
        // A pod running two carve generations (a group-granular split,
        // RecarvePolicy::Partial) has its own dispatch path: merge when
        // the whole pod is idle, otherwise route between generations.
        if router.pods[pod].recarver.is_split() {
            let out = self.dispatch_split(
                router,
                pod,
                batch,
                &workload,
                ready,
                service,
                preferred,
                state,
                sched,
            );
            // Split pods run the exact pipeline on both carve
            // generations; with a quality knob on, record them as Full
            // so the histogram still accounts for every completion.
            if (self.config.quality.forced.is_some() || self.config.quality.floor.is_some())
                && !out.is_empty()
            {
                *state
                    .quality_histogram
                    .entry(QualityMode::Full.label())
                    .or_insert(0) += out.len();
            }
            return out;
        }
        let free_at = router.pods[pod].free_at;
        // Compute the modeled gain only for policies that read it.
        let gain = {
            let rc = &router.pods[pod].recarver;
            if rc.policy.wants_gain() {
                match rc.carve() {
                    Some(from) if Some(from) != preferred => {
                        service.recarve_gain(&workload, &from)
                    }
                    _ => None,
                }
            } else {
                None
            }
        };
        let ctx = PolicyCtx::at(ready, free_at)
            .preferred(preferred)
            .gain(gain)
            .forecast_share(forecast_share)
            .backlog(batch.size());
        let mut t = router.pods[pod].recarver.on_dispatch(&ctx);
        if t.split_pending {
            // The Partial policy fired on a busy pod: split off the idle
            // machines and serve this batch on the fresh side carve.
            if let Some(out) =
                self.try_split(router, pod, &batch, &workload, ready, service, state, sched)
            {
                // Side-carve dispatches run the exact pipeline.
                if (self.config.quality.forced.is_some() || self.config.quality.floor.is_some())
                    && !out.is_empty()
                {
                    *state
                        .quality_histogram
                        .entry(QualityMode::Full.label())
                        .or_insert(0) += out.len();
                }
                return out;
            }
            // No machine-aligned split exists (or the model cannot plan
            // the subset, or the predicted gain does not clear the
            // threshold): fall back to the pod-wide transition plain
            // hysteresis would have made at this point.
            t = router.pods[pod].recarver.force(ready, free_at, preferred);
        }
        let mut dur = self.service_duration(
            &sched.price,
            fp,
            service,
            &workload,
            batch.size(),
            t.carve.as_ref(),
        );
        if !dur.is_finite() {
            // The live carve cannot serve this batch at all (e.g. a
            // patch granularity larger than the sequence); dispatching
            // an infinite duration would poison the pod's timeline
            // forever. If the preferred plan can serve it, the re-carve
            // is forced by physics, overriding the policy; if nothing
            // can, the batch is rejected rather than dispatched.
            let pref_dur = if t.carve == preferred {
                dur
            } else {
                self.service_duration(
                    &sched.price,
                    fp,
                    service,
                    &workload,
                    batch.size(),
                    preferred.as_ref(),
                )
            };
            if !pref_dur.is_finite() {
                for r in &batch.requests {
                    state.rejected.push((
                        r.id,
                        format!(
                            "no plan can serve workload '{}' on this pod (modeled \
                             service time is infinite under both the live carve and \
                             the preferred plan)",
                            workload.name
                        ),
                    ));
                }
                return Vec::new();
            }
            t = router.pods[pod].recarver.force(ready, free_at, preferred);
            dur = pref_dur;
        }
        // Quality-elastic admission: scale the (finite, memoized-exact)
        // duration by the chosen mode's time factor. The factor applies
        // outside `service_duration` so the pricing cache stays keyed on
        // exact plans only.
        if let Some(q) = self.pick_quality(free_at, ready) {
            dur *= crate::analysis::quality_time_factor(&workload, q);
            *state.quality_histogram.entry(q.label()).or_insert(0) += batch.size();
        }
        if t.recarved && t.setup > 0.0 {
            router.commit_recarve(pod, ready, t.setup);
        }
        if self.config.co_batch
            && batch.size() > 1
            && t.carve.is_some_and(|s| s.batch_replicas > 1)
        {
            state.co_batched += 1;
        }
        if let Some(label) = t
            .carve
            .map(|s| s.label())
            .or_else(|| service.plan_label(&workload))
        {
            *state.plan_histogram.entry(label).or_insert(0) += batch.size();
        }
        router.pods[pod].recarver.record_served(batch.size());
        let out = router.dispatch(pod, ready, dur);
        let reps = self.occupied_replicas(t.carve.as_ref(), batch.size());
        router.pods[pod].recarver.note_inflight(ready, out.done, reps);
        batch
            .requests
            .iter()
            .map(|r| Completion {
                id: r.id,
                workload: workload.name,
                arrival: r.arrival,
                done: out.done,
                pod,
            })
            .collect()
    }

    /// Pick the quality mode for a batch dispatched at `ready` onto a
    /// pod free at `free_at`, or `None` when both quality knobs are off
    /// (the knob-off path must not touch the histogram or the duration,
    /// keeping reports byte-identical to pre-quality output).
    ///
    /// A forced [`ServeConfig::quality`] always wins. Under a
    /// [`ServeConfig::quality_floor`], an idle pod serves `Full`; a
    /// backlogged pod walks [`QualityMode::ladder`] (ordered
    /// best-to-cheapest) and takes the cheapest mode whose score still
    /// clears the floor, falling back to `Full` when the floor excludes
    /// every approximate mode.
    fn pick_quality(&self, free_at: f64, ready: f64) -> Option<QualityMode> {
        if let Some(q) = self.config.quality.forced {
            return Some(q);
        }
        let floor = self.config.quality.floor?;
        if free_at <= ready {
            return Some(QualityMode::Full);
        }
        Some(
            QualityMode::ladder()
                .into_iter()
                .filter(|q| q.score() >= floor)
                .last()
                .unwrap_or(QualityMode::Full),
        )
    }

    /// How many replica groups of `carve` a dispatched batch occupies
    /// while in flight: with co-batching on, a batch of `B` scatters
    /// one shard onto each of `min(R, B)` groups; serial dispatch keeps
    /// the whole batch on one group. Feeds the per-pod occupancy log
    /// ([`crate::cluster::recarve::EpochTracker::note_inflight`]) that
    /// [`Self::try_split`] derives the busy machine footprint from.
    fn occupied_replicas(&self, carve: Option<&ParallelSpec>, batch_size: usize) -> usize {
        if self.config.co_batch {
            carve.map_or(1, |s| s.batch_replicas.min(batch_size).max(1))
        } else {
            1
        }
    }

    /// Modeled service seconds for `batch_size` requests of `workload`
    /// under `carve`: with co-batching on, the batch scatters across the
    /// carve's replica groups and the makespan is one group's largest
    /// shard; otherwise the whole batch serves on one group (the
    /// pre-redesign behaviour). Memoized through `price` (keyed by the
    /// pod footprint `fp` + full workload class) in indexed mode.
    fn service_duration(
        &self,
        price: &RefCell<PriceCache>,
        fp: (usize, usize),
        service: &dyn ServiceModel,
        workload: &Workload,
        batch_size: usize,
        carve: Option<&ParallelSpec>,
    ) -> f64 {
        let eff = if self.config.co_batch {
            carve
                .map(|s| s.replica_shards(batch_size)[0])
                .unwrap_or(batch_size)
        } else {
            batch_size
        };
        price
            .borrow_mut()
            .service_time_under(fp, workload, eff, carve, || {
                service.service_time_under(workload, eff, carve)
            })
    }

    /// Attempt a group-granular split on `pod` (the `Partial` policy
    /// fired while the pod was busy): narrow the busy carve to its
    /// in-flight machine footprint, re-carve the idle machines to the
    /// model's subset plan, and serve this batch on the fresh side
    /// generation — no drain barrier is paid. Returns `None` when no
    /// machine-aligned split exists, the model cannot plan the subset,
    /// or the predicted gain ([`Planner::partial_recarve_gain`]) does
    /// not clear the policy threshold; the caller then falls back to a
    /// pod-wide transition.
    ///
    /// The busy footprint is derived from the pod's in-flight occupancy
    /// log ([`crate::cluster::recarve::EpochTracker::busy_replicas`]):
    /// a serial dispatch occupies one replica's groups, but a
    /// *co-batched* in-flight batch scatters a shard onto every replica
    /// group it touched — narrowing to one replica's machines would
    /// hand machines that are still computing to the side carve and
    /// make the split optimistic by the batch's residual service time.
    #[allow(clippy::too_many_arguments)]
    fn try_split(
        &self,
        router: &mut Router,
        pod: usize,
        batch: &Batch,
        workload: &Workload,
        ready: f64,
        service: &dyn ServiceModel,
        state: &mut ServeState,
        sched: &mut SchedState,
    ) -> Option<Vec<Completion>> {
        let threshold = match router.pods[pod].recarver.policy {
            RecarvePolicy::Partial { threshold, .. } => threshold,
            _ => return None,
        };
        let gpm = router.pods[pod].cluster.gpus_per_machine;
        let machines = router.pods[pod].cluster.machines;
        let fp = (machines, gpm);
        let live = router.pods[pod].recarver.carve()?;
        // machine-footprint accounting: one replica's worth of groups,
        // scaled by how many replica groups the in-flight work actually
        // occupies, rounded up to whole machines; only what is left can
        // re-carve
        let unit = live.narrowed_to_machines(gpm)?;
        let unit_machines = unit.total_ranks() / gpm;
        let reps = router.pods[pod].recarver.busy_replicas(ready).max(1);
        let scale = reps.div_ceil(unit.batch_replicas.max(1));
        let busy = unit_machines * scale;
        let idle = machines.checked_sub(busy).filter(|&i| i > 0)?;
        let narrowed = if scale > 1 {
            ParallelSpec::with_pp(
                unit.cfg_degree,
                unit.pp_degree,
                unit.batch_replicas * scale,
                unit.sp,
            )
        } else {
            unit
        };
        let side_plan = service.plan_spec_on(workload, idle)?;
        let gain = service.partial_recarve_gain(workload, &live, idle)?;
        if gain < threshold {
            return None;
        }
        let dur = self.service_duration(
            &sched.price,
            fp,
            service,
            workload,
            batch.size(),
            Some(&side_plan),
        );
        if !dur.is_finite() {
            return None;
        }
        router.pods[pod]
            .recarver
            .split(ready, Some(narrowed), Some(side_plan), busy, idle);
        // the side carve exists to serve this class — remember it so
        // the forecast-gated absorb can ask whether it will return
        router.pods[pod].recarver.note_side_class(workload.name);
        sched.split.insert(pod);
        let (_, done) = router.pods[pod].recarver.dispatch_side(ready, dur);
        if self.config.co_batch && batch.size() > 1 && side_plan.batch_replicas > 1 {
            state.co_batched += 1;
        }
        *state.plan_histogram.entry(side_plan.label()).or_insert(0) += batch.size();
        router.pods[pod].recarver.record_side_served(batch.size());
        Some(completions_for(batch, workload, done, pod))
    }

    /// Dispatch onto a pod running two carve generations: re-unify when
    /// the whole pod is idle ([`crate::cluster::recarve::EpochTracker::merge`]),
    /// otherwise route the batch to the generation completing it
    /// earliest — or, with co-batching on, scatter its shards across
    /// **both** generations when the gathered result lands sooner than
    /// either generation alone (cross-epoch co-batching).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_split(
        &self,
        router: &mut Router,
        pod: usize,
        batch: Batch,
        workload: &Workload,
        ready: f64,
        service: &dyn ServiceModel,
        preferred: Option<ParallelSpec>,
        state: &mut ServeState,
        sched: &mut SchedState,
    ) -> Vec<Completion> {
        let fp = (
            router.pods[pod].cluster.machines,
            router.pods[pod].cluster.gpus_per_machine,
        );
        let main_free = router.pods[pod].free_at;
        let side_free = router.pods[pod]
            .recarver
            .side_free_at()
            .expect("dispatch_split on an unsplit pod");

        // Whole pod idle: merge the side generation back and serve this
        // batch under the re-admitted full-footprint carve.
        if main_free <= ready && side_free <= ready {
            let setup = router.pods[pod].recarver.merge(ready);
            sched.split.remove(&pod);
            router.commit_recarve(pod, ready, setup);
            let free_at = router.pods[pod].free_at;
            let t = router.pods[pod]
                .recarver
                .on_dispatch(&PolicyCtx::at(ready, free_at).preferred(preferred));
            let dur = self.service_duration(
                &sched.price,
                fp,
                service,
                workload,
                batch.size(),
                t.carve.as_ref(),
            );
            if !dur.is_finite() {
                for r in &batch.requests {
                    state.rejected.push((
                        r.id,
                        format!(
                            "no plan can serve workload '{}' on this pod after \
                             re-unification",
                            workload.name
                        ),
                    ));
                }
                return Vec::new();
            }
            if let Some(label) = t
                .carve
                .map(|s| s.label())
                .or_else(|| service.plan_label(workload))
            {
                *state.plan_histogram.entry(label).or_insert(0) += batch.size();
            }
            router.pods[pod].recarver.record_served(batch.size());
            let out = router.dispatch(pod, ready, dur);
            let reps = self.occupied_replicas(t.carve.as_ref(), batch.size());
            router.pods[pod].recarver.note_inflight(ready, out.done, reps);
            return completions_for(&batch, workload, out.done, pod);
        }

        // Cost-gated absorb (the forecast knob): the side generation
        // drained but the main is still busy — the full-idle merge
        // above cannot fire, and without a forecast the split idles
        // until it does. When the forecaster says the side's class has
        // left the mix ([`FORECAST_ABSORB_EPS`]), the side will not see
        // traffic again: re-unify *now*
        // ([`crate::cluster::recarve::EpochTracker::absorb_side`] — the
        // busy main generation keeps computing through the setup) and
        // serve this batch on the re-unified main timeline.
        if let Some(f) = sched.forecaster.as_ref() {
            let side_gone = router.pods[pod]
                .recarver
                .side_class()
                .is_none_or(|c| f.share(c, ready) < FORECAST_ABSORB_EPS);
            if side_free <= ready && main_free > ready && side_gone {
                let setup = router.pods[pod].recarver.absorb_side(ready);
                sched.split.remove(&pod);
                router.commit_recarve(pod, ready, setup);
                let carve = router.pods[pod].recarver.carve();
                let dur = self.service_duration(
                    &sched.price,
                    fp,
                    service,
                    workload,
                    batch.size(),
                    carve.as_ref(),
                );
                if !dur.is_finite() {
                    for r in &batch.requests {
                        state.rejected.push((
                            r.id,
                            format!(
                                "no plan can serve workload '{}' on this pod after \
                                 side-carve absorption",
                                workload.name
                            ),
                        ));
                    }
                    return Vec::new();
                }
                if let Some(label) = carve
                    .map(|s| s.label())
                    .or_else(|| service.plan_label(workload))
                {
                    *state.plan_histogram.entry(label).or_insert(0) += batch.size();
                }
                router.pods[pod].recarver.record_served(batch.size());
                let out = router.dispatch(pod, ready, dur);
                let reps = self.occupied_replicas(carve.as_ref(), batch.size());
                router.pods[pod].recarver.note_inflight(ready, out.done, reps);
                return completions_for(&batch, workload, out.done, pod);
            }
        }

        let main_carve = router.pods[pod].recarver.carve();
        let side_carve = router.pods[pod].recarver.side_carve();
        let b = batch.size();
        let dur_main =
            self.service_duration(&sched.price, fp, service, workload, b, main_carve.as_ref());
        let dur_side =
            self.service_duration(&sched.price, fp, service, workload, b, side_carve.as_ref());
        let fin = |free: f64, dur: f64| {
            if dur.is_finite() {
                free.max(ready) + dur
            } else {
                f64::INFINITY
            }
        };
        let fin_main = fin(main_free, dur_main);
        let fin_side = fin(side_free, dur_side);

        // Cross-epoch co-batching: shards of one scattered batch span
        // the group-granular re-carve boundary when that helps.
        if self.config.co_batch && b > 1 && dur_main.is_finite() && dur_side.is_finite() {
            let rm = main_carve.map_or(1, |s| s.batch_replicas).max(1);
            let rs = side_carve.map_or(1, |s| s.batch_replicas).max(1);
            // proportional to each generation's replica width, with both
            // generations guaranteed a non-empty shard
            let b_main = (b * rm).div_ceil(rm + rs).clamp(1, b - 1);
            let b_side = b - b_main;
            let dm =
                self.service_duration(&sched.price, fp, service, workload, b_main, main_carve.as_ref());
            let ds =
                self.service_duration(&sched.price, fp, service, workload, b_side, side_carve.as_ref());
            let fin_cross = fin(main_free, dm).max(fin(side_free, ds));
            if fin_cross < fin_main.min(fin_side) {
                let out_m = router.dispatch(pod, ready, dm);
                let reps = self.occupied_replicas(main_carve.as_ref(), b_main);
                router.pods[pod].recarver.note_inflight(ready, out_m.done, reps);
                let (_, done_s) = router.pods[pod].recarver.dispatch_side(ready, ds);
                // the batch gathers when its last shard finishes
                let done = out_m.done.max(done_s);
                state.co_batched += 1;
                state.co_batched_cross += 1;
                if let Some(s) = main_carve {
                    *state.plan_histogram.entry(s.label()).or_insert(0) += b_main;
                }
                if let Some(s) = side_carve {
                    *state.plan_histogram.entry(s.label()).or_insert(0) += b_side;
                }
                router.pods[pod].recarver.record_served(b_main);
                router.pods[pod].recarver.record_side_served(b_side);
                return completions_for(&batch, workload, done, pod);
            }
        }

        if !fin_main.is_finite() && !fin_side.is_finite() {
            for r in &batch.requests {
                state.rejected.push((
                    r.id,
                    format!(
                        "no live carve generation can serve workload '{}' on this pod \
                         (modeled service time is infinite under both the main and the \
                         side carve)",
                        workload.name
                    ),
                ));
            }
            return Vec::new();
        }
        if fin_side <= fin_main {
            if self.config.co_batch && b > 1 && side_carve.is_some_and(|s| s.batch_replicas > 1) {
                state.co_batched += 1;
            }
            if let Some(s) = side_carve {
                *state.plan_histogram.entry(s.label()).or_insert(0) += b;
            }
            let (_, done) = router.pods[pod].recarver.dispatch_side(ready, dur_side);
            router.pods[pod].recarver.record_side_served(b);
            completions_for(&batch, workload, done, pod)
        } else {
            if self.config.co_batch && b > 1 && main_carve.is_some_and(|s| s.batch_replicas > 1) {
                state.co_batched += 1;
            }
            if let Some(label) = main_carve
                .map(|s| s.label())
                .or_else(|| service.plan_label(workload))
            {
                *state.plan_histogram.entry(label).or_insert(0) += b;
            }
            let out = router.dispatch(pod, ready, dur_main);
            let reps = self.occupied_replicas(main_carve.as_ref(), b);
            router.pods[pod].recarver.note_inflight(ready, out.done, reps);
            router.pods[pod].recarver.record_served(b);
            completions_for(&batch, workload, out.done, pod)
        }
    }
}

/// [`EarliestFinish`] over the router's `free_at` index instead of a
/// linear scan. Split pods (whose estimate may be *negative* — the side
/// generation can start before the main timeline frees) are priced
/// unconditionally first; the remaining pods are visited in ascending
/// `(free_at, id)` order, and the scan stops as soon as a pod's
/// earliest possible start alone exceeds the best finish so far — valid
/// because estimates are non-negative for unsplit pods. Tie-breaking
/// (equal finish → lowest pod id) matches the linear policy exactly, so
/// both paths pick the same pod on every dispatch.
fn pruned_earliest_finish(
    router: &Router,
    batch: &Batch,
    est: &dyn Fn(usize, &Batch) -> f64,
    split: &BTreeSet<usize>,
) -> usize {
    let ready = batch.ready_at();
    let mut best: Option<(f64, usize)> = None;
    let better = |fin: f64, id: usize, best: &Option<(f64, usize)>| match best {
        None => true,
        Some((bf, bi)) => match fin.total_cmp(bf) {
            Ordering::Less => true,
            Ordering::Equal => id < *bi,
            Ordering::Greater => false,
        },
    };
    for &id in split {
        let fin = router.pods[id].free_at.max(ready) + est(id, batch);
        if better(fin, id, &best) {
            best = Some((fin, id));
        }
    }
    for id in router.pods_by_free() {
        if split.contains(&id) {
            continue;
        }
        let start = router.pods[id].free_at.max(ready);
        if let Some((bf, _)) = best {
            if start.total_cmp(&bf) == Ordering::Greater {
                break;
            }
        }
        let fin = start + est(id, batch);
        if better(fin, id, &best) {
            best = Some((fin, id));
        }
    }
    best.expect("router has no pods").1
}

/// One [`Completion`] per request of `batch`, all finishing at `done`
/// (batched requests complete together; a cross-epoch scatter gathers at
/// its last shard).
fn completions_for(
    batch: &Batch,
    workload: &Workload,
    done: f64,
    pod: usize,
) -> Vec<Completion> {
    batch
        .requests
        .iter()
        .map(|r| Completion {
            id: r.id,
            workload: workload.name,
            arrival: r.arrival,
            done,
            pod,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CostModel;
    use crate::coordinator::Planner;
    use crate::workload::Workload;

    struct ConstService(f64);
    impl CostModel for ConstService {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            self.0 * batch as f64
        }
    }
    impl Planner for ConstService {}

    fn req(id: u64, w: Workload, arrival: f64) -> Request {
        Request { id, workload: w, arrival, seed: id }
    }

    #[test]
    fn config_summary_is_one_reproducible_line() {
        let cfg = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 4, window: 2.0 })
            .plan(PlanPolicy::Auto)
            .recarve(RecarvePolicy::Hysteresis { threshold: 0.15, window: 2 })
            .dispatch(Arc::new(EarliestFinish))
            .co_batch(true)
            .rebalance(RebalancePolicy::Gain { threshold: 0.1, window: 2 });
        assert_eq!(
            cfg.summary(),
            "serve: batch=4x2s plan=auto patches=4 recarve=hysteresis(15% x 2) \
             dispatch=earliest-finish co-batch=on rebalance=gain(10% x 2) \
             scheduler=indexed"
        );
        // defaults render the legacy-shim posture
        let s = ServeConfig::new().summary();
        assert!(s.contains("plan=single"), "{s}");
        assert!(s.contains("recarve=inherit"), "{s}");
        assert!(s.contains("dispatch=least-loaded"), "{s}");
        assert!(s.contains("co-batch=off"), "{s}");
        assert!(s.contains("rebalance=never"), "{s}");
        assert!(s.contains("scheduler=indexed"), "{s}");
    }

    #[test]
    fn scheduler_mode_names_round_trip() {
        assert_eq!(SchedulerMode::from_name("indexed"), Some(SchedulerMode::Indexed));
        assert_eq!(SchedulerMode::from_name("linear"), Some(SchedulerMode::Linear));
        assert!(SchedulerMode::from_name("fast").is_none());
        assert_eq!(SchedulerMode::Indexed.to_string(), "indexed");
        assert_eq!(SchedulerMode::Linear.to_string(), "linear");
        assert_eq!(ServeConfig::new().scheduler, SchedulerMode::Indexed);
        assert_eq!(
            ServeConfig::new().scheduler(SchedulerMode::Linear).scheduler,
            SchedulerMode::Linear
        );
    }

    #[test]
    fn dispatch_policy_names_round_trip() {
        for name in ["least-loaded", "earliest-finish"] {
            assert_eq!(dispatch_policy_from_name(name).unwrap().name(), name);
        }
        assert!(dispatch_policy_from_name("random").is_none());
        assert_eq!(
            RebalancePolicy::from_name("never", 0.0, 0),
            Some(RebalancePolicy::Never)
        );
        assert_eq!(
            RebalancePolicy::from_name("gain", 0.2, 3),
            Some(RebalancePolicy::Gain { threshold: 0.2, window: 3 })
        );
        assert!(RebalancePolicy::from_name("sometimes", 0.0, 0).is_none());
    }

    #[test]
    fn least_loaded_matches_router_pick() {
        let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        router.dispatch(0, 0.0, 10.0);
        let batch = Batch { requests: vec![req(0, Workload::flux_3072(), 0.0)] };
        let est = |_: usize, _: &Batch| 0.0;
        let ctx = PolicyCtx::at(batch.ready_at(), 0.0);
        assert_eq!(LeastLoaded.pick(&router, &batch, &ctx, &est), router.pick());
    }

    #[test]
    fn earliest_finish_prefers_the_faster_pod() {
        // pod 0 free now but slow; pod 1 busy briefly but much faster:
        // earliest-finish picks pod 1, least-loaded picks pod 0.
        let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        router.dispatch(1, 0.0, 1.0);
        let batch = Batch { requests: vec![req(0, Workload::flux_3072(), 0.0)] };
        let est = |pod: usize, _: &Batch| if pod == 0 { 100.0 } else { 2.0 };
        let ctx = PolicyCtx::at(batch.ready_at(), 0.0);
        assert_eq!(EarliestFinish.pick(&router, &batch, &ctx, &est), 1);
        assert_eq!(LeastLoaded.pick(&router, &batch, &ctx, &est), 0);
        // ties break to the lowest pod id
        let router2 = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        let flat = |_: usize, _: &Batch| 1.0;
        assert_eq!(EarliestFinish.pick(&router2, &batch, &ctx, &flat), 0);
    }

    #[test]
    fn session_serves_a_trace_like_the_legacy_loop() {
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, Workload::flux_3072(), i as f64)).collect();
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let report = ServeSession::new(
            ServeConfig::new().batch(BatchPolicy { max_batch: 2, window: 1.0 }),
            &ConstService(0.5),
        )
        .run(&mut router, reqs);
        assert_eq!(report.metrics.completed(), 6);
        assert!(report.rejected.is_empty());
        assert!(report.rebalances.is_empty());
        assert_eq!(report.co_batched, 0);
        // completion events are processed in time order
        let dones: Vec<f64> = report.completions.iter().map(|c| c.2).collect();
        assert!(dones.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn deadline_arrival_joins_the_closing_batch() {
        // The flush-deadline edge: r1 arrives exactly when r0's window
        // expires. Arrival pushes before the batch-close sweep, so r1
        // must ride in r0's batch (one dispatch), not strand behind it.
        let reqs = vec![
            req(0, Workload::flux_3072(), 0.0),
            req(1, Workload::flux_3072(), 1.0), // == window deadline of r0
        ];
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let report = ServeSession::new(
            ServeConfig::new().batch(BatchPolicy { max_batch: 8, window: 1.0 }),
            &ConstService(0.5),
        )
        .run(&mut router, reqs);
        assert_eq!(report.metrics.completed(), 2);
        let dones: Vec<f64> = report.completions.iter().map(|c| c.2).collect();
        assert_eq!(dones[0], dones[1], "one shared batch, one completion time");
        assert_eq!(dones[0], 2.0, "closed at t=1 with 2 requests x 0.5s");
    }

    #[test]
    fn recarve_config_installs_on_every_pod() {
        let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        let cfg = ServeConfig::new()
            .recarve(RecarvePolicy::Never)
            .recarve_setup(0.125);
        ServeSession::new(cfg, &ConstService(0.1)).run(&mut router, Vec::new());
        for p in &router.pods {
            assert_eq!(p.recarver.policy, RecarvePolicy::Never);
            assert_eq!(p.recarver.setup_cost, 0.125);
        }
    }

    // ---- group-granular (partial) re-carving ------------------------------

    use crate::config::SpDegrees;
    use crate::coordinator::engine::ServeReport;

    fn short_spec() -> ParallelSpec {
        ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
    }

    fn narrowed_spec() -> ParallelSpec {
        ParallelSpec::new(1, 1, SpDegrees::new(8, 1))
    }

    fn video_full() -> ParallelSpec {
        ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
    }

    fn video_sub() -> ParallelSpec {
        // the 3-machine subset plan: one-machine pipeline stages
        ParallelSpec::with_pp(1, 3, 1, SpDegrees::new(8, 1))
    }

    fn is_video(w: &Workload) -> bool {
        w.name.starts_with("cfg-video")
    }

    /// Scripted two-workload model with hand-set times per
    /// (workload, carve), so every split/merge/routing decision below is
    /// hand-checkable.
    struct SplitScript;

    impl CostModel for SplitScript {
        fn service_time(&self, w: &Workload, batch: usize) -> f64 {
            let b = batch as f64;
            if is_video(w) {
                b
            } else {
                2.0 * b
            }
        }

        fn service_time_under(
            &self,
            w: &Workload,
            batch: usize,
            carve: Option<&ParallelSpec>,
        ) -> f64 {
            let b = batch as f64;
            let Some(c) = carve else {
                return self.service_time(w, batch);
            };
            if is_video(w) {
                if *c == video_full() {
                    b
                } else if *c == video_sub() {
                    1.5 * b
                } else {
                    4.0 * b // stale under a short carve
                }
            } else if *c == short_spec() || *c == narrowed_spec() {
                2.0 * b
            } else {
                3.0 * b // short under a video carve
            }
        }
    }

    impl Planner for SplitScript {
        fn plan_spec(&self, w: &Workload) -> Option<ParallelSpec> {
            Some(if is_video(w) { video_full() } else { short_spec() })
        }

        fn plan_label(&self, w: &Workload) -> Option<String> {
            self.plan_spec(w).map(|s| s.label())
        }

        fn recarve_gain(&self, _w: &Workload, _from: &ParallelSpec) -> Option<f64> {
            Some(0.9)
        }

        fn plan_spec_on(&self, w: &Workload, machines: usize) -> Option<ParallelSpec> {
            (is_video(w) && machines == 3).then(video_sub)
        }

        fn partial_recarve_gain(
            &self,
            _w: &Workload,
            _from: &ParallelSpec,
            idle_machines: usize,
        ) -> Option<f64> {
            (idle_machines == 3).then_some(0.9)
        }
    }

    fn partial_session(reqs: Vec<Request>, co_batch: bool) -> (ServeReport, Router) {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        router.set_recarve_with_setup(
            RecarvePolicy::Partial { threshold: 0.15, window: 1 },
            0.25,
        );
        let report = ServeSession::new(
            ServeConfig::new()
                .batch(BatchPolicy { max_batch: 1, window: 0.0 })
                .co_batch(co_batch),
            &SplitScript,
        )
        .run(&mut router, reqs);
        (report, router)
    }

    #[test]
    fn partial_policy_splits_a_busy_pod_and_serves_both_generations() {
        let reqs = vec![
            req(0, Workload::short_image_4k(), 0.0), // adopts the short carve, 2.0 s
            req(1, Workload::cfg_video_96k(), 0.5),  // busy pod → split, side serves
            req(2, Workload::short_image_4k(), 0.8), // routed to the narrowed main
        ];
        let (report, router) = partial_session(reqs, false);
        assert_eq!(report.metrics.completed(), 3);
        // r0: start 0, 2.0 s on the admission short carve → 2.0
        // r1: split at 0.5 (no drain), 0.25 setup, 1.5 s on the side → 2.25
        // r2: main busy till 2.0; short under the narrowed carve → 4.0
        let mut done: Vec<(u64, f64)> =
            report.completions.iter().map(|c| (c.0, c.2)).collect();
        done.sort_unstable_by_key(|&(id, _)| id);
        assert_eq!(done, vec![(0, 2.0), (1, 2.25), (2, 4.0)]);
        assert_eq!(report.recarve.partial_splits, 1);
        assert_eq!(report.recarve.recarve_count, 0, "no pod-wide transition paid");
        assert_eq!(report.recarve.drain_time, 0.0, "group barriers drain nothing");
        assert_eq!(report.recarve.setup_time, 0.25);
        assert_eq!(report.recarve.merges, 0);
        assert_eq!(report.recarve.group_epochs.len(), 1);
        let (gpod, ge) = &report.recarve.group_epochs[0];
        assert_eq!(*gpod, 0);
        assert_eq!((ge.base_machine, ge.machines), (1, 3));
        assert_eq!(ge.plan, Some(video_sub()));
        assert_eq!(ge.started_at, 0.75);
        assert_eq!(ge.served, 1);
        assert_eq!(ge.merged_at, None, "still live at end of run");
        assert!(router.pods[0].recarver.is_split());
        // histogram: one request under each of the three carves
        assert_eq!(report.plan_histogram.get(&short_spec().label()), Some(&1));
        assert_eq!(report.plan_histogram.get(&video_sub().label()), Some(&1));
        assert_eq!(report.plan_histogram.get(&narrowed_spec().label()), Some(&1));
        // observability: the partial block serializes (only) when it fired
        let json = crate::util::json::to_string(&report.to_json());
        assert!(json.contains("\"partial\":{"), "{json}");
        assert!(json.contains("\"splits\":1"), "{json}");
    }

    #[test]
    fn split_pod_reunifies_when_idle_and_readmits_for_free() {
        let reqs = vec![
            req(0, Workload::short_image_4k(), 0.0),
            req(1, Workload::cfg_video_96k(), 0.5), // split
            req(2, Workload::cfg_video_96k(), 10.0), // both idle → merge + re-admit
        ];
        let (report, router) = partial_session(reqs, false);
        assert_eq!(report.metrics.completed(), 3);
        assert_eq!(report.recarve.partial_splits, 1);
        assert_eq!(report.recarve.merges, 1);
        assert_eq!(report.recarve.group_epochs[0].1.merged_at, Some(10.0));
        assert!(!router.pods[0].recarver.is_split());
        // the merge pays one more re-setup (free_at → 10.25), then the
        // re-admitted full-pod video plan serves r2 in 1.0 s
        let r2 = report.completions.iter().find(|c| c.0 == 2).unwrap();
        assert_eq!(r2.2, 11.25);
        assert_eq!(report.recarve.setup_time, 0.5, "split + merge setups");
        assert!(report
            .recarve
            .epochs
            .iter()
            .any(|(_, e)| e.plan == Some(video_full())));
    }

    #[test]
    fn cross_epoch_co_batching_spans_both_generations() {
        // A split pod with a busy main generation: a 4-request short
        // batch either queues whole on one generation, or (co-batching)
        // scatters 2 + 2 across the re-carve boundary and gathers.
        let run = |co: bool| {
            let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
            router.set_recarve_with_setup(
                RecarvePolicy::Partial { threshold: 0.15, window: 1 },
                0.0,
            );
            router.pods[0]
                .recarver
                .on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(narrowed_spec()));
            router.pods[0]
                .recarver
                .split(0.0, Some(narrowed_spec()), Some(video_sub()), 1, 3);
            router.dispatch(0, 0.0, 0.5); // main busy till 0.5 (no merge)
            let reqs: Vec<Request> = (0..4)
                .map(|i| req(i, Workload::short_image_4k(), i as f64 * 0.1))
                .collect();
            ServeSession::new(
                ServeConfig::new()
                    .batch(BatchPolicy { max_batch: 4, window: 1.0 })
                    .co_batch(co),
                &SplitScript,
            )
            .run(&mut router, reqs)
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.metrics.completed(), 4);
        assert_eq!(on.metrics.completed(), 4);
        // off: whole batch on main → max(0.5, 0.3) + 2*4 = 8.5
        assert_eq!((off.co_batched, off.co_batched_cross), (0, 0));
        assert_eq!(off.metrics.horizon, 8.5);
        // on: 2 shards on main (busy till 0.5, 2*2 s) and 2 on the side
        // (free, 3*2 s) → gather at max(4.5, 6.3) = 6.3
        assert_eq!((on.co_batched, on.co_batched_cross), (1, 1));
        assert_eq!(on.metrics.horizon, 6.3);
        assert_eq!(on.plan_histogram.get(&narrowed_spec().label()), Some(&2));
        assert_eq!(on.plan_histogram.get(&video_sub().label()), Some(&2));
        // all four requests gather at the same instant
        assert!(on.completions.iter().all(|c| c.2 == 6.3));
        let json = crate::util::json::to_string(&on.to_json());
        assert!(json.contains("\"co_batched_cross\":1"), "{json}");
        assert!(!crate::util::json::to_string(&off.to_json()).contains("co_batched_cross"));
    }

    #[test]
    fn earliest_finish_prices_pods_by_their_live_carve() {
        // Satellite regression: a re-carve-averse (Never) pod frozen on a
        // carve that serves this workload slowly must *lose* a dispatch
        // it used to win under preferred-plan pricing.
        struct TwoCarve;
        impl CostModel for TwoCarve {
            fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
                2.0 * batch as f64
            }
            fn service_time_under(
                &self,
                _w: &Workload,
                batch: usize,
                carve: Option<&ParallelSpec>,
            ) -> f64 {
                match carve {
                    Some(c) if *c == short_spec() => 10.0 * batch as f64, // stale
                    _ => 2.0 * batch as f64,
                }
            }
        }
        impl Planner for TwoCarve {
            fn plan_spec(&self, _w: &Workload) -> Option<ParallelSpec> {
                Some(video_full())
            }
        }
        let mut router = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        router.set_recarve(RecarvePolicy::Never);
        // pod 0: idle, but frozen on the stale carve it admitted
        router.pods[0]
            .recarver
            .on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(short_spec()));
        // pod 1: on the preferred carve, busy until t = 1
        router.pods[1]
            .recarver
            .on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(video_full()));
        router.dispatch(1, 0.0, 1.0);
        let report = ServeSession::new(
            ServeConfig::new()
                .batch(BatchPolicy { max_batch: 1, window: 0.0 })
                .dispatch(Arc::new(EarliestFinish)),
            &TwoCarve,
        )
        .run(&mut router, vec![req(0, Workload::cfg_video_96k(), 0.0)]);
        // preferred-plan pricing: pod 0 wins (0 + 2 < 1 + 2) and serves a
        // 10 s stale generation. Live-carve pricing: pod 1 finishes at
        // 1 + 2 = 3 and wins.
        assert_eq!(report.metrics.completed(), 1);
        assert_eq!(report.completions[0].2, 3.0, "routed around the frozen pod");
    }

    #[test]
    fn partial_config_summary_renders() {
        let cfg = ServeConfig::new()
            .recarve(RecarvePolicy::Partial { threshold: 0.15, window: 2 });
        assert!(cfg.summary().contains("recarve=partial(15% x 2)"), "{}", cfg.summary());
    }

    #[test]
    fn split_pod_side_availability_flips_earliest_finish() {
        // Satellite regression (split-pod pricing): pod 0 is split — its
        // main generation is busy until t = 10, but its side generation
        // is idle and serves the video in 1.5 s. The old estimate took
        // the cheaper generation's *duration* and let EarliestFinish add
        // the pod's main free_at (finish 10 + 1.5 = 11.5), so pod 1
        // (busy till 2, then 1 s ⇒ finish 3) won and the idle side sat
        // unused. Generation-aware pricing sees the side's own timeline:
        // pod 0 finishes at 1.5 and wins — in both scheduler modes.
        let run = |mode: SchedulerMode| {
            let mut router = Router::new(8, 8, 2, SpAlgo::SwiftFusion);
            router.set_recarve_with_setup(
                RecarvePolicy::Partial { threshold: 0.15, window: 1 },
                0.0,
            );
            router.pods[0]
                .recarver
                .on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(narrowed_spec()));
            router.pods[0]
                .recarver
                .split(0.0, Some(narrowed_spec()), Some(video_sub()), 1, 3);
            router.dispatch(0, 0.0, 10.0); // main generation busy till t = 10
            router.pods[1]
                .recarver
                .on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(video_full()));
            router.dispatch(1, 0.0, 2.0); // pod 1 busy till t = 2
            ServeSession::new(
                ServeConfig::new()
                    .batch(BatchPolicy { max_batch: 1, window: 0.0 })
                    .dispatch(Arc::new(EarliestFinish))
                    .scheduler(mode),
                &SplitScript,
            )
            .run(&mut router, vec![req(0, Workload::cfg_video_96k(), 0.0)])
        };
        for mode in [SchedulerMode::Linear, SchedulerMode::Indexed] {
            let report = run(mode);
            assert_eq!(report.metrics.completed(), 1, "{mode}");
            assert_eq!(
                report.completions[0].2, 1.5,
                "{mode}: served on the idle side generation"
            );
        }
    }

    #[test]
    fn co_batched_occupancy_blocks_the_partial_split() {
        // Satellite regression (co-batch occupancy): a co-batched short
        // batch scatters one shard onto every replica group of the
        // 4-replica short carve, so *all four* machines are computing
        // when the video arrives — there is nothing idle to split off,
        // and the policy must fall back to the pod-wide transition. The
        // pre-fix footprint model counted one replica's machines busy
        // (as if the batch were serial) and split optimistically,
        // handing three still-computing machines to the side carve.
        let run = |co: bool| {
            let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
            router.set_recarve_with_setup(
                RecarvePolicy::Partial { threshold: 0.15, window: 1 },
                0.25,
            );
            let mut reqs: Vec<Request> = (0..4)
                .map(|i| req(i, Workload::short_image_4k(), 0.1 * i as f64))
                .collect();
            reqs.push(req(4, Workload::cfg_video_96k(), 0.5));
            ServeSession::new(
                ServeConfig::new()
                    .batch(BatchPolicy { max_batch: 4, window: 1.0 })
                    .co_batch(co),
                &SplitScript,
            )
            .run(&mut router, reqs)
        };

        // co-batching off: the shorts queue whole on one replica group
        // (done at 0.3 + 4·2 = 8.3), three machines really are idle at
        // t = 0.5, and the split fires exactly as before the fix.
        let off = run(false);
        assert_eq!(off.metrics.completed(), 5);
        assert_eq!(off.recarve.partial_splits, 1);
        assert_eq!(off.recarve.recarve_count, 0);
        assert_eq!(off.recarve.drain_time, 0.0);
        let video = off.completions.iter().find(|c| c.0 == 4).unwrap();
        // split at 0.5 (0.25 setup), 1.5 s on the 3-machine side carve
        assert_eq!(video.2, 2.25);

        // co-batching on: the short batch occupies all 4 replica groups
        // until 0.3 + 2 = 2.3; no split is possible, so the video pays
        // the pod-wide transition (drain 1.8 + setup 0.25) and serves
        // under the full-pod video plan at 2.55 + 1 = 3.55.
        let on = run(true);
        assert_eq!(on.metrics.completed(), 5);
        assert_eq!(on.recarve.partial_splits, 0, "no machine is idle to split off");
        assert_eq!(on.recarve.recarve_count, 1, "pod-wide transition instead");
        assert_eq!(on.recarve.drain_time, 1.8);
        assert_eq!(on.recarve.setup_time, 0.25);
        let video = on.completions.iter().find(|c| c.0 == 4).unwrap();
        assert_eq!(video.2, 3.55);
    }

    #[test]
    fn gain_policy_shrinks_back_when_the_mix_reverses() {
        // Satellite regression (shrink symmetry). Phase 1 pins the
        // established grow behaviour: a video-heavy trace on two 2-machine
        // pods migrates a machine toward the video pod (3 + 1). Phase 2
        // is the fix: when the mix reverses to shorts — which gain
        // nothing from a big pod — the 1-machine pod keeps receiving
        // dispatches while already busy (queue pressure), and the big pod
        // must give the machine back (2 + 2). Pre-fix, the trigger was
        // grow-only and the fleet stayed frozen at 3 + 1 forever.
        struct ScriptFleet;
        struct ScriptModel {
            machines: usize,
        }
        impl CostModel for ScriptModel {
            fn service_time(&self, w: &Workload, batch: usize) -> f64 {
                let b = batch as f64;
                if is_video(w) {
                    10.0 * b / self.machines as f64 // videos scale with the pod
                } else {
                    2.5 * b // shorts don't
                }
            }
        }
        impl Planner for ScriptModel {}
        impl FleetModel for ScriptFleet {
            fn model_for(&self, cluster: &ClusterSpec) -> Arc<dyn ServiceModel> {
                Arc::new(ScriptModel { machines: cluster.machines })
            }
        }

        let mut reqs: Vec<Request> = (0..3)
            .map(|i| req(i, Workload::cfg_video_96k(), 20.0 * i as f64))
            .collect();
        for i in 0..8 {
            reqs.push(req(3 + i, Workload::short_image_4k(), 60.0 + i as f64));
        }
        let mut router = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        let report = ServeSession::with_fleet(
            ServeConfig::new()
                .batch(BatchPolicy { max_batch: 1, window: 0.0 })
                // 16 patches: the regime the grow trigger is known to
                // clear 10% predicted gain in for the video workload on
                // a 2-machine pod (pinned by the drifting-mix test)
                .patches(16)
                .dispatch(Arc::new(EarliestFinish))
                .recarve_setup(0.01)
                .rebalance(RebalancePolicy::Gain { threshold: 0.1, window: 2 }),
            &ScriptFleet,
        )
        .run(&mut router, reqs);

        assert_eq!(report.metrics.completed(), 11);
        assert_eq!(report.rebalances.len(), 2, "one grow, one shrink");
        // grow: the second consecutive gainful video dispatch (t = 20)
        // pulls the idle pod 1's machine toward pod 0
        let grow = &report.rebalances[0];
        assert_eq!((grow.from_pod, grow.to_pod), (1, 0));
        assert_eq!((grow.from_machines, grow.to_machines), (1, 3));
        // shrink: under the short burst, pod 1 receives its second
        // consecutive dispatch while busy (t = 65) and pulls the
        // machine back from the strictly bigger pod 0
        let shrink = &report.rebalances[1];
        assert_eq!((shrink.from_pod, shrink.to_pod), (0, 1));
        assert_eq!((shrink.from_machines, shrink.to_machines), (2, 2));
        let machines: Vec<usize> =
            router.pods.iter().map(|p| p.cluster.machines).collect();
        assert_eq!(machines, vec![2, 2], "fleet returned to balance");
    }
}
