//! The event-driven serving scheduler: a [`ServeSession`] built from a
//! typed [`ServeConfig`] drives **arrival → batch-close → dispatch →
//! recarve-commit → completion** events over the virtual clock.
//!
//! Before this redesign the serving loop was one hard-coded
//! batch → pick → dispatch path (a 150-line free function with an inner
//! closure); policies lived in scattered places — batch policy as a
//! `serve()` argument, plan policy + patches in `SimService`
//! constructors, re-carving in ad-hoc `Router` setters. [`ServeConfig`]
//! folds all of them into one reproducible value (see
//! [`ServeConfig::summary`]), and the explicit event loop makes dispatch
//! policy pluggable ([`DispatchPolicy`]) and leaves room for fleet-level
//! events. The redesign ships its first two new scheduler clients:
//!
//! * **replica co-batching** (`ServeConfig::co_batch`) — a closed
//!   batch is *scattered* across its carve's batch-replica groups (each
//!   group serves `⌈B/R⌉` requests concurrently, outputs gathered)
//!   instead of the whole batch queueing on one group;
//! * **cross-pod re-balancing** ([`RebalancePolicy`]) — a fleet-level
//!   event that migrates an idle machine between pods when the workload
//!   mix shifts, extending [`crate::cluster::recarve`] epochs from
//!   per-pod to fleet scope
//!   ([`crate::coordinator::router::Router::rebalance_machine`]).
//!
//! The legacy [`crate::coordinator::engine::serve`] entry point remains
//! as a thin shim over [`ServeSession`] and reproduces the pre-redesign
//! results bit-for-bit on the pinned goldens
//! (`rust/tests/serve_session.rs`, `rust/tests/recarve_serving.rs`);
//! the one deliberate observable change is that completions are
//! recorded in completion-time order (see
//! [`crate::coordinator::engine::ServeReport::completions`]).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::{Arc, Mutex};

use crate::cluster::recarve::RecarvePolicy;
use crate::config::{ClusterSpec, ParallelSpec, ParallelSpecError};
use crate::coordinator::batcher::{Batch, BatchPolicy, Batcher};
use crate::coordinator::engine::{PlanPolicy, RecarveReport, ServeReport, SimService};
use crate::coordinator::metrics::{Completion, Metrics};
use crate::coordinator::router::{RebalanceEvent, Router};
use crate::coordinator::{CostModel, Planner, ServiceModel};
use crate::sp::SpAlgo;
use crate::workload::{Request, Workload};

// ---------------------------------------------------------------------------
// Dispatch policy
// ---------------------------------------------------------------------------

/// Pluggable "which pod serves this batch" policy. `est(pod, batch)`
/// is a service-time estimate on that pod (the pod-sized model's
/// preferred-plan time); policies that only read queue state may ignore
/// it — it is never called unless the policy asks.
pub trait DispatchPolicy: Sync {
    /// Stable policy name for the effective-config line
    /// ([`ServeConfig::summary`]) and CLI parsing.
    fn name(&self) -> &'static str;

    /// Pick the pod for `batch`. Must be deterministic.
    fn pick(
        &self,
        router: &Router,
        batch: &Batch,
        est: &dyn Fn(usize, &Batch) -> f64,
    ) -> usize;
}

/// The default (and the pre-redesign behaviour, `Router::pick`):
/// earliest-free pod, ties by lowest id.
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLoaded;

impl DispatchPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(
        &self,
        router: &Router,
        _batch: &Batch,
        _est: &dyn Fn(usize, &Batch) -> f64,
    ) -> usize {
        router.pick()
    }
}

/// Plan-aware dispatch: minimize the batch's predicted completion time
/// `max(free_at, ready) + est(pod, batch)` — with differently-sized pods
/// (cross-pod re-balancing) this routes long sequences to the pod whose
/// carve actually serves them fastest, where least-loaded is blind to
/// pod shape. Ties by lowest pod id.
#[derive(Debug, Clone, Copy, Default)]
pub struct EarliestFinish;

impl DispatchPolicy for EarliestFinish {
    fn name(&self) -> &'static str {
        "earliest-finish"
    }

    fn pick(
        &self,
        router: &Router,
        batch: &Batch,
        est: &dyn Fn(usize, &Batch) -> f64,
    ) -> usize {
        let ready = batch.ready_at();
        router
            .pods
            .iter()
            .map(|p| (p.id, p.free_at.max(ready) + est(p.id, batch)))
            .min_by(|(ia, a), (ib, b)| a.total_cmp(b).then(ia.cmp(ib)))
            .map(|(id, _)| id)
            .unwrap()
    }
}

/// Parse a dispatch policy by CLI name.
pub fn dispatch_policy_from_name(name: &str) -> Option<Arc<dyn DispatchPolicy>> {
    match name {
        "least-loaded" => Some(Arc::new(LeastLoaded)),
        "earliest-finish" => Some(Arc::new(EarliestFinish)),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Fleet scope: pod-sized models + re-balancing policy
// ---------------------------------------------------------------------------

/// Fleet-scope extension of the cost/plan pair: resolves a service model
/// *per pod footprint*. Cross-pod re-balancing changes pod sizes at
/// runtime, so a single cluster-bound model (like one `SimService`)
/// cannot price every pod; a `FleetModel` can.
pub trait FleetModel: Sync {
    /// The cost/plan model for a pod carved as `cluster`.
    fn model_for(&self, cluster: &ClusterSpec) -> Arc<dyn ServiceModel>;
}

/// [`FleetModel`] over auto-planning [`SimService`]s, one per distinct
/// pod footprint, built lazily and cached (the timing schedules behind
/// them are themselves cached per workload/batch/plan).
pub struct SimFleet {
    algo: SpAlgo,
    patches: usize,
    models: Mutex<HashMap<(usize, usize), Arc<SimService>>>,
}

impl SimFleet {
    /// An auto-planning fleet: every footprint gets
    /// [`SimService::auto_plan`] with the given patch count.
    pub fn auto(algo: SpAlgo, patches: usize) -> Self {
        Self { algo, patches, models: Mutex::new(HashMap::new()) }
    }
}

impl FleetModel for SimFleet {
    fn model_for(&self, cluster: &ClusterSpec) -> Arc<dyn ServiceModel> {
        let key = (cluster.machines, cluster.gpus_per_machine);
        let mut models = self.models.lock().unwrap();
        let model = models.entry(key).or_insert_with(|| {
            let mut svc = SimService::auto_plan(cluster.clone(), self.algo);
            svc.patches = self.patches;
            Arc::new(svc)
        });
        let model: Arc<SimService> = Arc::clone(model);
        model
    }
}

/// When the fleet may migrate an idle machine between pods
/// ([`crate::coordinator::router::Router::rebalance_machine`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RebalancePolicy {
    /// Pods keep their admission-time footprint (the pre-redesign
    /// behaviour, and the default).
    Never,
    /// Migrate one machine toward the dispatching pod when
    /// [`crate::analysis::rebalance_gain`] predicts at least `threshold`
    /// fractional per-step improvement from one more machine for
    /// `window` consecutive dispatches (fleet-scope hysteresis), and
    /// some other pod is idle with a machine to spare. Requires a
    /// [`FleetModel`] (pods change size); without one the policy is
    /// inert.
    Gain {
        /// Minimum predicted fractional gain (e.g. `0.1` for 10 %).
        threshold: f64,
        /// Consecutive gainful dispatches required before migrating.
        window: usize,
    },
}

impl RebalancePolicy {
    /// Parse a CLI policy name; `threshold`/`window` feed the gain
    /// variant and are ignored by `never`.
    pub fn from_name(name: &str, threshold: f64, window: usize) -> Option<Self> {
        match name {
            "never" => Some(Self::Never),
            "gain" => Some(Self::Gain { threshold, window }),
            _ => None,
        }
    }
}

impl std::fmt::Display for RebalancePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Never => write!(f, "never"),
            Self::Gain { threshold, window } => {
                write!(f, "gain({:.0}% x {window})", threshold * 100.0)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// ServeConfig
// ---------------------------------------------------------------------------

/// Typed serving configuration — every knob of one serving run in one
/// value, where they used to be scattered across `serve()` arguments,
/// `SimService` constructors, and `Router` setters. Built with the
/// builder methods; [`Self::summary`] renders the effective config as
/// one line so any run is reproducible from its log.
#[derive(Clone)]
pub struct ServeConfig {
    /// Batching policy (max batch size + batching window — how long
    /// the head request may wait for same-workload companions; distinct
    /// from replica *co*-batching, which is the `co_batch` flag).
    pub batch: BatchPolicy,
    /// Plan policy the service model is built from
    /// ([`Self::sim_service`]); informational for hand-built models.
    pub plan: PlanPolicy,
    /// Patch count for pipelined (`pp_degree > 1`) plans.
    pub patches: usize,
    /// Re-carving policy to install on every pod at run start; `None`
    /// (the default) inherits whatever the router already has — the
    /// legacy-shim behaviour.
    pub recarve: Option<RecarvePolicy>,
    /// Per-transition re-setup seconds to install on every pod at run
    /// start; `None` keeps each pod's modeled
    /// [`crate::cluster::recarve::resetup_cost`].
    pub recarve_setup: Option<f64>,
    /// Which pod serves each batch ([`LeastLoaded`] by default).
    pub dispatch: Arc<dyn DispatchPolicy>,
    /// Replica co-batching: scatter a closed batch across its carve's
    /// batch-replica groups (service time of `⌈B/R⌉` per group) instead
    /// of queueing the whole batch on one group. Off by default (the
    /// pre-redesign behaviour).
    pub co_batch: bool,
    /// Cross-pod machine migration policy ([`RebalancePolicy::Never`]
    /// by default).
    pub rebalance: RebalancePolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batch: BatchPolicy::default(),
            plan: PlanPolicy::SingleMesh,
            patches: crate::analysis::DEFAULT_PATCHES,
            recarve: None,
            recarve_setup: None,
            dispatch: Arc::new(LeastLoaded),
            co_batch: false,
            rebalance: RebalancePolicy::Never,
        }
    }
}

impl ServeConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the batching policy.
    pub fn batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }

    /// Set the plan policy ([`Self::sim_service`] builds from it).
    pub fn plan(mut self, plan: PlanPolicy) -> Self {
        self.plan = plan;
        self
    }

    /// Set the pipeline patch count.
    pub fn patches(mut self, patches: usize) -> Self {
        assert!(patches > 0, "patches must be >= 1");
        self.patches = patches;
        self
    }

    /// Install a re-carving policy on every pod at run start.
    pub fn recarve(mut self, policy: RecarvePolicy) -> Self {
        self.recarve = Some(policy);
        self
    }

    /// Pin the per-transition re-setup cost (seconds) on every pod.
    pub fn recarve_setup(mut self, seconds: f64) -> Self {
        self.recarve_setup = Some(seconds);
        self
    }

    /// Set the dispatch policy.
    pub fn dispatch(mut self, policy: Arc<dyn DispatchPolicy>) -> Self {
        self.dispatch = policy;
        self
    }

    /// Enable/disable replica co-batching.
    pub fn co_batch(mut self, on: bool) -> Self {
        self.co_batch = on;
        self
    }

    /// Set the cross-pod re-balancing policy.
    pub fn rebalance(mut self, policy: RebalancePolicy) -> Self {
        self.rebalance = policy;
        self
    }

    /// Build the timing-mode service model this config describes for one
    /// pod footprint — the constructor scatter
    /// (`SimService::{new, auto_plan, with_plan}` + `patches` field
    /// pokes) behind one call.
    pub fn sim_service(
        &self,
        cluster: ClusterSpec,
        algo: SpAlgo,
    ) -> Result<SimService, ParallelSpecError> {
        let mut svc = match &self.plan {
            PlanPolicy::SingleMesh => SimService::new(cluster, algo),
            PlanPolicy::Auto => SimService::auto_plan(cluster, algo),
            PlanPolicy::Fixed(spec) => SimService::with_plan(cluster, algo, *spec)?,
        };
        svc.patches = self.patches;
        Ok(svc)
    }

    /// The effective-config line, e.g.
    /// `serve: batch=4x2s plan=auto patches=4 recarve=hysteresis(15% x 2)
    /// dispatch=least-loaded co-batch=off rebalance=never` — printed by
    /// the CLI so a run is reproducible from its log.
    pub fn summary(&self) -> String {
        format!(
            "serve: batch={}x{}s plan={} patches={} recarve={} dispatch={} co-batch={} \
             rebalance={}",
            self.batch.max_batch,
            self.batch.window,
            self.plan,
            self.patches,
            self.recarve
                .map_or_else(|| "inherit".to_string(), |p| p.to_string()),
            self.dispatch.name(),
            if self.co_batch { "on" } else { "off" },
            self.rebalance,
        )
    }
}

// ---------------------------------------------------------------------------
// ServeState — the named accumulation state of one run
// ---------------------------------------------------------------------------

/// Mutable accumulation state of one serving run — the six `&mut`
/// arguments the pre-redesign `serve_batch` closure threaded, as one
/// named struct the dispatch handler receives.
#[derive(Default)]
pub struct ServeState {
    pub metrics: Metrics,
    /// (request id, arrival, completion), in completion-event order.
    pub completions: Vec<(u64, f64, f64)>,
    /// (request id, reason) for admission- and dispatch-time rejections.
    pub rejected: Vec<(u64, String)>,
    /// Plan label served under → request count.
    pub plan_histogram: std::collections::BTreeMap<String, usize>,
    /// Fleet-scope machine migrations, in commit order.
    pub rebalances: Vec<RebalanceEvent>,
    /// Dispatches whose batch was scattered across replica groups.
    pub co_batched: usize,
    /// Of `co_batched`, dispatches whose shards spanned both carve
    /// generations of a split pod (cross-epoch co-batching).
    pub co_batched_cross: usize,
}

impl ServeState {
    /// Finalize into a [`ServeReport`], snapshotting the pods' epoch
    /// logs (the tail of the pre-redesign `serve()`).
    pub fn into_report(self, router: &Router) -> ServeReport {
        let mut recarve = RecarveReport::default();
        for pod in &router.pods {
            let rc = &pod.recarver;
            recarve.recarve_count += rc.recarve_count();
            recarve.drain_time += rc.drain_time();
            recarve.setup_time += rc.setup_time();
            for e in rc.epochs() {
                *recarve.epoch_histogram.entry(e.label()).or_insert(0) += 1;
                recarve.epochs.push((pod.id, e.clone()));
            }
            recarve.partial_splits += rc.partial_splits();
            recarve.merges += rc.merges();
            for e in rc.group_epochs() {
                recarve.group_epochs.push((pod.id, e.clone()));
            }
        }
        ServeReport {
            metrics: self.metrics,
            completions: self.completions,
            rejected: self.rejected,
            plan_histogram: self.plan_histogram,
            recarve,
            rebalances: self.rebalances,
            co_batched: self.co_batched,
            co_batched_cross: self.co_batched_cross,
        }
    }
}

// ---------------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------------

/// One scheduler event over the virtual clock.
enum Event {
    /// A request reaches the coordinator (admission + batching).
    Arrival(Request),
    /// The batcher closed a batch at this instant; dispatch it.
    Dispatch(Batch),
    /// A dispatched batch's requests finish service.
    Completion(Completion),
    /// End of trace: force-close everything still queued.
    Flush,
}

/// Heap entry: events process in `(time, seq)` order — seq is the
/// creation order, so same-instant events are FIFO and the loop is
/// deterministic (and, with the default config, reproduces the legacy
/// nested-loop order exactly).
struct Timed {
    at: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for Timed {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Timed {}
impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first
        other
            .at
            .total_cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Where the scheduler gets cost/plan models from: one shared model
/// (pods priced identically — the classic path), or a [`FleetModel`]
/// pricing each pod by its current footprint (required for cross-pod
/// re-balancing).
#[derive(Clone, Copy)]
enum ModelSource<'a> {
    Shared(&'a dyn ServiceModel),
    Fleet(&'a dyn FleetModel),
}

/// A resolved per-pod model (borrowed or fleet-owned).
enum PodModel<'a> {
    Shared(&'a dyn ServiceModel),
    Owned(Arc<dyn ServiceModel>),
}

impl PodModel<'_> {
    fn get(&self) -> &dyn ServiceModel {
        match self {
            PodModel::Shared(s) => *s,
            PodModel::Owned(a) => a.as_ref(),
        }
    }
}

impl<'a> ModelSource<'a> {
    fn for_pod(&self, cluster: &ClusterSpec) -> PodModel<'a> {
        match self {
            ModelSource::Shared(s) => PodModel::Shared(*s),
            ModelSource::Fleet(f) => PodModel::Owned(f.model_for(cluster)),
        }
    }

    /// Fleet-wide admission: a shared model speaks for every pod; with
    /// a fleet source a request is admitted when *any* pod's
    /// footprint-sized model admits it (footprints diverge after
    /// re-balancing — a workload only the big pod can serve must not be
    /// rejected because a small pod cannot). On rejection the first
    /// pod's reason is reported.
    fn admit(&self, router: &Router, workload: &Workload) -> Result<(), String> {
        match self {
            ModelSource::Shared(s) => s.admit(workload),
            ModelSource::Fleet(f) => {
                let mut first_err = None;
                for p in &router.pods {
                    match f.model_for(&p.cluster).admit(workload) {
                        Ok(()) => return Ok(()),
                        Err(e) => {
                            first_err.get_or_insert(e);
                        }
                    }
                }
                Err(first_err.unwrap_or_else(|| "router has no pods".to_string()))
            }
        }
    }
}

/// One serving run: a [`ServeConfig`], a model source, and the
/// event-driven scheduler that executes a request trace against a
/// [`Router`]. Construct with [`Self::new`] (one shared service model)
/// or [`Self::with_fleet`] (per-footprint models, enables cross-pod
/// re-balancing), then call [`Self::run`].
pub struct ServeSession<'a> {
    config: ServeConfig,
    source: ModelSource<'a>,
}

impl<'a> ServeSession<'a> {
    /// A session pricing every pod with one shared service model.
    pub fn new(config: ServeConfig, service: &'a dyn ServiceModel) -> Self {
        Self { config, source: ModelSource::Shared(service) }
    }

    /// A session pricing each pod by its current footprint — required
    /// for [`RebalancePolicy::Gain`] (pods change size at runtime).
    pub fn with_fleet(config: ServeConfig, fleet: &'a dyn FleetModel) -> Self {
        Self { config, source: ModelSource::Fleet(fleet) }
    }

    /// Execute `requests` (time-ordered) against `router`. Deterministic
    /// virtual time; every request ends as exactly one completion or one
    /// rejection in the report.
    pub fn run(self, router: &mut Router, requests: Vec<Request>) -> ServeReport {
        if let Some(policy) = self.config.recarve {
            match self.config.recarve_setup {
                Some(s) => router.set_recarve_with_setup(policy, s),
                None => router.set_recarve(policy),
            }
        } else if let Some(s) = self.config.recarve_setup {
            for p in &mut router.pods {
                p.recarver.setup_cost = s;
            }
        }

        let mut state = ServeState::default();
        let mut batcher = Batcher::new(self.config.batch.clone());
        // Fleet-rebalance hysteresis streaks, keyed by the *receiving*
        // pod (mirroring the per-pod EpochTracker streak): a pod earns
        // its machine with its own consecutive gainful dispatches, so
        // two gainful pods cannot pool their streaks and interleaved
        // traffic to other pods does not reset a pod's progress.
        let mut fleet_streaks: HashMap<usize, usize> = HashMap::new();
        let mut heap: BinaryHeap<Timed> = BinaryHeap::new();
        let mut seq = 0u64;
        let mut push = |heap: &mut BinaryHeap<Timed>, at: f64, ev: Event| {
            heap.push(Timed { at, seq, ev });
            seq += 1;
        };
        for r in requests {
            push(&mut heap, r.arrival, Event::Arrival(r));
        }
        push(&mut heap, f64::INFINITY, Event::Flush);

        while let Some(Timed { at, ev, .. }) = heap.pop() {
            match ev {
                Event::Arrival(r) => {
                    if let Err(reason) = self.source.admit(router, &r.workload) {
                        state.rejected.push((r.id, reason));
                        continue;
                    }
                    batcher.push(r);
                    // batch-close: sweep synchronously at the arrival
                    // instant (push-then-sweep, so a request arriving
                    // exactly at a window deadline joins the closing
                    // batch), dispatch as queued events
                    while let Some(batch) = batcher.pop_ready(at) {
                        push(&mut heap, at, Event::Dispatch(batch));
                    }
                }
                Event::Dispatch(batch) => {
                    for c in
                        self.dispatch_batch(router, batch, &mut state, &mut fleet_streaks)
                    {
                        push(&mut heap, c.done, Event::Completion(c));
                    }
                }
                Event::Completion(c) => {
                    state.metrics.observe(&c);
                    state.completions.push((c.id, c.arrival, c.done));
                }
                Event::Flush => {
                    while let Some(batch) = batcher.pop_any() {
                        push(&mut heap, at, Event::Dispatch(batch));
                    }
                }
            }
        }
        state.into_report(router)
    }

    /// The dispatch handler: pick a pod, run the fleet re-balancing and
    /// per-pod re-carving policies, commit the (possibly co-batched)
    /// service to the pod timeline. Returns one [`Completion`] per
    /// request (empty when the batch is rejected at dispatch).
    fn dispatch_batch(
        &self,
        router: &mut Router,
        batch: Batch,
        state: &mut ServeState,
        fleet_streaks: &mut HashMap<usize, usize>,
    ) -> Vec<Completion> {
        let workload = batch.requests[0].workload.clone();
        let ready = batch.ready_at();
        let source = self.source;
        // Plan-aware dispatch estimates price each pod by the carve it
        // will actually serve under: for pods whose policy can hold a
        // stale carve (anything but the free idealization), that is the
        // pod's *live* carve — a re-carve-averse pod no longer wins
        // dispatches on the strength of a preferred plan it will refuse
        // to adopt. Free-policy pods adopt the preferred plan at
        // dispatch, unpaid, so the preferred-plan estimate remains exact
        // for them. A split pod is approximated by its cheaper
        // generation's *duration* (EarliestFinish adds the pod's main
        // free_at, not the side's own timeline — generation-aware pod
        // pricing is a known follow-up).
        let est = |pod: usize, b: &Batch| -> f64 {
            let p = &router.pods[pod];
            let svc = source.for_pod(&p.cluster);
            let svc = svc.get();
            let w = &b.requests[0].workload;
            let live = if matches!(p.recarver.policy, RecarvePolicy::Free) {
                None
            } else {
                p.recarver.carve()
            };
            match live {
                None => svc.service_time(w, b.size()),
                Some(c) => {
                    let t = svc.service_time_under(w, b.size(), Some(&c));
                    match p.recarver.side_carve() {
                        Some(s) => t.min(svc.service_time_under(w, b.size(), Some(&s))),
                        None => t,
                    }
                }
            }
        };
        let pod = self.config.dispatch.pick(router, &batch, &est);

        // Fleet event: would one more machine pay off here, and is some
        // other pod idle enough to donate one?
        if let RebalancePolicy::Gain { threshold, window } = self.config.rebalance {
            if matches!(self.source, ModelSource::Fleet(_)) {
                let cur = router.pods[pod].cluster.clone();
                let grown = cur.resized(cur.machines + 1);
                let gain = crate::analysis::rebalance_gain(
                    &cur,
                    &grown,
                    router.pods[pod].algo,
                    &workload.shape,
                    workload.cfg_evals,
                    self.config.patches,
                );
                let streak = fleet_streaks.entry(pod).or_insert(0);
                if gain >= threshold {
                    *streak += 1;
                } else {
                    *streak = 0;
                }
                if *streak >= window.max(1) {
                    let donor = router
                        .pods
                        .iter()
                        .filter(|p| {
                            p.id != pod && p.free_at <= ready && p.cluster.machines >= 2
                        })
                        .min_by_key(|p| (Reverse(p.cluster.machines), p.id))
                        .map(|p| p.id);
                    if let Some(donor) = donor {
                        state.rebalances.push(router.rebalance_machine(donor, pod, ready));
                        fleet_streaks.clear();
                    }
                }
            }
        }

        let model = self.source.for_pod(&router.pods[pod].cluster);
        let service = model.get();
        let preferred = service.plan_spec(&workload);
        // A pod running two carve generations (a group-granular split,
        // RecarvePolicy::Partial) has its own dispatch path: merge when
        // the whole pod is idle, otherwise route between generations.
        if router.pods[pod].recarver.is_split() {
            return self.dispatch_split(
                router,
                pod,
                batch,
                &workload,
                ready,
                service,
                preferred,
                state,
            );
        }
        let free_at = router.pods[pod].free_at;
        // Compute the modeled gain only for policies that read it.
        let gain = {
            let rc = &router.pods[pod].recarver;
            if rc.policy.wants_gain() {
                match rc.carve() {
                    Some(from) if Some(from) != preferred => {
                        service.recarve_gain(&workload, &from)
                    }
                    _ => None,
                }
            } else {
                None
            }
        };
        let mut t = router.pods[pod]
            .recarver
            .on_dispatch(ready, free_at, preferred, gain);
        if t.split_pending {
            // The Partial policy fired on a busy pod: split off the idle
            // machines and serve this batch on the fresh side carve.
            if let Some(out) =
                self.try_split(router, pod, &batch, &workload, ready, service, state)
            {
                return out;
            }
            // No machine-aligned split exists (or the model cannot plan
            // the subset, or the predicted gain does not clear the
            // threshold): fall back to the pod-wide transition plain
            // hysteresis would have made at this point.
            t = router.pods[pod].recarver.force(ready, free_at, preferred);
        }
        let mut dur = self.service_duration(service, &workload, batch.size(), t.carve.as_ref());
        if !dur.is_finite() {
            // The live carve cannot serve this batch at all (e.g. a
            // patch granularity larger than the sequence); dispatching
            // an infinite duration would poison the pod's timeline
            // forever. If the preferred plan can serve it, the re-carve
            // is forced by physics, overriding the policy; if nothing
            // can, the batch is rejected rather than dispatched.
            let pref_dur = if t.carve == preferred {
                dur
            } else {
                self.service_duration(service, &workload, batch.size(), preferred.as_ref())
            };
            if !pref_dur.is_finite() {
                for r in &batch.requests {
                    state.rejected.push((
                        r.id,
                        format!(
                            "no plan can serve workload '{}' on this pod (modeled \
                             service time is infinite under both the live carve and \
                             the preferred plan)",
                            workload.name
                        ),
                    ));
                }
                return Vec::new();
            }
            t = router.pods[pod].recarver.force(ready, free_at, preferred);
            dur = pref_dur;
        }
        if t.recarved && t.setup > 0.0 {
            router.commit_recarve(pod, ready, t.setup);
        }
        if self.config.co_batch
            && batch.size() > 1
            && t.carve.is_some_and(|s| s.batch_replicas > 1)
        {
            state.co_batched += 1;
        }
        if let Some(label) = t
            .carve
            .map(|s| s.label())
            .or_else(|| service.plan_label(&workload))
        {
            *state.plan_histogram.entry(label).or_insert(0) += batch.size();
        }
        router.pods[pod].recarver.record_served(batch.size());
        let out = router.dispatch(pod, ready, dur);
        batch
            .requests
            .iter()
            .map(|r| Completion {
                id: r.id,
                workload: workload.name,
                arrival: r.arrival,
                done: out.done,
                pod,
            })
            .collect()
    }

    /// Modeled service seconds for `batch_size` requests of `workload`
    /// under `carve`: with co-batching on, the batch scatters across the
    /// carve's replica groups and the makespan is one group's largest
    /// shard; otherwise the whole batch serves on one group (the
    /// pre-redesign behaviour).
    fn service_duration(
        &self,
        service: &dyn ServiceModel,
        workload: &Workload,
        batch_size: usize,
        carve: Option<&ParallelSpec>,
    ) -> f64 {
        let eff = if self.config.co_batch {
            carve
                .map(|s| s.replica_shards(batch_size)[0])
                .unwrap_or(batch_size)
        } else {
            batch_size
        };
        service.service_time_under(workload, eff, carve)
    }

    /// Attempt a group-granular split on `pod` (the `Partial` policy
    /// fired while the pod was busy): narrow the busy carve to its
    /// in-flight machine footprint, re-carve the idle machines to the
    /// model's subset plan, and serve this batch on the fresh side
    /// generation — no drain barrier is paid. Returns `None` when no
    /// machine-aligned split exists, the model cannot plan the subset,
    /// or the predicted gain ([`Planner::partial_recarve_gain`]) does
    /// not clear the policy threshold; the caller then falls back to a
    /// pod-wide transition.
    ///
    /// Modeling simplification: the busy footprint is taken as **one
    /// replica's groups** — exact for the serial dispatch path (a batch
    /// serves on one replica group). A *co-batched* in-flight batch may
    /// actually occupy every replica group, in which case the split is
    /// optimistic by up to that batch's residual service time on the
    /// "idle" machines (the router does not track per-group occupancy;
    /// a finer model would narrow to the scattered footprint).
    #[allow(clippy::too_many_arguments)]
    fn try_split(
        &self,
        router: &mut Router,
        pod: usize,
        batch: &Batch,
        workload: &Workload,
        ready: f64,
        service: &dyn ServiceModel,
        state: &mut ServeState,
    ) -> Option<Vec<Completion>> {
        let threshold = match router.pods[pod].recarver.policy {
            RecarvePolicy::Partial { threshold, .. } => threshold,
            _ => return None,
        };
        let gpm = router.pods[pod].cluster.gpus_per_machine;
        let machines = router.pods[pod].cluster.machines;
        let live = router.pods[pod].recarver.carve()?;
        // machine-footprint accounting: the in-flight batch occupies one
        // replica's worth of groups, rounded up to whole machines; only
        // what is left can re-carve
        let narrowed = live.narrowed_to_machines(gpm)?;
        let busy = narrowed.total_ranks() / gpm;
        let idle = machines.checked_sub(busy).filter(|&i| i > 0)?;
        let side_plan = service.plan_spec_on(workload, idle)?;
        let gain = service.partial_recarve_gain(workload, &live, idle)?;
        if gain < threshold {
            return None;
        }
        let dur = self.service_duration(service, workload, batch.size(), Some(&side_plan));
        if !dur.is_finite() {
            return None;
        }
        router.pods[pod]
            .recarver
            .split(ready, Some(narrowed), Some(side_plan), busy, idle);
        let (_, done) = router.pods[pod].recarver.dispatch_side(ready, dur);
        if self.config.co_batch && batch.size() > 1 && side_plan.batch_replicas > 1 {
            state.co_batched += 1;
        }
        *state.plan_histogram.entry(side_plan.label()).or_insert(0) += batch.size();
        router.pods[pod].recarver.record_side_served(batch.size());
        Some(completions_for(batch, workload, done, pod))
    }

    /// Dispatch onto a pod running two carve generations: re-unify when
    /// the whole pod is idle ([`crate::cluster::recarve::EpochTracker::merge`]),
    /// otherwise route the batch to the generation completing it
    /// earliest — or, with co-batching on, scatter its shards across
    /// **both** generations when the gathered result lands sooner than
    /// either generation alone (cross-epoch co-batching).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_split(
        &self,
        router: &mut Router,
        pod: usize,
        batch: Batch,
        workload: &Workload,
        ready: f64,
        service: &dyn ServiceModel,
        preferred: Option<ParallelSpec>,
        state: &mut ServeState,
    ) -> Vec<Completion> {
        let main_free = router.pods[pod].free_at;
        let side_free = router.pods[pod]
            .recarver
            .side_free_at()
            .expect("dispatch_split on an unsplit pod");

        // Whole pod idle: merge the side generation back and serve this
        // batch under the re-admitted full-footprint carve.
        if main_free <= ready && side_free <= ready {
            let setup = router.pods[pod].recarver.merge(ready);
            router.commit_recarve(pod, ready, setup);
            let free_at = router.pods[pod].free_at;
            let t = router.pods[pod]
                .recarver
                .on_dispatch(ready, free_at, preferred, None);
            let dur = self.service_duration(service, workload, batch.size(), t.carve.as_ref());
            if !dur.is_finite() {
                for r in &batch.requests {
                    state.rejected.push((
                        r.id,
                        format!(
                            "no plan can serve workload '{}' on this pod after \
                             re-unification",
                            workload.name
                        ),
                    ));
                }
                return Vec::new();
            }
            if let Some(label) = t
                .carve
                .map(|s| s.label())
                .or_else(|| service.plan_label(workload))
            {
                *state.plan_histogram.entry(label).or_insert(0) += batch.size();
            }
            router.pods[pod].recarver.record_served(batch.size());
            let out = router.dispatch(pod, ready, dur);
            return completions_for(&batch, workload, out.done, pod);
        }

        let main_carve = router.pods[pod].recarver.carve();
        let side_carve = router.pods[pod].recarver.side_carve();
        let b = batch.size();
        let dur_main = self.service_duration(service, workload, b, main_carve.as_ref());
        let dur_side = self.service_duration(service, workload, b, side_carve.as_ref());
        let fin = |free: f64, dur: f64| {
            if dur.is_finite() {
                free.max(ready) + dur
            } else {
                f64::INFINITY
            }
        };
        let fin_main = fin(main_free, dur_main);
        let fin_side = fin(side_free, dur_side);

        // Cross-epoch co-batching: shards of one scattered batch span
        // the group-granular re-carve boundary when that helps.
        if self.config.co_batch && b > 1 && dur_main.is_finite() && dur_side.is_finite() {
            let rm = main_carve.map_or(1, |s| s.batch_replicas).max(1);
            let rs = side_carve.map_or(1, |s| s.batch_replicas).max(1);
            // proportional to each generation's replica width, with both
            // generations guaranteed a non-empty shard
            let b_main = (b * rm).div_ceil(rm + rs).clamp(1, b - 1);
            let b_side = b - b_main;
            let dm = self.service_duration(service, workload, b_main, main_carve.as_ref());
            let ds = self.service_duration(service, workload, b_side, side_carve.as_ref());
            let fin_cross = fin(main_free, dm).max(fin(side_free, ds));
            if fin_cross < fin_main.min(fin_side) {
                let out_m = router.dispatch(pod, ready, dm);
                let (_, done_s) = router.pods[pod].recarver.dispatch_side(ready, ds);
                // the batch gathers when its last shard finishes
                let done = out_m.done.max(done_s);
                state.co_batched += 1;
                state.co_batched_cross += 1;
                if let Some(s) = main_carve {
                    *state.plan_histogram.entry(s.label()).or_insert(0) += b_main;
                }
                if let Some(s) = side_carve {
                    *state.plan_histogram.entry(s.label()).or_insert(0) += b_side;
                }
                router.pods[pod].recarver.record_served(b_main);
                router.pods[pod].recarver.record_side_served(b_side);
                return completions_for(&batch, workload, done, pod);
            }
        }

        if !fin_main.is_finite() && !fin_side.is_finite() {
            for r in &batch.requests {
                state.rejected.push((
                    r.id,
                    format!(
                        "no live carve generation can serve workload '{}' on this pod \
                         (modeled service time is infinite under both the main and the \
                         side carve)",
                        workload.name
                    ),
                ));
            }
            return Vec::new();
        }
        if fin_side <= fin_main {
            if self.config.co_batch && b > 1 && side_carve.is_some_and(|s| s.batch_replicas > 1) {
                state.co_batched += 1;
            }
            if let Some(s) = side_carve {
                *state.plan_histogram.entry(s.label()).or_insert(0) += b;
            }
            let (_, done) = router.pods[pod].recarver.dispatch_side(ready, dur_side);
            router.pods[pod].recarver.record_side_served(b);
            completions_for(&batch, workload, done, pod)
        } else {
            if self.config.co_batch && b > 1 && main_carve.is_some_and(|s| s.batch_replicas > 1) {
                state.co_batched += 1;
            }
            if let Some(label) = main_carve
                .map(|s| s.label())
                .or_else(|| service.plan_label(workload))
            {
                *state.plan_histogram.entry(label).or_insert(0) += b;
            }
            let out = router.dispatch(pod, ready, dur_main);
            router.pods[pod].recarver.record_served(b);
            completions_for(&batch, workload, out.done, pod)
        }
    }
}

/// One [`Completion`] per request of `batch`, all finishing at `done`
/// (batched requests complete together; a cross-epoch scatter gathers at
/// its last shard).
fn completions_for(
    batch: &Batch,
    workload: &Workload,
    done: f64,
    pod: usize,
) -> Vec<Completion> {
    batch
        .requests
        .iter()
        .map(|r| Completion {
            id: r.id,
            workload: workload.name,
            arrival: r.arrival,
            done,
            pod,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CostModel;
    use crate::coordinator::Planner;
    use crate::workload::Workload;

    struct ConstService(f64);
    impl CostModel for ConstService {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            self.0 * batch as f64
        }
    }
    impl Planner for ConstService {}

    fn req(id: u64, w: Workload, arrival: f64) -> Request {
        Request { id, workload: w, arrival, seed: id }
    }

    #[test]
    fn config_summary_is_one_reproducible_line() {
        let cfg = ServeConfig::new()
            .batch(BatchPolicy { max_batch: 4, window: 2.0 })
            .plan(PlanPolicy::Auto)
            .recarve(RecarvePolicy::Hysteresis { threshold: 0.15, window: 2 })
            .dispatch(Arc::new(EarliestFinish))
            .co_batch(true)
            .rebalance(RebalancePolicy::Gain { threshold: 0.1, window: 2 });
        assert_eq!(
            cfg.summary(),
            "serve: batch=4x2s plan=auto patches=4 recarve=hysteresis(15% x 2) \
             dispatch=earliest-finish co-batch=on rebalance=gain(10% x 2)"
        );
        // defaults render the legacy-shim posture
        let s = ServeConfig::new().summary();
        assert!(s.contains("plan=single"), "{s}");
        assert!(s.contains("recarve=inherit"), "{s}");
        assert!(s.contains("dispatch=least-loaded"), "{s}");
        assert!(s.contains("co-batch=off"), "{s}");
        assert!(s.contains("rebalance=never"), "{s}");
    }

    #[test]
    fn dispatch_policy_names_round_trip() {
        for name in ["least-loaded", "earliest-finish"] {
            assert_eq!(dispatch_policy_from_name(name).unwrap().name(), name);
        }
        assert!(dispatch_policy_from_name("random").is_none());
        assert_eq!(
            RebalancePolicy::from_name("never", 0.0, 0),
            Some(RebalancePolicy::Never)
        );
        assert_eq!(
            RebalancePolicy::from_name("gain", 0.2, 3),
            Some(RebalancePolicy::Gain { threshold: 0.2, window: 3 })
        );
        assert!(RebalancePolicy::from_name("sometimes", 0.0, 0).is_none());
    }

    #[test]
    fn least_loaded_matches_router_pick() {
        let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        router.dispatch(0, 0.0, 10.0);
        let batch = Batch { requests: vec![req(0, Workload::flux_3072(), 0.0)] };
        let est = |_: usize, _: &Batch| 0.0;
        assert_eq!(LeastLoaded.pick(&router, &batch, &est), router.pick());
    }

    #[test]
    fn earliest_finish_prefers_the_faster_pod() {
        // pod 0 free now but slow; pod 1 busy briefly but much faster:
        // earliest-finish picks pod 1, least-loaded picks pod 0.
        let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        router.dispatch(1, 0.0, 1.0);
        let batch = Batch { requests: vec![req(0, Workload::flux_3072(), 0.0)] };
        let est = |pod: usize, _: &Batch| if pod == 0 { 100.0 } else { 2.0 };
        assert_eq!(EarliestFinish.pick(&router, &batch, &est), 1);
        assert_eq!(LeastLoaded.pick(&router, &batch, &est), 0);
        // ties break to the lowest pod id
        let router2 = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        let flat = |_: usize, _: &Batch| 1.0;
        assert_eq!(EarliestFinish.pick(&router2, &batch, &flat), 0);
    }

    #[test]
    fn session_serves_a_trace_like_the_legacy_loop() {
        let reqs: Vec<Request> =
            (0..6).map(|i| req(i, Workload::flux_3072(), i as f64)).collect();
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let report = ServeSession::new(
            ServeConfig::new().batch(BatchPolicy { max_batch: 2, window: 1.0 }),
            &ConstService(0.5),
        )
        .run(&mut router, reqs);
        assert_eq!(report.metrics.completed(), 6);
        assert!(report.rejected.is_empty());
        assert!(report.rebalances.is_empty());
        assert_eq!(report.co_batched, 0);
        // completion events are processed in time order
        let dones: Vec<f64> = report.completions.iter().map(|c| c.2).collect();
        assert!(dones.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn deadline_arrival_joins_the_closing_batch() {
        // The flush-deadline edge: r1 arrives exactly when r0's window
        // expires. Arrival pushes before the batch-close sweep, so r1
        // must ride in r0's batch (one dispatch), not strand behind it.
        let reqs = vec![
            req(0, Workload::flux_3072(), 0.0),
            req(1, Workload::flux_3072(), 1.0), // == window deadline of r0
        ];
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let report = ServeSession::new(
            ServeConfig::new().batch(BatchPolicy { max_batch: 8, window: 1.0 }),
            &ConstService(0.5),
        )
        .run(&mut router, reqs);
        assert_eq!(report.metrics.completed(), 2);
        let dones: Vec<f64> = report.completions.iter().map(|c| c.2).collect();
        assert_eq!(dones[0], dones[1], "one shared batch, one completion time");
        assert_eq!(dones[0], 2.0, "closed at t=1 with 2 requests x 0.5s");
    }

    #[test]
    fn recarve_config_installs_on_every_pod() {
        let mut router = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        let cfg = ServeConfig::new()
            .recarve(RecarvePolicy::Never)
            .recarve_setup(0.125);
        ServeSession::new(cfg, &ConstService(0.1)).run(&mut router, Vec::new());
        for p in &router.pods {
            assert_eq!(p.recarver.policy, RecarvePolicy::Never);
            assert_eq!(p.recarver.setup_cost, 0.125);
        }
    }

    // ---- group-granular (partial) re-carving ------------------------------

    use crate::config::SpDegrees;
    use crate::coordinator::engine::ServeReport;

    fn short_spec() -> ParallelSpec {
        ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
    }

    fn narrowed_spec() -> ParallelSpec {
        ParallelSpec::new(1, 1, SpDegrees::new(8, 1))
    }

    fn video_full() -> ParallelSpec {
        ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
    }

    fn video_sub() -> ParallelSpec {
        // the 3-machine subset plan: one-machine pipeline stages
        ParallelSpec::with_pp(1, 3, 1, SpDegrees::new(8, 1))
    }

    fn is_video(w: &Workload) -> bool {
        w.name.starts_with("cfg-video")
    }

    /// Scripted two-workload model with hand-set times per
    /// (workload, carve), so every split/merge/routing decision below is
    /// hand-checkable.
    struct SplitScript;

    impl CostModel for SplitScript {
        fn service_time(&self, w: &Workload, batch: usize) -> f64 {
            let b = batch as f64;
            if is_video(w) {
                b
            } else {
                2.0 * b
            }
        }

        fn service_time_under(
            &self,
            w: &Workload,
            batch: usize,
            carve: Option<&ParallelSpec>,
        ) -> f64 {
            let b = batch as f64;
            let Some(c) = carve else {
                return self.service_time(w, batch);
            };
            if is_video(w) {
                if *c == video_full() {
                    b
                } else if *c == video_sub() {
                    1.5 * b
                } else {
                    4.0 * b // stale under a short carve
                }
            } else if *c == short_spec() || *c == narrowed_spec() {
                2.0 * b
            } else {
                3.0 * b // short under a video carve
            }
        }
    }

    impl Planner for SplitScript {
        fn plan_spec(&self, w: &Workload) -> Option<ParallelSpec> {
            Some(if is_video(w) { video_full() } else { short_spec() })
        }

        fn plan_label(&self, w: &Workload) -> Option<String> {
            self.plan_spec(w).map(|s| s.label())
        }

        fn recarve_gain(&self, _w: &Workload, _from: &ParallelSpec) -> Option<f64> {
            Some(0.9)
        }

        fn plan_spec_on(&self, w: &Workload, machines: usize) -> Option<ParallelSpec> {
            (is_video(w) && machines == 3).then(video_sub)
        }

        fn partial_recarve_gain(
            &self,
            _w: &Workload,
            _from: &ParallelSpec,
            idle_machines: usize,
        ) -> Option<f64> {
            (idle_machines == 3).then_some(0.9)
        }
    }

    fn partial_session(reqs: Vec<Request>, co_batch: bool) -> (ServeReport, Router) {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        router.set_recarve_with_setup(
            RecarvePolicy::Partial { threshold: 0.15, window: 1 },
            0.25,
        );
        let report = ServeSession::new(
            ServeConfig::new()
                .batch(BatchPolicy { max_batch: 1, window: 0.0 })
                .co_batch(co_batch),
            &SplitScript,
        )
        .run(&mut router, reqs);
        (report, router)
    }

    #[test]
    fn partial_policy_splits_a_busy_pod_and_serves_both_generations() {
        let reqs = vec![
            req(0, Workload::short_image_4k(), 0.0), // adopts the short carve, 2.0 s
            req(1, Workload::cfg_video_96k(), 0.5),  // busy pod → split, side serves
            req(2, Workload::short_image_4k(), 0.8), // routed to the narrowed main
        ];
        let (report, router) = partial_session(reqs, false);
        assert_eq!(report.metrics.completed(), 3);
        // r0: start 0, 2.0 s on the admission short carve → 2.0
        // r1: split at 0.5 (no drain), 0.25 setup, 1.5 s on the side → 2.25
        // r2: main busy till 2.0; short under the narrowed carve → 4.0
        let mut done: Vec<(u64, f64)> =
            report.completions.iter().map(|c| (c.0, c.2)).collect();
        done.sort_unstable_by_key(|&(id, _)| id);
        assert_eq!(done, vec![(0, 2.0), (1, 2.25), (2, 4.0)]);
        assert_eq!(report.recarve.partial_splits, 1);
        assert_eq!(report.recarve.recarve_count, 0, "no pod-wide transition paid");
        assert_eq!(report.recarve.drain_time, 0.0, "group barriers drain nothing");
        assert_eq!(report.recarve.setup_time, 0.25);
        assert_eq!(report.recarve.merges, 0);
        assert_eq!(report.recarve.group_epochs.len(), 1);
        let (gpod, ge) = &report.recarve.group_epochs[0];
        assert_eq!(*gpod, 0);
        assert_eq!((ge.base_machine, ge.machines), (1, 3));
        assert_eq!(ge.plan, Some(video_sub()));
        assert_eq!(ge.started_at, 0.75);
        assert_eq!(ge.served, 1);
        assert_eq!(ge.merged_at, None, "still live at end of run");
        assert!(router.pods[0].recarver.is_split());
        // histogram: one request under each of the three carves
        assert_eq!(report.plan_histogram.get(&short_spec().label()), Some(&1));
        assert_eq!(report.plan_histogram.get(&video_sub().label()), Some(&1));
        assert_eq!(report.plan_histogram.get(&narrowed_spec().label()), Some(&1));
        // observability: the partial block serializes (only) when it fired
        let json = crate::util::json::to_string(&report.to_json());
        assert!(json.contains("\"partial\":{"), "{json}");
        assert!(json.contains("\"splits\":1"), "{json}");
    }

    #[test]
    fn split_pod_reunifies_when_idle_and_readmits_for_free() {
        let reqs = vec![
            req(0, Workload::short_image_4k(), 0.0),
            req(1, Workload::cfg_video_96k(), 0.5), // split
            req(2, Workload::cfg_video_96k(), 10.0), // both idle → merge + re-admit
        ];
        let (report, router) = partial_session(reqs, false);
        assert_eq!(report.metrics.completed(), 3);
        assert_eq!(report.recarve.partial_splits, 1);
        assert_eq!(report.recarve.merges, 1);
        assert_eq!(report.recarve.group_epochs[0].1.merged_at, Some(10.0));
        assert!(!router.pods[0].recarver.is_split());
        // the merge pays one more re-setup (free_at → 10.25), then the
        // re-admitted full-pod video plan serves r2 in 1.0 s
        let r2 = report.completions.iter().find(|c| c.0 == 2).unwrap();
        assert_eq!(r2.2, 11.25);
        assert_eq!(report.recarve.setup_time, 0.5, "split + merge setups");
        assert!(report
            .recarve
            .epochs
            .iter()
            .any(|(_, e)| e.plan == Some(video_full())));
    }

    #[test]
    fn cross_epoch_co_batching_spans_both_generations() {
        // A split pod with a busy main generation: a 4-request short
        // batch either queues whole on one generation, or (co-batching)
        // scatters 2 + 2 across the re-carve boundary and gathers.
        let run = |co: bool| {
            let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
            router.set_recarve_with_setup(
                RecarvePolicy::Partial { threshold: 0.15, window: 1 },
                0.0,
            );
            router.pods[0]
                .recarver
                .on_dispatch(0.0, 0.0, Some(narrowed_spec()), None);
            router.pods[0]
                .recarver
                .split(0.0, Some(narrowed_spec()), Some(video_sub()), 1, 3);
            router.dispatch(0, 0.0, 0.5); // main busy till 0.5 (no merge)
            let reqs: Vec<Request> = (0..4)
                .map(|i| req(i, Workload::short_image_4k(), i as f64 * 0.1))
                .collect();
            ServeSession::new(
                ServeConfig::new()
                    .batch(BatchPolicy { max_batch: 4, window: 1.0 })
                    .co_batch(co),
                &SplitScript,
            )
            .run(&mut router, reqs)
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.metrics.completed(), 4);
        assert_eq!(on.metrics.completed(), 4);
        // off: whole batch on main → max(0.5, 0.3) + 2*4 = 8.5
        assert_eq!((off.co_batched, off.co_batched_cross), (0, 0));
        assert_eq!(off.metrics.horizon, 8.5);
        // on: 2 shards on main (busy till 0.5, 2*2 s) and 2 on the side
        // (free, 3*2 s) → gather at max(4.5, 6.3) = 6.3
        assert_eq!((on.co_batched, on.co_batched_cross), (1, 1));
        assert_eq!(on.metrics.horizon, 6.3);
        assert_eq!(on.plan_histogram.get(&narrowed_spec().label()), Some(&2));
        assert_eq!(on.plan_histogram.get(&video_sub().label()), Some(&2));
        // all four requests gather at the same instant
        assert!(on.completions.iter().all(|c| c.2 == 6.3));
        let json = crate::util::json::to_string(&on.to_json());
        assert!(json.contains("\"co_batched_cross\":1"), "{json}");
        assert!(!crate::util::json::to_string(&off.to_json()).contains("co_batched_cross"));
    }

    #[test]
    fn earliest_finish_prices_pods_by_their_live_carve() {
        // Satellite regression: a re-carve-averse (Never) pod frozen on a
        // carve that serves this workload slowly must *lose* a dispatch
        // it used to win under preferred-plan pricing.
        struct TwoCarve;
        impl CostModel for TwoCarve {
            fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
                2.0 * batch as f64
            }
            fn service_time_under(
                &self,
                _w: &Workload,
                batch: usize,
                carve: Option<&ParallelSpec>,
            ) -> f64 {
                match carve {
                    Some(c) if *c == short_spec() => 10.0 * batch as f64, // stale
                    _ => 2.0 * batch as f64,
                }
            }
        }
        impl Planner for TwoCarve {
            fn plan_spec(&self, _w: &Workload) -> Option<ParallelSpec> {
                Some(video_full())
            }
        }
        let mut router = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        router.set_recarve(RecarvePolicy::Never);
        // pod 0: idle, but frozen on the stale carve it admitted
        router.pods[0]
            .recarver
            .on_dispatch(0.0, 0.0, Some(short_spec()), None);
        // pod 1: on the preferred carve, busy until t = 1
        router.pods[1]
            .recarver
            .on_dispatch(0.0, 0.0, Some(video_full()), None);
        router.dispatch(1, 0.0, 1.0);
        let report = ServeSession::new(
            ServeConfig::new()
                .batch(BatchPolicy { max_batch: 1, window: 0.0 })
                .dispatch(Arc::new(EarliestFinish)),
            &TwoCarve,
        )
        .run(&mut router, vec![req(0, Workload::cfg_video_96k(), 0.0)]);
        // preferred-plan pricing: pod 0 wins (0 + 2 < 1 + 2) and serves a
        // 10 s stale generation. Live-carve pricing: pod 1 finishes at
        // 1 + 2 = 3 and wins.
        assert_eq!(report.metrics.completed(), 1);
        assert_eq!(report.completions[0].2, 3.0, "routed around the frozen pod");
    }

    #[test]
    fn partial_config_summary_renders() {
        let cfg = ServeConfig::new()
            .recarve(RecarvePolicy::Partial { threshold: 0.15, window: 2 });
        assert!(cfg.summary().contains("recarve=partial(15% x 2)"), "{}", cfg.summary());
    }
}
