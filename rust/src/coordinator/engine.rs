//! The serving engine: a deterministic virtual-time loop over
//! router + batcher + a [`ServiceModel`].
//!
//! Also provides [`SimService`]: the paper-scale service model that runs
//! the *actual* SP schedules in timing mode (threaded cluster, shape-only
//! buffers) to get per-layer latencies, then scales by layers × steps.
//! Results are cached per (workload, batch) since the schedules are
//! deterministic.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::cluster::exec::{run_cluster, ExecMode};
use crate::comm::Buf;
use crate::config::{ClusterSpec, SpDegrees};
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::Router;
use crate::coordinator::ServiceModel;
use crate::sp::{SpAlgo, SpParams};
use crate::workload::{Request, Workload};

/// Timing-mode service model: one full generation = steps × layers ×
/// (per-layer distributed attention + pointwise stages).
pub struct SimService {
    pub cluster: ClusterSpec,
    pub algo: SpAlgo,
    /// Per-generation fixed overhead (VAE decode, host sync), seconds.
    pub fixed_overhead: f64,
    cache: Mutex<HashMap<(String, usize), f64>>,
}

impl SimService {
    pub fn new(cluster: ClusterSpec, algo: SpAlgo) -> Self {
        Self { cluster, algo, fixed_overhead: 0.05, cache: Mutex::new(HashMap::new()) }
    }

    /// One attention layer's simulated makespan for `workload` at batch b.
    pub fn layer_time(&self, workload: &Workload, batch: usize) -> f64 {
        let p = self.cluster.total_gpus();
        let w = workload.aligned_to(p * 64);
        let mut shape = w.shape;
        shape.b = batch;
        let degrees = match self.algo {
            SpAlgo::Usp => {
                let pu = crate::config::gcd(self.cluster.gpus_per_machine, shape.h);
                SpDegrees::new(pu, p / pu)
            }
            SpAlgo::Ring => SpDegrees::new(1, p),
            SpAlgo::Ulysses => SpDegrees::new(crate::config::gcd(p, shape.h), p / crate::config::gcd(p, shape.h)),
            _ => SpDegrees::swiftfusion_default(&self.cluster, shape.h),
        };
        let params = SpParams {
            shape,
            chunk: shape.l / p,
            mesh: self.algo.mesh(&self.cluster, degrees),
        };
        let ls = params.shard_len();
        let algo = self.algo;
        let run = run_cluster(&self.cluster, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![shape.b, ls, shape.h, shape.d]);
            algo.run(ctx, &params, s.clone(), s.clone(), s);
        });
        // pointwise stages: qkv proj (2·3·hid²) + out proj (2·hid²) +
        // MLP at 4x ratio (2·2·4·hid²) = 24·hid² MACs per token
        let hidden = (shape.h * shape.d) as f64;
        let mlp = self.cluster.gpu.tile_time(
            24.0 * shape.b as f64 * ls as f64 * hidden * hidden,
            10.0 * (shape.b * ls * shape.h * shape.d) as f64 * 4.0,
        );
        run.makespan() + mlp
    }
}

impl ServiceModel for SimService {
    fn service_time(&self, workload: &Workload, batch: usize) -> f64 {
        let key = (workload.name.to_string(), batch);
        if let Some(&t) = self.cache.lock().unwrap().get(&key) {
            return t;
        }
        let layer = self.layer_time(workload, batch);
        let total = layer * workload.layers as f64 * workload.steps as f64 + self.fixed_overhead;
        self.cache.lock().unwrap().insert(key, total);
        total
    }
}

/// Outcome of a serving run.
pub struct ServeReport {
    pub metrics: Metrics,
    /// (request id, arrival, completion) per request.
    pub completions: Vec<(u64, f64, f64)>,
}

/// Deterministic virtual-time serving loop: requests (time-ordered) flow
/// through the batcher; closed batches dispatch to the least-loaded pod.
pub fn serve(
    router: &mut Router,
    policy: BatchPolicy,
    requests: Vec<Request>,
    service: &dyn ServiceModel,
) -> ServeReport {
    let mut batcher = Batcher::new(policy);
    let mut metrics = Metrics::new();
    let mut completions = Vec::new();

    let serve_batch = |router: &mut Router,
                           batch: crate::coordinator::batcher::Batch,
                           metrics: &mut Metrics,
                           completions: &mut Vec<(u64, f64, f64)>| {
        let pod = router.pick();
        let workload = batch.requests[0].workload.clone();
        let dur = service.service_time(&workload, batch.size());
        let (_, done) = router.dispatch(pod, batch.ready_at(), dur);
        for r in &batch.requests {
            metrics.record(workload.name, done - r.arrival, done);
            completions.push((r.id, r.arrival, done));
        }
    };

    for r in requests {
        let now = r.arrival;
        batcher.push(r);
        while let Some(batch) = batcher.pop_ready(now) {
            serve_batch(router, batch, &mut metrics, &mut completions);
        }
    }
    // end of trace: drain
    while let Some(batch) = batcher.pop_any() {
        serve_batch(router, batch, &mut metrics, &mut completions);
    }
    ServeReport { metrics, completions }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceGen;

    struct ConstService(f64);
    impl ServiceModel for ConstService {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            self.0 * batch as f64
        }
    }

    #[test]
    fn serves_all_requests_exactly_once() {
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(3, 1.0, Workload::paper_suite()).take(40);
        let report = serve(
            &mut router,
            BatchPolicy { max_batch: 4, window: 1.0 },
            reqs,
            &ConstService(0.5),
        );
        assert_eq!(report.metrics.completed(), 40);
        let mut ids: Vec<u64> = report.completions.iter().map(|c| c.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "no request lost or served twice");
    }

    #[test]
    fn completions_never_precede_arrivals() {
        let mut router = Router::new(1, 2, 1, SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(9, 2.0, vec![Workload::flux_3072()]).take(30);
        let report = serve(&mut router, BatchPolicy::default(), reqs, &ConstService(0.2));
        for (_, arrival, done) in &report.completions {
            assert!(done > arrival);
        }
    }

    #[test]
    fn more_pods_more_throughput() {
        let reqs = || TraceGen::new(4, 50.0, vec![Workload::flux_3072()]).take(64);
        let run = |pods: usize| {
            let mut router = Router::new(4, 2, pods, SpAlgo::SwiftFusion);
            let rep = serve(
                &mut router,
                BatchPolicy { max_batch: 1, window: 0.0 },
                reqs(),
                &ConstService(1.0),
            );
            rep.metrics.horizon
        };
        let t1 = run(1);
        let t4 = run(4);
        assert!(t4 < t1 / 2.0, "4 pods {t4} vs 1 pod {t1}");
    }

    #[test]
    fn batching_amortizes_under_load() {
        // With a sub-linear service model, batching must beat no-batching
        // on saturated arrivals.
        struct SubLinear;
        impl ServiceModel for SubLinear {
            fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
                1.0 + 0.1 * batch as f64
            }
        }
        let reqs = || TraceGen::new(4, 100.0, vec![Workload::flux_3072()]).take(64);
        let run = |max_batch: usize| {
            let mut router = Router::new(1, 2, 1, SpAlgo::SwiftFusion);
            let rep = serve(
                &mut router,
                BatchPolicy { max_batch, window: 0.05 },
                reqs(),
                &SubLinear,
            );
            rep.metrics.horizon
        };
        assert!(run(8) < run(1) / 2.0);
    }

    #[test]
    fn sim_service_is_cached_and_scales_with_steps() {
        let svc = SimService::new(ClusterSpec::new(2, 2), SpAlgo::SwiftFusion);
        let w20 = Workload::cogvideo_20s();
        let t1 = svc.service_time(&w20, 1);
        let t1_again = svc.service_time(&w20, 1);
        assert_eq!(t1, t1_again, "cache hit must be identical");
        let w40 = Workload::cogvideo_40s();
        let t40 = svc.service_time(&w40, 1);
        assert!(t40 > t1, "40s video must cost more than 20s");
    }
}
