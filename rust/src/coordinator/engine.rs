//! Service models and the serving report: [`SimService`] — the
//! paper-scale service model that runs the *actual* SP schedules in
//! timing mode (threaded cluster, shape-only buffers) to get per-layer
//! latencies, then scales by layers × steps, cached per
//! (workload, batch, plan) since the schedules are deterministic — plus
//! [`ServeReport`] and the legacy [`serve`] entry point, now a thin shim
//! over the event-driven scheduler
//! ([`crate::coordinator::session::ServeSession`]).

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::cluster::exec::{run_in_world, ExecMode};
use crate::cluster::plan::ParallelPlan;
use crate::cluster::recarve::{GroupEpoch, PlanEpoch};
use crate::comm::{Buf, CommStats, CommWorld};
use crate::config::{ClusterSpec, ParallelSpec, ParallelSpecError, SpDegrees};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{RebalanceEvent, Router};
use crate::coordinator::session::{ServeConfig, ServeSession};
use crate::coordinator::{CostModel, Planner, ServiceModel};
use crate::sp::{hybrid, pipefusion, SpAlgo, SpParams};
use crate::util::json::Json;
use crate::workload::{Request, Workload};

/// How the engine maps requests to hybrid CFG×SP plans.
#[derive(Debug, Clone)]
pub enum PlanPolicy {
    /// Seed behaviour: the whole pod is one SP mesh and guidance
    /// branches are folded into the per-layer constant. Kept for
    /// baseline comparisons against the hybrid plans.
    SingleMesh,
    /// One fixed spec for every request. Strict: requests whose sequence
    /// length does not divide over the spec's SP ranks are *rejected* at
    /// admission (no silent cropping).
    Fixed(ParallelSpec),
    /// Per-workload choice via [`crate::analysis::choose_spec`];
    /// workloads are aligned to the chosen group size.
    Auto,
}

impl std::fmt::Display for PlanPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::SingleMesh => write!(f, "single"),
            Self::Fixed(spec) => write!(f, "fixed({})", spec.label()),
            Self::Auto => write!(f, "auto"),
        }
    }
}

/// Timing-mode service model: one full generation = steps × layers ×
/// (per-layer distributed attention + pointwise stages), with the
/// per-layer attention makespan taken from the executable schedule of
/// the policy's plan.
pub struct SimService {
    pub cluster: ClusterSpec,
    pub algo: SpAlgo,
    /// Per-generation fixed overhead (VAE decode, host sync), seconds.
    pub fixed_overhead: f64,
    pub plan: PlanPolicy,
    /// Patch count for pipelined (`pp_degree > 1`) plans — PipeFusion's
    /// `M`, shared with the cost model's pipeline term.
    pub patches: usize,
    /// When set, [`Self::patches`] is ignored and the patch count is
    /// chosen per workload by the closed-form argmin
    /// ([`crate::analysis::choose_patches`]) — `--patches auto`.
    pub patches_auto: bool,
    /// (workload, batch, plan label) → service seconds. The plan label
    /// keys the cache because the epoch-aware engine may serve the same
    /// workload under a *stale* carve as well as its preferred plan.
    cache: Mutex<HashMap<(String, usize, String), f64>>,
    /// Auto-plan memo: workload name → chosen spec (the chooser
    /// re-enumerates the whole plan space otherwise — once per batch).
    spec_cache: Mutex<HashMap<String, ParallelSpec>>,
    /// Subset-plan memo for group-granular re-carving:
    /// (workload name, machines) → chosen spec for that footprint.
    sub_spec_cache: Mutex<HashMap<(String, usize), ParallelSpec>>,
    /// Auto-patch memo: workload name → argmin patch count (the argmin
    /// re-prices every candidate × the whole plan space otherwise).
    patch_cache: Mutex<HashMap<String, usize>>,
    /// Comm counters accumulated across every *executed* pricing run
    /// (cache hits add nothing — the counters describe the modeled
    /// schedules, not per-request traffic). Surfaced by
    /// [`Self::comm_stats`] into the serve report's `comm` section.
    comm: Mutex<CommStats>,
}

impl SimService {
    pub fn new(cluster: ClusterSpec, algo: SpAlgo) -> Self {
        Self {
            cluster,
            algo,
            fixed_overhead: 0.05,
            plan: PlanPolicy::SingleMesh,
            patches: crate::analysis::DEFAULT_PATCHES,
            patches_auto: false,
            cache: Mutex::new(HashMap::new()),
            spec_cache: Mutex::new(HashMap::new()),
            sub_spec_cache: Mutex::new(HashMap::new()),
            patch_cache: Mutex::new(HashMap::new()),
            comm: Mutex::new(CommStats::default()),
        }
    }

    /// A service bound to one fixed hybrid spec (validated here).
    pub fn with_plan(
        cluster: ClusterSpec,
        algo: SpAlgo,
        spec: ParallelSpec,
    ) -> Result<Self, ParallelSpecError> {
        spec.validate(&cluster)?;
        let mut s = Self::new(cluster, algo);
        s.plan = PlanPolicy::Fixed(spec);
        Ok(s)
    }

    /// A service that picks a plan per workload via the cost model.
    pub fn auto_plan(cluster: ClusterSpec, algo: SpAlgo) -> Self {
        let mut s = Self::new(cluster, algo);
        s.plan = PlanPolicy::Auto;
        s
    }

    /// The pipeline patch count used for `workload`: the fixed
    /// [`Self::patches`] normally, or the per-workload closed-form
    /// argmin when [`Self::patches_auto`] is on (memoized — the argmin
    /// prices every candidate against the whole plan space).
    pub fn patches_for(&self, workload: &Workload) -> usize {
        if !self.patches_auto {
            return self.patches;
        }
        if let Some(&m) = self.patch_cache.lock().unwrap().get(workload.name) {
            return m;
        }
        let m = crate::analysis::choose_patches(
            &self.cluster,
            self.algo,
            &workload.shape,
            workload.cfg_evals,
        );
        self.patch_cache
            .lock()
            .unwrap()
            .insert(workload.name.to_string(), m);
        m
    }

    /// One attention layer's simulated makespan for `workload` at batch b.
    pub fn layer_time(&self, workload: &Workload, batch: usize) -> f64 {
        let p = self.cluster.total_gpus();
        let w = workload.aligned_to(p * 64);
        let mut shape = w.shape;
        shape.b = batch;
        let degrees = match self.algo {
            SpAlgo::Usp => {
                let pu = crate::config::gcd(self.cluster.gpus_per_machine, shape.h);
                SpDegrees::new(pu, p / pu)
            }
            SpAlgo::Ring => SpDegrees::new(1, p),
            SpAlgo::Ulysses => {
                let pu = crate::config::gcd(p, shape.h);
                SpDegrees::new(pu, p / pu)
            }
            _ => SpDegrees::swiftfusion_default(&self.cluster, shape.h),
        };
        let params = SpParams {
            shape,
            chunk: shape.l / p,
            mesh: self.algo.mesh(&self.cluster, degrees),
        };
        let ls = params.shard_len();
        let algo = self.algo;
        let world = CommWorld::new(self.cluster.clone());
        let run = run_in_world(&world, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![shape.b, ls, shape.h, shape.d]);
            algo.run(ctx, &params, s.clone(), s.clone(), s);
        });
        self.record_comm(&world.stats());
        run.makespan() + self.pointwise_time(&shape, ls)
    }

    /// Fold one pricing run's comm counters into the service's
    /// accumulator (see the `comm` field).
    fn record_comm(&self, stats: &CommStats) {
        self.comm.lock().unwrap().absorb(stats);
    }

    /// Accumulated comm observability of every pricing run this service
    /// executed — `None` while the comm-optimization pass is fully off
    /// (all [`crate::config::NetSpec`] knobs at their defaults), so the
    /// serve report's `comm` section stays additive and knob-off runs
    /// keep rendering byte-identically to the pinned goldens.
    pub fn comm_stats_if_active(&self) -> Option<CommStats> {
        let n = &self.cluster.net;
        if !n.nic_schedule && n.inter_compress >= 1.0 && !n.cfg_fuse {
            return None;
        }
        Some(*self.comm.lock().unwrap())
    }

    /// Pointwise (non-attention) stage cost on one rank's `ls`-token
    /// shard: qkv proj (2·3·hid²) + out proj (2·hid²) + MLP at 4x ratio
    /// (2·2·4·hid²) = 24·hid² MACs per token. Shared by the single-mesh
    /// and hybrid-plan models so their comparisons stay consistent.
    fn pointwise_time(&self, shape: &crate::config::AttnShape, ls: usize) -> f64 {
        let hidden = (shape.h * shape.d) as f64;
        self.cluster.gpu.tile_time(
            24.0 * shape.b as f64 * ls as f64 * hidden * hidden,
            10.0 * (shape.b * ls * shape.h * shape.d) as f64 * 4.0,
        )
    }

    /// One attention layer's makespan under a hybrid spec: the group-
    /// scoped schedule on the carved meshes, plus the pointwise stages on
    /// each group's shard (paid once per guidance eval the group runs).
    /// Alignment is to the plan's sharding granularity only — a request
    /// admitted by a fixed plan is modeled at its full length, never
    /// cropped.
    ///
    /// Pipelined specs (`pp_degree > 1`) are timed by the executable
    /// displaced-patch-pipeline schedule
    /// ([`pipefusion::pipefusion_layer_makespan`]): the makespan of one
    /// pp-layer block divided by `pp_degree` is the per-layer
    /// equivalent, since the pipeline keeps all stages busy across the
    /// layer partition.
    pub fn plan_layer_time(&self, spec: &ParallelSpec, workload: &Workload, batch: usize) -> f64 {
        self.plan_layer_time_on(&self.cluster, spec, workload, batch)
    }

    /// [`Self::plan_layer_time`] on an explicit footprint — the whole
    /// pod normally, or the whole-machine *subset* a partial re-carve's
    /// side generation occupies ([`Self::pricing_cluster`]).
    fn plan_layer_time_on(
        &self,
        cluster: &ClusterSpec,
        spec: &ParallelSpec,
        workload: &Workload,
        batch: usize,
    ) -> f64 {
        if spec.pp_degree > 1 {
            let stage_ranks = spec.ranks_per_stage();
            let patches = self.patches_for(workload);
            // the pipeline shards by patches x stage ranks (pp partitions
            // layers, not the sequence) — the same granularity admit()
            // checks, so admitted requests are never cropped
            let w = workload.aligned_to(stage_ranks * patches);
            if w.shape.l == 0 {
                // the workload is too short to patch-pipeline at all
                return f64::INFINITY;
            }
            let mut shape = w.shape;
            shape.b = batch;
            let plan = ParallelPlan::build(cluster, *spec, self.algo)
                .expect("spec validated against its pricing footprint");
            let chunk = shape.l / patches / stage_ranks;
            let (block, stats) = pipefusion::pipefusion_layer_makespan_traced(
                &plan,
                shape,
                chunk,
                patches,
                workload.cfg_evals,
            );
            self.record_comm(&stats);
            let evals = workload.cfg_evals.div_ceil(spec.cfg_degree) as f64;
            // pointwise pipelines across stages exactly like attention
            // (each stage runs its own layers' pointwise concurrently),
            // so the per-layer equivalent divides by pp_degree too
            let ls = shape.l / stage_ranks;
            let pointwise = self.pointwise_time(&shape, ls) / spec.pp_degree as f64;
            return block / spec.pp_degree as f64 + evals * pointwise;
        }
        let sp_ranks = spec.ranks_per_group();
        let w = workload.aligned_to(sp_ranks);
        if w.shape.l == 0 {
            // the workload is too short for this carve's SP sharding
            // (mirrors the pipelined branch above): unserveable, not
            // free
            return f64::INFINITY;
        }
        let mut shape = w.shape;
        shape.b = batch;
        let plan = ParallelPlan::build(cluster, *spec, self.algo)
            .expect("spec validated against its pricing footprint");
        let ls = shape.l / sp_ranks;
        let (attn, stats) =
            hybrid::hybrid_layer_makespan_traced(&plan, shape, ls, workload.cfg_evals);
        self.record_comm(&stats);
        let evals = workload.cfg_evals.div_ceil(spec.cfg_degree) as f64;
        attn + evals * self.pointwise_time(&shape, ls)
    }

    /// The footprint a carve is priced on: this service's whole cluster
    /// when the spec tiles it exactly, or the whole-machine subset the
    /// spec tiles (a group-granular re-carve's side generation — its
    /// carve spans fewer machines than the pod, and its service time is
    /// what those machines deliver). `None` when the spec fits neither:
    /// modeled as unserveable (infinite time), never a panic.
    fn pricing_cluster(&self, spec: &ParallelSpec) -> Option<ClusterSpec> {
        if spec.validate(&self.cluster).is_ok() {
            return Some(self.cluster.clone());
        }
        let m = self.cluster.gpus_per_machine;
        let ranks = spec.total_ranks();
        if ranks < self.cluster.total_gpus() && ranks % m == 0 {
            let sub = self.cluster.resized(ranks / m);
            if spec.validate(&sub).is_ok() {
                return Some(sub);
            }
        }
        None
    }

    /// The spec the policy resolves to for one workload (None for the
    /// legacy single-mesh path).
    pub fn resolve_spec(&self, workload: &Workload) -> Option<ParallelSpec> {
        match &self.plan {
            PlanPolicy::SingleMesh => None,
            PlanPolicy::Fixed(spec) => Some(*spec),
            PlanPolicy::Auto => {
                if let Some(&s) = self.spec_cache.lock().unwrap().get(workload.name) {
                    return Some(s);
                }
                let s = crate::analysis::choose_spec_with_patches(
                    &self.cluster,
                    self.algo,
                    &workload.shape,
                    workload.cfg_evals,
                    1,
                    self.patches_for(workload),
                );
                self.spec_cache
                    .lock()
                    .unwrap()
                    .insert(workload.name.to_string(), s);
                Some(s)
            }
        }
    }

    /// Full-generation service time under an explicit carve (`None` =
    /// the legacy single-mesh path): the shared implementation behind
    /// both [`ServiceModel::service_time`] (preferred plan) and
    /// [`ServiceModel::service_time_under`] (possibly stale epoch
    /// carve). A carve that is structurally invalid for this service's
    /// cluster models as unserveable (infinite time) rather than
    /// panicking.
    fn timed(&self, workload: &Workload, batch: usize, spec: Option<ParallelSpec>) -> f64 {
        let plan_key = spec.map_or_else(|| "single-mesh".to_string(), |s| s.label());
        let key = (workload.name.to_string(), batch, plan_key);
        if let Some(&t) = self.cache.lock().unwrap().get(&key) {
            return t;
        }
        let layer = match spec {
            None => self.layer_time(workload, batch),
            Some(spec) => match self.pricing_cluster(&spec) {
                Some(cluster) => self.plan_layer_time_on(&cluster, &spec, workload, batch),
                None => f64::INFINITY,
            },
        };
        let total = layer * workload.layers as f64 * workload.steps as f64 + self.fixed_overhead;
        self.cache.lock().unwrap().insert(key, total);
        total
    }
}

impl CostModel for SimService {
    fn service_time(&self, workload: &Workload, batch: usize) -> f64 {
        self.timed(workload, batch, self.resolve_spec(workload))
    }

    fn service_time_under(
        &self,
        workload: &Workload,
        batch: usize,
        carve: Option<&ParallelSpec>,
    ) -> f64 {
        self.timed(workload, batch, carve.copied())
    }

    fn comm_stats(&self) -> Option<CommStats> {
        self.comm_stats_if_active()
    }
}

impl Planner for SimService {
    fn plan_spec(&self, workload: &Workload) -> Option<ParallelSpec> {
        self.resolve_spec(workload)
    }

    fn recarve_gain(&self, workload: &Workload, from: &ParallelSpec) -> Option<f64> {
        let to = self.resolve_spec(workload)?;
        if to == *from {
            return None;
        }
        Some(crate::analysis::recarve_gain(
            &self.cluster,
            self.algo,
            &workload.shape,
            workload.cfg_evals,
            self.patches_for(workload),
            from,
            &to,
        ))
    }

    fn plan_spec_on(&self, workload: &Workload, machines: usize) -> Option<ParallelSpec> {
        // only the auto planner can size a carve to an arbitrary subset;
        // fixed plans are pod-sized and single-mesh does not plan
        if !matches!(self.plan, PlanPolicy::Auto)
            || machines == 0
            || machines > self.cluster.machines
        {
            return None;
        }
        let key = (workload.name.to_string(), machines);
        if let Some(&s) = self.sub_spec_cache.lock().unwrap().get(&key) {
            return Some(s);
        }
        let sub = self.cluster.resized(machines);
        let s = crate::analysis::choose_spec_with_patches(
            &sub,
            self.algo,
            &workload.shape,
            workload.cfg_evals,
            1,
            self.patches_for(workload),
        );
        self.sub_spec_cache.lock().unwrap().insert(key, s);
        Some(s)
    }

    fn partial_recarve_gain(
        &self,
        workload: &Workload,
        from: &ParallelSpec,
        idle_machines: usize,
    ) -> Option<f64> {
        if !matches!(self.plan, PlanPolicy::Auto)
            || idle_machines == 0
            || idle_machines >= self.cluster.machines
        {
            return None;
        }
        Some(crate::analysis::partial_recarve_gain(
            &self.cluster,
            self.algo,
            &workload.shape,
            workload.cfg_evals,
            self.patches_for(workload),
            idle_machines,
            from,
        ))
    }

    fn admit(&self, workload: &Workload) -> Result<(), String> {
        match &self.plan {
            // legacy + auto paths align the workload themselves
            PlanPolicy::SingleMesh | PlanPolicy::Auto => Ok(()),
            PlanPolicy::Fixed(spec) => {
                spec.validate_workload(&workload.shape).map_err(|e| e.to_string())?;
                if spec.pp_degree > 1 {
                    spec.validate_patches(&workload.shape, self.patches_for(workload))
                        .map_err(|e| e.to_string())?;
                }
                Ok(())
            }
        }
    }

    fn plan_label(&self, workload: &Workload) -> Option<String> {
        Some(match self.resolve_spec(workload) {
            None => "single-mesh".to_string(),
            Some(spec) => spec.label(),
        })
    }
}

/// Epoch/drain observability of one serving run, aggregated over the
/// router's pods — how often live pods were re-carved and what the
/// transitions cost ([`crate::cluster::recarve`]).
#[derive(Debug, Default)]
pub struct RecarveReport {
    /// Epoch transitions paid across all pods (admission-time carves are
    /// not transitions).
    pub recarve_count: usize,
    /// Total seconds epoch-opening batches waited on drain barriers.
    pub drain_time: f64,
    /// Total modeled re-setup seconds charged to pod timelines.
    pub setup_time: f64,
    /// Per-epoch plan histogram: plan label → number of epochs (across
    /// all pods) that ran it.
    pub epoch_histogram: BTreeMap<String, usize>,
    /// Every pod's epoch log, as (pod id, epoch) in pod order.
    pub epochs: Vec<(usize, PlanEpoch)>,
    /// Group-granular (partial) re-carves performed across all pods —
    /// splits that opened a side carve generation on a busy pod's idle
    /// machines ([`crate::cluster::recarve::RecarvePolicy::Partial`]).
    pub partial_splits: usize,
    /// Side generations merged back into their pod's full-footprint
    /// carve.
    pub merges: usize,
    /// Of `recarve_count`, transitions the forecast short-circuited
    /// ahead of the hysteresis window
    /// ([`crate::cluster::recarve::RecarvePolicy::Forecast`]).
    /// Deliberately kept out of [`ServeReport::to_json`] so knob-off
    /// reports stay byte-identical to the pinned goldens.
    pub proactive_recarves: usize,
    /// Every pod's side-generation log, as (pod id, group epoch) in pod
    /// order; empty unless partial re-carving fired.
    pub group_epochs: Vec<(usize, GroupEpoch)>,
}

/// Outcome of a serving run.
pub struct ServeReport {
    pub metrics: Metrics,
    /// (request id, arrival, completion) per request, in
    /// completion-time order (ties in dispatch order). The pre-redesign
    /// loop recorded these in dispatch order; on a single pod the two
    /// orders coincide (and the pinned goldens reproduce bit-for-bit),
    /// on multiple pods the completion-time order is the deliberate new
    /// contract.
    pub completions: Vec<(u64, f64, f64)>,
    /// Requests refused, as (request id, reason) — at admission when the
    /// service's plan cannot run the workload (e.g. sequence length not
    /// divisible by the plan's SP ranks), or at dispatch when *no*
    /// available carve (neither the pod's live one nor the preferred
    /// plan) models a finite service time. A request is rejected — never
    /// panicked on, and never dispatched with an infinite duration.
    pub rejected: Vec<(u64, String)>,
    /// Parallel plan *served under* → request count
    /// ([`crate::config::ParallelSpec::label`] keys, sorted), so
    /// auto-planning and stale-carve behaviour are observable from
    /// `serve()` output. Under
    /// [`RecarvePolicy::Never`](crate::cluster::recarve::RecarvePolicy::Never)
    /// this is the pod's frozen carve, not the plan the model would
    /// have preferred. Empty when the service model does not report
    /// plans.
    pub plan_histogram: BTreeMap<String, usize>,
    /// Quality mode *served under* → request count
    /// ([`crate::config::QualityMode::label`] keys, sorted). Populated
    /// only when a quality knob
    /// (`ServeConfig::quality_floor` / `ServeConfig::quality` in
    /// [`crate::coordinator::session`]) is set; empty — and absent from
    /// [`Self::to_json`] — otherwise, so knob-off runs render
    /// byte-identically to the pre-quality format.
    pub quality_histogram: BTreeMap<String, usize>,
    /// Epoch/drain observability (see [`RecarveReport`]).
    pub recarve: RecarveReport,
    /// Fleet-scope machine migrations
    /// ([`crate::coordinator::session::RebalancePolicy`]), in commit
    /// order; empty unless cross-pod re-balancing fired.
    pub rebalances: Vec<RebalanceEvent>,
    /// Dispatches whose batch was scattered across replica groups
    /// (`ServeConfig::co_batch` in [`crate::coordinator::session`]); zero
    /// unless co-batching was enabled and fired.
    pub co_batched: usize,
    /// Of `co_batched`, dispatches whose shards spanned **both carve
    /// generations** of a split pod (cross-epoch co-batching); zero
    /// unless partial re-carving and co-batching fired together.
    pub co_batched_cross: usize,
    /// Scheduler events processed over the run (arrivals, dispatches,
    /// completions, the flush) — the denominator of the fleet-scale
    /// bench's events/sec figure. Observability only: deliberately
    /// **not** serialized by [`Self::to_json`], so the pinned goldens
    /// are unaffected.
    pub events: u64,
    /// Per-link comm counters of the pricing runs behind the session's
    /// service model ([`CostModel::comm_stats`]): intra- vs
    /// inter-machine wire bytes, scheduled-NIC busy seconds, fused
    /// transfer count. `None` — and absent from [`Self::to_json`] —
    /// whenever the comm-optimization pass is off, so existing goldens
    /// render unchanged.
    pub comm: Option<CommStats>,
    /// Stage-pipeline observability
    /// ([`crate::coordinator::stages::StageReport`]): per-class
    /// queue-depth histogram, decode/diffusion overlap seconds, and
    /// per-class machine counts over time. `Some` only when the run was
    /// staged (`ServeConfig::stages` in
    /// [`crate::coordinator::session`]); `None` — and absent from
    /// [`Self::to_json`] — otherwise, so the monolithic goldens stay
    /// byte-identical.
    pub stages: Option<crate::coordinator::stages::StageReport>,
}

impl ServeReport {
    /// Stable JSON rendering of the report's observable fields (plan
    /// histogram, epoch log, drain/setup totals) — the serialization the
    /// golden regression test in `rust/tests/recarve_serving.rs` pins.
    ///
    /// The scheduler's new capabilities serialize *additively*: a
    /// `"rebalance"` array / `"co_batched"` count appear only when
    /// cross-pod re-balancing / replica co-batching actually fired, so
    /// runs that do not use them — including everything reachable
    /// through the legacy [`serve`] shim — render byte-identically to
    /// the pre-redesign format.
    pub fn to_json(&self) -> Json {
        let obj = |pairs: Vec<(&str, Json)>| {
            Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
        };
        let plan_histogram = Json::Obj(
            self.plan_histogram
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                .collect(),
        );
        let epoch_histogram = Json::Obj(
            self.recarve
                .epoch_histogram
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                .collect(),
        );
        let epochs = Json::Arr(
            self.recarve
                .epochs
                .iter()
                .map(|(pod, e)| {
                    obj(vec![
                        ("pod", Json::Num(*pod as f64)),
                        ("index", Json::Num(e.index as f64)),
                        ("plan", Json::Str(e.label())),
                        ("started_at", Json::Num(e.started_at)),
                        ("served", Json::Num(e.served as f64)),
                    ])
                })
                .collect(),
        );
        let rejected = Json::Arr(
            self.rejected
                .iter()
                .map(|(id, reason)| {
                    Json::Arr(vec![Json::Num(*id as f64), Json::Str(reason.clone())])
                })
                .collect(),
        );
        let mut fields = vec![
            ("completed", Json::Num(self.metrics.completed() as f64)),
            ("horizon", Json::Num(self.metrics.horizon)),
            ("rejected", rejected),
            ("plan_histogram", plan_histogram),
            (
                "recarve",
                obj(vec![
                    ("count", Json::Num(self.recarve.recarve_count as f64)),
                    ("drain_time", Json::Num(self.recarve.drain_time)),
                    ("setup_time", Json::Num(self.recarve.setup_time)),
                    ("epoch_histogram", epoch_histogram),
                    ("epochs", epochs),
                ]),
            ),
        ];
        if !self.quality_histogram.is_empty() {
            let quality_histogram = Json::Obj(
                self.quality_histogram
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                    .collect(),
            );
            fields.push(("quality_histogram", quality_histogram));
        }
        if self.co_batched > 0 {
            fields.push(("co_batched", Json::Num(self.co_batched as f64)));
        }
        if self.co_batched_cross > 0 {
            fields.push(("co_batched_cross", Json::Num(self.co_batched_cross as f64)));
        }
        if self.recarve.partial_splits > 0 {
            let group_epochs = Json::Arr(
                self.recarve
                    .group_epochs
                    .iter()
                    .map(|(pod, e)| {
                        let mut pairs = vec![
                            ("pod", Json::Num(*pod as f64)),
                            ("index", Json::Num(e.index as f64)),
                            ("base_machine", Json::Num(e.base_machine as f64)),
                            ("machines", Json::Num(e.machines as f64)),
                            ("plan", Json::Str(e.label())),
                            ("started_at", Json::Num(e.started_at)),
                            ("served", Json::Num(e.served as f64)),
                        ];
                        if let Some(m) = e.merged_at {
                            pairs.push(("merged_at", Json::Num(m)));
                        }
                        obj(pairs)
                    })
                    .collect(),
            );
            fields.push((
                "partial",
                obj(vec![
                    ("splits", Json::Num(self.recarve.partial_splits as f64)),
                    ("merges", Json::Num(self.recarve.merges as f64)),
                    ("group_epochs", group_epochs),
                ]),
            ));
        }
        if let Some(c) = &self.comm {
            fields.push((
                "comm",
                obj(vec![
                    ("intra_in", Json::Num(c.traffic.intra_in)),
                    ("intra_out", Json::Num(c.traffic.intra_out)),
                    ("inter_in", Json::Num(c.traffic.inter_in)),
                    ("inter_out", Json::Num(c.traffic.inter_out)),
                    ("nic_busy", Json::Num(c.nic_busy)),
                    ("fused_transfers", Json::Num(c.fused_transfers as f64)),
                ]),
            ));
        }
        if let Some(stages) = &self.stages {
            fields.push(("stages", stages.to_json()));
        }
        if !self.rebalances.is_empty() {
            fields.push((
                "rebalance",
                Json::Arr(
                    self.rebalances
                        .iter()
                        .map(|ev| {
                            obj(vec![
                                ("at", Json::Num(ev.at)),
                                ("from_pod", Json::Num(ev.from_pod as f64)),
                                ("to_pod", Json::Num(ev.to_pod as f64)),
                                ("from_machines", Json::Num(ev.from_machines as f64)),
                                ("to_machines", Json::Num(ev.to_machines as f64)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        obj(fields)
    }
}

/// Deterministic virtual-time serving loop: requests (time-ordered) flow
/// through the batcher; closed batches dispatch to the least-loaded pod.
/// Requests failing the service's admission check are recorded in
/// [`ServeReport::rejected`] and never reach a batch.
///
/// Dispatch is *epoch-aware*: the pod's
/// [`RecarvePolicy`](crate::cluster::recarve::RecarvePolicy) (installed
/// via [`Router::set_recarve`]; the default
/// [`RecarvePolicy::Free`](crate::cluster::recarve::RecarvePolicy::Free)
/// keeps the pre-epoch behaviour exactly) decides per batch whether the pod
/// keeps its live carve — serving the batch under a possibly stale plan
/// — or drains, pays the modeled re-setup, and re-carves to the plan the
/// service prefers for this workload. A batch never spans two carves:
/// transitions happen strictly between batches, behind the drain
/// barrier [`Router::commit_recarve`] enforces.
///
/// This is the **legacy entry point**, kept as a thin shim over the
/// event-driven [`ServeSession`]: a default [`ServeConfig`] with only
/// the batch policy set inherits the router's installed re-carving
/// policies, dispatches least-loaded, and leaves co-batching and
/// re-balancing off — reproducing the pre-redesign results bit-for-bit
/// on the pinned goldens (`rust/tests/recarve_serving.rs`,
/// `rust/tests/serve_session.rs`). One deliberate observable change:
/// [`ServeReport::completions`] is now in completion-time order, which
/// coincides with the old dispatch order on a single pod but can
/// reorder the (identical) entries of multi-pod runs.
pub fn serve(
    router: &mut Router,
    policy: BatchPolicy,
    requests: Vec<Request>,
    service: &dyn ServiceModel,
) -> ServeReport {
    ServeSession::new(ServeConfig::new().batch(policy), service).run(router, requests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::recarve::RecarvePolicy;
    use crate::workload::TraceGen;

    struct ConstService(f64);
    impl CostModel for ConstService {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            self.0 * batch as f64
        }
    }
    impl Planner for ConstService {}

    #[test]
    fn serves_all_requests_exactly_once() {
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(3, 1.0, Workload::paper_suite()).take(40);
        let report = serve(
            &mut router,
            BatchPolicy { max_batch: 4, window: 1.0 },
            reqs,
            &ConstService(0.5),
        );
        assert_eq!(report.metrics.completed(), 40);
        let mut ids: Vec<u64> = report.completions.iter().map(|c| c.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "no request lost or served twice");
    }

    #[test]
    fn completions_never_precede_arrivals() {
        let mut router = Router::new(1, 2, 1, SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(9, 2.0, vec![Workload::flux_3072()]).take(30);
        let report = serve(&mut router, BatchPolicy::default(), reqs, &ConstService(0.2));
        for (_, arrival, done) in &report.completions {
            assert!(done > arrival);
        }
    }

    #[test]
    fn more_pods_more_throughput() {
        let reqs = || TraceGen::new(4, 50.0, vec![Workload::flux_3072()]).take(64);
        let run = |pods: usize| {
            let mut router = Router::new(4, 2, pods, SpAlgo::SwiftFusion);
            let rep = serve(
                &mut router,
                BatchPolicy { max_batch: 1, window: 0.0 },
                reqs(),
                &ConstService(1.0),
            );
            rep.metrics.horizon
        };
        let t1 = run(1);
        let t4 = run(4);
        assert!(t4 < t1 / 2.0, "4 pods {t4} vs 1 pod {t1}");
    }

    #[test]
    fn batching_amortizes_under_load() {
        // With a sub-linear service model, batching must beat no-batching
        // on saturated arrivals.
        struct SubLinear;
        impl CostModel for SubLinear {
            fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
                1.0 + 0.1 * batch as f64
            }
        }
        impl Planner for SubLinear {}
        let reqs = || TraceGen::new(4, 100.0, vec![Workload::flux_3072()]).take(64);
        let run = |max_batch: usize| {
            let mut router = Router::new(1, 2, 1, SpAlgo::SwiftFusion);
            let rep = serve(
                &mut router,
                BatchPolicy { max_batch, window: 0.05 },
                reqs(),
                &SubLinear,
            );
            rep.metrics.horizon
        };
        assert!(run(8) < run(1) / 2.0);
    }

    #[test]
    fn sim_service_is_cached_and_scales_with_steps() {
        let svc = SimService::new(ClusterSpec::new(2, 2), SpAlgo::SwiftFusion);
        let w20 = Workload::cogvideo_20s();
        let t1 = svc.service_time(&w20, 1);
        let t1_again = svc.service_time(&w20, 1);
        assert_eq!(t1, t1_again, "cache hit must be identical");
        let w40 = Workload::cogvideo_40s();
        let t40 = svc.service_time(&w40, 1);
        assert!(t40 > t1, "40s video must cost more than 20s");
    }

    #[test]
    fn fixed_plan_rejects_indivisible_requests_cleanly() {
        use crate::config::{ParallelSpec, SpDegrees};
        // Plan with 8 SP ranks per group on 2x8; a workload whose L is
        // not divisible by 8 must be rejected, not panicked on.
        let cluster = ClusterSpec::new(2, 8);
        let spec = ParallelSpec::new(2, 1, SpDegrees::new(8, 1));
        let svc = SimService::with_plan(cluster, SpAlgo::SwiftFusion, spec).unwrap();
        let mut odd = Workload::flux_3072();
        odd.shape.l = 36_001; // not divisible by 8
        let ok = Workload::flux_3072();
        let reqs = vec![
            crate::workload::Request { id: 0, workload: odd, arrival: 0.0, seed: 0 },
            crate::workload::Request { id: 1, workload: ok, arrival: 0.1, seed: 1 },
        ];
        let mut router = Router::new(2, 8, 1, SpAlgo::SwiftFusion);
        let report = serve(
            &mut router,
            BatchPolicy { max_batch: 1, window: 0.0 },
            reqs,
            &svc,
        );
        assert_eq!(report.metrics.completed(), 1, "valid request served");
        assert_eq!(report.rejected.len(), 1, "invalid request rejected");
        assert_eq!(report.rejected[0].0, 0);
        assert!(
            report.rejected[0].1.contains("not divisible"),
            "actionable reason: {}",
            report.rejected[0].1
        );
    }

    #[test]
    fn cfg_parallel_plan_serves_guided_video_faster() {
        // The tentpole's serving-level claim: for CFG workloads the auto
        // hybrid plan (branches on disjoint groups) beats the fixed
        // single-mesh plan that pays both branches sequentially.
        let cluster = ClusterSpec::new(4, 8);
        let w = Workload::cogvideo_20s();
        let single = {
            let svc = SimService::with_plan(
                cluster.clone(),
                SpAlgo::SwiftFusion,
                crate::config::ParallelSpec::new(1, 1, SpDegrees::new(8, 4)),
            )
            .unwrap();
            svc.service_time(&w, 1)
        };
        let hybrid = {
            let svc = SimService::auto_plan(cluster, SpAlgo::SwiftFusion);
            svc.service_time(&w, 1)
        };
        assert!(
            hybrid < single,
            "auto hybrid plan {hybrid} must beat single mesh {single}"
        );
    }

    #[test]
    fn auto_plan_admits_and_serves_the_paper_suite() {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(17, 0.02, Workload::paper_suite()).take(12);
        let report = serve(&mut router, BatchPolicy::default(), reqs, &svc);
        assert_eq!(report.metrics.completed(), 12);
        assert!(report.rejected.is_empty());
    }

    #[test]
    fn serve_report_histograms_chosen_plans() {
        // Auto planning on a mixed trace: every served request lands in
        // the plan histogram under its spec's label.
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(23, 0.02, Workload::paper_suite()).take(10);
        let report = serve(&mut router, BatchPolicy::default(), reqs, &svc);
        let counted: usize = report.plan_histogram.values().sum();
        assert_eq!(counted, report.metrics.completed(), "every request counted once");
        assert!(
            report.plan_histogram.keys().all(|k| k.starts_with("cfg")),
            "spec labels: {:?}",
            report.plan_histogram
        );
        // the guided video workloads pipeline on the 4x8 testbed, so the
        // histogram is where that becomes observable
        assert!(
            report.plan_histogram.keys().any(|k| k.contains("pp2") || k.contains("pp4")),
            "expected a pipelined plan in {:?}",
            report.plan_histogram
        );
        // models that don't plan (ConstService) leave it empty
        let mut router2 = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let reqs2 = TraceGen::new(3, 1.0, Workload::paper_suite()).take(5);
        let rep2 = serve(&mut router2, BatchPolicy::default(), reqs2, &ConstService(0.1));
        assert!(rep2.plan_histogram.is_empty());
    }

    #[test]
    fn fixed_pipelined_plan_serves_and_rejects_cleanly() {
        use crate::config::ParallelSpec;
        // cfg2 x pp2 x sp8 on 4x8: stage-aligned paper workloads serve;
        // a sequence that cannot split into patches is rejected with an
        // actionable reason, never panicked on.
        let cluster = ClusterSpec::new(4, 8);
        let spec = ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1));
        let svc = SimService::with_plan(cluster, SpAlgo::SwiftFusion, spec).unwrap();
        let ok = Workload::cogvideo_20s(); // L = 163200 = 2550 * 64
        let mut odd = Workload::cogvideo_20s();
        odd.shape.l = 163_208; // divisible by sp=8 but not by patches*sp
        let reqs = vec![
            crate::workload::Request { id: 0, workload: ok, arrival: 0.0, seed: 0 },
            crate::workload::Request { id: 1, workload: odd, arrival: 0.1, seed: 1 },
        ];
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let report = serve(
            &mut router,
            BatchPolicy { max_batch: 1, window: 0.0 },
            reqs,
            &svc,
        );
        assert_eq!(report.metrics.completed(), 1);
        assert_eq!(report.rejected.len(), 1);
        assert_eq!(report.rejected[0].0, 1);
        assert!(
            report.rejected[0].1.contains("patches"),
            "actionable reason: {}",
            report.rejected[0].1
        );
        assert_eq!(report.plan_histogram.get("cfg2 x pp2 x rep1 x U8R1"), Some(&1));
    }

    // ---- dynamic re-carving ------------------------------------------------

    /// [`Workload::short_image_4k`] (chosen plan stays on one machine,
    /// proven by `analysis::tests::deep_queues_favor_batch_replicas`)
    /// shrunk to 2 layers × 2 steps so the test trace serves fast.
    fn short_workload() -> Workload {
        let mut w = Workload::short_image_4k();
        w.layers = 2;
        w.steps = 2;
        w
    }

    /// [`Workload::cfg_video_96k`] (chosen plan is CFG- and
    /// pipeline-parallel, proven by
    /// `analysis::tests::pipeline_chosen_for_long_sequence_multi_machine`),
    /// shrunk like [`short_workload`].
    fn long_workload() -> Workload {
        let mut w = Workload::cfg_video_96k();
        w.layers = 2;
        w.steps = 2;
        w
    }

    fn serve_bimodal(policy: RecarvePolicy) -> ServeReport {
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        router.set_recarve_with_setup(policy, 0.01);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let reqs = crate::workload::bimodal_trace(&short_workload(), &long_workload(), 3, 6);
        serve(&mut router, BatchPolicy { max_batch: 1, window: 0.0 }, reqs, &svc)
    }

    #[test]
    fn never_policy_freezes_the_admission_carve() {
        // The motivating failure: traffic shifts short → long, but the
        // pod keeps the short-optimal admission carve and serves the
        // videos stale. One epoch, zero transitions, and the histogram
        // shows every request under the frozen plan.
        let report = serve_bimodal(RecarvePolicy::Never);
        assert_eq!(report.metrics.completed(), 18);
        assert_eq!(report.recarve.recarve_count, 0);
        assert_eq!(report.recarve.epochs.len(), 1, "{:?}", report.recarve.epochs);
        assert_eq!(
            report.plan_histogram.len(),
            1,
            "stale serving keeps one label: {:?}",
            report.plan_histogram
        );
        let pinned = report.plan_histogram.keys().next().unwrap();
        assert!(pinned.starts_with("cfg1"), "admission carve is the short plan: {pinned}");
        assert_eq!(report.recarve.drain_time, 0.0);
        assert_eq!(report.recarve.setup_time, 0.0);
    }

    #[test]
    fn hysteresis_recarving_beats_the_frozen_carve_on_bimodal_traffic() {
        // The tentpole's serving-level claim: paying drain + re-setup to
        // follow a sustained traffic shift beats serving long videos
        // under a stale short-image carve.
        let frozen = serve_bimodal(RecarvePolicy::Never);
        let adaptive =
            serve_bimodal(RecarvePolicy::Hysteresis { threshold: 0.05, window: 2 });
        assert_eq!(adaptive.metrics.completed(), 18);
        assert!(adaptive.recarve.recarve_count >= 1, "the shift must fire the policy");
        assert!(
            adaptive.metrics.horizon < frozen.metrics.horizon,
            "adaptive {} must beat frozen {}",
            adaptive.metrics.horizon,
            frozen.metrics.horizon
        );
        // the epoch log shows the plan change; transitions were paid for
        assert!(adaptive.recarve.epochs.len() >= 2);
        assert!(adaptive.recarve.setup_time > 0.0);
        assert!(adaptive.recarve.epoch_histogram.len() >= 2);
        // hysteresis held the line for `window` dispatches: the first
        // stale epoch served at least 2 requests before the switch
        assert!(adaptive.recarve.epochs[0].1.served >= 2, "{:?}", adaptive.recarve.epochs);
    }

    #[test]
    fn free_policy_is_an_upper_bound_and_pays_nothing() {
        let free = serve_bimodal(RecarvePolicy::Free);
        let adaptive =
            serve_bimodal(RecarvePolicy::Hysteresis { threshold: 0.05, window: 2 });
        assert!(free.recarve.recarve_count >= 2, "free follows every shift");
        assert_eq!(free.recarve.setup_time, 0.0);
        assert_eq!(free.recarve.drain_time, 0.0);
        assert!(
            free.metrics.horizon <= adaptive.metrics.horizon,
            "free {} is the idealized lower bound vs {}",
            free.metrics.horizon,
            adaptive.metrics.horizon
        );
    }

    #[test]
    fn on_idle_recarves_between_lulls_only() {
        // Widely spaced arrivals: the pod is idle at each dispatch, so
        // on-idle adapts like free but pays the re-setup.
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        router.set_recarve_with_setup(RecarvePolicy::OnIdle, 0.01);
        let svc = SimService::auto_plan(router.pods[0].cluster.clone(), SpAlgo::SwiftFusion);
        let gap = 1e6; // far beyond any service time
        let reqs: Vec<Request> = [short_workload(), long_workload(), short_workload()]
            .into_iter()
            .enumerate()
            .map(|(i, w)| Request {
                id: i as u64,
                workload: w,
                arrival: i as f64 * gap,
                seed: i as u64,
            })
            .collect();
        let report = serve(&mut router, BatchPolicy { max_batch: 1, window: 0.0 }, reqs, &svc);
        assert_eq!(report.metrics.completed(), 3);
        assert_eq!(report.recarve.recarve_count, 2, "{:?}", report.recarve.epochs);
        assert_eq!(report.recarve.drain_time, 0.0, "idle pods drain for free");
        assert!((report.recarve.setup_time - 0.02).abs() < 1e-12);
    }

    #[test]
    fn unserveable_stale_carve_forces_a_recarve_instead_of_poisoning_the_pod() {
        // A carve that cannot serve a workload at all (infinite modeled
        // time) must never be dispatched — an infinite duration would
        // push the pod's free_at to infinity for the rest of the run.
        // The engine forces the transition even under Never.
        struct TwoPlan;
        impl TwoPlan {
            fn spec_for(w: &Workload) -> ParallelSpec {
                if w.name.starts_with("flux") {
                    ParallelSpec::new(1, 4, SpDegrees::new(8, 1))
                } else {
                    ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
                }
            }
        }
        impl CostModel for TwoPlan {
            fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
                batch as f64
            }
            fn service_time_under(
                &self,
                w: &Workload,
                batch: usize,
                carve: Option<&ParallelSpec>,
            ) -> f64 {
                if carve.copied() == Some(Self::spec_for(w)) {
                    batch as f64
                } else {
                    f64::INFINITY
                }
            }
        }
        impl Planner for TwoPlan {
            fn plan_spec(&self, w: &Workload) -> Option<ParallelSpec> {
                Some(Self::spec_for(w))
            }
        }
        let mut router = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        router.set_recarve_with_setup(RecarvePolicy::Never, 0.25);
        let reqs = vec![
            Request { id: 0, workload: Workload::flux_3072(), arrival: 0.0, seed: 0 },
            Request { id: 1, workload: Workload::cogvideo_20s(), arrival: 1.0, seed: 1 },
        ];
        let report = serve(
            &mut router,
            BatchPolicy { max_batch: 1, window: 0.0 },
            reqs,
            &TwoPlan,
        );
        assert_eq!(report.metrics.completed(), 2);
        assert!(report.metrics.horizon.is_finite(), "{}", report.metrics.horizon);
        assert_eq!(report.metrics.horizon, 2.25, "drain 0 + setup 0.25 + service 1");
        assert_eq!(report.recarve.recarve_count, 1, "forced despite Never");
        assert_eq!(report.recarve.setup_time, 0.25);
    }

    #[test]
    fn totally_unserveable_batches_are_rejected_not_dispatched() {
        // When neither the live carve nor the preferred plan can serve
        // a batch, it must land in `rejected` — the pod timeline stays
        // finite and later requests are unaffected.
        struct Unserveable;
        impl CostModel for Unserveable {
            fn service_time(&self, _w: &Workload, _b: usize) -> f64 {
                f64::INFINITY
            }
        }
        impl Planner for Unserveable {}
        let mut router = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        let reqs = TraceGen::new(5, 1.0, vec![Workload::flux_3072()]).take(3);
        let report = serve(
            &mut router,
            BatchPolicy { max_batch: 1, window: 0.0 },
            reqs,
            &Unserveable,
        );
        assert_eq!(report.metrics.completed(), 0);
        assert_eq!(report.rejected.len(), 3);
        assert!(report.rejected[0].1.contains("no plan can serve"));
        assert!(report.metrics.horizon.is_finite());
        assert_eq!(report.recarve.recarve_count, 0);
    }

    #[test]
    fn epoch_log_attributes_every_request_to_one_epoch() {
        for policy in [
            RecarvePolicy::Free,
            RecarvePolicy::Never,
            RecarvePolicy::Hysteresis { threshold: 0.05, window: 2 },
        ] {
            let report = serve_bimodal(policy);
            let served: usize = report.recarve.epochs.iter().map(|(_, e)| e.served).sum();
            assert_eq!(served, report.metrics.completed(), "{policy:?}");
            let histo: usize = report.recarve.epoch_histogram.values().sum();
            assert_eq!(histo, report.recarve.epochs.len(), "{policy:?}");
            // epochs open in order on the single pod; no batch can start
            // before its epoch does
            for w in report.recarve.epochs.windows(2) {
                assert!(w[0].1.started_at <= w[1].1.started_at, "{policy:?}");
            }
        }
    }

    #[test]
    fn stale_carve_for_the_wrong_cluster_models_as_unserveable() {
        let svc = SimService::new(ClusterSpec::new(2, 2), SpAlgo::SwiftFusion);
        // a 32-rank spec cannot carve a 4-GPU pod: infinite, not a panic
        let spec = ParallelSpec::new(2, 1, SpDegrees::new(8, 2));
        let t = svc.service_time_under(&Workload::flux_3072(), 1, Some(&spec));
        assert!(t.is_infinite());
    }

    #[test]
    fn subset_carves_price_on_their_own_footprint() {
        // A carve tiling a whole-machine *subset* of the pod (a partial
        // re-carve's side generation) is priced on that footprint: the
        // same number a service bound to the subset cluster computes.
        let pod = SimService::new(ClusterSpec::new(4, 8), SpAlgo::SwiftFusion);
        let sub = SimService::new(ClusterSpec::new(3, 8), SpAlgo::SwiftFusion);
        let spec = ParallelSpec::with_pp(1, 3, 1, SpDegrees::new(8, 1)); // 24 ranks
        let w = Workload::cfg_video_96k();
        let on_pod = pod.service_time_under(&w, 1, Some(&spec));
        let on_sub = sub.service_time_under(&w, 1, Some(&spec));
        assert!(on_pod.is_finite(), "subset carve must be serveable");
        assert_eq!(on_pod, on_sub, "priced exactly as its own footprint");
        // misaligned partial footprints stay unserveable: 12 ranks is
        // not a whole number of 8-GPU machines
        let ragged = ParallelSpec::new(2, 1, SpDegrees::new(6, 1));
        assert!(pod.service_time_under(&w, 1, Some(&ragged)).is_infinite());
    }

    #[test]
    fn auto_service_plans_machine_subsets() {
        let svc = SimService::auto_plan(ClusterSpec::new(4, 8), SpAlgo::SwiftFusion);
        let video = Workload::cfg_video_96k();
        let sub = svc.plan_spec_on(&video, 3).expect("auto planner sizes subsets");
        assert_eq!(sub.total_ranks(), 24, "spec tiles the 3-machine subset: {sub:?}");
        assert!(sub.validate(&ClusterSpec::new(3, 8)).is_ok());
        // and the chosen subset plan is serveable at its own footprint
        assert!(svc.service_time_under(&video, 1, Some(&sub)).is_finite());
        // the split-gain prediction exists and favours leaving a stale
        // short carve for the 3-machine video plan
        let short_carve = svc.resolve_spec(&Workload::short_image_4k()).unwrap();
        let gain = svc
            .partial_recarve_gain(&video, &short_carve, 3)
            .expect("auto planner predicts split gains");
        assert!(gain > 0.2, "{gain}");
        // degenerate subsets refuse to plan
        assert!(svc.plan_spec_on(&video, 0).is_none());
        assert!(svc.plan_spec_on(&video, 9).is_none());
        assert!(svc.partial_recarve_gain(&video, &short_carve, 4).is_none());
        // non-auto services do not plan subsets
        let single = SimService::new(ClusterSpec::new(4, 8), SpAlgo::SwiftFusion);
        assert!(single.plan_spec_on(&video, 3).is_none());
    }

    #[test]
    fn recarve_gain_prefers_the_chosen_plan() {
        let svc = SimService::auto_plan(ClusterSpec::new(4, 8), SpAlgo::SwiftFusion);
        let long = long_workload();
        // moving off a short-optimal carve onto the video plan is a big
        // predicted win; the reverse move is a loss
        let short_spec = svc.resolve_spec(&short_workload()).unwrap();
        let long_spec = svc.resolve_spec(&long).unwrap();
        assert_ne!(short_spec, long_spec);
        let gain = svc.recarve_gain(&long, &short_spec).unwrap();
        assert!(gain > 0.2, "stale video carve must predict a large gain: {gain}");
        let reverse = svc.recarve_gain(&short_workload(), &long_spec).unwrap();
        assert!(reverse < gain, "reverse move cannot look better: {reverse} vs {gain}");
        // already on the preferred plan: no prediction
        assert!(svc.recarve_gain(&long, &long_spec).is_none());
    }

    #[test]
    fn pipelined_plan_beats_single_mesh_for_guided_video() {
        // The tentpole's serving-level claim, now with the third plan
        // dimension: a fixed cfg2 x pp2 x sp8 plan (stages never touch
        // the inter-machine fabric for SP) must beat the full-mesh
        // single plan that pays the cross-machine all-to-all, and the
        // auto planner must do at least as well as CFG x SP alone.
        let cluster = ClusterSpec::new(4, 8);
        let w = Workload::cogvideo_20s();
        let single = {
            let svc = SimService::with_plan(
                cluster.clone(),
                SpAlgo::SwiftFusion,
                crate::config::ParallelSpec::new(1, 1, SpDegrees::new(8, 4)),
            )
            .unwrap();
            svc.service_time(&w, 1)
        };
        let piped = {
            let svc = SimService::with_plan(
                cluster.clone(),
                SpAlgo::SwiftFusion,
                crate::config::ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1)),
            )
            .unwrap();
            svc.service_time(&w, 1)
        };
        let cfg_sp = {
            let svc = SimService::with_plan(
                cluster,
                SpAlgo::SwiftFusion,
                crate::config::ParallelSpec::new(2, 1, SpDegrees::new(8, 2)),
            )
            .unwrap();
            svc.service_time(&w, 1)
        };
        assert!(
            piped < single,
            "cfg x pp x sp plan {piped} must beat single mesh {single}"
        );
        assert!(
            piped < cfg_sp,
            "adding the pp dimension ({piped}) must beat cfg x sp alone ({cfg_sp})"
        );
    }
}
