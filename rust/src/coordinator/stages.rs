//! Decoupled multi-stage request pipeline: every request walks the
//! linear stage DAG text-encode → DiT diffusion → VAE decode
//! ([`crate::workload::StageClass`]), each stage class owns its own
//! pods and carves (a [`StagePlacement`] partition of the fleet), and
//! requests flow between classes through bounded inter-stage queues —
//! so request *n*'s DiT steps overlap request *n−1*'s VAE decode
//! (PipeDiT's task pipelining, arxiv 2511.12056) and the decode pods
//! run xDiT-style sp-only patch-parallel carves (arxiv 2411.01738).
//!
//! The staged loop is a sibling of the monolithic
//! [`crate::coordinator::session::ServeSession`] loop, driven by the
//! same deterministic `(time, seq)` event order
//! ([`crate::coordinator::schedule::EventHeap`]) and the same
//! [`crate::coordinator::router::Router`] pods; the `stages` knob on
//! `ServeConfig` selects it. With the knob off nothing in this module
//! runs, so the monolithic goldens stay byte-identical.
//!
//! Machines move *between stage classes* under drifting load: when a
//! class's queue backs up and the closed-form
//! [`crate::analysis::rebalance_gain`] clears the configured threshold
//! for `window` consecutive backlogged enqueues, one machine migrates
//! from an idle pod of another class via
//! [`crate::coordinator::router::Router::rebalance_machine`] — the
//! same drain + `resize_reset` machinery the monolithic fleet uses.
//!
//! Stage boundaries balance *cost*, not layer count: the per-class
//! `time_share` split that prices every stage here
//! ([`crate::workload::Workload::stage_shapes`]) weights uneven
//! per-layer DiT block costs when the workload declares them
//! ([`crate::workload::Workload::layer_costs`]) — a heavy
//! joint-attention front block grows the diffusion stage's share, and
//! [`crate::analysis::choose_stage_placement`] sizes the stage-class
//! pods accordingly. Workloads without declared costs (every preset)
//! keep the uniform split bit-for-bit.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::config::ClusterSpec;
use crate::coordinator::metrics::{Completion, Metrics};
use crate::coordinator::router::{RebalanceEvent, Router};
use crate::coordinator::schedule::EventHeap;
use crate::coordinator::session::RebalancePolicy;
use crate::sp::SpAlgo;
use crate::util::json::Json;
use crate::workload::{Request, StageClass, Workload};

/// How a fleet's pods are partitioned among the three stage classes:
/// pod ids `[0, enc)` encode, `[enc, enc+diff)` run the diffusion
/// loop, and the rest decode. Contiguous ranges keep the partition a
/// pure function of pod id — no lookup tables to drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StagePlacement {
    /// Pods per class, in [`StageClass::ALL`] order.
    pub pods: [usize; 3],
}

impl StagePlacement {
    pub fn new(encode: usize, diffusion: usize, decode: usize) -> Self {
        assert!(
            encode >= 1 && diffusion >= 1 && decode >= 1,
            "every stage class needs at least one pod"
        );
        Self { pods: [encode, diffusion, decode] }
    }

    /// Minimal sensible default: one encode pod, one decode pod, the
    /// rest of the fleet on the diffusion loop. Requires >= 3 pods.
    pub fn balanced(num_pods: usize) -> Self {
        assert!(num_pods >= 3, "a staged fleet needs one pod per stage class");
        Self::new(1, num_pods - 2, 1)
    }

    pub fn total_pods(&self) -> usize {
        self.pods.iter().sum()
    }

    /// The class pod `id` serves.
    pub fn class_of(&self, pod: usize) -> StageClass {
        let [e, d, _] = self.pods;
        if pod < e {
            StageClass::TextEncode
        } else if pod < e + d {
            StageClass::Diffusion
        } else {
            StageClass::VaeDecode
        }
    }

    /// Pod-id range of one class.
    pub fn range(&self, class: StageClass) -> std::ops::Range<usize> {
        let [e, d, v] = self.pods;
        match class {
            StageClass::TextEncode => 0..e,
            StageClass::Diffusion => e..e + d,
            StageClass::VaeDecode => e + d..e + d + v,
        }
    }
}

impl std::fmt::Display for StagePlacement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "enc{}/dit{}/vae{}", self.pods[0], self.pods[1], self.pods[2])
    }
}

/// The `stages` knob: turn the fleet into a stage pipeline with this
/// pod partition and inter-stage queue bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StagePolicy {
    pub placement: StagePlacement,
    /// Max requests parked in each inter-stage queue; a completed
    /// upstream stage whose downstream queue is full holds its output
    /// (backpressure) until the downstream dispatches.
    pub queue_bound: usize,
}

impl StagePolicy {
    pub fn new(placement: StagePlacement) -> Self {
        Self { placement, queue_bound: 8 }
    }

    pub fn queue_bound(mut self, bound: usize) -> Self {
        assert!(bound >= 1, "a zero-length inter-stage queue deadlocks the DAG");
        self.queue_bound = bound;
        self
    }
}

impl std::fmt::Display for StagePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} q{}", self.placement, self.queue_bound)
    }
}

/// Observability of one staged run, rendered into the serve report's
/// additive `stages` JSON section (absent when the knob is off).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageReport {
    /// class name → queue depth (at enqueue) → occurrence count.
    pub queue_depth: BTreeMap<String, BTreeMap<usize, usize>>,
    /// Seconds of VAE decode execution that ran concurrently with DiT
    /// diffusion execution — the pipelining headline. Strictly positive
    /// whenever decode actually hid inside the diffusion loop.
    pub overlap_time: f64,
    /// Per-class machine counts over time: one entry at t = 0 and one
    /// after every cross-class migration.
    pub machines: Vec<(f64, [usize; 3])>,
    /// class name → stage dispatches served.
    pub dispatches: BTreeMap<String, usize>,
}

impl StageReport {
    pub fn to_json(&self) -> Json {
        let obj = |fields: Vec<(&str, Json)>| {
            Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
        };
        let queues = Json::Obj(
            self.queue_depth
                .iter()
                .map(|(class, hist)| {
                    (
                        class.clone(),
                        Json::Obj(
                            hist.iter()
                                .map(|(depth, n)| (depth.to_string(), Json::Num(*n as f64)))
                                .collect(),
                        ),
                    )
                })
                .collect(),
        );
        let machines = Json::Arr(
            self.machines
                .iter()
                .map(|(at, counts)| {
                    let mut fields = vec![("at", Json::Num(*at))];
                    for (class, n) in StageClass::ALL.iter().zip(counts) {
                        fields.push((class.name(), Json::Num(*n as f64)));
                    }
                    obj(fields)
                })
                .collect(),
        );
        let dispatches = Json::Obj(
            self.dispatches
                .iter()
                .map(|(class, n)| (class.clone(), Json::Num(*n as f64)))
                .collect(),
        );
        obj(vec![
            ("queue_depth", queues),
            ("overlap_time", Json::Num(self.overlap_time)),
            ("machines", machines),
            ("dispatches", dispatches),
        ])
    }
}

/// Everything a staged run produces; the session layer folds this into
/// the regular [`crate::coordinator::engine::ServeReport`].
#[derive(Debug, Default)]
pub struct StagedOutcome {
    pub metrics: Metrics,
    pub completions: Vec<(u64, f64, f64)>,
    pub rejected: Vec<(u64, String)>,
    /// `class:carve-label` → stage dispatches under that carve.
    pub plan_histogram: BTreeMap<String, usize>,
    pub rebalances: Vec<RebalanceEvent>,
    pub report: StageReport,
    pub events: u64,
}

/// One staged event: arrivals enter the DAG, stage completions advance
/// it, wakes re-poll after a migration's setup delay. Ordered by the
/// same `(time, seq)` key as the monolithic loop.
enum Ev {
    Arrival(Request),
    StageDone { id: u64, class: StageClass, pod: usize },
    Wake,
}

struct Job {
    req: Request,
}

/// Run the staged pipeline over the fleet. `stage_time` prices one
/// stage of one request on a pod footprint (the session layer plugs in
/// the configured [`crate::coordinator::CostModel`] share split);
/// `admit` is the usual admission check. Deterministic: events pop in
/// `(time, seq)` order, queues are FIFO, and every pod/donor choice is
/// totally ordered.
pub fn run_staged(
    router: &mut Router,
    requests: Vec<Request>,
    policy: &StagePolicy,
    rebalance: &RebalancePolicy,
    algo: SpAlgo,
    patches: usize,
    stage_time: &mut dyn FnMut(&ClusterSpec, &Workload, StageClass) -> f64,
    admit: &mut dyn FnMut(&Workload) -> Result<(), String>,
) -> StagedOutcome {
    assert_eq!(
        policy.placement.total_pods(),
        router.pods.len(),
        "stage placement must partition the fleet's pods exactly"
    );
    let mut out = StagedOutcome::default();
    let mut queue: EventHeap<Ev> = EventHeap::new();
    for r in requests {
        queue.push(r.arrival, Ev::Arrival(r));
    }

    let mut jobs: HashMap<u64, Job> = HashMap::new();
    // per-class FIFO of job ids waiting for a pod, plus the held-back
    // jobs whose target queue was at the bound when their upstream
    // stage finished
    let mut waiting: [VecDeque<u64>; 3] = Default::default();
    let mut blocked: [VecDeque<u64>; 3] = Default::default();
    // in-flight (start, done) execution intervals per overlap side
    let mut diff_busy: Vec<(f64, f64)> = Vec::new();
    let mut dec_busy: Vec<(f64, f64)> = Vec::new();
    // cross-class migration pressure: consecutive backlogged enqueues
    // whose predicted grow-gain clears the threshold
    let mut streaks: [usize; 3] = [0; 3];
    let mut gain_memo: HashMap<(usize, usize, String), f64> = HashMap::new();
    // stage carve labels are a pure function of (class, footprint,
    // workload) — memoized, the chooser enumerates the plan space
    let mut label_memo: HashMap<(usize, usize, String), String> = HashMap::new();

    let class_machines = |router: &Router| -> [usize; 3] {
        let mut counts = [0usize; 3];
        for (i, class) in StageClass::ALL.iter().enumerate() {
            counts[i] = policy
                .placement
                .range(*class)
                .map(|p| router.pods[p].cluster.machines)
                .sum();
        }
        counts
    };
    out.report.machines.push((0.0, class_machines(router)));
    for class in StageClass::ALL {
        out.report.queue_depth.insert(class.name().to_string(), BTreeMap::new());
        out.report.dispatches.insert(class.name().to_string(), 0);
    }

    while let Some((now, ev)) = queue.pop() {
        out.events += 1;
        let mut touched: Vec<StageClass> = Vec::new();
        match ev {
            Ev::Arrival(r) => {
                if let Err(e) = admit(&r.workload) {
                    out.rejected.push((r.id, e));
                    continue;
                }
                let id = r.id;
                jobs.insert(id, Job { req: r });
                enqueue(StageClass::TextEncode, id, policy, &mut waiting, &mut blocked, &mut out);
                touched.push(StageClass::TextEncode);
            }
            Ev::StageDone { id, class, pod } => {
                touched.push(class);
                if class == StageClass::VaeDecode {
                    let job = jobs.remove(&id).expect("completed job is tracked");
                    out.completions.push((id, job.req.arrival, now));
                    out.metrics.observe(&Completion {
                        id,
                        workload: job.req.workload.name,
                        arrival: job.req.arrival,
                        done: now,
                        pod,
                    });
                } else {
                    let next = StageClass::ALL[class.index() + 1];
                    enqueue(next, id, policy, &mut waiting, &mut blocked, &mut out);
                    touched.push(next);
                }
            }
            Ev::Wake => touched.extend(StageClass::ALL),
        }

        // drain every touched class: idle pods pick up FIFO work
        touched.sort_by_key(|c| c.index());
        touched.dedup();
        for class in touched {
            loop {
                if waiting[class.index()].is_empty() {
                    break;
                }
                let Some(pod) = pick_pod(router, policy, class, now) else {
                    // backlogged: build cross-class migration pressure
                    pressure(
                        router, policy, rebalance, class, algo, patches, now, &jobs,
                        &waiting, &mut streaks, &mut gain_memo, &mut out, &mut queue,
                        &class_machines,
                    );
                    break;
                };
                let id = waiting[class.index()].pop_front().expect("checked non-empty");
                // a held-back upstream output takes the freed slot
                if let Some(b) = blocked[class.index()].pop_front() {
                    waiting[class.index()].push_back(b);
                    depth_mark(class, waiting[class.index()].len(), &mut out);
                }
                let w = jobs[&id].req.workload.clone();
                let cluster = router.pods[pod].cluster.clone();
                let dur = stage_time(&cluster, &w, class);
                let done = router.dispatch(pod, now, dur).done;
                queue.push(done, Ev::StageDone { id, class, pod });
                *out.report.dispatches.get_mut(class.name()).expect("seeded") += 1;
                let label = stage_label(&cluster, algo, patches, &w, class, &mut label_memo);
                *out.plan_histogram.entry(label).or_insert(0) += 1;
                // decode hiding inside the diffusion loop: credit the
                // concurrency between the two classes' executions
                match class {
                    StageClass::Diffusion => {
                        out.report.overlap_time += overlap(now, done, &mut dec_busy);
                        diff_busy.push((now, done));
                    }
                    StageClass::VaeDecode => {
                        out.report.overlap_time += overlap(now, done, &mut diff_busy);
                        dec_busy.push((now, done));
                    }
                    StageClass::TextEncode => {}
                }
            }
        }
    }
    out
}

/// Park `id` on `class`'s queue, or hold it back when the inter-stage
/// bound is reached (arrivals are never held — admission already
/// gated them; the bound models inter-stage activation buffers).
fn enqueue(
    class: StageClass,
    id: u64,
    policy: &StagePolicy,
    waiting: &mut [VecDeque<u64>; 3],
    blocked: &mut [VecDeque<u64>; 3],
    out: &mut StagedOutcome,
) {
    let i = class.index();
    if class != StageClass::TextEncode && waiting[i].len() >= policy.queue_bound {
        blocked[i].push_back(id);
        depth_mark(class, policy.queue_bound + blocked[i].len(), out);
        return;
    }
    waiting[i].push_back(id);
    depth_mark(class, waiting[i].len(), out);
}

fn depth_mark(class: StageClass, depth: usize, out: &mut StagedOutcome) {
    *out.report
        .queue_depth
        .get_mut(class.name())
        .expect("seeded at start")
        .entry(depth)
        .or_insert(0) += 1;
}

/// The idle pod of `class` that has been free longest (total order:
/// free_at, then pod id), or `None` when every class pod is busy at
/// `now`.
fn pick_pod(router: &Router, policy: &StagePolicy, class: StageClass, now: f64) -> Option<usize> {
    policy
        .placement
        .range(class)
        .filter(|&p| router.pods[p].free_at <= now)
        .min_by(|&a, &b| {
            router.pods[a]
                .free_at
                .total_cmp(&router.pods[b].free_at)
                .then_with(|| a.cmp(&b))
        })
}

/// Total execution-time overlap of `[start, done)` against the
/// intervals in `other` (pruning ones that ended before `start` — they
/// can never overlap a later dispatch).
fn overlap(start: f64, done: f64, other: &mut Vec<(f64, f64)>) -> f64 {
    other.retain(|&(_, e)| e > start);
    other
        .iter()
        .map(|&(s, e)| (done.min(e) - start.max(s)).max(0.0))
        .sum()
}

/// Stable `class:carve` label for the plan histogram, memoized per
/// (class, footprint, workload).
fn stage_label(
    cluster: &ClusterSpec,
    algo: SpAlgo,
    patches: usize,
    w: &Workload,
    class: StageClass,
    memo: &mut HashMap<(usize, usize, String), String>,
) -> String {
    let key = (class.index(), cluster.machines, w.name.to_string());
    if let Some(l) = memo.get(&key) {
        return l.clone();
    }
    let stage = &w.stage_shapes()[class.index()];
    let spec = crate::analysis::stage_spec(cluster, algo, stage, patches);
    let label = format!("{}:{}", class.name(), spec.label());
    memo.insert(key, label.clone());
    label
}

/// Backlog pressure on `class`: when the closed-form gain of growing
/// the class's smallest pod by one machine clears the threshold for
/// `window` consecutive backlogged enqueues and another class has an
/// idle >= 2-machine pod to donate, migrate one machine (the same
/// drain + `resize_reset` path as monolithic fleet rebalancing) and
/// schedule wakes at both pods' post-setup free times.
#[allow(clippy::too_many_arguments)]
fn pressure(
    router: &mut Router,
    policy: &StagePolicy,
    rebalance: &RebalancePolicy,
    class: StageClass,
    algo: SpAlgo,
    patches: usize,
    now: f64,
    jobs: &HashMap<u64, Job>,
    waiting: &[VecDeque<u64>; 3],
    streaks: &mut [usize; 3],
    gain_memo: &mut HashMap<(usize, usize, String), f64>,
    out: &mut StagedOutcome,
    queue: &mut EventHeap<Ev>,
    class_machines: &dyn Fn(&Router) -> [usize; 3],
) {
    let RebalancePolicy::Gain { threshold, window } = rebalance else {
        return;
    };
    // the stage shape of the job at the head of the backlog prices the
    // grow decision
    let Some(&head) = waiting[class.index()].front() else { return };
    let w = &jobs[&head].req.workload;
    let stage = &w.stage_shapes()[class.index()];
    let receiver = policy
        .placement
        .range(class)
        .min_by_key(|&p| (router.pods[p].cluster.machines, p))
        .expect("every class has a pod");
    let machines = router.pods[receiver].cluster.machines;
    let key = (class.index(), machines, w.name.to_string());
    let gain = *gain_memo.entry(key).or_insert_with(|| {
        let cur = router.pods[receiver].cluster.clone();
        crate::analysis::rebalance_gain(
            &cur,
            &cur.resized(machines + 1),
            algo,
            &stage.shape,
            stage.cfg_evals,
            patches,
        )
    });
    if gain < *threshold {
        streaks[class.index()] = 0;
        return;
    }
    streaks[class.index()] += 1;
    if streaks[class.index()] < *window {
        return;
    }
    // donor: an idle pod of another class with a machine to spare —
    // biggest first, then lowest id (mirrors the monolithic donor rule)
    let donor = (0..router.pods.len())
        .filter(|&p| policy.placement.class_of(p) != class)
        .filter(|&p| router.pods[p].free_at <= now && router.pods[p].cluster.machines >= 2)
        .min_by_key(|&p| (std::cmp::Reverse(router.pods[p].cluster.machines), p));
    let Some(donor) = donor else { return };
    out.rebalances.push(router.rebalance_machine(donor, receiver, now));
    *streaks = [0; 3];
    gain_memo.clear();
    out.report.machines.push((now, class_machines(router)));
    queue.push(router.pods[donor].free_at, Ev::Wake);
    queue.push(router.pods[receiver].free_at, Ev::Wake);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shrunk_video() -> Workload {
        let mut w = Workload::cfg_video_96k();
        w.layers = 2;
        w.steps = 2;
        w
    }

    fn burst(n: usize, w: &Workload, spacing: f64) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                workload: w.clone(),
                arrival: i as f64 * spacing,
                seed: i as u64,
            })
            .collect()
    }

    /// Synthetic stage pricing: the request's share split over a 1.0 s
    /// monolithic cost — hermetic, no timing simulation.
    fn unit_stage_time(_c: &ClusterSpec, w: &Workload, class: StageClass) -> f64 {
        w.stage_shapes()[class.index()].time_share
    }

    fn run(n: usize, bound: usize, spacing: f64) -> StagedOutcome {
        let mut router = Router::new(3, 8, 3, SpAlgo::SwiftFusion);
        let policy = StagePolicy::new(StagePlacement::balanced(3)).queue_bound(bound);
        run_staged(
            &mut router,
            burst(n, &shrunk_video(), spacing),
            &policy,
            &RebalancePolicy::Never,
            SpAlgo::SwiftFusion,
            4,
            &mut unit_stage_time,
            &mut |_w| Ok(()),
        )
    }

    #[test]
    fn placement_partitions_pod_ids() {
        let p = StagePlacement::new(1, 2, 1);
        assert_eq!(p.total_pods(), 4);
        assert_eq!(p.class_of(0), StageClass::TextEncode);
        assert_eq!(p.class_of(1), StageClass::Diffusion);
        assert_eq!(p.class_of(2), StageClass::Diffusion);
        assert_eq!(p.class_of(3), StageClass::VaeDecode);
        assert_eq!(p.range(StageClass::Diffusion), 1..3);
        assert_eq!(StagePlacement::balanced(3).pods, [1, 1, 1]);
        assert_eq!(format!("{}", StagePolicy::new(p)), "enc1/dit2/vae1 q8");
    }

    #[test]
    fn staged_run_completes_the_dag_with_overlap() {
        let out = run(6, 8, 0.1);
        assert_eq!(out.metrics.completed(), 6);
        assert!(out.rejected.is_empty());
        // three stage dispatches per request
        let total: usize = out.report.dispatches.values().sum();
        assert_eq!(total, 18);
        // e2e latency can never be below the serial stage sum (1.0 s)
        for &(_, arrival, done) in &out.completions {
            assert!(done - arrival >= 1.0 - 1e-9, "{arrival} -> {done}");
        }
        // decode hid inside the diffusion loop on the closely-spaced burst
        assert!(out.report.overlap_time > 0.0);
        // carve labels are per class
        assert!(out.plan_histogram.keys().any(|k| k.starts_with("diffusion:")));
        assert!(out.plan_histogram.keys().any(|k| k.starts_with("vae-decode:")));
    }

    #[test]
    fn staged_run_is_deterministic() {
        let a = run(8, 2, 0.05);
        let b = run(8, 2, 0.05);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.report, b.report);
        assert_eq!(a.plan_histogram, b.plan_histogram);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn bounded_queue_backpressures_without_losing_work() {
        // diffusion is ~half the request on the shrunk video and owns
        // one pod, so a tight burst backs its queue up past bound 1:
        // held-back encoder outputs land in the blocked lane and are
        // recorded at depths beyond the bound
        let out = run(8, 1, 0.01);
        assert_eq!(out.metrics.completed(), 8, "backpressure must not drop requests");
        let diff = &out.report.queue_depth["diffusion"];
        assert!(
            diff.keys().any(|&d| d > 1),
            "the diffusion queue never hit its bound: {diff:?}"
        );
    }

    #[test]
    fn layer_costs_reweight_the_staged_bottleneck() {
        // declared per-layer costs grow the diffusion stage's share of
        // the request (cost-weighted stage boundaries) ...
        let heavy = shrunk_video().with_layer_costs(vec![8.0, 8.0]);
        let share = |w: &Workload| w.stage_shapes()[StageClass::Diffusion.index()].time_share;
        assert!(share(&heavy) > share(&shrunk_video()));
        // ... and the staged pipeline's rate is set by its bottleneck
        // stage: each request still costs 1 s end to end under the
        // unit pricing (the shares sum to 1), but the heavier
        // diffusion stage serializes more of the burst behind its pod
        let run_w = |w: &Workload| {
            let mut router = Router::new(3, 8, 3, SpAlgo::SwiftFusion);
            let policy = StagePolicy::new(StagePlacement::balanced(3)).queue_bound(2);
            run_staged(
                &mut router,
                burst(4, w, 0.01),
                &policy,
                &RebalancePolicy::Never,
                SpAlgo::SwiftFusion,
                4,
                &mut unit_stage_time,
                &mut |_w| Ok(()),
            )
        };
        let uniform = run_w(&shrunk_video());
        let weighted = run_w(&heavy);
        assert_eq!(uniform.metrics.completed(), 4);
        assert_eq!(weighted.metrics.completed(), 4);
        assert!(
            weighted.metrics.horizon > uniform.metrics.horizon,
            "cost-weighted diffusion must dominate the pipeline rate: {} vs {}",
            weighted.metrics.horizon,
            uniform.metrics.horizon
        );
    }

    #[test]
    fn backlog_pressure_migrates_machines_between_classes() {
        // 4 pods x 2 machines, balanced-ish placement, diffusion slow:
        // the diffusion class backlog grows a pod with a machine from an
        // idle side class
        let mut router = Router::new(8, 8, 4, SpAlgo::SwiftFusion);
        let policy = StagePolicy::new(StagePlacement::new(1, 2, 1));
        let before: usize =
            policy.placement.range(StageClass::Diffusion).map(|p| router.pods[p].cluster.machines).sum();
        let out = run_staged(
            &mut router,
            burst(16, &shrunk_video(), 0.01),
            &policy,
            &RebalancePolicy::Gain { threshold: 0.01, window: 2 },
            SpAlgo::SwiftFusion,
            4,
            &mut unit_stage_time,
            &mut |_w| Ok(()),
        );
        assert_eq!(out.metrics.completed(), 16);
        assert!(!out.rebalances.is_empty(), "the backlogged class never grew");
        let after = out.report.machines.last().unwrap().1;
        let diff_after = after[StageClass::Diffusion.index()];
        assert!(diff_after > before, "{before} -> {diff_after}");
        assert_eq!(out.report.machines[0].1.iter().sum::<usize>(), 8);
        assert_eq!(after.iter().sum::<usize>(), 8, "machines are conserved");
    }
}
