//! Fleet-scale scheduler data structures: the indexed event heap and the
//! per-pod pricing cache behind
//! [`SchedulerMode::Indexed`](crate::coordinator::session::SchedulerMode).
//!
//! At the 4×8-testbed scale the scheduler's cost per event is invisible;
//! at tens of pods and 10⁵–10⁶ requests (`benches/fig_fleet_scale.rs`)
//! three linear costs dominate the wall clock:
//!
//! 1. the event queue — `BinaryHeap<Timed>` pays a `total_cmp` +
//!    `seq` compare through an `Ord` wrapper at every sift step;
//! 2. dispatch pricing — every `est(pod, batch)` call re-enters the
//!    service model (label `String` construction, a `Mutex`, and a
//!    `String`-keyed `HashMap` inside [`SimService`]);
//! 3. pod selection — `Router::pick` / `EarliestFinish` scan all `P`
//!    pods per dispatch, so dispatch cost is `O(P)` and the run is
//!    `O(N·P)`.
//!
//! This module fixes (1) and (2); the `free_at`-ordered pod index fixing
//! (3) lives on [`crate::coordinator::router::Router`] (it must stay in
//! sync with the pod timelines the router owns). Everything here is
//! *order-preserving*: [`EventHeap`] pops in exactly the `(time, seq)`
//! order of the naive binary heap (the `(time, seq)` pair is packed into
//! one `u128` via the monotone total-order bit mapping, so heap compares
//! are single integer compares), and [`PriceCache`] memoizes pure
//! service-model lookups keyed by (pod footprint, workload class, batch
//! size, carve) — the determinism-at-scale property test
//! (`tests/fleet_scale.rs`) pins bit-identical reports against the
//! naive path.
//!
//! [`SimService`]: crate::coordinator::engine::SimService

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use crate::config::{AttnShape, ParallelSpec};
use crate::workload::Workload;

// ---------------------------------------------------------------------------
// Monotone time key
// ---------------------------------------------------------------------------

/// Map an `f64` to a `u64` whose unsigned order equals
/// [`f64::total_cmp`] order (the standard IEEE-754 total-order
/// transform: flip all bits of negatives, flip the sign bit of
/// non-negatives). Virtual times are non-negative finite or `+inf`
/// (the flush sentinel), but the full transform costs nothing and keeps
/// the equivalence exact for every input.
#[inline]
pub fn time_key(at: f64) -> u64 {
    let b = at.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

// ---------------------------------------------------------------------------
// EventHeap — the indexed event queue
// ---------------------------------------------------------------------------

/// A 4-ary implicit min-heap over `(time, seq)` with the pair
/// pre-encoded into one `u128` index key (`time_key(at) << 64 | seq`):
/// one integer compare per sift step instead of a `total_cmp` +
/// tiebreak through an `Ord` wrapper, and a shallower tree (log₄ vs
/// log₂ levels) for the pop-heavy access pattern of an event loop.
/// `seq` is assigned in push order, so same-instant events pop FIFO —
/// exactly the ordering contract of the naive `BinaryHeap` path, which
/// `tests/fleet_scale.rs` pins bit-for-bit.
pub struct EventHeap<T> {
    /// `(packed key, original time, payload)` — the raw `f64` rides
    /// along so `pop` returns it without inverting the bit transform.
    items: Vec<(u128, f64, T)>,
    seq: u64,
}

impl<T> Default for EventHeap<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventHeap<T> {
    pub fn new() -> Self {
        Self { items: Vec::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Push an event at virtual time `at`; the creation sequence number
    /// (FIFO tiebreak) is assigned internally.
    pub fn push(&mut self, at: f64, item: T) {
        let key = (u128::from(time_key(at)) << 64) | u128::from(self.seq);
        self.seq += 1;
        self.items.push((key, at, item));
        self.sift_up(self.items.len() - 1);
    }

    /// Pop the earliest event (ties in push order).
    pub fn pop(&mut self) -> Option<(f64, T)> {
        if self.items.is_empty() {
            return None;
        }
        let last = self.items.len() - 1;
        self.items.swap(0, last);
        let (_, at, item) = self.items.pop().unwrap();
        if !self.items.is_empty() {
            self.sift_down(0);
        }
        Some((at, item))
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 4;
            if self.items[i].0 >= self.items[parent].0 {
                break;
            }
            self.items.swap(i, parent);
            i = parent;
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.items.len();
        loop {
            let first = 4 * i + 1;
            if first >= n {
                break;
            }
            let mut min = first;
            for c in (first + 1)..(first + 4).min(n) {
                if self.items[c].0 < self.items[min].0 {
                    min = c;
                }
            }
            if self.items[i].0 <= self.items[min].0 {
                break;
            }
            self.items.swap(i, min);
            i = min;
        }
    }
}

// ---------------------------------------------------------------------------
// FxHasher — a fast deterministic hasher for the pricing cache
// ---------------------------------------------------------------------------

/// Firefox's multiply-rotate hash. The pricing cache is on the per-event
/// hot path and its keys are small fixed-size structs; SipHash's
/// per-lookup setup cost is the dominant term there, and HashDoS
/// resistance buys nothing against a deterministic simulation's own
/// keys. Deterministic across runs (no random seed) by construction.
#[derive(Default)]
pub struct FxHasher(u64);

const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.0 = (self.0.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut word = [0u8; 8];
            word[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(word));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add(i as u64);
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add(u64::from(i));
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

type FxBuild = BuildHasherDefault<FxHasher>;

// ---------------------------------------------------------------------------
// PriceCache — memoized per-pod service pricing
// ---------------------------------------------------------------------------

/// Which costing entry point a cached price came from. `Preferred` is
/// [`crate::coordinator::CostModel::service_time`] (the model's own
/// plan); `Under` is
/// [`crate::coordinator::CostModel::service_time_under`] pinned to a
/// carve (`None` = the model's explicit no-carve path — kept distinct
/// from `Preferred` because a model may implement the two entry points
/// differently).
#[derive(PartialEq, Eq, Hash, Clone, Copy)]
enum CarveKey {
    Preferred,
    Under(Option<ParallelSpec>),
}

/// Full cache key: pod footprint + the complete workload class + batch
/// size + carve. The workload *value* (shape, layers, steps, cfg_evals,
/// name) is in the key — not just the name — so two same-named workloads
/// with different shapes can never alias an entry.
#[derive(PartialEq, Eq, Hash, Clone, Copy)]
struct PriceKey {
    machines: usize,
    gpus_per_machine: usize,
    name: &'static str,
    shape: AttnShape,
    layers: usize,
    steps: usize,
    cfg_evals: usize,
    batch: usize,
    carve: CarveKey,
}

impl PriceKey {
    fn new(fp: (usize, usize), w: &Workload, batch: usize, carve: CarveKey) -> Self {
        Self {
            machines: fp.0,
            gpus_per_machine: fp.1,
            name: w.name,
            shape: w.shape,
            layers: w.layers,
            steps: w.steps,
            cfg_evals: w.cfg_evals,
            batch,
            carve,
        }
    }
}

/// Memoized per-pod pricing: service times keyed by
/// `(pod footprint, workload class, batch size, carve)`, fronting the
/// service model so the dispatch path stops re-pricing every estimate
/// from scratch (label construction + `Mutex` + `String`-keyed map
/// inside [`crate::coordinator::engine::SimService`], model resolution
/// inside [`crate::coordinator::session::SimFleet`]).
///
/// Soundness: service times are pure functions of the key — the model a
/// [`crate::coordinator::session::FleetModel`] resolves per footprint
/// must itself be a pure function of that footprint (true for
/// `SimFleet`; a shared model trivially so). A disabled cache (the
/// [`SchedulerMode::Linear`](crate::coordinator::session::SchedulerMode)
/// reference path) passes every call straight through.
#[derive(Default)]
pub struct PriceCache {
    enabled: bool,
    prices: HashMap<PriceKey, f64, FxBuild>,
}

impl PriceCache {
    pub fn new(enabled: bool) -> Self {
        Self { enabled, prices: HashMap::default() }
    }

    /// Cached entries (observability / tests).
    pub fn len(&self) -> usize {
        self.prices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prices.is_empty()
    }

    fn get_or(
        &mut self,
        key: PriceKey,
        compute: impl FnOnce() -> f64,
    ) -> f64 {
        if !self.enabled {
            return compute();
        }
        *self.prices.entry(key).or_insert_with(compute)
    }

    /// Memoized [`crate::coordinator::CostModel::service_time`]. `fp` is
    /// the pod footprint `(machines, gpus_per_machine)`; `compute` —
    /// model resolution plus the actual pricing call — runs only on a
    /// miss, so a fleet-model `Mutex` resolution is skipped entirely on
    /// the hot (hit) path.
    pub fn service_time(
        &mut self,
        fp: (usize, usize),
        w: &Workload,
        batch: usize,
        compute: impl FnOnce() -> f64,
    ) -> f64 {
        self.get_or(PriceKey::new(fp, w, batch, CarveKey::Preferred), compute)
    }

    /// Memoized [`crate::coordinator::CostModel::service_time_under`];
    /// `compute` must price `w` at `batch` under exactly `carve`.
    pub fn service_time_under(
        &mut self,
        fp: (usize, usize),
        w: &Workload,
        batch: usize,
        carve: Option<&ParallelSpec>,
        compute: impl FnOnce() -> f64,
    ) -> f64 {
        self.get_or(PriceKey::new(fp, w, batch, CarveKey::Under(carve.copied())), compute)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[test]
    fn time_key_matches_total_cmp() {
        let vals = [
            0.0,
            -0.0,
            1.0,
            1.5,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            -3.25,
            1e300,
        ];
        for &a in &vals {
            for &b in &vals {
                assert_eq!(
                    time_key(a).cmp(&time_key(b)),
                    a.total_cmp(&b),
                    "{a} vs {b}"
                );
            }
        }
    }

    /// The naive reference ordering: min by `(total_cmp(at), seq)`, the
    /// exact `Timed` wrapper the session's naive path uses.
    struct Ref {
        at: f64,
        seq: u64,
        v: usize,
    }
    impl PartialEq for Ref {
        fn eq(&self, o: &Self) -> bool {
            self.at == o.at && self.seq == o.seq
        }
    }
    impl Eq for Ref {}
    impl PartialOrd for Ref {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Ref {
        fn cmp(&self, o: &Self) -> Ordering {
            o.at.total_cmp(&self.at).then_with(|| o.seq.cmp(&self.seq))
        }
    }

    #[test]
    fn pops_in_naive_binary_heap_order() {
        // Adversarial mix: heavy time duplication (quantized times) so
        // the FIFO seq tiebreak carries most of the ordering.
        let mut rng = SplitMix64::new(9);
        let mut heap = EventHeap::new();
        let mut naive = BinaryHeap::new();
        let mut pushed = Vec::new();
        for i in 0..5000usize {
            let at = (rng.below(64) as f64) * 0.25;
            heap.push(at, i);
            naive.push(Ref { at, seq: pushed.len() as u64, v: i });
            pushed.push(at);
        }
        // interleave pops and pushes to exercise sift_down mid-stream
        for i in 5000..6000usize {
            let (a, va) = heap.pop().unwrap();
            let r = naive.pop().unwrap();
            assert_eq!((a.to_bits(), va), (r.at.to_bits(), r.v));
            let at = (rng.below(64) as f64) * 0.25;
            heap.push(at, i);
            naive.push(Ref { at, seq: pushed.len() as u64, v: i });
            pushed.push(at);
        }
        while let Some((a, va)) = heap.pop() {
            let r = naive.pop().unwrap();
            assert_eq!((a.to_bits(), va), (r.at.to_bits(), r.v));
        }
        assert!(naive.pop().is_none());
    }

    #[test]
    fn flush_sentinel_pops_last() {
        let mut heap = EventHeap::new();
        heap.push(f64::INFINITY, "flush");
        heap.push(3.0, "a");
        heap.push(0.0, "b");
        assert_eq!(heap.pop().unwrap().1, "b");
        assert_eq!(heap.pop().unwrap().1, "a");
        assert_eq!(heap.pop().unwrap().1, "flush");
        assert!(heap.pop().is_none());
    }

    use crate::coordinator::{CostModel, Planner};
    use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};

    struct Counting(AtomicUsize);
    impl CostModel for Counting {
        fn service_time(&self, _w: &Workload, batch: usize) -> f64 {
            self.0.fetch_add(1, AtomicOrdering::SeqCst);
            batch as f64
        }
    }
    impl Planner for Counting {}

    #[test]
    fn price_cache_memoizes_by_full_workload_class() {
        let model = Counting(AtomicUsize::new(0));
        let mut cache = PriceCache::new(true);
        let w = Workload::short_image_4k();
        let fp = (2, 8);
        let t = cache.service_time(fp, &w, 4, || model.service_time(&w, 4));
        assert_eq!(t, 4.0);
        assert_eq!(cache.service_time(fp, &w, 4, || model.service_time(&w, 4)), 4.0);
        assert_eq!(model.0.load(AtomicOrdering::SeqCst), 1, "second call is a hit");
        // a different batch size, footprint, or *shape* is a different key
        cache.service_time(fp, &w, 8, || model.service_time(&w, 8));
        cache.service_time((4, 8), &w, 4, || model.service_time(&w, 4));
        let mut shrunk = w.clone();
        shrunk.layers = 2;
        cache.service_time(fp, &shrunk, 4, || model.service_time(&shrunk, 4));
        assert_eq!(model.0.load(AtomicOrdering::SeqCst), 4);
        assert_eq!(cache.len(), 4);
        // the carve dimension keys separately, None carve included
        let spec = ParallelSpec::new(1, 2, crate::config::SpDegrees::new(8, 1));
        cache.service_time_under(fp, &w, 4, Some(&spec), || {
            model.service_time_under(&w, 4, Some(&spec))
        });
        cache.service_time_under(fp, &w, 4, None, || model.service_time_under(&w, 4, None));
        cache.service_time_under(fp, &w, 4, Some(&spec), || unreachable!("cached"));
        assert_eq!(model.0.load(AtomicOrdering::SeqCst), 6);
        assert_eq!(cache.len(), 6);
        // disabled cache = passthrough
        let mut off = PriceCache::new(false);
        off.service_time(fp, &w, 4, || model.service_time(&w, 4));
        off.service_time(fp, &w, 4, || model.service_time(&w, 4));
        assert_eq!(model.0.load(AtomicOrdering::SeqCst), 8);
        assert!(off.is_empty());
    }
}
