//! Request batcher: groups compatible (same-workload) requests.
//!
//! Diffusion serving differs from LLM serving: every request of a given
//! workload runs the *same* number of uniform steps, so batching is a
//! pure B-dimension stack with no continuous batching / eviction. Policy:
//! FIFO per workload; a batch closes when it reaches `max_batch` or the
//! oldest member has waited `window` seconds.

use std::collections::VecDeque;

use crate::workload::Request;

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// Max time the head request may wait for co-batching (seconds).
    pub window: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 4, window: 2.0 }
    }
}

/// A closed batch ready for service.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn workload_name(&self) -> &str {
        self.requests[0].workload.name
    }

    pub fn size(&self) -> usize {
        self.requests.len()
    }

    /// A batch is ready at max(arrivals) (all members must have arrived).
    pub fn ready_at(&self) -> f64 {
        self.requests
            .iter()
            .map(|r| r.arrival)
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

/// FIFO batcher over a time-ordered request stream.
#[derive(Debug, Default)]
pub struct Batcher {
    queues: Vec<(String, VecDeque<Request>)>,
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(mut policy: BatchPolicy) -> Self {
        // max_batch == 0 would close empty batches forever; clamp to 1
        // (a zero-capacity batcher is a misconfiguration, not a request
        // error — serve every request individually instead of hanging).
        policy.max_batch = policy.max_batch.max(1);
        Self { queues: Vec::new(), policy }
    }

    pub fn push(&mut self, r: Request) {
        let name = r.workload.name.to_string();
        if let Some((_, q)) = self.queues.iter_mut().find(|(n, _)| *n == name) {
            q.push_back(r);
        } else {
            let mut q = VecDeque::new();
            q.push_back(r);
            self.queues.push((name, q));
        }
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Next batch that is closeable at virtual time `now`: either a full
    /// batch, or a queue whose head has waited past the window. Returns
    /// the earliest-deadline batch first (fairness across workloads).
    pub fn pop_ready(&mut self, now: f64) -> Option<Batch> {
        let policy = self.policy.clone();
        let mut best: Option<(f64, usize)> = None; // (head arrival, queue idx)
        for (i, (_, q)) in self.queues.iter().enumerate() {
            if q.is_empty() {
                continue;
            }
            let head = q.front().unwrap().arrival;
            let full = q.len() >= policy.max_batch;
            let expired = now - head >= policy.window;
            if full || expired {
                match best {
                    Some((h, _)) if h <= head => {}
                    _ => best = Some((head, i)),
                }
            }
        }
        let (_, idx) = best?;
        let q = &mut self.queues[idx].1;
        let n = q.len().min(policy.max_batch);
        let requests: Vec<Request> = q.drain(..n).collect();
        Some(Batch { requests })
    }

    /// Force-close the oldest non-empty queue (drain at end of trace).
    pub fn pop_any(&mut self) -> Option<Batch> {
        let policy = self.policy.clone();
        let mut best: Option<(f64, usize)> = None;
        for (i, (_, q)) in self.queues.iter().enumerate() {
            if let Some(head) = q.front() {
                match best {
                    Some((h, _)) if h <= head.arrival => {}
                    _ => best = Some((head.arrival, i)),
                }
            }
        }
        let (_, idx) = best?;
        let q = &mut self.queues[idx].1;
        let n = q.len().min(policy.max_batch);
        Some(Batch { requests: q.drain(..n).collect() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Workload;

    fn req(id: u64, w: Workload, arrival: f64) -> Request {
        Request { id, workload: w, arrival, seed: id }
    }

    #[test]
    fn full_batch_closes_immediately() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, window: 100.0 });
        b.push(req(0, Workload::flux_3072(), 0.0));
        assert!(b.pop_ready(0.0).is_none(), "not full, window open");
        b.push(req(1, Workload::flux_3072(), 0.1));
        let batch = b.pop_ready(0.1).expect("full batch");
        assert_eq!(batch.size(), 2);
        assert_eq!(batch.workload_name(), "flux-3072");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn window_expiry_closes_partial_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, window: 1.0 });
        b.push(req(0, Workload::flux_3072(), 0.0));
        assert!(b.pop_ready(0.5).is_none());
        let batch = b.pop_ready(1.5).expect("window expired");
        assert_eq!(batch.size(), 1);
    }

    #[test]
    fn deadline_arrival_joins_the_closing_batch() {
        // Flush-deadline edge: a request arriving *exactly* when the
        // head's window expires must ride in the closing batch — the
        // serving loop pushes the arrival before sweeping, and the
        // sweep's `now - head >= window` close takes the whole queue up
        // to max_batch, so nothing strands behind the deadline.
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, window: 1.0 });
        b.push(req(0, Workload::flux_3072(), 0.0));
        assert!(b.pop_ready(0.999).is_none(), "window still open");
        b.push(req(1, Workload::flux_3072(), 1.0)); // exactly the deadline
        let batch = b.pop_ready(1.0).expect("deadline closes the batch");
        assert_eq!(batch.size(), 2, "the deadline arrival joins, not strands");
        assert_eq!(batch.requests[1].id, 1);
        assert_eq!(b.pending(), 0);
        // beyond capacity the overflow stays queued (capacity, not a
        // stranding bug): the next sweep picks it up
        let mut b2 = Batcher::new(BatchPolicy { max_batch: 2, window: 1.0 });
        b2.push(req(0, Workload::flux_3072(), 0.0));
        b2.push(req(1, Workload::flux_3072(), 0.5));
        b2.push(req(2, Workload::flux_3072(), 1.0));
        assert_eq!(b2.pop_ready(1.0).unwrap().size(), 2);
        assert_eq!(b2.pending(), 1);
        assert_eq!(b2.pop_ready(2.0).unwrap().requests[0].id, 2);
    }

    #[test]
    fn workloads_never_mix() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, window: 0.0 });
        b.push(req(0, Workload::flux_3072(), 0.0));
        b.push(req(1, Workload::cogvideo_20s(), 0.0));
        let first = b.pop_ready(10.0).unwrap();
        let second = b.pop_ready(10.0).unwrap();
        assert_ne!(first.workload_name(), second.workload_name());
        assert_eq!(first.size() + second.size(), 2);
    }

    #[test]
    fn fifo_order_within_workload() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, window: 0.0 });
        for i in 0..4 {
            b.push(req(i, Workload::flux_3072(), i as f64));
        }
        let b1 = b.pop_ready(100.0).unwrap();
        let b2 = b.pop_ready(100.0).unwrap();
        assert_eq!(b1.requests[0].id, 0);
        assert_eq!(b1.requests[1].id, 1);
        assert_eq!(b2.requests[0].id, 2);
        assert_eq!(b2.requests[1].id, 3);
    }

    #[test]
    fn oldest_queue_wins() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, window: 0.0 });
        b.push(req(1, Workload::cogvideo_20s(), 5.0));
        b.push(req(0, Workload::flux_3072(), 1.0));
        let first = b.pop_ready(10.0).unwrap();
        assert_eq!(first.requests[0].id, 0, "older head goes first");
    }

    #[test]
    fn pop_any_drains_everything() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, window: 1e9 });
        for i in 0..5 {
            b.push(req(i, Workload::flux_3072(), 0.0));
        }
        let mut total = 0;
        while let Some(batch) = b.pop_any() {
            total += batch.size();
        }
        assert_eq!(total, 5);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn empty_batcher_flushes_cleanly() {
        // Popping an empty batcher — fresh, and again after a drain —
        // must return None, never an empty batch (which would make the
        // serving loop spin or panic on requests[0]).
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(b.pop_ready(0.0).is_none());
        assert!(b.pop_ready(f64::MAX).is_none());
        assert!(b.pop_any().is_none());
        b.push(req(0, Workload::flux_3072(), 0.0));
        assert_eq!(b.pop_any().unwrap().size(), 1);
        // drained: queues exist but are empty
        assert_eq!(b.pending(), 0);
        assert!(b.pop_ready(f64::MAX).is_none());
        assert!(b.pop_any().is_none());
    }

    #[test]
    fn capacity_overflow_splits_into_full_batches() {
        // 10 requests into max_batch=3: batches of 3/3/3/1, FIFO order
        // preserved, nothing lost or duplicated.
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, window: 1e9 });
        for i in 0..10 {
            b.push(req(i, Workload::flux_3072(), i as f64 * 0.01));
        }
        assert_eq!(b.pending(), 10);
        let mut sizes = Vec::new();
        let mut ids = Vec::new();
        while let Some(batch) = b.pop_ready(0.0).or_else(|| b.pop_any()) {
            sizes.push(batch.size());
            ids.extend(batch.requests.iter().map(|r| r.id));
        }
        assert_eq!(sizes, vec![3, 3, 3, 1]);
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn zero_max_batch_is_clamped_not_livelocked() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 0, window: 0.0 });
        b.push(req(0, Workload::flux_3072(), 0.0));
        let batch = b.pop_ready(1.0).expect("clamped to singleton batches");
        assert_eq!(batch.size(), 1);
        assert!(b.pop_ready(1.0).is_none());
    }

    #[test]
    fn batch_ready_at_is_max_arrival() {
        let batch = Batch {
            requests: vec![
                req(0, Workload::flux_3072(), 1.0),
                req(1, Workload::flux_3072(), 3.0),
            ],
        };
        assert_eq!(batch.ready_at(), 3.0);
    }
}
