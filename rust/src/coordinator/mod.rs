//! The serving engine: SwiftFusion as a *system*, not just an attention
//! algorithm. Mirrors the shape of production DiT serving stacks
//! (vLLM-style router → batcher → engine workers):
//!
//! * [`router`] — partitions the cluster into pods (one 2D mesh each) and
//!   routes requests to the pod a [`session::DispatchPolicy`] picks;
//! * [`batcher`] — groups same-workload requests within a batching
//!   window up to a max batch size (diffusion requests are uniform-length
//!   per workload, so batching is along B);
//! * [`session`] — the event-driven serving scheduler: a
//!   [`session::ServeSession`] built from a typed [`session::ServeConfig`]
//!   drives arrival → batch-close → dispatch → recarve-commit →
//!   completion events over the virtual clock;
//! * [`engine`] — the service models ([`engine::SimService`] times the
//!   *actual* SP schedules; `examples/serve_images.rs` plugs in measured
//!   numeric sampling) plus the legacy [`engine::serve`] shim;
//! * [`stages`] — the decoupled multi-stage request pipeline
//!   (text-encode → diffusion → VAE decode as a stage DAG over
//!   stage-class pods with bounded inter-stage queues), selected by the
//!   `stages` knob on [`session::ServeConfig`];
//! * [`metrics`] — per-workload latency/throughput summaries.
//!
//! Serving is *epoch-aware*: each pod carries an
//! [`crate::cluster::recarve::EpochTracker`], so the scheduler can drain a
//! pod and re-carve it into a different `cfg × pp × sp` plan between
//! requests when its [`crate::cluster::recarve::RecarvePolicy`] fires —
//! see [`crate::cluster::recarve`] for the epoch model. With a
//! [`session::FleetModel`] installed, epochs extend to *fleet* scope:
//! cross-pod re-balancing can migrate an idle machine between pods when
//! the workload mix shifts ([`session::RebalancePolicy`]).
//!
//! ## Migration note (old combined trait → new surface)
//!
//! The old six-method `ServiceModel` god-trait is now two focused traits
//! plus a blanket-implemented marker; old call sites map as follows:
//!
//! | old (`ServiceModel` method / API)      | new home                                      |
//! |----------------------------------------|-----------------------------------------------|
//! | `service_time`, `service_time_under`   | [`CostModel`]                                 |
//! | `plan_spec`, `plan_label`, `admit`, `recarve_gain` | [`Planner`]                       |
//! | `impl ServiceModel for T { … }`        | `impl CostModel for T { … }` + `impl Planner for T { … }` (empty for plan-agnostic models) |
//! | `&dyn ServiceModel` bounds             | unchanged — [`ServiceModel`] is blanket-implemented for every `CostModel + Planner` |
//! | `serve(router, policy, reqs, svc)`     | unchanged (thin shim over [`session::ServeSession`]) |
//! | `Router::set_recarve(_with_setup)`     | `ServeConfig::recarve` / `ServeConfig::recarve_setup` in [`session`] (the router setters remain for direct use) |
//! | `SimService` constructor scatter (`new`/`with_plan`/`auto_plan` + `patches` field pokes) | [`session::ServeConfig::sim_service`] builds the model from the config's plan policy + patches |
//! | `Router::pick` hard-wired in `serve()` | [`session::DispatchPolicy`] (least-loaded stays the default) |
//! | `Router::dispatch` `(f64, f64)` return | [`router::DispatchOutcome`]                   |
//! | `serve_batch`'s six-`&mut` closure     | [`session::ServeState`]                       |
//!
//! ## Migration note (ad-hoc policy arguments → [`crate::cluster::recarve::PolicyCtx`])
//!
//! Per-dispatch policy decisions used to receive whatever positional
//! arguments their call sites had grown; they now read one shared
//! context view, built with chainable setters (fields a caller does not
//! know stay `None`/`0`):
//!
//! | old call shape                                            | new call shape                                |
//! |-----------------------------------------------------------|-----------------------------------------------|
//! | `EpochTracker::on_dispatch(ready, free_at, preferred, gain)` | `on_dispatch(&PolicyCtx::at(ready, free_at).preferred(spec).gain(g))` |
//! | `DispatchPolicy::pick(router, batch, est)`                | `pick(router, batch, &ctx, est)` — `ctx.ready` replaces `batch.ready_at()` re-derivation |
//! | forecast inputs (new)                                     | `ctx.forecast_share` ([`session::ServeConfig::forecast_window`] knob), read by `RecarvePolicy::Forecast` and the cost-gated absorb |
//! | `EpochTracker::force(ready, free_at, preferred)`          | unchanged — the physics override is not a policy decision |
//!
//! ## Migration note (loose `ServeConfig` fields → typed sub-structs)
//!
//! The ~20 loose knobs accreted across PRs 3–9 are grouped into policy
//! sub-structs; every *builder method* keeps its old name and
//! signature, so code built through the builder compiles unchanged.
//! Direct field accesses map as follows:
//!
//! | old field                  | new path                              |
//! |----------------------------|---------------------------------------|
//! | `config.recarve`           | `config.recarve.policy` ([`session::RecarveCfg`]) |
//! | `config.recarve_setup`     | `config.recarve.setup`                |
//! | `config.rebalance`         | `config.rebalance.policy` ([`session::RebalanceCfg`]) |
//! | `config.quality`           | `config.quality.forced` ([`session::QualityCfg`]) |
//! | `config.quality_floor`     | `config.quality.floor`                |
//! | `config.stages`            | `config.stages.policy` ([`session::StageCfg`]) |
//! | *(new)*                    | `config.forecast` ([`session::ForecastCfg`], `None` = knob off) |

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod schedule;
pub mod session;
pub mod stages;

use crate::config::ParallelSpec;
use crate::workload::Workload;

/// Abstraction over "how long does one batched generation take": the
/// simulated engine plugs in the timing-mode cluster model; the numeric
/// engine plugs in real measured sampling. One half of the old combined
/// `ServiceModel` trait — the other half (planning/admission) is
/// [`Planner`].
pub trait CostModel: Sync {
    /// End-to-end service time (seconds) for a batch of `batch` requests
    /// of `workload` on one pod, under the plan this model prefers.
    fn service_time(&self, workload: &Workload, batch: usize) -> f64;

    /// Service time when the pod is pinned to `carve` — a possibly
    /// *stale* plan epoch — instead of the model's preferred plan for
    /// `workload`. Models that do not plan ignore the carve. The default
    /// delegates to [`Self::service_time`], so plan-agnostic models need
    /// not implement it.
    fn service_time_under(
        &self,
        workload: &Workload,
        batch: usize,
        _carve: Option<&ParallelSpec>,
    ) -> f64 {
        self.service_time(workload, batch)
    }

    /// Comm observability of the pricing runs behind this model's
    /// estimates, for the serve report's additive `comm` section.
    /// `None` (the default, and whenever the comm-optimization pass is
    /// off) keeps knob-off reports byte-identical to the pinned goldens;
    /// models that execute measured schedules with a comm-opt knob on
    /// override this ([`engine::SimService::comm_stats_if_active`]).
    fn comm_stats(&self) -> Option<crate::comm::CommStats> {
        None
    }
}

/// Plan resolution and admission: which hybrid carve a model would serve
/// a workload with, whether it can serve it at all, and what re-carving
/// toward the preferred plan is predicted to buy. All methods default to
/// "this model does not plan", so plan-agnostic cost models implement
/// this trait with an empty `impl Planner for T {}`.
pub trait Planner: Sync {
    /// Admission check: can this workload run under the model's plan at
    /// all? `Err` carries an actionable reason; the serving loop rejects
    /// such requests cleanly instead of batching them (see
    /// [`engine::ServeReport::rejected`]). Default: admit everything.
    fn admit(&self, _workload: &Workload) -> Result<(), String> {
        Ok(())
    }

    /// Stable label of the parallel plan this model would serve
    /// `workload` with (e.g. `cfg2 x pp2 x rep1 x U8R1`), if it plans at
    /// all — feeds [`engine::ServeReport::plan_histogram`] so
    /// auto-planning behaviour is observable from serving output.
    fn plan_label(&self, _workload: &Workload) -> Option<String> {
        None
    }

    /// The hybrid spec this model would carve a pod into for `workload`
    /// — the *preferred* plan the epoch-aware scheduler compares a pod's
    /// live carve against. `None` (the default) means the model does not
    /// plan; such pods stay in a single unplanned epoch.
    fn plan_spec(&self, _workload: &Workload) -> Option<ParallelSpec> {
        None
    }

    /// Predicted fractional per-step improvement of re-carving a pod
    /// from `from` to this model's preferred plan for `workload`
    /// (`0.1` = 10 % cheaper per step; negative when the move hurts).
    /// Feeds [`crate::cluster::recarve::RecarvePolicy::Hysteresis`];
    /// `None` (the default) means no prediction is available and the
    /// hysteresis streak resets.
    fn recarve_gain(&self, _workload: &Workload, _from: &ParallelSpec) -> Option<f64> {
        None
    }

    /// The hybrid spec this model would carve a `machines`-machine
    /// *subset* of its pod into for `workload` — how a group-granular
    /// (partial) re-carve plans the idle machines while the busy carve
    /// keeps serving ([`crate::cluster::recarve::RecarvePolicy::Partial`]).
    /// `None` (the default) means the model cannot plan subsets; the
    /// scheduler then falls back to a pod-wide transition.
    fn plan_spec_on(&self, _workload: &Workload, _machines: usize) -> Option<ParallelSpec> {
        None
    }

    /// Predicted fractional per-step improvement of serving `workload`
    /// on the best plan for `idle_machines` idle machines *now* instead
    /// of stale under the pod's live carve `from`
    /// ([`crate::analysis::partial_recarve_gain`]). Gates the split
    /// decision of
    /// [`crate::cluster::recarve::RecarvePolicy::Partial`]; `None` (the
    /// default) means no prediction, so no split is attempted.
    fn partial_recarve_gain(
        &self,
        _workload: &Workload,
        _from: &ParallelSpec,
        _idle_machines: usize,
    ) -> Option<f64> {
        None
    }
}

/// The full service-model surface the scheduler drives: costing
/// ([`CostModel`]) plus planning/admission ([`Planner`]). Blanket-
/// implemented for every type that implements both halves, so existing
/// `&dyn ServiceModel` call sites keep working and a plan-agnostic model
/// only needs `impl CostModel` + an empty `impl Planner`.
pub trait ServiceModel: CostModel + Planner {}

impl<T: CostModel + Planner> ServiceModel for T {}
