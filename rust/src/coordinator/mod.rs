//! The serving engine: SwiftFusion as a *system*, not just an attention
//! algorithm. Mirrors the shape of production DiT serving stacks
//! (vLLM-style router → batcher → engine workers):
//!
//! * [`router`] — partitions the cluster into pods (one 2D mesh each) and
//!   routes requests to the least-loaded compatible pod;
//! * [`batcher`] — groups same-workload requests within a batching
//!   window up to a max batch size (diffusion requests are uniform-length
//!   per workload, so batching is along B);
//! * [`engine`] — virtual-time serving loop over a [`ServiceModel`]
//!   (simulated paper-scale service times, or measured numeric sampling
//!   as in `examples/serve_images.rs`);
//! * [`metrics`] — per-workload latency/throughput summaries.
//!
//! Serving is *epoch-aware*: each pod carries an
//! [`crate::cluster::recarve::EpochTracker`], so the router can drain a
//! pod and re-carve it into a different `cfg × pp × sp` plan between
//! requests when its [`crate::cluster::recarve::RecarvePolicy`] fires —
//! see [`crate::cluster::recarve`] for the epoch model.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;

use crate::config::ParallelSpec;
use crate::workload::Workload;

/// Abstraction over "how long does one batched generation take": the
/// simulated engine plugs in the timing-mode cluster model; the numeric
/// engine plugs in real measured sampling.
pub trait ServiceModel: Sync {
    /// End-to-end service time (seconds) for a batch of `batch` requests
    /// of `workload` on one pod.
    fn service_time(&self, workload: &Workload, batch: usize) -> f64;

    /// Admission check: can this workload run under the engine's plan at
    /// all? `Err` carries an actionable reason; the serving loop rejects
    /// such requests cleanly instead of batching them (see
    /// [`engine::ServeReport::rejected`]). Default: admit everything.
    fn admit(&self, _workload: &Workload) -> Result<(), String> {
        Ok(())
    }

    /// Stable label of the parallel plan this model would serve
    /// `workload` with (e.g. `cfg2 x pp2 x rep1 x U8R1`), if it plans at
    /// all — feeds [`engine::ServeReport::plan_histogram`] so
    /// auto-planning behaviour is observable from `serve()` output.
    fn plan_label(&self, _workload: &Workload) -> Option<String> {
        None
    }

    /// The hybrid spec this model would carve a pod into for `workload`
    /// — the *preferred* plan the epoch-aware serving loop compares a
    /// pod's live carve against. `None` (the default) means the model
    /// does not plan; such pods stay in a single unplanned epoch.
    fn plan_spec(&self, _workload: &Workload) -> Option<ParallelSpec> {
        None
    }

    /// Service time when the pod is pinned to `carve` — a possibly
    /// *stale* plan epoch — instead of the model's preferred plan for
    /// `workload`. Models that do not plan ignore the carve. The default
    /// delegates to [`Self::service_time`], so plan-agnostic models need
    /// not implement it.
    fn service_time_under(
        &self,
        workload: &Workload,
        batch: usize,
        _carve: Option<&ParallelSpec>,
    ) -> f64 {
        self.service_time(workload, batch)
    }

    /// Predicted fractional per-step improvement of re-carving a pod
    /// from `from` to this model's preferred plan for `workload`
    /// (`0.1` = 10 % cheaper per step; negative when the move hurts).
    /// Feeds [`crate::cluster::recarve::RecarvePolicy::Hysteresis`];
    /// `None` (the default) means no prediction is available and the
    /// hysteresis streak resets.
    fn recarve_gain(&self, _workload: &Workload, _from: &ParallelSpec) -> Option<f64> {
        None
    }
}
