//! Pod router: carves the cluster into serving pods and picks where each
//! batch runs.
//!
//! A *pod* is a set of machines operated as one 2D SP mesh. The router
//! implements the paper's placement rule per workload (P_u = gcd(P, H),
//! §4.2) and least-loaded dispatch (earliest-free pod, ties by index —
//! deterministic).
//!
//! The router is also *epoch-aware*: each pod carries an
//! [`EpochTracker`] recording the plan epoch it is currently carved
//! into. The serving loop drives the tracker's policy decision per
//! dispatch, and [`Router::commit_recarve`] applies the resulting drain
//! barrier + re-setup cost to the pod's timeline, so no batch of a new
//! epoch can start before the old epoch's in-flight work has drained
//! and the sub-meshes have been rebuilt.
//!
//! Epochs extend to *fleet* scope with [`Router::rebalance_machine`]:
//! cross-pod re-balancing migrates one machine between pods (the
//! workload mix shifted and one pod's traffic wants a bigger carve while
//! another sits idle). Both pods drain, pay their re-setup, and re-admit
//! a fresh carve on their next dispatch — see
//! [`crate::coordinator::session::RebalancePolicy`] for the policy that
//! drives it.

use std::collections::BTreeSet;

use crate::analysis;
use crate::cluster::recarve::{resetup_cost, EpochTracker, RecarvePolicy};
use crate::config::{ClusterSpec, ParallelSpec, SpDegrees};
use crate::coordinator::schedule::time_key;
use crate::sp::SpAlgo;
use crate::workload::Workload;

/// One serving pod: a sub-cluster running a fixed algorithm.
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: usize,
    pub cluster: ClusterSpec,
    pub algo: SpAlgo,
    /// Virtual time at which the pod becomes free.
    pub free_at: f64,
    /// Plan-epoch state: the live carve, the re-carving policy, and the
    /// epoch/drain log the serving report aggregates.
    pub recarver: EpochTracker,
}

impl Pod {
    /// Degrees for a workload with `heads` heads on this pod (gcd rule
    /// for the SwiftFusion family; max-intra-Ulysses for USP).
    pub fn degrees_for(&self, heads: usize) -> SpDegrees {
        match self.algo {
            SpAlgo::Usp => {
                let m = self.cluster.gpus_per_machine;
                let pu = crate::config::gcd(m, heads);
                SpDegrees::new(pu, self.cluster.total_gpus() / pu)
            }
            _ => SpDegrees::swiftfusion_default(&self.cluster, heads),
        }
    }

    /// Hybrid CFG×PP×SP plan for one request of `workload` on this pod,
    /// given how many similar requests are queued behind it — the
    /// analysis cost model trades SP degree against CFG-branch groups,
    /// pipeline stages ([`analysis::DEFAULT_PATCHES`] patches), and
    /// batch replicas.
    pub fn plan_for(&self, workload: &Workload, queue_depth: usize) -> ParallelSpec {
        analysis::choose_spec(
            &self.cluster,
            self.algo,
            &workload.shape,
            workload.cfg_evals,
            queue_depth,
        )
    }
}

/// Outcome of committing one batch to a pod (what [`Router::dispatch`]
/// used to return as a bare `(f64, f64)` pair).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DispatchOutcome {
    /// Virtual time service started (max of pod-free and batch-ready).
    pub start: f64,
    /// Virtual time the batch completes.
    pub done: f64,
}

/// One fleet-scope machine migration, as recorded by
/// [`Router::rebalance_machine`] and reported in
/// `ServeReport::rebalances`.
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceEvent {
    /// Virtual time the migration was committed.
    pub at: f64,
    /// Donor pod (shrinks by one machine).
    pub from_pod: usize,
    /// Receiver pod (grows by one machine).
    pub to_pod: usize,
    /// Donor machine count *after* the migration.
    pub from_machines: usize,
    /// Receiver machine count *after* the migration.
    pub to_machines: usize,
}

/// The router: owns the pods, assigns batches.
#[derive(Debug)]
pub struct Router {
    pub pods: Vec<Pod>,
    /// `free_at`-ordered pod index: `(time_key(free_at), id)`, kept in
    /// sync by every timeline mutation the router itself performs
    /// ([`Self::dispatch`], [`Self::commit_recarve`],
    /// [`Self::rebalance_machine`]). Makes earliest-free selection
    /// `O(log P)` ([`Self::pick_indexed`]) and yields pods in ascending
    /// `free_at` order for pruned earliest-finish scans
    /// ([`Self::pods_by_free`]). Code that pokes `pods[i].free_at`
    /// directly must call [`Self::rebuild_free_index`] afterwards.
    free_index: BTreeSet<(u64, usize)>,
}

impl Router {
    /// Split `machines` total machines into `num_pods` equal pods of
    /// `gpus_per_machine`-GPU machines.
    pub fn new(machines: usize, gpus_per_machine: usize, num_pods: usize, algo: SpAlgo) -> Self {
        assert!(num_pods > 0 && machines % num_pods == 0);
        let per_pod = machines / num_pods;
        let pods = (0..num_pods)
            .map(|id| {
                let cluster = ClusterSpec::new(per_pod, gpus_per_machine);
                let setup = resetup_cost(&cluster);
                Pod {
                    id,
                    cluster,
                    algo,
                    free_at: 0.0,
                    // Free keeps the pre-epoch serving behaviour (adopt
                    // the preferred plan each dispatch, unpaid) unless a
                    // policy is installed via [`Self::set_recarve`].
                    recarver: EpochTracker::new(RecarvePolicy::Free, setup),
                }
            })
            .collect();
        let mut r = Self { pods, free_index: BTreeSet::new() };
        r.rebuild_free_index();
        r
    }

    /// Install a re-carving policy on every pod (the modeled re-setup
    /// cost stays at [`resetup_cost`] for each pod's cluster).
    pub fn set_recarve(&mut self, policy: RecarvePolicy) {
        for p in &mut self.pods {
            p.recarver.policy = policy;
        }
    }

    /// [`Self::set_recarve`] with an explicit per-transition re-setup
    /// cost (seconds) — tests and benches pin this for determinism.
    pub fn set_recarve_with_setup(&mut self, policy: RecarvePolicy, setup_cost: f64) {
        for p in &mut self.pods {
            p.recarver.policy = policy;
            p.recarver.setup_cost = setup_cost;
        }
    }

    /// Apply an epoch transition to `pod`'s timeline: the pod drains
    /// (in-flight work runs to `free_at`), then pays `setup` seconds
    /// rebuilding its carved sub-meshes; only then can the next batch
    /// start ([`Self::dispatch`] starts at the updated `free_at`).
    pub fn commit_recarve(&mut self, pod: usize, ready_at: f64, setup: f64) {
        let p = &mut self.pods[pod];
        let old = p.free_at;
        p.free_at = p.free_at.max(ready_at) + setup;
        let new = p.free_at;
        self.free_index.remove(&(time_key(old), pod));
        self.free_index.insert((time_key(new), pod));
    }

    /// Earliest-free pod (ties broken by lowest id — deterministic).
    pub fn pick(&self) -> usize {
        self.pods
            .iter()
            .enumerate()
            .min_by(|(ia, a), (ib, b)| {
                a.free_at
                    .partial_cmp(&b.free_at)
                    .unwrap()
                    .then(ia.cmp(ib))
            })
            .map(|(i, _)| i)
            .unwrap()
    }

    /// [`Self::pick`] in `O(log P)`: the first entry of the `free_at`
    /// index. Identical to the linear scan for every timeline the
    /// scheduler can produce — `time_key` order equals `partial_cmp`
    /// order for non-NaN times, and pod timelines are built purely from
    /// non-negative `max`/`+`, so the one divergence of the total order
    /// (`-0.0 < 0.0`) cannot arise.
    pub fn pick_indexed(&self) -> usize {
        self.free_index.iter().next().map(|&(_, id)| id).expect("router has no pods")
    }

    /// Pod ids in ascending `(free_at, id)` order — the scan order a
    /// pruned earliest-finish dispatch walks (it can stop as soon as a
    /// pod's `free_at` alone exceeds the best finish seen).
    pub fn pods_by_free(&self) -> impl Iterator<Item = usize> + '_ {
        self.free_index.iter().map(|&(_, id)| id)
    }

    /// Re-derive the `free_at` index from the pod timelines. Required
    /// after mutating `pods[i].free_at` without going through the
    /// router's own methods (tests script timelines this way; the
    /// serving loop calls it once before its event loop starts).
    pub fn rebuild_free_index(&mut self) {
        self.free_index.clear();
        for p in &self.pods {
            self.free_index.insert((time_key(p.free_at), p.id));
        }
    }

    /// Commit a batch to `pod`: service starts when both the pod is free
    /// and the batch is ready.
    pub fn dispatch(&mut self, pod: usize, ready_at: f64, service: f64) -> DispatchOutcome {
        let p = &mut self.pods[pod];
        let start = p.free_at.max(ready_at);
        let done = start + service;
        let old = p.free_at;
        p.free_at = done;
        self.free_index.remove(&(time_key(old), pod));
        self.free_index.insert((time_key(done), pod));
        DispatchOutcome { start, done }
    }

    /// Fleet-scope epoch boundary: migrate one machine from pod `from`
    /// to pod `to` at virtual time `at`. Both pods drain (their timeline
    /// already carries in-flight work), pay their installed re-setup
    /// cost, and have their epoch trackers reset so the next dispatch
    /// re-admits a carve sized for the new footprint
    /// ([`EpochTracker::resize_reset`] — the adoption itself is free,
    /// the migration barrier charged here is the paid part). The donor
    /// must keep at least one machine.
    pub fn rebalance_machine(&mut self, from: usize, to: usize, at: f64) -> RebalanceEvent {
        assert_ne!(from, to, "a pod cannot donate a machine to itself");
        assert!(
            self.pods[from].cluster.machines >= 2,
            "donor pod {from} has only {} machine(s); migrating it away would kill the pod",
            self.pods[from].cluster.machines
        );
        for (pod, delta) in [(from, -1isize), (to, 1)] {
            let p = &mut self.pods[pod];
            let machines = p.cluster.machines.checked_add_signed(delta).unwrap();
            p.cluster = p.cluster.resized(machines);
            let old = p.free_at;
            p.free_at = p.free_at.max(at) + p.recarver.setup_cost;
            let new = p.free_at;
            p.recarver.resize_reset();
            self.free_index.remove(&(time_key(old), pod));
            self.free_index.insert((time_key(new), pod));
        }
        RebalanceEvent {
            at,
            from_pod: from,
            to_pod: to,
            from_machines: self.pods[from].cluster.machines,
            to_machines: self.pods[to].cluster.machines,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::recarve::PolicyCtx;

    #[test]
    fn pods_partition_the_cluster() {
        let r = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        assert_eq!(r.pods.len(), 2);
        assert_eq!(r.pods[0].cluster.total_gpus(), 16);
    }

    #[test]
    fn gcd_rule_degrees() {
        let r = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        // P=32, H=24 -> Pu=8, Pr=4 (§4.2's example)
        assert_eq!(r.pods[0].degrees_for(24), SpDegrees::new(8, 4));
        // USP maxes intra-machine Ulysses: Pu = gcd(M=8, 24) = 8
        let r2 = Router::new(4, 8, 1, SpAlgo::Usp);
        assert_eq!(r2.pods[0].degrees_for(24), SpDegrees::new(8, 4));
    }

    #[test]
    fn least_loaded_dispatch() {
        let mut r = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        assert_eq!(r.pick(), 0);
        let out = r.dispatch(0, 0.0, 10.0);
        assert_eq!(out, DispatchOutcome { start: 0.0, done: 10.0 });
        assert_eq!(r.pick(), 1, "pod 0 busy until 10");
        r.dispatch(1, 0.0, 3.0);
        assert_eq!(r.pick(), 1, "pod 1 free sooner");
        // batch not ready until t=20: idles the pod
        let out = r.dispatch(1, 20.0, 1.0);
        assert_eq!((out.start, out.done), (20.0, 21.0));
    }

    #[test]
    fn deterministic_tiebreak() {
        let r = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        assert_eq!(r.pick(), 0, "equal free_at -> lowest id");
    }

    #[test]
    fn commit_recarve_delays_the_next_dispatch() {
        let mut r = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        r.set_recarve_with_setup(RecarvePolicy::Hysteresis { threshold: 0.1, window: 1 }, 0.5);
        assert_eq!(r.pods[0].recarver.setup_cost, 0.5);
        // pod busy until t=10; a re-carve committed for a batch ready at
        // t=4 drains to t=10, then pays 0.5s of re-setup
        r.dispatch(0, 0.0, 10.0);
        r.commit_recarve(0, 4.0, 0.5);
        let out = r.dispatch(0, 4.0, 1.0);
        assert_eq!((out.start, out.done), (10.5, 11.5));
        // an idle pod pays only the re-setup
        let mut r2 = Router::new(2, 2, 1, SpAlgo::SwiftFusion);
        r2.commit_recarve(0, 3.0, 0.25);
        let out = r2.dispatch(0, 3.0, 1.0);
        assert_eq!(out.start, 3.25);
    }

    #[test]
    fn rebalance_migrates_a_machine_and_resets_both_pods() {
        let mut r = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        r.set_recarve_with_setup(RecarvePolicy::Never, 0.25);
        // adopt admission carves so the reset is observable
        let spec = crate::config::ParallelSpec::new(2, 1, crate::config::SpDegrees::new(8, 2));
        for p in &mut r.pods {
            p.recarver.on_dispatch(&PolicyCtx::at(0.0, 0.0).preferred(spec));
        }
        // pod 0 busy until t=5, pod 1 idle; migrate 1 -> 0 at t=2
        r.dispatch(0, 0.0, 5.0);
        let ev = r.rebalance_machine(1, 0, 2.0);
        assert_eq!(
            ev,
            RebalanceEvent {
                at: 2.0,
                from_pod: 1,
                to_pod: 0,
                from_machines: 1,
                to_machines: 3
            }
        );
        assert_eq!(r.pods[0].cluster.machines, 3);
        assert_eq!(r.pods[1].cluster.machines, 1);
        // receiver drains (to 5.0) then pays setup; idle donor pays setup only
        assert_eq!(r.pods[0].free_at, 5.25);
        assert_eq!(r.pods[1].free_at, 2.25);
        // both trackers re-admit on the next dispatch (fresh epoch, free)
        for p in &mut r.pods {
            let tr = p.recarver.on_dispatch(&PolicyCtx::at(6.0, p.free_at).preferred(spec));
            assert!(!tr.recarved, "re-admission after a resize is unpaid");
            assert_eq!((tr.drain, tr.setup), (0.0, 0.0));
        }
        assert_eq!(r.pods[0].recarver.epochs().len(), 2, "resize opened a new epoch");
    }

    #[test]
    #[should_panic(expected = "donor pod")]
    fn rebalance_never_empties_a_pod() {
        let mut r = Router::new(2, 8, 2, SpAlgo::SwiftFusion);
        r.rebalance_machine(0, 1, 0.0); // pods have 1 machine each
    }

    #[test]
    fn free_index_tracks_every_timeline_mutation() {
        // 4 pods x 2 machines of 8 GPUs
        let mut r = Router::new(8, 8, 4, SpAlgo::SwiftFusion);
        assert_eq!(r.pick_indexed(), r.pick());
        assert_eq!(r.pick_indexed(), 0, "all idle -> lowest id");
        r.dispatch(0, 0.0, 10.0);
        r.dispatch(1, 0.0, 3.0);
        assert_eq!(r.pick_indexed(), r.pick());
        assert_eq!(r.pick_indexed(), 2);
        r.dispatch(2, 0.0, 1.0);
        r.dispatch(3, 0.0, 2.0);
        assert_eq!(r.pick_indexed(), r.pick(), "pod 2 free soonest");
        r.commit_recarve(2, 1.0, 5.0); // pod 2: drained at 1.0 + 5.0 setup
        assert_eq!(r.pods[2].free_at, 6.0);
        assert_eq!(r.pick_indexed(), r.pick());
        assert_eq!(r.pick_indexed(), 3);
        // ascending (free_at, id): p3=2.0, p1=3.0, p2=6.0, p0=10.0
        let order: Vec<usize> = r.pods_by_free().collect();
        assert_eq!(order, vec![3, 1, 2, 0]);
        // a migration re-times both pods and the index follows
        let ev = r.rebalance_machine(0, 3, 4.0);
        assert_eq!((ev.from_machines, ev.to_machines), (1, 3));
        assert_eq!(r.pick_indexed(), r.pick());
        // direct timeline pokes need an explicit rebuild
        r.pods[1].free_at = 100.0;
        r.rebuild_free_index();
        assert_eq!(r.pick_indexed(), r.pick());
        let order: Vec<usize> = r.pods_by_free().collect();
        assert_eq!(order.len(), 4, "every pod indexed exactly once");
        assert!(order.windows(2).all(|w| {
            let (a, b) = (r.pods[w[0]].free_at, r.pods[w[1]].free_at);
            a < b || (a == b && w[0] < w[1])
        }));
    }

    #[test]
    fn pods_default_to_the_free_policy() {
        let r = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        for p in &r.pods {
            assert_eq!(p.recarver.policy, RecarvePolicy::Free);
            assert!(p.recarver.carve().is_none(), "no carve before admission");
            assert!(p.recarver.setup_cost > 0.0);
        }
    }

    #[test]
    fn pod_plans_follow_workload_guidance() {
        use crate::workload::Workload;
        let r = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let pod = &r.pods[0];
        // CFG video workload: the long sequence is comm-bound, so the
        // cost model splits the guidance branches across groups
        let video = pod.plan_for(&Workload::cogvideo_20s(), 1);
        assert!(video.validate(&pod.cluster).is_ok());
        assert_eq!(video.cfg_degree, 2, "{video:?}");
        // the long sequence is inter-machine-bound: the planner also
        // carves pipeline stages so SP stays intra-machine
        assert!(video.pp_degree > 1, "{video:?}");
        // distilled Flux has one branch: nothing to CFG-split
        let flux = pod.plan_for(&Workload::flux_3072(), 1);
        assert!(flux.validate(&pod.cluster).is_ok());
        assert_eq!(flux.cfg_degree, 1, "{flux:?}");
    }
}
