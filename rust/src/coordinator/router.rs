//! Pod router: carves the cluster into serving pods and picks where each
//! batch runs.
//!
//! A *pod* is a set of machines operated as one 2D SP mesh. The router
//! implements the paper's placement rule per workload (P_u = gcd(P, H),
//! §4.2) and least-loaded dispatch (earliest-free pod, ties by index —
//! deterministic).

use crate::analysis;
use crate::config::{ClusterSpec, ParallelSpec, SpDegrees};
use crate::sp::SpAlgo;
use crate::workload::Workload;

/// One serving pod: a sub-cluster running a fixed algorithm.
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: usize,
    pub cluster: ClusterSpec,
    pub algo: SpAlgo,
    /// Virtual time at which the pod becomes free.
    pub free_at: f64,
}

impl Pod {
    /// Degrees for a workload with `heads` heads on this pod (gcd rule
    /// for the SwiftFusion family; max-intra-Ulysses for USP).
    pub fn degrees_for(&self, heads: usize) -> SpDegrees {
        match self.algo {
            SpAlgo::Usp => {
                let m = self.cluster.gpus_per_machine;
                let pu = crate::config::gcd(m, heads);
                SpDegrees::new(pu, self.cluster.total_gpus() / pu)
            }
            _ => SpDegrees::swiftfusion_default(&self.cluster, heads),
        }
    }

    /// Hybrid CFG×PP×SP plan for one request of `workload` on this pod,
    /// given how many similar requests are queued behind it — the
    /// analysis cost model trades SP degree against CFG-branch groups,
    /// pipeline stages ([`analysis::DEFAULT_PATCHES`] patches), and
    /// batch replicas.
    pub fn plan_for(&self, workload: &Workload, queue_depth: usize) -> ParallelSpec {
        analysis::choose_spec(
            &self.cluster,
            self.algo,
            &workload.shape,
            workload.cfg_evals,
            queue_depth,
        )
    }
}

/// The router: owns the pods, assigns batches.
#[derive(Debug)]
pub struct Router {
    pub pods: Vec<Pod>,
}

impl Router {
    /// Split `machines` total machines into `num_pods` equal pods of
    /// `gpus_per_machine`-GPU machines.
    pub fn new(machines: usize, gpus_per_machine: usize, num_pods: usize, algo: SpAlgo) -> Self {
        assert!(num_pods > 0 && machines % num_pods == 0);
        let per_pod = machines / num_pods;
        let pods = (0..num_pods)
            .map(|id| Pod {
                id,
                cluster: ClusterSpec::new(per_pod, gpus_per_machine),
                algo,
                free_at: 0.0,
            })
            .collect();
        Self { pods }
    }

    /// Earliest-free pod (ties broken by lowest id — deterministic).
    pub fn pick(&self) -> usize {
        self.pods
            .iter()
            .enumerate()
            .min_by(|(ia, a), (ib, b)| {
                a.free_at
                    .partial_cmp(&b.free_at)
                    .unwrap()
                    .then(ia.cmp(ib))
            })
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Commit a batch to `pod`: service starts when both the pod is free
    /// and the batch is ready; returns (start, completion).
    pub fn dispatch(&mut self, pod: usize, ready_at: f64, service: f64) -> (f64, f64) {
        let p = &mut self.pods[pod];
        let start = p.free_at.max(ready_at);
        let done = start + service;
        p.free_at = done;
        (start, done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pods_partition_the_cluster() {
        let r = Router::new(4, 8, 2, SpAlgo::SwiftFusion);
        assert_eq!(r.pods.len(), 2);
        assert_eq!(r.pods[0].cluster.total_gpus(), 16);
    }

    #[test]
    fn gcd_rule_degrees() {
        let r = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        // P=32, H=24 -> Pu=8, Pr=4 (§4.2's example)
        assert_eq!(r.pods[0].degrees_for(24), SpDegrees::new(8, 4));
        // USP maxes intra-machine Ulysses: Pu = gcd(M=8, 24) = 8
        let r2 = Router::new(4, 8, 1, SpAlgo::Usp);
        assert_eq!(r2.pods[0].degrees_for(24), SpDegrees::new(8, 4));
    }

    #[test]
    fn least_loaded_dispatch() {
        let mut r = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        assert_eq!(r.pick(), 0);
        let (s0, d0) = r.dispatch(0, 0.0, 10.0);
        assert_eq!((s0, d0), (0.0, 10.0));
        assert_eq!(r.pick(), 1, "pod 0 busy until 10");
        r.dispatch(1, 0.0, 3.0);
        assert_eq!(r.pick(), 1, "pod 1 free sooner");
        // batch not ready until t=20: idles the pod
        let (s, d) = r.dispatch(1, 20.0, 1.0);
        assert_eq!((s, d), (20.0, 21.0));
    }

    #[test]
    fn deterministic_tiebreak() {
        let r = Router::new(2, 2, 2, SpAlgo::SwiftFusion);
        assert_eq!(r.pick(), 0, "equal free_at -> lowest id");
    }

    #[test]
    fn pod_plans_follow_workload_guidance() {
        use crate::workload::Workload;
        let r = Router::new(4, 8, 1, SpAlgo::SwiftFusion);
        let pod = &r.pods[0];
        // CFG video workload: the long sequence is comm-bound, so the
        // cost model splits the guidance branches across groups
        let video = pod.plan_for(&Workload::cogvideo_20s(), 1);
        assert!(video.validate(&pod.cluster).is_ok());
        assert_eq!(video.cfg_degree, 2, "{video:?}");
        // the long sequence is inter-machine-bound: the planner also
        // carves pipeline stages so SP stays intra-machine
        assert!(video.pp_degree > 1, "{video:?}");
        // distilled Flux has one branch: nothing to CFG-split
        let flux = pod.plan_for(&Workload::flux_3072(), 1);
        assert!(flux.validate(&pod.cluster).is_ok());
        assert_eq!(flux.cfg_degree, 1, "{flux:?}");
    }
}
