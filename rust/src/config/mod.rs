//! Configuration system: cluster topology, hardware constants, attention
//! workload shapes, and engine settings.
//!
//! Hardware presets encode the paper's testbed (§5.1: 4× AWS p4de.24xlarge,
//! 8× A100-40GB per machine, NVSwitch intra-machine, 400 Gbps EFA
//! inter-machine) so the analysis model and the netsim share one source of
//! truth. All bandwidths are *per direction* in bytes/second.

use anyhow::{bail, Result};

/// Per-GPU compute model (used to convert attention FLOPs to seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Dense bf16/fp16 tensor-core throughput actually achievable for
    /// flash-attention-like kernels (fraction of peak).
    pub flops: f64,
    /// HBM bandwidth in bytes/s (roofline for memory-bound shapes).
    pub mem_bw: f64,
    /// GPU memory capacity in bytes (activation-fit checks, Fig. 7 memory).
    pub mem_capacity: f64,
    /// Fixed per-kernel launch overhead, seconds. The paper's Fig. 8
    /// discussion: small Ring degrees fragment attention into many kernel
    /// launches, and this constant is what makes that visible.
    pub launch_overhead: f64,
}

impl GpuSpec {
    /// NVIDIA A100-SXM 40 GiB (paper's GPU): 312 TFLOPS bf16 peak; flash
    /// attention sustains ~60% of peak on long sequences.
    pub fn a100_40g() -> Self {
        Self {
            flops: 312e12 * 0.6,
            mem_bw: 1.555e12,
            mem_capacity: 40.0 * (1u64 << 30) as f64,
            launch_overhead: 4e-6,
        }
    }

    /// Seconds to run an attention tile of `flops` touching `bytes` of
    /// HBM: roofline max of compute and memory time plus launch overhead.
    pub fn tile_time(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.flops).max(bytes / self.mem_bw) + self.launch_overhead
    }
}

/// Network link model: classic α–β (latency + inverse bandwidth) with an
/// SM-contention tax for kernel-based (two-sided) transfers — the three
/// effects Challenge 1–3 of the paper are about.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetSpec {
    /// Intra-machine (NVSwitch) per-GPU bandwidth, bytes/s per direction.
    pub intra_bw: f64,
    /// Intra-machine per-transfer latency, seconds.
    pub intra_lat: f64,
    /// Inter-machine NIC bandwidth *per machine*, bytes/s per direction
    /// (shared by all GPUs of the machine — the EFA aggregation of Fig 3a).
    pub inter_bw: f64,
    /// Inter-machine per-transfer latency, seconds.
    pub inter_lat: f64,
    /// Per-transfer rendezvous penalty of two-sided libraries (sender and
    /// receiver synchronize before data moves; Fig. 4), seconds.
    pub two_sided_sync: f64,
    /// Effective-bandwidth loss of kernel-based two-sided transfers (the
    /// copy kernels steal SMs — Challenge 3); one-sided driver-level
    /// copies don't pay it.
    pub sm_tax: f64,
    /// Fraction of a two-sided transfer that *blocks* the issuing rank
    /// (NCCL send/recv kernels occupy stream slots and SMs, so posted
    /// transfers only partially progress behind compute — Fig. 4 / the
    /// Fig. 3b comm-bound breakdown). One-sided puts/gets are fully
    /// asynchronous (driver-level copies).
    pub two_sided_stream_block: f64,
    /// Cost of a barrier across a process group, seconds (scales ~log P,
    /// applied per barrier call by the models).
    pub barrier_lat: f64,
    /// Contention-aware NIC chunk scheduling (the comm optimization
    /// pass). Off (the default), every concurrent inter-machine flow
    /// pays the constant worst-case fair share
    /// [`Self::inter_bw_per_flow`]. On, [`crate::comm::CommWorld`]
    /// keeps a per-rank NIC lane timeline and schedules concurrent
    /// transfers round-robin by chunk: each chunk moves at full NIC
    /// bandwidth in its TDMA slot, so a transfer that does *not*
    /// actually collide stops paying for neighbours that finished.
    pub nic_schedule: bool,
    /// Inter-machine activation compression ratio (wire bytes = payload
    /// bytes × this). 1.0 (the default) ships full precision; 0.5
    /// models fp16-over-the-wire, 0.25 int8-style quantization.
    /// Intra-machine hops never compress. Timing, `Traffic` counters,
    /// and the `analysis` closed forms all see wire bytes; HostNumeric
    /// runs quantize the payload so the error is observable
    /// (`tests/sp_property.rs` bounds it like stale-KV).
    pub inter_compress: f64,
    /// Fuse the CFG branches' identical-shape inter-machine collectives
    /// into one scheduled flow when a carved plan's branch groups have
    /// matching footprints ([`crate::cluster::plan::ParallelPlan::cfg_fusible`]):
    /// the fused transfer pays the per-transfer α and the two-sided
    /// rendezvous once for both branches (halved per branch). Off by
    /// default.
    pub cfg_fuse: bool,
}

impl NetSpec {
    /// Paper's testbed: NVSwitch (A100 gen: 600 GB/s/GPU total, ~300 GB/s
    /// per direction) + 400 Gbps EFA per machine. `inter_bw` is the
    /// *effective* collective bandwidth: EFA's 50 GB/s line rate delivers
    /// ~25 GB/s of NCCL busbw on p4d-class instances (public nccl-tests
    /// numbers) — using line rate would make USP's ring fully hideable,
    /// contradicting the paper's measured Fig. 3b breakdown.
    pub fn p4de_efa() -> Self {
        Self {
            intra_bw: 300e9,
            intra_lat: 3e-6,
            inter_bw: 25e9,
            inter_lat: 15e-6,
            two_sided_sync: 10e-6,
            sm_tax: 0.12,
            two_sided_stream_block: 0.85,
            barrier_lat: 20e-6,
            nic_schedule: false,
            inter_compress: 1.0,
            cfg_fuse: false,
        }
    }

    /// A slower "commodity ethernet" variant (wider intra/inter gap) used
    /// by the topology_explorer example and sensitivity tests.
    ///
    /// Only the link terms change: 100 Gbps line rate (12.5 GB/s) and
    /// 30 µs RTT-class latency. The remaining constants are *deliberate*
    /// p4de carry-overs, not omissions:
    /// - `sm_tax` and `two_sided_stream_block` model the NCCL copy
    ///   kernels stealing SMs/stream slots on the *GPU*, which does not
    ///   change with the fabric;
    /// - `two_sided_sync` is the library rendezvous handshake, host-side
    ///   and fabric-independent to first order;
    /// - `barrier_lat` is dominated by the same host/library path.
    ///
    /// `tests/sensitivity.rs::commodity_carries_host_side_constants`
    /// pins the carry-over and shows the comparisons this preset feeds
    /// are insensitive to plausible perturbations of the carried
    /// constants (the intra/inter gap dominates).
    pub fn commodity_100g() -> Self {
        Self {
            inter_bw: 100e9 / 8.0,
            inter_lat: 30e-6,
            ..Self::p4de_efa()
        }
    }

    /// Effective per-GPU inter-machine bandwidth when `flows` GPUs of a
    /// machine communicate off-machine concurrently (NIC fair share).
    pub fn inter_bw_per_flow(&self, flows: usize) -> f64 {
        self.inter_bw / flows.max(1) as f64
    }
}

/// The cluster: N machines × M GPUs + hardware constants.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub machines: usize,
    pub gpus_per_machine: usize,
    pub gpu: GpuSpec,
    pub net: NetSpec,
}

impl ClusterSpec {
    pub fn new(machines: usize, gpus_per_machine: usize) -> Self {
        Self {
            machines,
            gpus_per_machine,
            gpu: GpuSpec::a100_40g(),
            net: NetSpec::p4de_efa(),
        }
    }

    /// The paper's evaluation cluster: 4 × 8 A100.
    pub fn paper_testbed() -> Self {
        Self::new(4, 8)
    }

    /// The same hardware with a different machine count — how cross-pod
    /// re-balancing models a pod after a machine migrated in or out
    /// (GPU/network constants are fleet-wide, only the footprint moves).
    pub fn resized(&self, machines: usize) -> Self {
        assert!(machines > 0, "a pod needs at least one machine");
        Self { machines, ..self.clone() }
    }

    pub fn total_gpus(&self) -> usize {
        self.machines * self.gpus_per_machine
    }

    pub fn machine_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_machine
    }

    pub fn same_machine(&self, a: usize, b: usize) -> bool {
        self.machine_of(a) == self.machine_of(b)
    }
}

/// Attention workload shape, paper notation (§2.2): Q/K/V are [B, L, H, D].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AttnShape {
    pub b: usize,
    pub l: usize,
    pub h: usize,
    pub d: usize,
}

impl AttnShape {
    pub fn new(b: usize, l: usize, h: usize, d: usize) -> Self {
        Self { b, l, h, d }
    }

    /// Elements of one of Q/K/V (the paper's BLHD product).
    pub fn blhd(&self) -> usize {
        self.b * self.l * self.h * self.d
    }

    pub fn bytes_per_tensor(&self) -> f64 {
        self.blhd() as f64 * 4.0 // f32 on this testbed (paper uses bf16: x0.5)
    }

    /// Total attention FLOPs: 2 matmuls (QK^T and PV), 2*B*H*L^2*D each.
    pub fn attention_flops(&self) -> f64 {
        4.0 * self.b as f64 * self.h as f64 * (self.l as f64) * (self.l as f64) * self.d as f64
    }
}

/// The 2D parallelization degrees: `pu` for Ulysses, `pr` for Ring
/// (`P_u × P_r` mesh, §4.2). The paper's UxRy notation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpDegrees {
    pub pu: usize,
    pub pr: usize,
}

impl SpDegrees {
    pub fn new(pu: usize, pr: usize) -> Self {
        Self { pu, pr }
    }

    pub fn total(&self) -> usize {
        self.pu * self.pr
    }

    /// The paper's placement rule (§4.2): `P_u = gcd(N·M, H)`, maximizing
    /// Ulysses usage, `P_r = N·M / P_u`.
    pub fn swiftfusion_default(cluster: &ClusterSpec, heads: usize) -> Self {
        let p = cluster.total_gpus();
        let pu = gcd(p, heads);
        Self { pu, pr: p / pu }
    }

    /// Validate against a cluster + workload (divisibility constraints the
    /// paper states: H % P_u == 0, L % P == 0).
    pub fn validate(&self, cluster: &ClusterSpec, shape: &AttnShape) -> Result<()> {
        if self.total() != cluster.total_gpus() {
            bail!(
                "degrees {}x{} != cluster {} GPUs",
                self.pu,
                self.pr,
                cluster.total_gpus()
            );
        }
        if shape.h % self.pu != 0 {
            bail!("H={} not divisible by P_u={}", shape.h, self.pu);
        }
        if shape.l % self.total() != 0 {
            bail!("L={} not divisible by P={}", shape.l, self.total());
        }
        Ok(())
    }
}

pub fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Serving quality mode — the fourth scheduler dimension (beside
/// `cfg × pp × sp`). Each degraded mode trades bounded output error for
/// latency; the bounds are derived and pinned in
/// `rust/tests/sp_property.rs` against the plain-softmax oracle, the
/// prices in [`crate::analysis::plan_step_cost_quality`] /
/// [`crate::analysis::quality_time_factor`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QualityMode {
    /// Exact serving: the plan's SP algorithm, fresh KV every layer.
    Full,
    /// DistriFusion-style displaced patch parallelism
    /// ([`crate::sp::displaced`]): remote KV served one-step-stale, the
    /// fresh-patch allgather pushed off the critical path, and — because
    /// stale activations already admit `STALE_TOL`-scale error — fresh
    /// patches ship half-precision (`inter_compress = 0.5`) on the wire.
    /// This is the per-batch form of the `NetSpec::inter_compress` knob:
    /// the scheduler decides it per dispatch instead of per pod.
    Displaced,
    /// DiTFastAttn-style windowed attention
    /// ([`crate::sp::displaced::fastattn_attention`]): each query tile
    /// attends only the `keep_ratio` fraction of KV tiles nearest to it.
    /// `keep_ratio = 1.0` is exact.
    FastAttn {
        /// Fraction of KV tiles each query tile keeps, in (0, 1].
        keep_ratio: f64,
    },
    /// Distilled few-step sampling under SLO pressure: run
    /// `steps / factor` diffusion steps, and — guidance distillation —
    /// drop the unconditional branch when the workload runs CFG
    /// (`Workload::evals_under` prices this).
    ReducedSteps {
        /// Step-count divisor, ≥ 1.
        factor: usize,
    },
}

/// Typed "unknown name" error for the CLI-facing `from_name` parsers
/// ([`QualityMode::from_name`], [`crate::sp::SpAlgo::from_name`]):
/// carries what was being named, the rejected spelling, and every
/// accepted spelling, so callers print an actionable message instead of
/// a bare failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NameError {
    /// What was being named (e.g. `quality mode`, `sp algorithm`).
    pub what: &'static str,
    /// The rejected spelling.
    pub given: String,
    /// Every accepted spelling (forms like `fastattn[:RATIO]` allowed).
    pub valid: Vec<String>,
}

impl NameError {
    pub fn new(what: &'static str, given: &str, valid: &[&str]) -> Self {
        Self {
            what,
            given: given.to_string(),
            valid: valid.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl std::fmt::Display for NameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown {} '{}': expected one of {}",
            self.what,
            self.given,
            self.valid.join(", ")
        )
    }
}

impl std::error::Error for NameError {}

impl QualityMode {
    /// The accepted [`Self::from_name`] spellings, for error messages
    /// and CLI help.
    pub const NAME_FORMS: [&'static str; 4] =
        ["full", "displaced", "fastattn[:RATIO]", "reduced[:FACTOR]"];

    /// Histogram / CLI label.
    pub fn label(&self) -> String {
        match self {
            QualityMode::Full => "full".to_string(),
            QualityMode::Displaced => "displaced".to_string(),
            QualityMode::FastAttn { keep_ratio } => format!("fastattn@{keep_ratio:.2}"),
            QualityMode::ReducedSteps { factor } => format!("steps/{factor}"),
        }
    }

    /// Parse a CLI spelling: `full`, `displaced`, `fastattn[:RATIO]`
    /// (default ratio 0.5), `reduced[:FACTOR]` (default factor 2).
    /// Misspellings and malformed parameters return a typed
    /// [`NameError`] listing every accepted form.
    pub fn from_name(s: &str) -> Result<Self, NameError> {
        let unknown = || NameError::new("quality mode", s, &Self::NAME_FORMS);
        match s {
            "full" => return Ok(QualityMode::Full),
            "displaced" => return Ok(QualityMode::Displaced),
            "fastattn" => return Ok(QualityMode::FastAttn { keep_ratio: 0.5 }),
            "reduced" => return Ok(QualityMode::ReducedSteps { factor: 2 }),
            _ => {}
        }
        if let Some(r) = s.strip_prefix("fastattn:") {
            let keep_ratio: f64 = r.parse().map_err(|_| unknown())?;
            if keep_ratio > 0.0 && keep_ratio <= 1.0 {
                return Ok(QualityMode::FastAttn { keep_ratio });
            }
            return Err(unknown());
        }
        if let Some(f) = s.strip_prefix("reduced:") {
            let factor: usize = f.parse().map_err(|_| unknown())?;
            if factor >= 1 {
                return Ok(QualityMode::ReducedSteps { factor });
            }
            return Err(unknown());
        }
        Err(unknown())
    }

    /// Quality score in (0, 1] the `--quality-floor` admission knob
    /// compares against: 1.0 is exact; degraded modes discount by their
    /// bounded error. `Displaced` scores `1 − STALE_TOL` (the one-step
    /// staleness bound); `FastAttn` scores the kept attention fraction
    /// blended toward exact (`0.5 + keep_ratio/2` — half the mass a
    /// window drops is far-field and near-zero after softmax);
    /// `ReducedSteps` scores `1/factor` (few-step sampling loses detail
    /// roughly with the step budget).
    pub fn score(&self) -> f64 {
        match self {
            QualityMode::Full => 1.0,
            QualityMode::Displaced => 0.9,
            QualityMode::FastAttn { keep_ratio } => 0.5 + 0.5 * keep_ratio,
            QualityMode::ReducedSteps { factor } => 1.0 / (*factor).max(1) as f64,
        }
    }

    /// Wire-byte multiplier this mode applies to inter-machine hops —
    /// the per-batch `inter_compress` decision. Exact serving ships full
    /// precision; every degraded mode already tolerates quantization
    /// noise, so it ships fp16 (`0.5`).
    pub fn wire_compress(&self) -> f64 {
        match self {
            QualityMode::Full => 1.0,
            _ => 0.5,
        }
    }

    /// The admission ladder, best quality first — what the scheduler
    /// walks when the priced queue delay exceeds the floor.
    pub fn ladder() -> [QualityMode; 4] {
        [
            QualityMode::Full,
            QualityMode::Displaced,
            QualityMode::FastAttn { keep_ratio: 0.5 },
            QualityMode::ReducedSteps { factor: 2 },
        ]
    }
}

/// Full parallelization recipe for a cluster: the 3D plan space
/// `cfg_degree × pp_degree × batch_replicas` with 2D SP degrees *inside
/// each pipeline stage*. The hybrid planner (`cluster::plan`) turns a
/// validated spec into carved sub-meshes;
/// `cfg_degree × pp_degree × batch_replicas × P_u × P_r` must exactly
/// tile the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelSpec {
    /// CFG-parallel degree: 1 = both guidance branches run on one mesh
    /// (sequentially), 2 = conditional/unconditional branches run
    /// concurrently on disjoint device groups (xDiT-style CFG parallel).
    pub cfg_degree: usize,
    /// Patch-level pipeline-parallel degree (PipeFusion's displaced
    /// patch pipeline): 1 = no pipelining; k > 1 carves each CFG/replica
    /// group into k contiguous *stages* of `sp` ranks each. DiT layers
    /// are partitioned across the stages and the latent sequence streams
    /// between them as patches (`crate::sp::pipefusion`).
    pub pp_degree: usize,
    /// Independent batch-replica groups beyond the CFG split (data
    /// parallelism over requests).
    pub batch_replicas: usize,
    /// Sequence-parallel degrees inside each pipeline stage.
    pub sp: SpDegrees,
}

/// Why a [`ParallelSpec`] cannot run on a cluster/workload. Every variant
/// renders an actionable message (what was asked, what the constraint is,
/// and how to fix it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParallelSpecError {
    /// `cfg_degree` must be 1 or 2 — guidance has two branches.
    BadCfgDegree { got: usize },
    /// `pp_degree` must be at least 1.
    ZeroPipelineStages,
    /// `batch_replicas` must be at least 1.
    ZeroReplicas,
    /// The product of all degrees must equal the cluster size.
    SizeMismatch {
        cfg_degree: usize,
        pp_degree: usize,
        batch_replicas: usize,
        sp_total: usize,
        cluster_gpus: usize,
    },
    /// Groups must align with machine boundaries: the group size must be
    /// a multiple of GPUs-per-machine (whole machines per group) or
    /// divide it (several groups per machine).
    MisalignedGroups { group_ranks: usize, gpus_per_machine: usize },
    /// Pipeline stages must align with machine boundaries too (each
    /// stage is a contiguous SP sub-mesh).
    MisalignedStages { stage_ranks: usize, gpus_per_machine: usize },
    /// Ulysses needs `P_u | H`.
    HeadsNotDivisible { heads: usize, pu: usize },
    /// SP needs `(P_u · P_r) | L`.
    SeqNotDivisible { l: usize, sp_ranks: usize },
    /// The patch pipeline needs `(patches · P_u · P_r) | L` so every
    /// patch SP-shards evenly inside its stage.
    PatchesNotDivisible { l: usize, patches: usize, stage_ranks: usize },
    /// A machine-subset carve
    /// (`crate::cluster::plan::ParallelPlan::build_subset`) must fit
    /// inside the pod: `base_machine + machines <= pod_machines`.
    SubsetOutOfRange { base_machine: usize, machines: usize, pod_machines: usize },
}

impl std::fmt::Display for ParallelSpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParallelSpecError::BadCfgDegree { got } => write!(
                f,
                "cfg_degree must be 1 (sequential guidance) or 2 (branch-parallel), got {got}"
            ),
            ParallelSpecError::ZeroPipelineStages => {
                write!(f, "pp_degree must be >= 1 (use 1 for no patch pipelining)")
            }
            ParallelSpecError::ZeroReplicas => {
                write!(f, "batch_replicas must be >= 1 (use 1 for no batch replication)")
            }
            ParallelSpecError::SizeMismatch {
                cfg_degree,
                pp_degree,
                batch_replicas,
                sp_total,
                cluster_gpus,
            } => write!(
                f,
                "cfg_degree({cfg_degree}) x pp_degree({pp_degree}) x \
                 batch_replicas({batch_replicas}) x sp_ranks({sp_total}) \
                 = {} but the cluster has {cluster_gpus} GPUs; pick degrees whose product is \
                 exactly {cluster_gpus}",
                cfg_degree * pp_degree * batch_replicas * sp_total
            ),
            ParallelSpecError::MisalignedGroups { group_ranks, gpus_per_machine } => write!(
                f,
                "group size {group_ranks} straddles machine boundaries (machines have \
                 {gpus_per_machine} GPUs); use a group size that divides {gpus_per_machine} \
                 or is a multiple of it"
            ),
            ParallelSpecError::MisalignedStages { stage_ranks, gpus_per_machine } => write!(
                f,
                "pipeline stage size {stage_ranks} straddles machine boundaries (machines \
                 have {gpus_per_machine} GPUs); use a stage size that divides \
                 {gpus_per_machine} or is a multiple of it"
            ),
            ParallelSpecError::HeadsNotDivisible { heads, pu } => write!(
                f,
                "H={heads} attention heads not divisible by P_u={pu}; lower P_u to a divisor \
                 of {heads} (the paper's rule: P_u = gcd(group size, H))"
            ),
            ParallelSpecError::SeqNotDivisible { l, sp_ranks } => write!(
                f,
                "sequence length L={l} not divisible by the group's {sp_ranks} SP ranks; \
                 align the workload (Workload::aligned_to) or change the SP degrees"
            ),
            ParallelSpecError::PatchesNotDivisible { l, patches, stage_ranks } => write!(
                f,
                "sequence length L={l} cannot be split into {patches} patches that \
                 SP-shard over {stage_ranks} stage ranks; align the workload \
                 (Workload::aligned_to) so patches x sp_ranks divides L, or change \
                 --patches"
            ),
            ParallelSpecError::SubsetOutOfRange { base_machine, machines, pod_machines } => {
                write!(
                    f,
                    "machine subset [{base_machine}, {}) exceeds the pod's \
                     {pod_machines} machine(s); lower the base machine or shrink the \
                     subset spec",
                    base_machine + machines
                )
            }
        }
    }
}

impl std::error::Error for ParallelSpecError {}

impl ParallelSpec {
    /// A non-pipelined spec (`pp_degree == 1`).
    pub fn new(cfg_degree: usize, batch_replicas: usize, sp: SpDegrees) -> Self {
        Self { cfg_degree, pp_degree: 1, batch_replicas, sp }
    }

    /// A spec with an explicit patch-pipeline degree.
    pub fn with_pp(
        cfg_degree: usize,
        pp_degree: usize,
        batch_replicas: usize,
        sp: SpDegrees,
    ) -> Self {
        Self { cfg_degree, pp_degree, batch_replicas, sp }
    }

    /// The trivial plan: one group spanning the whole cluster with the
    /// paper's §4.2 placement rule for the SP degrees.
    pub fn single(cluster: &ClusterSpec, heads: usize) -> Self {
        Self::new(1, 1, SpDegrees::swiftfusion_default(cluster, heads))
    }

    /// A spec whose per-group SP degrees follow the paper's gcd
    /// placement rule (`P_u = gcd(group, H)`) — the one way to build
    /// hybrid specs from (cfg, replicas, group size, heads), shared by
    /// the CLI, the plan enumerator, and the benches.
    pub fn with_gcd_placement(
        cfg_degree: usize,
        batch_replicas: usize,
        group_ranks: usize,
        heads: usize,
    ) -> Self {
        Self::with_gcd_placement_pp(cfg_degree, 1, batch_replicas, group_ranks, heads)
    }

    /// [`Self::with_gcd_placement`] for the 3D plan space: the gcd rule
    /// is applied to the *stage* size (each pipeline stage is its own SP
    /// mesh).
    pub fn with_gcd_placement_pp(
        cfg_degree: usize,
        pp_degree: usize,
        batch_replicas: usize,
        stage_ranks: usize,
        heads: usize,
    ) -> Self {
        let pu = gcd(stage_ranks, heads);
        Self::with_pp(
            cfg_degree,
            pp_degree,
            batch_replicas,
            SpDegrees::new(pu, stage_ranks / pu),
        )
    }

    /// Number of replica groups (CFG branches × batch replicas).
    pub fn groups(&self) -> usize {
        self.cfg_degree * self.batch_replicas
    }

    /// Ranks inside one pipeline stage (the SP mesh size).
    pub fn ranks_per_stage(&self) -> usize {
        self.sp.total()
    }

    /// Ranks inside each group (all of its pipeline stages).
    pub fn ranks_per_group(&self) -> usize {
        self.pp_degree * self.sp.total()
    }

    /// Total ranks the spec occupies.
    pub fn total_ranks(&self) -> usize {
        self.groups() * self.ranks_per_group()
    }

    /// The busy-subset spec of a group-granular (partial) re-carve: this
    /// spec narrowed to the fewest batch replicas that still occupy
    /// *whole* machines of `gpus_per_machine` GPUs. An in-flight batch
    /// occupies one replica's worth of groups (`cfg_degree` branch
    /// groups of `ranks_per_group()` ranks each); the machines carrying
    /// them keep serving while the rest of the pod re-carves, so the
    /// busy generation's carve is this spec with `batch_replicas`
    /// reduced to the whole-machine minimum. `None` when narrowing
    /// cannot free any machine (the spec already has that few replicas —
    /// one request's groups span the whole footprint).
    pub fn narrowed_to_machines(&self, gpus_per_machine: usize) -> Option<ParallelSpec> {
        let per_replica = self.cfg_degree * self.ranks_per_group();
        // smallest replica count whose rank footprint is whole machines
        let k = gpus_per_machine / gcd(per_replica, gpus_per_machine);
        if k >= self.batch_replicas {
            return None;
        }
        Some(ParallelSpec { batch_replicas: k, ..*self })
    }

    /// Replica co-batching scatter arithmetic: how a closed batch of
    /// `batch` requests splits across this spec's `batch_replicas`
    /// groups (balanced, largest shards first, empty groups omitted).
    /// The first entry is the makespan-determining shard — the batch
    /// size each replica group effectively serves when the scheduler
    /// scatters one shared batch instead of queueing the whole batch on
    /// one group (`coordinator::session::ServeConfig::co_batch`).
    pub fn replica_shards(&self, batch: usize) -> Vec<usize> {
        let groups = self.batch_replicas.max(1).min(batch);
        if groups == 0 {
            return Vec::new();
        }
        let base = batch / groups;
        let extra = batch % groups;
        (0..groups)
            .map(|g| if g < extra { base + 1 } else { base })
            .collect()
    }

    /// Human-readable plan key, e.g. `cfg2 x pp2 x rep1 x U8R1` — the
    /// stable label the serving report's plan histogram and the benches
    /// key on.
    pub fn label(&self) -> String {
        format!(
            "cfg{} x pp{} x rep{} x U{}R{}",
            self.cfg_degree, self.pp_degree, self.batch_replicas, self.sp.pu, self.sp.pr
        )
    }

    /// Structural validation against a cluster: degree product and
    /// machine alignment. Workload divisibility is checked separately by
    /// [`Self::validate_workload`] (the same spec serves many shapes).
    pub fn validate(&self, cluster: &ClusterSpec) -> Result<(), ParallelSpecError> {
        if self.cfg_degree == 0 || self.cfg_degree > 2 {
            return Err(ParallelSpecError::BadCfgDegree { got: self.cfg_degree });
        }
        if self.pp_degree == 0 {
            return Err(ParallelSpecError::ZeroPipelineStages);
        }
        if self.batch_replicas == 0 {
            return Err(ParallelSpecError::ZeroReplicas);
        }
        if self.total_ranks() != cluster.total_gpus() {
            return Err(ParallelSpecError::SizeMismatch {
                cfg_degree: self.cfg_degree,
                pp_degree: self.pp_degree,
                batch_replicas: self.batch_replicas,
                sp_total: self.sp.total(),
                cluster_gpus: cluster.total_gpus(),
            });
        }
        let m = cluster.gpus_per_machine;
        let group = self.ranks_per_group();
        if group % m != 0 && m % group != 0 {
            return Err(ParallelSpecError::MisalignedGroups {
                group_ranks: group,
                gpus_per_machine: m,
            });
        }
        let stage = self.ranks_per_stage();
        if stage % m != 0 && m % stage != 0 {
            return Err(ParallelSpecError::MisalignedStages {
                stage_ranks: stage,
                gpus_per_machine: m,
            });
        }
        Ok(())
    }

    /// Per-workload divisibility: `P_u | H` and `(P_u·P_r) | L` (each
    /// stage's SP mesh shards the sequence it is handed). Patch
    /// divisibility for pipelined plans is checked separately by
    /// [`Self::validate_patches`] (the patch count is a runtime knob,
    /// not part of the spec).
    pub fn validate_workload(&self, shape: &AttnShape) -> Result<(), ParallelSpecError> {
        if shape.h % self.sp.pu != 0 {
            return Err(ParallelSpecError::HeadsNotDivisible {
                heads: shape.h,
                pu: self.sp.pu,
            });
        }
        if shape.l % self.sp.total() != 0 {
            return Err(ParallelSpecError::SeqNotDivisible {
                l: shape.l,
                sp_ranks: self.sp.total(),
            });
        }
        Ok(())
    }

    /// Patch divisibility for the displaced patch pipeline: the sequence
    /// must split into `patches` patches that each SP-shard evenly over
    /// the stage's ranks.
    pub fn validate_patches(
        &self,
        shape: &AttnShape,
        patches: usize,
    ) -> Result<(), ParallelSpecError> {
        let stage = self.ranks_per_stage();
        if patches == 0 || shape.l % (patches * stage) != 0 {
            return Err(ParallelSpecError::PatchesNotDivisible {
                l: shape.l,
                patches,
                stage_ranks: stage,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let c = ClusterSpec::paper_testbed();
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.machine_of(0), 0);
        assert_eq!(c.machine_of(7), 0);
        assert_eq!(c.machine_of(8), 1);
        assert!(c.same_machine(9, 15));
        assert!(!c.same_machine(7, 8));
    }

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd(32, 24), 8);
        assert_eq!(gcd(24, 32), 8);
        assert_eq!(gcd(7, 3), 1);
        assert_eq!(gcd(8, 8), 8);
    }

    #[test]
    fn swiftfusion_default_is_gcd_rule() {
        // paper §4.2: H=24, N*M=32 -> P_u = gcd(32,24) = 8, P_r = 4
        let c = ClusterSpec::paper_testbed();
        let d = SpDegrees::swiftfusion_default(&c, 24);
        assert_eq!(d, SpDegrees::new(8, 4));
        // H = 32 -> full Ulysses
        let d = SpDegrees::swiftfusion_default(&c, 32);
        assert_eq!(d, SpDegrees::new(32, 1));
    }

    #[test]
    fn degrees_validation() {
        let c = ClusterSpec::new(2, 2);
        let s = AttnShape::new(1, 128, 4, 16);
        assert!(SpDegrees::new(2, 2).validate(&c, &s).is_ok());
        assert!(SpDegrees::new(4, 2).validate(&c, &s).is_err()); // != 4 gpus
        assert!(SpDegrees::new(1, 4).validate(&c, &s).is_ok());
        let odd = AttnShape::new(1, 130, 4, 16);
        assert!(SpDegrees::new(2, 2).validate(&c, &odd).is_err()); // L % P
        let h3 = AttnShape::new(1, 128, 3, 16);
        assert!(SpDegrees::new(2, 2).validate(&c, &h3).is_err()); // H % Pu
    }

    #[test]
    fn parallel_spec_valid_combinations() {
        let c = ClusterSpec::new(4, 8); // 32 GPUs
        // cfg 2 x rep 1 x sp 16 (2 machines per branch)
        assert!(ParallelSpec::new(2, 1, SpDegrees::new(8, 2)).validate(&c).is_ok());
        // cfg 2 x rep 2 x sp 8 (1 machine per group)
        assert!(ParallelSpec::new(2, 2, SpDegrees::new(8, 1)).validate(&c).is_ok());
        // cfg 1 x rep 4 x sp 8
        assert!(ParallelSpec::new(1, 4, SpDegrees::new(4, 2)).validate(&c).is_ok());
        // single-group plan
        let s = ParallelSpec::single(&c, 24);
        assert_eq!(s.total_ranks(), 32);
        assert!(s.validate(&c).is_ok());
        // sub-machine groups: 8 groups of 4 on 4x8
        assert!(ParallelSpec::new(2, 4, SpDegrees::new(4, 1)).validate(&c).is_ok());
    }

    #[test]
    fn parallel_spec_size_mismatch_is_actionable() {
        let c = ClusterSpec::new(4, 8);
        let err = ParallelSpec::new(2, 1, SpDegrees::new(8, 1)).validate(&c).unwrap_err();
        assert!(matches!(err, ParallelSpecError::SizeMismatch { .. }));
        let msg = err.to_string();
        assert!(msg.contains("16"), "states the product: {msg}");
        assert!(msg.contains("32"), "states the cluster size: {msg}");
        assert!(msg.contains("exactly 32"), "tells the fix: {msg}");
    }

    #[test]
    fn parallel_spec_rejects_bad_degrees() {
        let c = ClusterSpec::new(2, 2);
        let e = ParallelSpec::new(3, 1, SpDegrees::new(1, 1)).validate(&c).unwrap_err();
        assert!(matches!(e, ParallelSpecError::BadCfgDegree { got: 3 }));
        assert!(e.to_string().contains("1") && e.to_string().contains("2"));
        let e = ParallelSpec::new(1, 0, SpDegrees::new(2, 2)).validate(&c).unwrap_err();
        assert!(matches!(e, ParallelSpecError::ZeroReplicas));
        let e = ParallelSpec::new(0, 1, SpDegrees::new(2, 2)).validate(&c).unwrap_err();
        assert!(matches!(e, ParallelSpecError::BadCfgDegree { got: 0 }));
    }

    #[test]
    fn parallel_spec_rejects_straddling_groups() {
        // 2 machines x 3 GPUs, groups of 2: 2 does not divide 3 and is
        // not a multiple of 3 -> a group would straddle machines.
        let c = ClusterSpec::new(2, 3);
        let err = ParallelSpec::new(1, 3, SpDegrees::new(2, 1)).validate(&c).unwrap_err();
        assert!(matches!(err, ParallelSpecError::MisalignedGroups { .. }));
        assert!(err.to_string().contains("straddles"));
    }

    #[test]
    fn parallel_spec_workload_divisibility() {
        let spec = ParallelSpec::new(2, 1, SpDegrees::new(4, 2));
        assert!(spec.validate_workload(&AttnShape::new(1, 128, 8, 16)).is_ok());
        let e = spec.validate_workload(&AttnShape::new(1, 128, 6, 16)).unwrap_err();
        assert!(matches!(e, ParallelSpecError::HeadsNotDivisible { heads: 6, pu: 4 }));
        assert!(e.to_string().contains("gcd"), "suggests the rule: {e}");
        let e = spec.validate_workload(&AttnShape::new(1, 130, 8, 16)).unwrap_err();
        assert!(matches!(e, ParallelSpecError::SeqNotDivisible { l: 130, sp_ranks: 8 }));
        assert!(e.to_string().contains("aligned_to"), "suggests the fix: {e}");
    }

    #[test]
    fn parallel_spec_pipeline_dimension() {
        let c = ClusterSpec::new(4, 8); // 32 GPUs
        // cfg2 x pp2 x rep1 x sp8: one machine per stage
        let s = ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1));
        assert!(s.validate(&c).is_ok());
        assert_eq!(s.ranks_per_stage(), 8);
        assert_eq!(s.ranks_per_group(), 16);
        assert_eq!(s.groups(), 2);
        assert_eq!(s.total_ranks(), 32);
        assert_eq!(s.label(), "cfg2 x pp2 x rep1 x U8R1");
        // cfg1 x pp4 x rep1 x sp8
        assert!(ParallelSpec::with_pp(1, 4, 1, SpDegrees::new(8, 1)).validate(&c).is_ok());
        // sub-machine stages: cfg1 x pp2 x rep4 x sp4
        assert!(ParallelSpec::with_pp(1, 2, 4, SpDegrees::new(4, 1)).validate(&c).is_ok());
        // pp = 0 rejected with an actionable message
        let e = ParallelSpec::with_pp(1, 0, 1, SpDegrees::new(8, 4)).validate(&c).unwrap_err();
        assert!(matches!(e, ParallelSpecError::ZeroPipelineStages));
        assert!(e.to_string().contains("pp_degree"));
        // product must still tile the cluster
        let e = ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 2)).validate(&c).unwrap_err();
        assert!(matches!(e, ParallelSpecError::SizeMismatch { pp_degree: 2, .. }));
        assert!(e.to_string().contains("pp_degree(2)"), "{e}");
    }

    #[test]
    fn parallel_spec_rejects_straddling_stages() {
        // 4 machines x 3 GPUs: stages of 2 straddle machine boundaries
        // even though the group (pp x sp = 6) is machine-aligned.
        let c = ClusterSpec::new(4, 3);
        let e = ParallelSpec::with_pp(2, 3, 1, SpDegrees::new(2, 1)).validate(&c).unwrap_err();
        assert!(matches!(e, ParallelSpecError::MisalignedStages { .. }));
        assert!(e.to_string().contains("stage"));
    }

    #[test]
    fn parallel_spec_patch_divisibility() {
        let spec = ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1));
        // L = 64 splits into 4 patches of 16 = 2 tokens per stage rank
        assert!(spec.validate_patches(&AttnShape::new(1, 64, 8, 4), 4).is_ok());
        // L = 40 does not split into 4 patches over 8 stage ranks
        let e = spec.validate_patches(&AttnShape::new(1, 40, 8, 4), 4).unwrap_err();
        assert!(matches!(
            e,
            ParallelSpecError::PatchesNotDivisible { l: 40, patches: 4, stage_ranks: 8 }
        ));
        assert!(e.to_string().contains("--patches"), "actionable: {e}");
        // zero patches is rejected, not a division panic
        assert!(spec.validate_patches(&AttnShape::new(1, 64, 8, 4), 0).is_err());
    }

    #[test]
    fn resized_cluster_keeps_hardware_constants() {
        let c = ClusterSpec::paper_testbed();
        let bigger = c.resized(5);
        assert_eq!(bigger.machines, 5);
        assert_eq!(bigger.gpus_per_machine, c.gpus_per_machine);
        assert_eq!(bigger.gpu, c.gpu);
        assert_eq!(bigger.net, c.net);
        assert_eq!(c.machines, 4, "original untouched");
    }

    #[test]
    #[should_panic(expected = "at least one machine")]
    fn resized_to_zero_is_rejected() {
        ClusterSpec::paper_testbed().resized(0);
    }

    #[test]
    fn narrowing_keeps_whole_machines() {
        // rep4 one-machine groups on 8-GPU machines: one replica's
        // groups fill exactly one machine
        let rep4 = ParallelSpec::new(1, 4, SpDegrees::new(8, 1));
        let n = rep4.narrowed_to_machines(8).unwrap();
        assert_eq!(n.batch_replicas, 1);
        assert_eq!(n.total_ranks(), 8);
        // sub-machine groups round up to a whole machine: 4-rank groups
        // on 8-GPU machines narrow to 2 replicas (= 8 ranks)
        let rep8 = ParallelSpec::new(1, 8, SpDegrees::new(4, 1));
        let n = rep8.narrowed_to_machines(8).unwrap();
        assert_eq!(n.batch_replicas, 2);
        assert_eq!(n.total_ranks(), 8);
        // cfg2 doubles the per-replica footprint: cfg2 x rep2 x sp8 on
        // 4x8 narrows to one replica spanning two machines
        let cfg2 = ParallelSpec::new(2, 2, SpDegrees::new(8, 1));
        let n = cfg2.narrowed_to_machines(8).unwrap();
        assert_eq!(n.batch_replicas, 1);
        assert_eq!(n.total_ranks(), 16);
        // a single-replica spec cannot free any machine
        assert!(ParallelSpec::new(2, 1, SpDegrees::new(8, 2))
            .narrowed_to_machines(8)
            .is_none());
        assert!(ParallelSpec::with_pp(2, 2, 1, SpDegrees::new(8, 1))
            .narrowed_to_machines(8)
            .is_none());
    }

    #[test]
    fn replica_shards_balance_the_batch() {
        let rep4 = ParallelSpec::new(1, 4, SpDegrees::new(8, 1));
        assert_eq!(rep4.replica_shards(8), vec![2, 2, 2, 2]);
        assert_eq!(rep4.replica_shards(6), vec![2, 2, 1, 1]);
        assert_eq!(rep4.replica_shards(3), vec![1, 1, 1], "empty groups omitted");
        assert_eq!(rep4.replica_shards(1), vec![1]);
        assert_eq!(rep4.replica_shards(0), Vec::<usize>::new());
        // shards sum to the batch and the head shard is the makespan one
        for b in 1..20 {
            let shards = rep4.replica_shards(b);
            assert_eq!(shards.iter().sum::<usize>(), b);
            assert_eq!(shards[0], b.div_ceil(shards.len()));
        }
        // a replica-free spec serves the whole batch on its one group
        let rep1 = ParallelSpec::new(2, 1, SpDegrees::new(8, 2));
        assert_eq!(rep1.replica_shards(5), vec![5]);
    }

    #[test]
    fn attn_shape_arithmetic() {
        let s = AttnShape::new(2, 1024, 24, 64);
        assert_eq!(s.blhd(), 2 * 1024 * 24 * 64);
        assert_eq!(s.bytes_per_tensor(), (2 * 1024 * 24 * 64) as f64 * 4.0);
        // 4*B*H*L^2*D
        assert_eq!(
            s.attention_flops(),
            4.0 * 2.0 * 24.0 * 1024.0 * 1024.0 * 64.0
        );
    }

    #[test]
    fn nic_fair_share() {
        let n = NetSpec::p4de_efa();
        assert_eq!(n.inter_bw_per_flow(1), n.inter_bw);
        assert_eq!(n.inter_bw_per_flow(8), n.inter_bw / 8.0);
        assert_eq!(n.inter_bw_per_flow(0), n.inter_bw);
    }

    #[test]
    fn presets_sane() {
        let n = NetSpec::p4de_efa();
        // the whole paper premise: intra >> inter
        assert!(n.intra_bw > 4.0 * n.inter_bw);
        assert!(n.inter_lat > n.intra_lat);
        let g = GpuSpec::a100_40g();
        assert!(g.flops > 1e14);
    }

    #[test]
    fn comm_opt_knobs_default_off() {
        // The optimization pass is opt-in: both presets ship with the
        // legacy constant fair-share model, full-precision wires, and
        // unfused CFG collectives, so every pre-existing schedule and
        // golden reproduces bit-for-bit.
        for n in [NetSpec::p4de_efa(), NetSpec::commodity_100g()] {
            assert!(!n.nic_schedule);
            assert_eq!(n.inter_compress, 1.0);
            assert!(!n.cfg_fuse);
        }
    }

    #[test]
    fn commodity_preset_carries_host_side_constants() {
        // The documented carry-over contract: commodity_100g changes the
        // *link* terms only; the GPU/host-side constants are inherited
        // from p4de on purpose (see the preset's doc comment).
        let p4 = NetSpec::p4de_efa();
        let c = NetSpec::commodity_100g();
        assert_eq!(c.inter_bw, 100e9 / 8.0);
        assert_eq!(c.inter_lat, 30e-6);
        assert_eq!(c.sm_tax, p4.sm_tax);
        assert_eq!(c.two_sided_sync, p4.two_sided_sync);
        assert_eq!(c.barrier_lat, p4.barrier_lat);
        assert_eq!(c.two_sided_stream_block, p4.two_sided_stream_block);
        assert_eq!(c.intra_bw, p4.intra_bw);
        assert_eq!(c.intra_lat, p4.intra_lat);
    }
}
