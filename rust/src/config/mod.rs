//! Configuration system: cluster topology, hardware constants, attention
//! workload shapes, and engine settings.
//!
//! Hardware presets encode the paper's testbed (§5.1: 4× AWS p4de.24xlarge,
//! 8× A100-40GB per machine, NVSwitch intra-machine, 400 Gbps EFA
//! inter-machine) so the analysis model and the netsim share one source of
//! truth. All bandwidths are *per direction* in bytes/second.

use anyhow::{bail, Result};

/// Per-GPU compute model (used to convert attention FLOPs to seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Dense bf16/fp16 tensor-core throughput actually achievable for
    /// flash-attention-like kernels (fraction of peak).
    pub flops: f64,
    /// HBM bandwidth in bytes/s (roofline for memory-bound shapes).
    pub mem_bw: f64,
    /// GPU memory capacity in bytes (activation-fit checks, Fig. 7 memory).
    pub mem_capacity: f64,
    /// Fixed per-kernel launch overhead, seconds. The paper's Fig. 8
    /// discussion: small Ring degrees fragment attention into many kernel
    /// launches, and this constant is what makes that visible.
    pub launch_overhead: f64,
}

impl GpuSpec {
    /// NVIDIA A100-SXM 40 GiB (paper's GPU): 312 TFLOPS bf16 peak; flash
    /// attention sustains ~60% of peak on long sequences.
    pub fn a100_40g() -> Self {
        Self {
            flops: 312e12 * 0.6,
            mem_bw: 1.555e12,
            mem_capacity: 40.0 * (1u64 << 30) as f64,
            launch_overhead: 4e-6,
        }
    }

    /// Seconds to run an attention tile of `flops` touching `bytes` of
    /// HBM: roofline max of compute and memory time plus launch overhead.
    pub fn tile_time(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.flops).max(bytes / self.mem_bw) + self.launch_overhead
    }
}

/// Network link model: classic α–β (latency + inverse bandwidth) with an
/// SM-contention tax for kernel-based (two-sided) transfers — the three
/// effects Challenge 1–3 of the paper are about.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetSpec {
    /// Intra-machine (NVSwitch) per-GPU bandwidth, bytes/s per direction.
    pub intra_bw: f64,
    /// Intra-machine per-transfer latency, seconds.
    pub intra_lat: f64,
    /// Inter-machine NIC bandwidth *per machine*, bytes/s per direction
    /// (shared by all GPUs of the machine — the EFA aggregation of Fig 3a).
    pub inter_bw: f64,
    /// Inter-machine per-transfer latency, seconds.
    pub inter_lat: f64,
    /// Per-transfer rendezvous penalty of two-sided libraries (sender and
    /// receiver synchronize before data moves; Fig. 4), seconds.
    pub two_sided_sync: f64,
    /// Effective-bandwidth loss of kernel-based two-sided transfers (the
    /// copy kernels steal SMs — Challenge 3); one-sided driver-level
    /// copies don't pay it.
    pub sm_tax: f64,
    /// Fraction of a two-sided transfer that *blocks* the issuing rank
    /// (NCCL send/recv kernels occupy stream slots and SMs, so posted
    /// transfers only partially progress behind compute — Fig. 4 / the
    /// Fig. 3b comm-bound breakdown). One-sided puts/gets are fully
    /// asynchronous (driver-level copies).
    pub two_sided_stream_block: f64,
    /// Cost of a barrier across a process group, seconds (scales ~log P,
    /// applied per barrier call by the models).
    pub barrier_lat: f64,
}

impl NetSpec {
    /// Paper's testbed: NVSwitch (A100 gen: 600 GB/s/GPU total, ~300 GB/s
    /// per direction) + 400 Gbps EFA per machine. `inter_bw` is the
    /// *effective* collective bandwidth: EFA's 50 GB/s line rate delivers
    /// ~25 GB/s of NCCL busbw on p4d-class instances (public nccl-tests
    /// numbers) — using line rate would make USP's ring fully hideable,
    /// contradicting the paper's measured Fig. 3b breakdown.
    pub fn p4de_efa() -> Self {
        Self {
            intra_bw: 300e9,
            intra_lat: 3e-6,
            inter_bw: 25e9,
            inter_lat: 15e-6,
            two_sided_sync: 10e-6,
            sm_tax: 0.12,
            two_sided_stream_block: 0.85,
            barrier_lat: 20e-6,
        }
    }

    /// A slower "commodity ethernet" variant (wider intra/inter gap) used
    /// by the topology_explorer example and sensitivity tests.
    pub fn commodity_100g() -> Self {
        Self {
            inter_bw: 100e9 / 8.0,
            inter_lat: 30e-6,
            ..Self::p4de_efa()
        }
    }

    /// Effective per-GPU inter-machine bandwidth when `flows` GPUs of a
    /// machine communicate off-machine concurrently (NIC fair share).
    pub fn inter_bw_per_flow(&self, flows: usize) -> f64 {
        self.inter_bw / flows.max(1) as f64
    }
}

/// The cluster: N machines × M GPUs + hardware constants.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub machines: usize,
    pub gpus_per_machine: usize,
    pub gpu: GpuSpec,
    pub net: NetSpec,
}

impl ClusterSpec {
    pub fn new(machines: usize, gpus_per_machine: usize) -> Self {
        Self {
            machines,
            gpus_per_machine,
            gpu: GpuSpec::a100_40g(),
            net: NetSpec::p4de_efa(),
        }
    }

    /// The paper's evaluation cluster: 4 × 8 A100.
    pub fn paper_testbed() -> Self {
        Self::new(4, 8)
    }

    pub fn total_gpus(&self) -> usize {
        self.machines * self.gpus_per_machine
    }

    pub fn machine_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_machine
    }

    pub fn same_machine(&self, a: usize, b: usize) -> bool {
        self.machine_of(a) == self.machine_of(b)
    }
}

/// Attention workload shape, paper notation (§2.2): Q/K/V are [B, L, H, D].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    pub b: usize,
    pub l: usize,
    pub h: usize,
    pub d: usize,
}

impl AttnShape {
    pub fn new(b: usize, l: usize, h: usize, d: usize) -> Self {
        Self { b, l, h, d }
    }

    /// Elements of one of Q/K/V (the paper's BLHD product).
    pub fn blhd(&self) -> usize {
        self.b * self.l * self.h * self.d
    }

    pub fn bytes_per_tensor(&self) -> f64 {
        self.blhd() as f64 * 4.0 // f32 on this testbed (paper uses bf16: x0.5)
    }

    /// Total attention FLOPs: 2 matmuls (QK^T and PV), 2*B*H*L^2*D each.
    pub fn attention_flops(&self) -> f64 {
        4.0 * self.b as f64 * self.h as f64 * (self.l as f64) * (self.l as f64) * self.d as f64
    }
}

/// The 2D parallelization degrees: `pu` for Ulysses, `pr` for Ring
/// (`P_u × P_r` mesh, §4.2). The paper's UxRy notation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpDegrees {
    pub pu: usize,
    pub pr: usize,
}

impl SpDegrees {
    pub fn new(pu: usize, pr: usize) -> Self {
        Self { pu, pr }
    }

    pub fn total(&self) -> usize {
        self.pu * self.pr
    }

    /// The paper's placement rule (§4.2): `P_u = gcd(N·M, H)`, maximizing
    /// Ulysses usage, `P_r = N·M / P_u`.
    pub fn swiftfusion_default(cluster: &ClusterSpec, heads: usize) -> Self {
        let p = cluster.total_gpus();
        let pu = gcd(p, heads);
        Self { pu, pr: p / pu }
    }

    /// Validate against a cluster + workload (divisibility constraints the
    /// paper states: H % P_u == 0, L % P == 0).
    pub fn validate(&self, cluster: &ClusterSpec, shape: &AttnShape) -> Result<()> {
        if self.total() != cluster.total_gpus() {
            bail!(
                "degrees {}x{} != cluster {} GPUs",
                self.pu,
                self.pr,
                cluster.total_gpus()
            );
        }
        if shape.h % self.pu != 0 {
            bail!("H={} not divisible by P_u={}", shape.h, self.pu);
        }
        if shape.l % self.total() != 0 {
            bail!("L={} not divisible by P={}", shape.l, self.total());
        }
        Ok(())
    }
}

pub fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let c = ClusterSpec::paper_testbed();
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.machine_of(0), 0);
        assert_eq!(c.machine_of(7), 0);
        assert_eq!(c.machine_of(8), 1);
        assert!(c.same_machine(9, 15));
        assert!(!c.same_machine(7, 8));
    }

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd(32, 24), 8);
        assert_eq!(gcd(24, 32), 8);
        assert_eq!(gcd(7, 3), 1);
        assert_eq!(gcd(8, 8), 8);
    }

    #[test]
    fn swiftfusion_default_is_gcd_rule() {
        // paper §4.2: H=24, N*M=32 -> P_u = gcd(32,24) = 8, P_r = 4
        let c = ClusterSpec::paper_testbed();
        let d = SpDegrees::swiftfusion_default(&c, 24);
        assert_eq!(d, SpDegrees::new(8, 4));
        // H = 32 -> full Ulysses
        let d = SpDegrees::swiftfusion_default(&c, 32);
        assert_eq!(d, SpDegrees::new(32, 1));
    }

    #[test]
    fn degrees_validation() {
        let c = ClusterSpec::new(2, 2);
        let s = AttnShape::new(1, 128, 4, 16);
        assert!(SpDegrees::new(2, 2).validate(&c, &s).is_ok());
        assert!(SpDegrees::new(4, 2).validate(&c, &s).is_err()); // != 4 gpus
        assert!(SpDegrees::new(1, 4).validate(&c, &s).is_ok());
        let odd = AttnShape::new(1, 130, 4, 16);
        assert!(SpDegrees::new(2, 2).validate(&c, &odd).is_err()); // L % P
        let h3 = AttnShape::new(1, 128, 3, 16);
        assert!(SpDegrees::new(2, 2).validate(&c, &h3).is_err()); // H % Pu
    }

    #[test]
    fn attn_shape_arithmetic() {
        let s = AttnShape::new(2, 1024, 24, 64);
        assert_eq!(s.blhd(), 2 * 1024 * 24 * 64);
        assert_eq!(s.bytes_per_tensor(), (2 * 1024 * 24 * 64) as f64 * 4.0);
        // 4*B*H*L^2*D
        assert_eq!(
            s.attention_flops(),
            4.0 * 2.0 * 24.0 * 1024.0 * 1024.0 * 64.0
        );
    }

    #[test]
    fn nic_fair_share() {
        let n = NetSpec::p4de_efa();
        assert_eq!(n.inter_bw_per_flow(1), n.inter_bw);
        assert_eq!(n.inter_bw_per_flow(8), n.inter_bw / 8.0);
        assert_eq!(n.inter_bw_per_flow(0), n.inter_bw);
    }

    #[test]
    fn presets_sane() {
        let n = NetSpec::p4de_efa();
        // the whole paper premise: intra >> inter
        assert!(n.intra_bw > 4.0 * n.inter_bw);
        assert!(n.inter_lat > n.intra_lat);
        let g = GpuSpec::a100_40g();
        assert!(g.flops > 1e14);
    }
}
