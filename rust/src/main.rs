//! SwiftFusion CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   info                       manifest + config inventory
//!   validate [--config NAME]   distributed-vs-oracle numeric check
//!   bench-layer [...]          single-attention-layer latency (timing sim)
//!   serve [...]                virtual-time serving run on a trace
//!                              (epoch-aware: see the --recarve flags)
//!   volumes [...]              Appendix-D inter-machine volume table
//!   trace [...]                chrome://tracing timeline of one layer
//!
//! Examples:
//!   swiftfusion validate --config small4
//!   swiftfusion bench-layer --machines 4 --gpus 8 --workload cogvideox-40s
//!   swiftfusion serve --machines 4 --gpus 8 --pods 2 --requests 64 --rate 0.05

use std::sync::Arc;

use anyhow::{bail, Result};

use swiftfusion::cluster::exec::{run_cluster, ExecMode};
use swiftfusion::cluster::recarve::RecarvePolicy;
use swiftfusion::comm::Buf;
use swiftfusion::config::{AttnShape, ClusterSpec, ParallelSpec, QualityMode, SpDegrees};
use swiftfusion::coordinator::batcher::BatchPolicy;
use swiftfusion::coordinator::engine::{PlanPolicy, SimService};
use swiftfusion::coordinator::router::Router;
use swiftfusion::coordinator::session::{
    dispatch_policy_from_name, RebalancePolicy, SchedulerMode, ServeConfig, ServeSession,
    SimFleet, DEFAULT_FORECAST_WINDOW,
};
use swiftfusion::coordinator::stages::{StagePlacement, StagePolicy};
use swiftfusion::runtime::Runtime;
use swiftfusion::sp::{SpAlgo, SpParams};
use swiftfusion::tensor::Tensor;
use swiftfusion::util::cli::Args;
use swiftfusion::util::stats::{fmt_bytes, fmt_time};
use swiftfusion::workload::{TraceGen, Workload};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "info" => cmd_info(),
        "validate" => cmd_validate(&args),
        "bench-layer" => cmd_bench_layer(&args),
        "serve" => cmd_serve(&args),
        "volumes" => cmd_volumes(&args),
        "trace" => cmd_trace(&args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
swiftfusion — scalable sequence parallelism for distributed DiT inference

USAGE: swiftfusion <info|validate|bench-layer|serve|volumes|trace> [flags]

  info                                  artifact + config inventory
  validate  --config small4             numeric check: all SP algos vs oracle
  bench-layer --machines N --gpus M --workload NAME [--algo NAME] [plan flags]
  serve     --machines N --gpus M --pods K --requests R --rate Q [--algo NAME]
            [--preset NAME] [plan flags] [re-carving flags] [scheduler flags]
            [comm flags] [quality flags]
  volumes   --machines N --gpus M --heads H
  trace     --machines N --gpus M --workload NAME [--algo NAME] [--out FILE]
            (per-rank timeline of one attention layer, chrome://tracing JSON)

Hybrid plan flags (bench-layer, serve):
  --plan single|auto|fixed   single = one SP mesh over the whole pod;
                             auto = pick a cfg x pp x sp x replica plan per
                             workload via the cost model; fixed = build one
                             plan from --cfg-degree/--pp-degree/
                             --batch-replicas and serve everything under it.
                             Default: single, or fixed when any of those
                             three degree flags is given
  --cfg-degree N             guidance branches on disjoint groups (1 or 2;
                             only --plan fixed reads it, default 1)
  --pp-degree K              patch-pipeline stages per group (PipeFusion's
                             displaced patch pipeline; only --plan fixed
                             reads it, default 1 = off)
  --patches M|auto           patch count the sequence streams through
                             pipelined plans as (all plan modes;
                             default 4), or `auto` to argmin the modeled
                             per-step time over the candidate counts
                             per workload
  --batch-replicas R         independent replica groups beyond the CFG split
                             (only --plan fixed reads it, default 1)

Config presets (serve): a named ServeConfig posture as the flag base —
every explicitly-passed flag still overrides its knob, so a preset is a
starting point, not a mode.
  --preset NAME              throughput (auto plan, earliest-finish,
                             batch 8 / 2s window, replica co-batching,
                             partial re-carving, gain re-balancing),
                             latency (auto plan, earliest-finish,
                             batch 1 / zero window, forecast re-carving
                             with the default EWMA window), or quality
                             (auto plan, earliest-finish, every batch
                             pinned to full quality). A one-pod fleet
                             silently drops a preset's re-balancing
                             (nothing to migrate between)

Dynamic re-carving flags (serve):
  --recarve POLICY           when a live pod may drain and re-carve to the
                             plan the cost model prefers for the current
                             traffic: free (default; adopt per-request,
                             zero modeled cost — the pre-epoch behaviour),
                             never (freeze the admission-time carve),
                             on-idle (re-carve only when the pod is idle),
                             hysteresis (re-carve after a sustained
                             predicted gain; pays drain + re-setup),
                             partial (hysteresis-gated, but a busy pod
                             splits: only its idle machines re-carve —
                             no drain barrier — while the busy carve
                             keeps serving; the pod re-unifies when idle),
                             forecast (hysteresis arithmetic, but the
                             confirmation window is short-circuited when
                             the arrival-mix forecaster already predicts
                             the incoming class dominates the mix — the
                             pod re-carves ahead of the shift instead of
                             serving the window stale; never fires later
                             than hysteresis)
  --recarve-threshold F      hysteresis/partial/forecast: minimum
                             predicted fractional gain per step
                             (default 0.15 = 15%)
  --recarve-window N         hysteresis/partial/forecast: consecutive
                             gainful dispatches required before
                             re-carving (default 2)

Forecast flags (serve): a windowed EWMA over observed arrivals predicts
each workload class's share of the near-future mix. The forecast feeds
--recarve forecast (proactive re-carves) and cost-gates side-carve
merges: a main-busy split pod absorbs its drained side carve as soon as
the forecast says the side's class won't return, instead of waiting for
the whole pod to idle. With the knob off no forecaster runs and reports
are byte-identical to pre-forecast output.
  --forecast-window S        EWMA time constant in virtual seconds
                             (default 8): how far back the mix is
                             remembered — small values react within a
                             few arrivals, large ones smooth bursts.
                             --recarve forecast without this flag gets
                             the default window automatically

Scheduler flags (serve): every run prints its effective config as one
`serve: batch=... plan=... recarve=... dispatch=...` line, so a run is
reproducible from its log.
  --dispatch POLICY          which pod serves each batch: least-loaded
                             (default; earliest-free pod) or
                             earliest-finish (minimize predicted
                             completion — plan-aware, useful once pods
                             have different sizes)
  --co-batch                 replica co-batching: scatter a closed batch
                             across its carve's batch-replica groups
                             (each group serves ceil(B/R) requests
                             concurrently) instead of queueing the whole
                             batch on one group
  --rebalance POLICY         cross-pod machine migration: never (default)
                             or gain (migrate an idle machine toward a
                             pod whose traffic the cost model predicts
                             gains from one more machine; needs
                             --plan auto and >= 2 pods)
  --rebalance-threshold F    gain: minimum predicted fractional gain
                             (default 0.15 = 15%)
  --rebalance-window N       gain: consecutive gainful dispatches before
                             migrating (default 2)
  --scheduler MODE           scheduler data structures: indexed (default;
                             indexed event heap, memoized pricing,
                             O(log P) pod selection) or linear (the naive
                             reference path). Both modes produce
                             bit-identical reports; linear exists for
                             cross-checking and bisection

Comm-optimization flags (serve): the comm-layer optimization pass. With
every knob at its default the priced schedules are bit-identical to the
baseline; when any knob is on, the report gains a `comm` line (modeled
traffic, NIC busy time, fused transfers).
  --nic-schedule             contention-aware NIC chunk scheduling: price
                             inter-machine transfers on a per-NIC TDMA
                             timeline (only flows that actually contend
                             share the wire) instead of the constant
                             fair-share divisor
  --compress F               inter-machine activation compression: wire
                             bytes scale by F in (0, 1] (default 1.0 =
                             off); intra-machine hops are never
                             compressed
  --cfg-fuse                 fuse the two CFG branches' identical-shape
                             inter-machine transfers (halves per-transfer
                             latency and rendezvous; a plan opts in only
                             with cfg-degree 2 and machine-aligned
                             groups)

Stage-pipeline flags (serve): decouple each request into its stage DAG
(text-encode -> diffusion -> VAE decode) and give every stage class its
own pods, so request n's denoising overlaps request n-1's decode. With
--stages off the monolithic loop runs and the report is byte-identical
to the pre-stage output; when on, the report gains a `stages` section
(overlap time, per-stage-class dispatches, queue depths).
  --stages                   split the fleet's pods across the stage
                             classes (balanced: 1 encode pod, 1 decode
                             pod, the rest diffusion; needs >= 3 pods)
                             and flow requests through bounded
                             inter-stage queues; --rebalance gain
                             arbitrates machines between stage classes
  --stage-queue N            inter-stage queue bound per downstream pod
                             class (default 8): an upstream stage whose
                             successor queue is full blocks instead of
                             dispatching

Quality-elastic serving flags (serve): approximate inference modes as a
scheduler dimension. With both flags unset every batch serves exact
(Full) and the report is byte-identical to the pre-quality output; when
either is set, the report gains a `quality_histogram` of modes served
under.
  --quality-floor F          admission floor in (0, 1]: a batch landing
                             on a backlogged pod degrades to the
                             cheapest quality mode whose score still
                             clears F (full=1.0, displaced=0.9,
                             fastattn@0.50=0.75, steps/2=0.5); an idle
                             pod always serves full quality
  --quality MODE             force one mode for every batch, overriding
                             the floor: full, displaced (one-step-stale
                             remote patches, DistriFusion-style),
                             fastattn[:R] (windowed attention keeping
                             ratio R of KV tiles, default 0.5),
                             reduced[:K] (1/K denoising steps + dropped
                             CFG branch on distillable workloads,
                             default 2)
";

fn workload_by_name(name: &str) -> Result<Workload> {
    Workload::paper_suite()
        .into_iter()
        .find(|w| w.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown workload '{name}'"))
}

/// The plan mode the flags resolve to: `--cfg-degree`, `--pp-degree` or
/// `--batch-replicas` without `--plan` implies `--plan fixed`.
fn effective_plan(args: &Args) -> Result<&str> {
    let cfg_degree = args.usize_or("cfg-degree", 1)?;
    let pp_degree = args.usize_or("pp-degree", 1)?;
    let reps = args.usize_or("batch-replicas", 1)?;
    let default_plan = if cfg_degree > 1 || pp_degree > 1 || reps > 1 {
        "fixed"
    } else {
        "single"
    };
    Ok(args.enum_or("plan", default_plan, &["single", "auto", "fixed"])?)
}

/// The [`PlanPolicy`] the plan flags resolve to. `heads` sets the gcd
/// placement rule for fixed plans (24 for the whole paper suite);
/// `total_gpus` is the pod size the fixed degrees must tile.
fn plan_policy_for(args: &Args, total_gpus: usize, heads: usize) -> Result<PlanPolicy> {
    match effective_plan(args)? {
        "single" => Ok(PlanPolicy::SingleMesh),
        "auto" => Ok(PlanPolicy::Auto),
        "fixed" => {
            let cfg_degree = args.usize_or("cfg-degree", 1)?;
            let pp_degree = args.usize_or("pp-degree", 1)?;
            let reps = args.usize_or("batch-replicas", 1)?;
            let groups = cfg_degree * pp_degree * reps;
            anyhow::ensure!(
                groups > 0 && total_gpus % groups == 0,
                "cfg-degree x pp-degree x batch-replicas ({groups}) must divide the \
                 pod's {total_gpus} GPUs"
            );
            Ok(PlanPolicy::Fixed(ParallelSpec::with_gcd_placement_pp(
                cfg_degree,
                pp_degree,
                reps,
                total_gpus / groups,
                heads,
            )))
        }
        other => unreachable!("--plan '{other}' already validated by enum_or"),
    }
}

/// Fold the plan flags into a [`ServeConfig`] and build the service
/// model it describes. `heads` sets the gcd placement rule for fixed
/// plans (24 for the whole paper suite).
fn service_for(
    args: &Args,
    cluster: ClusterSpec,
    algo: SpAlgo,
    heads: usize,
) -> Result<SimService> {
    let (patches, patches_auto) = patches_flags(args)?;
    let config = ServeConfig::new()
        .plan(plan_policy_for(args, cluster.total_gpus(), heads)?)
        .patches(patches)
        .patches_auto(patches_auto);
    Ok(config.sim_service(cluster, algo)?)
}

/// The `--patches` flag: a fixed pipeline patch count, or `auto` for
/// the per-workload closed-form argmin
/// ([`swiftfusion::analysis::choose_patches`]). Returns
/// `(fixed count, auto?)`.
fn patches_flags(args: &Args) -> Result<(usize, bool)> {
    if args.get("patches") == Some("auto") {
        return Ok((swiftfusion::analysis::DEFAULT_PATCHES, true));
    }
    let patches = args.usize_or("patches", swiftfusion::analysis::DEFAULT_PATCHES)?;
    anyhow::ensure!(patches > 0, "--patches must be >= 1");
    Ok((patches, false))
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::load_default()?;
    let m = rt.manifest();
    println!("artifacts dir: {}", m.dir.display());
    println!("configs:");
    for c in &m.configs {
        println!(
            "  {:<8} B={} L={} H={} D={} hidden={} depth={} mesh={} chunk={}",
            c.name, c.b, c.l, c.h, c.d, c.hidden, c.depth, c.mesh, c.chunk
        );
    }
    println!("artifacts: {}", m.artifacts.len());
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let cfg_name = args.str_or("config", "small4");
    let rt = Runtime::load_default()?;
    let cfg = Arc::new(rt.manifest().config(cfg_name)?.clone());
    let mesh = cfg.mesh;
    // pick a 2-machine split of the mesh
    let (n, m) = (2, mesh / 2);
    let cluster = ClusterSpec::new(n, m);
    let q = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 1);
    let k = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 2);
    let v = Tensor::random(&[cfg.b, cfg.l, cfg.h, cfg.d], 3);
    let oracle = rt
        .handle()
        .call(&format!("attn_full_{cfg_name}"), &[q.clone(), k.clone(), v.clone()])?
        .remove(0);
    let ls = cfg.l / mesh;
    println!("validating {mesh}-rank distributed attention vs oracle ({cfg_name})");
    for algo in SpAlgo::ALL {
        let pu = match algo {
            SpAlgo::Ring => 1,
            SpAlgo::Ulysses => mesh,
            _ => swiftfusion::config::gcd(mesh, cfg.h),
        };
        let params = SpParams {
            shape: AttnShape::new(cfg.b, cfg.l, cfg.h, cfg.d),
            chunk: cfg.chunk,
            mesh: algo.mesh(&cluster, SpDegrees::new(pu, mesh / pu)),
        };
        let mode = ExecMode::Numeric { rt: rt.handle(), cfg: Arc::clone(&cfg) };
        let run = run_cluster(&cluster, &mode, |ctx| {
            let r = ctx.rank;
            let qs = Buf::Real(q.slice(1, r * ls, (r + 1) * ls).unwrap());
            let ks = Buf::Real(k.slice(1, r * ls, (r + 1) * ls).unwrap());
            let vs = Buf::Real(v.slice(1, r * ls, (r + 1) * ls).unwrap());
            algo.run(ctx, &params, qs, ks, vs).into_tensor()
        });
        let mut max_diff = 0f32;
        for (rank, got) in run.outputs.iter().enumerate() {
            let want = oracle.slice(1, rank * ls, (rank + 1) * ls)?;
            max_diff = max_diff.max(got.max_abs_diff(&want));
        }
        let status = if max_diff < 1e-4 { "OK " } else { "FAIL" };
        println!(
            "  {status} {:<12} (U{}R{})  max|Δ| = {max_diff:.2e}  sim {}",
            algo.name(),
            pu,
            mesh / pu,
            fmt_time(run.makespan())
        );
        if max_diff >= 1e-4 {
            bail!("{} diverged from oracle", algo.name());
        }
    }
    Ok(())
}

fn cmd_bench_layer(args: &Args) -> Result<()> {
    let n = args.usize_or("machines", 4)?;
    let m = args.usize_or("gpus", 8)?;
    let wname = args.str_or("workload", "cogvideox-20s");
    let w = workload_by_name(wname)?.aligned_to(n * m * 64);
    let cluster = ClusterSpec::new(n, m);
    println!(
        "single attention layer, {wname} (L={} H={} D={}) on {n}x{m}:",
        w.shape.l, w.shape.h, w.shape.d
    );
    let algos: Vec<SpAlgo> = match args.get("algo") {
        Some(a) => vec![SpAlgo::from_name(a)?],
        None => SpAlgo::ALL.to_vec(),
    };
    let mut baseline = None;
    for algo in algos {
        let svc = service_for(args, cluster.clone(), algo, w.shape.h)?;
        let spec = svc.resolve_spec(&w);
        let t = match &spec {
            None => svc.layer_time(&w, w.shape.b),
            Some(spec) => svc.plan_layer_time(spec, &w, w.shape.b),
        };
        if algo == SpAlgo::Usp {
            baseline = Some(t);
        }
        let speedup = baseline
            .map(|b| format!("{:.2}x vs USP", b / t))
            .unwrap_or_default();
        let plan_note = spec.map(|s| format!("  [{}]", s.label())).unwrap_or_default();
        println!("  {:<12} {:>12}  {speedup}{plan_note}", algo.name(), fmt_time(t));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.usize_or("machines", 4)?;
    let m = args.usize_or("gpus", 8)?;
    let pods = args.usize_or("pods", 1)?;
    let nreq = args.usize_or("requests", 32)?;
    let rate = args.f64_or("rate", 0.05)?;
    let algo = SpAlgo::from_name(args.str_or("algo", "swiftfusion"))?;
    let max_batch = args.usize_or("max-batch", 2)?;
    let threshold = args.f64_or("recarve-threshold", 0.15)?;
    let window = args.usize_or("recarve-window", 2)?;
    anyhow::ensure!(window > 0, "--recarve-window must be >= 1");
    let recarve_name = args.enum_or(
        "recarve",
        "free",
        &["free", "never", "on-idle", "hysteresis", "partial", "forecast"],
    )?;
    let recarve_cli = RecarvePolicy::from_name(recarve_name, threshold, window)
        .expect("name validated by enum_or");
    let dispatch_name =
        args.enum_or("dispatch", "least-loaded", &["least-loaded", "earliest-finish"])?;
    let dispatch =
        dispatch_policy_from_name(dispatch_name).expect("name validated by enum_or");
    let co_batch = args.bool_or("co-batch", false)?;
    let rb_threshold = args.f64_or("rebalance-threshold", 0.15)?;
    let rb_window = args.usize_or("rebalance-window", 2)?;
    anyhow::ensure!(rb_window > 0, "--rebalance-window must be >= 1");
    let rebalance_name = args.enum_or("rebalance", "never", &["never", "gain"])?;
    let rebalance = RebalancePolicy::from_name(rebalance_name, rb_threshold, rb_window)
        .expect("name validated by enum_or");
    let scheduler_name = args.enum_or("scheduler", "indexed", &["indexed", "linear"])?;
    let scheduler =
        SchedulerMode::from_name(scheduler_name).expect("name validated by enum_or");
    let (patches, patches_auto) = patches_flags(args)?;
    let nic_schedule = args.bool_or("nic-schedule", false)?;
    let compress = args.f64_or("compress", 1.0)?;
    anyhow::ensure!(
        compress > 0.0 && compress <= 1.0,
        "--compress must be in (0, 1]"
    );
    let cfg_fuse = args.bool_or("cfg-fuse", false)?;
    let quality_floor = if args.has("quality-floor") {
        let f = args.f64_or("quality-floor", 1.0)?;
        anyhow::ensure!(
            f > 0.0 && f <= 1.0,
            "--quality-floor must be in (0, 1]"
        );
        Some(f)
    } else {
        None
    };
    // the typed NameError lists every valid spelling on a misspelling
    let quality = args.choice_or("quality", QualityMode::from_name)?;
    let stages_on = args.bool_or("stages", false)?;
    let stage_queue = args.usize_or("stage-queue", 8)?;
    anyhow::ensure!(stage_queue >= 1, "--stage-queue must be >= 1");

    let mut router = Router::new(n, m, pods, algo);
    // Comm-opt knobs ride on each pod's NetSpec: the single-model path
    // prices with a clone of pod 0's cluster and the fleet path builds a
    // model per pod footprint, so mutating the pods here covers both.
    for pod in &mut router.pods {
        pod.cluster.net.nic_schedule = nic_schedule;
        pod.cluster.net.inter_compress = compress;
        pod.cluster.net.cfg_fuse = cfg_fuse;
    }
    // A preset is the config base; every explicitly-passed flag still
    // overrides its knob. Without --preset the pre-preset behaviour is
    // reproduced exactly: every knob is applied, flag defaults included.
    let preset_name = if args.has("preset") {
        Some(args.enum_or("preset", "latency", &["throughput", "latency", "quality"])?)
    } else {
        None
    };
    let mut config = match preset_name {
        Some(name) => ServeConfig::preset(name),
        None => ServeConfig::new(),
    };
    let explicit = |flag: &str| preset_name.is_none() || args.has(flag);
    // every paper-suite workload has 24 heads
    let plan_flags = args.has("plan")
        || args.has("cfg-degree")
        || args.has("pp-degree")
        || args.has("batch-replicas");
    let plan_label = if preset_name.is_some() && !plan_flags {
        // every preset plans with the auto chooser
        "auto".to_string()
    } else {
        effective_plan(args)?.to_string()
    };
    if preset_name.is_none() || plan_flags {
        config =
            config.plan(plan_policy_for(args, router.pods[0].cluster.total_gpus(), 24)?);
    }
    if explicit("max-batch") {
        config = config.batch(BatchPolicy { max_batch, window: 30.0 });
    }
    config = config.patches(patches).patches_auto(patches_auto);
    if explicit("recarve") || args.has("recarve-threshold") || args.has("recarve-window")
    {
        config = config.recarve(recarve_cli);
    }
    if explicit("dispatch") {
        config = config.dispatch(dispatch);
    }
    if explicit("co-batch") {
        config = config.co_batch(co_batch);
    }
    if explicit("rebalance")
        || args.has("rebalance-threshold")
        || args.has("rebalance-window")
    {
        config = config.rebalance(rebalance);
    }
    if explicit("scheduler") {
        config = config.scheduler(scheduler);
    }
    if let Some(f) = quality_floor {
        config = config.quality_floor(f);
    }
    if let Some(q) = quality {
        config = config.quality(q);
    }
    if stages_on {
        anyhow::ensure!(
            pods >= 3,
            "--stages needs at least 3 pods (one per stage class)"
        );
        config = config
            .stages(StagePolicy::new(StagePlacement::balanced(pods)).queue_bound(stage_queue));
    }
    // The effective (post-preset) policies drive everything below.
    let recarve = config.recarve.policy.unwrap_or(RecarvePolicy::Free);
    // a one-pod fleet has nothing to migrate between: drop a preset's
    // re-balancing rather than erroring on the preset's behalf
    if preset_name.is_some() && pods < 2 && !args.has("rebalance") {
        config = config.rebalance(RebalancePolicy::Never);
    }
    let rebalance = config.rebalance.policy;
    if args.has("forecast-window") {
        let fw = args.f64_or("forecast-window", DEFAULT_FORECAST_WINDOW)?;
        anyhow::ensure!(fw > 0.0, "--forecast-window must be > 0");
        config = config.forecast_window(fw);
    }
    // --recarve forecast without a forecaster would silently degrade to
    // plain hysteresis; give it the default window instead.
    if matches!(recarve, RecarvePolicy::Forecast { .. }) && config.forecast.is_none() {
        config = config.forecast_window(DEFAULT_FORECAST_WINDOW);
    }
    // Only auto planning ever changes a pod's preferred plan; under
    // single/fixed the preferred spec is constant, so any re-carving
    // policy is inert. Say so instead of letting a zero-recarve run
    // read as "the policy never helped".
    if recarve != RecarvePolicy::Free && plan_label != "auto" {
        eprintln!(
            "note: --recarve {recarve} has no effect with --plan {plan_label}: the \
             preferred plan never changes, so no transition can ever fire \
             (use --plan auto)"
        );
    }
    let reqs = TraceGen::new(42, rate, Workload::paper_suite()).take(nreq);
    println!(
        "serving {nreq} requests on {n}x{m} ({pods} pod(s), {})",
        algo.name(),
    );
    // the effective-config line: the whole run is reproducible from it
    println!("{}", config.summary());
    let report = if rebalance != RebalancePolicy::Never {
        // pods change size at runtime: price each by its live footprint
        anyhow::ensure!(
            plan_label == "auto",
            "--rebalance gain needs --plan auto (the fleet re-plans each pod \
             for its new footprint)"
        );
        anyhow::ensure!(pods >= 2, "--rebalance gain needs at least 2 pods");
        let mut fleet = SimFleet::auto(algo, patches);
        if patches_auto {
            fleet = fleet.auto_patches();
        }
        ServeSession::with_fleet(config, &fleet).run(&mut router, reqs)
    } else {
        let svc = config.sim_service(router.pods[0].cluster.clone(), algo)?;
        ServeSession::new(config, &svc).run(&mut router, reqs)
    };
    let mut metrics = report.metrics;
    if !report.rejected.is_empty() {
        println!("rejected {} request(s):", report.rejected.len());
        for (id, reason) in &report.rejected {
            println!("  #{id}: {reason}");
        }
    }
    if !report.plan_histogram.is_empty() {
        println!("plans served under (recarve policy: {recarve}):");
        for (label, count) in &report.plan_histogram {
            println!("  {label:<28} {count:>5} request(s)");
        }
    }
    if !report.quality_histogram.is_empty() {
        println!("quality modes served under:");
        for (label, count) in &report.quality_histogram {
            println!("  {label:<28} {count:>5} request(s)");
        }
    }
    if report.co_batched > 0 {
        println!("co-batched dispatches: {}", report.co_batched);
    }
    if let Some(c) = &report.comm {
        println!(
            "comm (modeled pricing runs): intra {:.3} GB, inter {:.3} GB wire, \
             nic busy {}, fused transfers {}",
            (c.traffic.intra_in + c.traffic.intra_out) / 1e9,
            (c.traffic.inter_in + c.traffic.inter_out) / 1e9,
            fmt_time(c.nic_busy),
            c.fused_transfers
        );
    }
    if !report.rebalances.is_empty() {
        println!("cross-pod re-balances: {}", report.rebalances.len());
        for ev in &report.rebalances {
            println!(
                "  t={:>10}: machine pod {} -> pod {} (now {} / {} machine(s))",
                fmt_time(ev.at),
                ev.from_pod,
                ev.to_pod,
                ev.from_machines,
                ev.to_machines
            );
        }
    }
    if let Some(st) = &report.stages {
        println!(
            "stage pipeline: overlap {} across {} stage dispatch(es)",
            fmt_time(st.overlap_time),
            st.dispatches.values().sum::<usize>()
        );
        for (label, count) in &st.dispatches {
            println!("  {label:<40} {count:>5} dispatch(es)");
        }
        for (class, depths) in &st.queue_depth {
            let peak = depths.keys().max().copied().unwrap_or(0);
            println!("  {class} queue peak depth {peak}");
        }
    }
    let rc = &report.recarve;
    if rc.recarve_count > 0 {
        println!(
            "re-carves: {} (drain {}, re-setup {})",
            rc.recarve_count,
            fmt_time(rc.drain_time),
            fmt_time(rc.setup_time)
        );
        for (pod, e) in &rc.epochs {
            println!(
                "  pod {pod} epoch {}: {:<28} opened {:>10}  served {:>5}",
                e.index,
                e.label(),
                fmt_time(e.started_at),
                e.served
            );
        }
    }
    if rc.partial_splits > 0 {
        println!(
            "partial re-carves: {} split(s), {} merge(s)",
            rc.partial_splits, rc.merges
        );
        for (pod, g) in &rc.group_epochs {
            let merged = g
                .merged_at
                .map(|t| format!("merged {}", fmt_time(t)))
                .unwrap_or_else(|| "live".to_string());
            println!(
                "  pod {pod} side {}: {:<28} machines [{}, {})  opened {:>10}  \
                 served {:>5}  {merged}",
                g.index,
                g.label(),
                g.base_machine,
                g.base_machine + g.machines,
                fmt_time(g.started_at),
                g.served
            );
        }
    }
    if report.co_batched_cross > 0 {
        println!(
            "cross-epoch co-batched dispatches: {}",
            report.co_batched_cross
        );
    }
    print!("{}", metrics.report());
    Ok(())
}

/// Export the per-rank virtual timeline of one attention layer as a
/// chrome://tracing JSON file (load in chrome://tracing or Perfetto).
fn cmd_trace(args: &Args) -> Result<()> {
    use swiftfusion::cluster::clock::TimeKind;
    use swiftfusion::util::json::{to_string, Json};
    use std::collections::BTreeMap;

    let n = args.usize_or("machines", 4)?;
    let m = args.usize_or("gpus", 8)?;
    let algo = SpAlgo::from_name(args.str_or("algo", "swiftfusion"))?;
    let wname = args.str_or("workload", "cogvideox-20s");
    let out_path = args.str_or("out", "/tmp/swiftfusion_trace.json").to_string();
    let w = workload_by_name(wname)?.aligned_to(n * m * 64);
    let cluster = ClusterSpec::new(n, m);
    let p = cluster.total_gpus();
    let pu = match algo {
        SpAlgo::Ring => 1,
        SpAlgo::Usp => swiftfusion::config::gcd(m, w.shape.h),
        _ => swiftfusion::config::gcd(p, w.shape.h),
    };
    let params = SpParams {
        shape: w.shape,
        chunk: w.shape.l / p,
        mesh: algo.mesh(&cluster, SpDegrees::new(pu, p / pu)),
    };
    let shape = w.shape;
    let run = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
        let s = Buf::Shape(vec![shape.b, shape.l / p, shape.h, shape.d]);
        algo.run(ctx, &params, s.clone(), s.clone(), s);
    });

    let mut events = Vec::new();
    for (rank, clock) in run.clocks.iter().enumerate() {
        for &(start, end, kind) in clock.spans() {
            let name = match kind {
                TimeKind::Compute => "compute",
                TimeKind::CommWait => "comm-wait",
                TimeKind::Sync => "sync",
                TimeKind::Overhead => "overhead",
            };
            let mut ev = BTreeMap::new();
            ev.insert("name".into(), Json::Str(name.into()));
            ev.insert("ph".into(), Json::Str("X".into()));
            ev.insert("ts".into(), Json::Num(start * 1e6)); // µs
            ev.insert("dur".into(), Json::Num((end - start) * 1e6));
            ev.insert("pid".into(), Json::Num(cluster.machine_of(rank) as f64));
            ev.insert("tid".into(), Json::Num(rank as f64));
            events.push(Json::Obj(ev));
        }
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert(
        "displayTimeUnit".into(),
        Json::Str("ms".into()),
    );
    std::fs::write(&out_path, to_string(&Json::Obj(root)))?;
    println!(
        "traced {} ({}) on {n}x{m}: makespan {}, {} spans -> {out_path}",
        w.name,
        algo.name(),
        fmt_time(run.makespan()),
        run.clocks.iter().map(|c| c.spans().len()).sum::<usize>()
    );
    Ok(())
}

fn cmd_volumes(args: &Args) -> Result<()> {
    let n = args.usize_or("machines", 4)?;
    let m = args.usize_or("gpus", 8)?;
    let h = args.usize_or("heads", 24)?;
    let shape = AttnShape::new(1, 96_000, h, 64);
    println!("inter-machine volume per GPU (Appendix D), N={n} M={m} H={h}:");
    let p = n * m;
    for algo in SpAlgo::ALL {
        let pu = match algo {
            SpAlgo::Ring => 1,
            SpAlgo::Ulysses => p,
            SpAlgo::Usp => swiftfusion::config::gcd(m, h),
            _ => swiftfusion::config::gcd(p, h),
        };
        let deg = SpDegrees::new(pu, p / pu);
        let v = swiftfusion::analysis::inter_volume(algo, &shape, n, m, deg);
        println!(
            "  {:<12} (U{:<2}R{:<2}) {:>12}",
            algo.name(),
            deg.pu,
            deg.pr,
            fmt_bytes(v * 4.0)
        );
    }
    Ok(())
}
