//! USP and TAS: the 2D Ulysses × Ring compositions.
//!
//! Both run the *same* dataflow — all-to-all QKV inside the Ulysses
//! group, Ring Attention across the Ring group, all-to-all O back — and
//! differ **only** in mesh placement (the paper's §4.2 insight):
//!
//! * **USP** (`Placement::UlyssesIntra`): Ulysses groups sit inside a
//!   machine (cheap all-to-alls) but the Ring crosses machines, and Ring
//!   volume does not shrink with more machines → Challenge 1.
//! * **TAS** (`Placement::UlyssesInter`): Ulysses groups span machines
//!   (volume ~4·BLHD/P_u, shrinking), the Ring stays on NVSwitch. The
//!   inter-machine all-to-all is *not overlapped* — that residual cost is
//!   what Torus Attention ([`super::torus`]) removes and
//!   [`super::swiftfusion`] folds into Algorithm 1's one-sided schedule.
//!
//! Both run unchanged on carved sub-meshes (`crate::cluster::plan`), so
//! the same code serves full-cluster baselines and hybrid-plan stages;
//! `rust/tests/sp_property.rs` proves either placement exact against
//! the plain-softmax oracle in `ExecMode::HostNumeric`.

use crate::cluster::exec::RankCtx;
use crate::comm::Buf;

use super::ring::ring_attention_group;
use super::tiles::AttnAccum;
use super::ulysses::all_to_all;
use super::SpParams;

/// Shared USP/TAS driver; behaviour is fully determined by
/// `p.mesh.placement`.
pub fn usp_like(ctx: &mut RankCtx, p: &SpParams, q: Buf, k: Buf, v: Buf) -> Buf {
    let ugroup = p.mesh.ulysses_group(ctx.rank);
    let rgroup = p.mesh.ring_group(ctx.rank);
    let flows = ctx.nic_flows(&p.mesh.ranks());

    // Phase 1: Ulysses all-to-alls gather sequence / scatter heads within
    // the Ulysses group.
    let qg = all_to_all(ctx, &ugroup, &q, 2, 1, "u.q", flows);
    let kg = all_to_all(ctx, &ugroup, &k, 2, 1, "u.k", flows);
    let vg = all_to_all(ctx, &ugroup, &v, 2, 1, "u.v", flows);

    // Phase 2: Ring Attention across the Ring group on the gathered
    // shards (KV blocks circulate; Q stays).
    let mut accum = AttnAccum::new(ctx, &qg, p.chunk);
    ring_attention_group(ctx, &mut accum, &rgroup, kg, vg, flows);
    let o = accum.finish(ctx);

    // Phase 3: restore the original [B, L/P, H, D] layout.
    all_to_all(ctx, &ugroup, &o, 1, 2, "u.o", flows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::exec::{run_cluster, ExecMode};
    use crate::config::{AttnShape, ClusterSpec, SpDegrees};
    use crate::sp::SpAlgo;

    fn run_one(algo: SpAlgo, n: usize, m: usize, pu: usize) -> f64 {
        let cluster = ClusterSpec::new(n, m);
        let total = n * m;
        let p = SpParams {
            shape: AttnShape::new(1, 65536, 8, 64),
            chunk: 65536 / total,
            mesh: algo.mesh(&cluster, SpDegrees::new(pu, total / pu)),
        };
        let run = run_cluster(&cluster, &ExecMode::Timing, |ctx| {
            let s = Buf::Shape(vec![1, p.shard_len(), 8, 64]);
            let out = algo.run(ctx, &p, s.clone(), s.clone(), s);
            assert_eq!(out.shape(), &[1, p.shard_len(), 8, 64]);
        });
        run.makespan()
    }

    #[test]
    fn usp_and_tas_run_and_preserve_shapes() {
        let t_usp = run_one(SpAlgo::Usp, 2, 2, 2);
        let t_tas = run_one(SpAlgo::Tas, 2, 2, 2);
        assert!(t_usp > 0.0 && t_tas > 0.0);
    }

    #[test]
    fn tas_beats_usp_on_many_machines() {
        // Paper Fig. 7 at the paper's geometry (4 machines x 8 GPUs, the
        // NIC shared 8 ways): USP's constant-volume inter-machine ring
        // can't hide behind the per-rank compute slice anymore, while
        // TAS's inter volume shrinks with P_u. On friendlier meshes
        // (fewer GPUs per NIC) USP's overlapped ring can win — that's
        // the `appendix_d_equal_volume_case_is_a_wash` test below.
        let t_usp = run_one(SpAlgo::Usp, 4, 8, 8);
        let t_tas = run_one(SpAlgo::Tas, 4, 8, 8);
        assert!(
            t_tas < t_usp,
            "TAS ({t_tas}) must beat USP ({t_usp}) at 4x8"
        );
    }

    #[test]
    fn appendix_d_equal_volume_case_is_a_wash() {
        // With P_u = 2 < N = 4 the Appendix-D volumes of USP and TAS are
        // comparable (both ~1.5·BLHD/N per GPU) — neither should win big.
        let t_usp = run_one(SpAlgo::Usp, 4, 2, 2);
        let t_tas = run_one(SpAlgo::Tas, 4, 2, 2);
        let ratio = t_tas / t_usp;
        assert!(
            (0.6..1.7).contains(&ratio),
            "expected a wash, got TAS/USP = {ratio}"
        );
    }

    #[test]
    fn usp_competitive_at_two_machines() {
        // Paper §5.2 observation 1: at M=2 machines TAS has no volume
        // advantage and its all-to-all is not overlapped, so it should
        // NOT be dramatically better (and can be worse).
        let t_usp = run_one(SpAlgo::Usp, 2, 4, 4);
        let t_tas = run_one(SpAlgo::Tas, 2, 4, 4);
        assert!(
            t_tas > 0.8 * t_usp,
            "at N=2, TAS ({t_tas}) shouldn't crush USP ({t_usp})"
        );
    }
}
