//! Sequence-parallel attention algorithms — the paper's subject matter.
//!
//! Six algorithms over the same per-rank contract: each rank holds the
//! sequence shard `[B, L/P, H, D]` of Q, K, V and must return the
//! *attention output for its own shard*, `[B, L/P, H, D]`, numerically
//! equal to single-device attention (validated in `rust/tests/`):
//!
//! | algorithm  | module      | communication structure                      |
//! |------------|-------------|----------------------------------------------|
//! | Ring       | [`ring`]    | ring KV exchange over all P ranks (§2.2)     |
//! | Ulysses    | [`ulysses`] | 4 all-to-alls over all P ranks (§2.2)        |
//! | USP        | [`unified`] | Ulysses intra-machine + Ring inter (§2.2)    |
//! | TAS        | [`unified`] | Ulysses inter-machine + Ring intra (§4.2)    |
//! | Torus      | [`torus`]   | chunked all-to-all overlap (§4.3)            |
//! | SwiftFusion| [`swiftfusion`] | Algorithm 1: one-sided Torus+Ulysses+Ring |
//!
//! On top of the per-mesh algorithms, [`hybrid`] runs classifier-free
//! guidance branches on disjoint carved groups and merges them with the
//! CFG combine (the `cfg` dimension of the hybrid `cfg × pp × sp` plan
//! space), and [`pipefusion`] implements PipeFusion's displaced patch
//! pipeline (the `pp` dimension): DiT layers partitioned across
//! pipeline stages, the sequence streaming between them as patches, and
//! off-stage KV served from one-step-stale activations.
//!
//! All algorithms decompose attention into *tile* operations
//! ([`tiles`]) on `[B, chunk, g, D]` blocks — the same universal
//! decomposition the paper's Algorithm 2 kernel provides (multiple
//! Q/KV tensors with carried softmax state), so numeric mode maps 1:1
//! onto the AOT Pallas artifacts.

pub mod displaced;
pub mod hybrid;
pub mod pipefusion;
pub mod ring;
pub mod swiftfusion;
pub mod tiles;
pub mod torus;
pub mod ulysses;
pub mod unified;

use crate::cluster::exec::RankCtx;
use crate::cluster::{Mesh2D, Placement};
use crate::comm::Buf;
use crate::config::{AttnShape, ClusterSpec, SpDegrees};

/// Parameters shared by every SP run.
#[derive(Debug, Clone)]
pub struct SpParams {
    /// Global attention shape (the full [B, L, H, D], before sharding).
    pub shape: AttnShape,
    /// Sequence tile granularity. Numeric mode: must equal the manifest
    /// config's `chunk` (= L / mesh). Timing mode: free.
    pub chunk: usize,
    /// The device mesh (degrees + placement).
    pub mesh: Mesh2D,
}

impl SpParams {
    pub fn total_ranks(&self) -> usize {
        self.mesh.total()
    }

    /// Local sequence length per rank.
    pub fn shard_len(&self) -> usize {
        self.shape.l / self.total_ranks()
    }
}

/// The algorithm selector used by benches, the CLI, and the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpAlgo {
    Ring,
    Ulysses,
    /// USP [5]: Ulysses intra-machine, Ring inter-machine.
    Usp,
    /// Topology-aware scheduling only (SwiftFusion idea 1, two-sided).
    Tas,
    /// TAS + Torus overlap, still two-sided NCCL-style (ablation point).
    TorusNccl,
    /// Full SwiftFusion: TAS + Torus + one-sided (Algorithm 1).
    SwiftFusion,
    /// DistriFusion-style displaced patch parallelism: one patch per
    /// rank, remote KV served one-step-stale in steady state
    /// ([`displaced`]). The stateless `run` entry executes the
    /// synchronous warm-up schedule (oracle-exact); not in [`Self::ALL`]
    /// because the exact-algorithm sweeps (property tests, volume
    /// cross-validation) cover the six always-fresh algorithms.
    DisplacedPatch,
}

impl SpAlgo {
    pub const ALL: [SpAlgo; 6] = [
        SpAlgo::Ring,
        SpAlgo::Ulysses,
        SpAlgo::Usp,
        SpAlgo::Tas,
        SpAlgo::TorusNccl,
        SpAlgo::SwiftFusion,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SpAlgo::Ring => "ring",
            SpAlgo::Ulysses => "ulysses",
            SpAlgo::Usp => "usp",
            SpAlgo::Tas => "tas",
            SpAlgo::TorusNccl => "torus-nccl",
            SpAlgo::SwiftFusion => "swiftfusion",
            SpAlgo::DisplacedPatch => "displaced-patch",
        }
    }

    /// Parse a CLI spelling. Misspellings return a typed
    /// [`crate::config::NameError`] listing every algorithm name.
    pub fn from_name(s: &str) -> Result<Self, crate::config::NameError> {
        if s == "displaced-patch" {
            return Ok(SpAlgo::DisplacedPatch);
        }
        Self::ALL.iter().copied().find(|a| a.name() == s).ok_or_else(|| {
            let mut valid: Vec<&str> = Self::ALL.iter().map(|a| a.name()).collect();
            valid.push("displaced-patch");
            crate::config::NameError::new("sp algorithm", s, &valid)
        })
    }

    /// Mesh placement this algorithm assumes (§4.2): USP puts Ulysses
    /// intra-machine; the SwiftFusion family puts Ring intra-machine.
    pub fn placement(&self) -> Placement {
        match self {
            SpAlgo::Usp => Placement::UlyssesIntra,
            // pure Ring/Ulysses have only one group; placement is moot but
            // UlyssesInter keeps ring groups contiguous.
            _ => Placement::UlyssesInter,
        }
    }

    /// Build the mesh this algorithm would use on `cluster` for `degrees`.
    pub fn mesh(&self, cluster: &ClusterSpec, degrees: SpDegrees) -> Mesh2D {
        Mesh2D::new(cluster.clone(), degrees, self.placement())
    }

    /// Run one distributed attention layer on this rank. `q`,`k`,`v` are
    /// the rank's sequence shards `[B, L/P, H, D]`; returns the rank's
    /// output shard `[B, L/P, H, D]`.
    pub fn run(&self, ctx: &mut RankCtx, p: &SpParams, q: Buf, k: Buf, v: Buf) -> Buf {
        match self {
            SpAlgo::Ring => ring::ring_attention_full(ctx, p, q, k, v),
            SpAlgo::Ulysses => ulysses::ulysses_attention(ctx, p, q, k, v),
            SpAlgo::Usp | SpAlgo::Tas => unified::usp_like(ctx, p, q, k, v),
            SpAlgo::TorusNccl => {
                torus::torus_attention(ctx, p, q, k, v, torus::CommStyle::TwoSided)
            }
            SpAlgo::SwiftFusion => swiftfusion::swiftfusion_attention(ctx, p, q, k, v),
            SpAlgo::DisplacedPatch => displaced::displaced_sync_attention(ctx, p, q, k, v),
        }
    }
}

/// Carried softmax state for one q-tile: (O', l, m) (Appendix C).
#[derive(Debug, Clone)]
pub struct AttnState {
    /// Unnormalized output O' = O · l, `[B, lq, g, D]`.
    pub o: Buf,
    /// Running softmax sum, `[B, g, lq]`.
    pub l: Buf,
    /// Running softmax max, `[B, g, lq]`.
    pub m: Buf,
}

impl AttnState {
    /// The merge monoid's identity: O'=0, l=0, m=-inf.
    pub fn zero(b: usize, lq: usize, g: usize, d: usize, numeric: bool) -> Self {
        if numeric {
            Self {
                o: Buf::Real(crate::tensor::Tensor::zeros(&[b, lq, g, d])),
                l: Buf::Real(crate::tensor::Tensor::zeros(&[b, g, lq])),
                m: Buf::Real(crate::tensor::Tensor::neg_inf(&[b, g, lq])),
            }
        } else {
            Self {
                o: Buf::Shape(vec![b, lq, g, d]),
                l: Buf::Shape(vec![b, g, lq]),
                m: Buf::Shape(vec![b, g, lq]),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_names_roundtrip() {
        for a in SpAlgo::ALL {
            assert_eq!(SpAlgo::from_name(a.name()).ok(), Some(a));
        }
        // displaced-patch is addressable by name but not part of the
        // exact-algorithm sweep
        assert_eq!(
            SpAlgo::from_name("displaced-patch").ok(),
            Some(SpAlgo::DisplacedPatch)
        );
        assert!(!SpAlgo::ALL.contains(&SpAlgo::DisplacedPatch));
        // a misspelling names every valid algorithm in the error
        let err = SpAlgo::from_name("nope").unwrap_err().to_string();
        assert!(err.contains("'nope'"), "{err}");
        for a in SpAlgo::ALL {
            assert!(err.contains(a.name()), "{err} missing {}", a.name());
        }
        assert!(err.contains("displaced-patch"), "{err}");
    }

    #[test]
    fn placements_match_paper() {
        assert_eq!(SpAlgo::Usp.placement(), Placement::UlyssesIntra);
        assert_eq!(SpAlgo::SwiftFusion.placement(), Placement::UlyssesInter);
        assert_eq!(SpAlgo::Tas.placement(), Placement::UlyssesInter);
    }

    #[test]
    fn params_shard_len() {
        let cluster = ClusterSpec::new(2, 2);
        let p = SpParams {
            shape: AttnShape::new(1, 128, 4, 16),
            chunk: 32,
            mesh: SpAlgo::Usp.mesh(&cluster, SpDegrees::new(2, 2)),
        };
        assert_eq!(p.shard_len(), 32);
        assert_eq!(p.total_ranks(), 4);
    }

    #[test]
    fn zero_state_shapes() {
        let s = AttnState::zero(2, 32, 4, 16, true);
        assert_eq!(s.o.shape(), &[2, 32, 4, 16]);
        assert_eq!(s.l.shape(), &[2, 4, 32]);
        assert_eq!(s.m.shape(), &[2, 4, 32]);
        assert!(s.m.tensor().data().iter().all(|&x| x == f32::NEG_INFINITY));
        let t = AttnState::zero(2, 32, 4, 16, false);
        assert_eq!(t.o.shape(), s.o.shape());
        assert!(!t.o.is_real());
    }
}
