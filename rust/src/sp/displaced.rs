//! DistriFusion-style **displaced patch parallelism** — the executable
//! core of the quality-elastic serving axis
//! ([`crate::config::QualityMode::Displaced`]).
//!
//! Where the six exact SP algorithms pay a fresh KV exchange inside
//! every layer, displaced patch parallelism splits the sequence into one
//! patch per rank and serves **remote patches from the previous step's
//! activations**: each rank attends its own fresh patch against its own
//! fresh KV plus the one-step-stale KV of every other rank, and the
//! allgather of fresh patches happens *asynchronously* — its results are
//! only needed at the next diffusion step, so the transfer overlaps the
//! current step's compute instead of sitting on the critical path. The
//! comm substrate is the same one-sided stale-window contract the
//! PipeFusion stale-KV path already uses ([`super::pipefusion`]):
//! exposed buffers stay readable for the epoch, and a stale read is a
//! legal read.
//!
//! ## Warm-up guarantee
//!
//! Exactly like the patch pipeline, the **first step of a generation
//! runs synchronously**: every rank blocks on the full fresh KV and the
//! step equals the plain-softmax oracle within the repo-wide 1e-4 f32
//! tile tolerance. Staleness therefore only ever appears *after* a
//! fully-correct step, bounding the steady-state error by one step of
//! input drift — the same argument (and the same `STALE_TOL` bound in
//! `rust/tests/sp_property.rs`) as stale-KV pipelining.
//!
//! The synchronous schedule doubles as the [`super::SpAlgo`] entry
//! point: [`SpAlgo::DisplacedPatch`](super::SpAlgo) has no cross-layer
//! cache in the stateless `run` contract, so `run` executes
//! [`displaced_sync_attention`] — the oracle-exact warm-up — and the
//! stale steady state lives in [`guided_displaced_step`] /
//! [`guided_displaced_generate`].
//!
//! ## DiTFastAttn-style windowed attention
//!
//! [`fastattn_attention`] implements the second approximate mode
//! ([`crate::config::QualityMode::FastAttn`]): each q tile attends only
//! the `keep_ratio` fraction of KV tiles nearest to it (a sliding
//! window, clamped at the sequence ends, always containing the tile's
//! own diagonal). The dropped attention mass bounds the error — the
//! property suite derives the tolerance from the data rather than
//! pinning a constant. `keep_ratio = 1` degenerates to the exact
//! schedule.

use anyhow::Result;

use crate::cluster::exec::{run_in_world, ExecMode, RankCtx};
use crate::cluster::plan::{BranchRole, ParallelPlan};
use crate::cluster::Mesh2D;
use crate::comm::{Buf, CommWorld};
use crate::config::AttnShape;
use crate::tensor::Tensor;

use super::hybrid::guidance_combine;
use super::tiles::AttnAccum;
use super::SpParams;

/// One-sided allgather of each rank's `own` buffer under `slot`,
/// reassembled in mesh-rank order. Every rank exposes before pulling, so
/// within one epoch all reads see the fresh buffers.
fn allgather_patches(
    ctx: &mut RankCtx,
    group: &[usize],
    local: usize,
    own: &Buf,
    slot: &str,
    flows: usize,
) -> Buf {
    let sp = group.len();
    if sp == 1 {
        return own.clone();
    }
    ctx.expose(slot, own.clone());
    let mut parts: Vec<Option<Buf>> = vec![None; sp];
    parts[local] = Some(own.clone());
    let mut pulls = Vec::new();
    for (j, &peer) in group.iter().enumerate() {
        if j != local {
            pulls.push((j, ctx.get(peer, slot, flows)));
        }
    }
    for (j, h) in pulls {
        parts[j] = Some(ctx.wait_get(h));
    }
    let bufs: Vec<Buf> = parts.into_iter().map(|b| b.unwrap()).collect();
    Buf::concat(&bufs, 1)
}

/// The synchronous (oracle-exact) displaced-patch schedule: allgather
/// the full fresh K and V, then tile-attend the rank's own patch against
/// the whole sequence. This is the warm-up step of a displaced
/// generation and the stateless [`super::SpAlgo::run`] entry for
/// [`super::SpAlgo::DisplacedPatch`].
pub fn displaced_sync_attention(ctx: &mut RankCtx, p: &SpParams, q: Buf, k: Buf, v: Buf) -> Buf {
    let group = p.mesh.ranks();
    let flows = ctx.nic_flows(&group);
    let local = group
        .iter()
        .position(|&r| r == ctx.rank)
        .expect("rank must belong to its own mesh");
    let kf = allgather_patches(ctx, &group, local, &k, "dp.sync.k", flows);
    let vf = allgather_patches(ctx, &group, local, &v, "dp.sync.v", flows);
    let mut accum = AttnAccum::new(ctx, &q, p.chunk);
    accum.absorb(ctx, &kf, &vf, None);
    accum.finish(ctx)
}

/// DiTFastAttn-style windowed attention: each q tile absorbs only the
/// `keep_ratio` fraction of global KV tiles nearest to its own position
/// (window clamped at the sequence ends, always spanning the tile's
/// diagonal). KV is allgathered exactly like the synchronous displaced
/// schedule; the saving is compute, not communication.
pub fn fastattn_attention(
    ctx: &mut RankCtx,
    p: &SpParams,
    q: Buf,
    k: Buf,
    v: Buf,
    keep_ratio: f64,
) -> Buf {
    let group = p.mesh.ranks();
    let flows = ctx.nic_flows(&group);
    let local = group
        .iter()
        .position(|&r| r == ctx.rank)
        .expect("rank must belong to its own mesh");
    let kf = allgather_patches(ctx, &group, local, &k, "dp.fa.k", flows);
    let vf = allgather_patches(ctx, &group, local, &v, "dp.fa.v", flows);
    let nt = p.shape.l / p.chunk;
    let keep = ((keep_ratio * nt as f64).ceil() as usize).clamp(1, nt);
    let mut accum = AttnAccum::new(ctx, &q, p.chunk);
    let base_tile = local * (p.shard_len() / p.chunk);
    for i in 0..accum.num_tiles() {
        let gi = base_tile + i;
        // window start: centered on the q tile, clamped into [0, nt-keep]
        let start = gi.saturating_sub(keep / 2).min(nt - keep);
        let ks = kf.slice(1, start * p.chunk, (start + keep) * p.chunk);
        let vs = vf.slice(1, start * p.chunk, (start + keep) * p.chunk);
        accum.absorb(ctx, &ks, &vs, Some(&[i]));
    }
    accum.finish(ctx)
}

/// Knobs of the displaced-patch schedules shared by warm-up and steady
/// state.
#[derive(Debug, Clone, Copy)]
pub struct DispParams {
    /// Full per-branch attention shape `[B, L, H, D]`.
    pub shape: AttnShape,
    /// Tile granularity; must divide the per-rank patch `L / sp_ranks`.
    pub chunk: usize,
}

/// One branch's per-rank result: (full fresh layer input, own output
/// shard).
type BranchResult = (Tensor, Tensor);
/// Per-rank results, tagged by branch ("c" / "u").
type BranchOut = (&'static str, BranchResult);

fn branch_out<'a>(per_rank: &'a [BranchOut], tag: &str) -> &'a BranchResult {
    per_rank
        .iter()
        .find(|(t, _)| *t == tag)
        .map(|(_, v)| v)
        .unwrap_or_else(|| panic!("missing '{tag}' branch output"))
}

/// Result of one guided diffusion step under displaced patch parallelism.
pub struct GuidedDispStep {
    /// The CFG-combined output `[B, L, H, D]`.
    pub eps: Tensor,
    /// The conditional branch's full fresh layer input — next step's
    /// stale activation cache.
    pub cond_cache: Tensor,
    /// Same for the unconditional branch.
    pub uncond_cache: Tensor,
    /// Virtual-time makespan of the step.
    pub makespan: f64,
}

/// One branch of one step on this rank: returns (full fresh layer input,
/// own output shard). `cache` is the previous step's full fresh input
/// (`None` selects the synchronous warm-up).
fn branch_step(
    ctx: &mut RankCtx,
    p: &DispParams,
    mesh: &Mesh2D,
    branch: &str,
    x: &Buf,
    cache: Option<&Buf>,
    flows: usize,
) -> (Buf, Buf) {
    let group = mesh.ranks();
    let sp = group.len();
    let local = group
        .iter()
        .position(|&r| r == ctx.rank)
        .expect("rank must belong to its own mesh");
    let ls = p.shape.l / sp;
    let own = x.slice(1, local * ls, (local + 1) * ls);
    match cache {
        // ---- warm-up: synchronous, oracle-exact ------------------------
        None => {
            let full =
                allgather_patches(ctx, &group, local, &own, &format!("dp.{branch}.sync"), flows);
            let mut accum = AttnAccum::new(ctx, &own, p.chunk);
            accum.absorb(ctx, &full, &full, None);
            let out = accum.finish(ctx);
            (full, out)
        }
        // ---- steady state: fresh own patch, one-step-stale remotes -----
        Some(cache_full) => {
            let mut accum = AttnAccum::new(ctx, &own, p.chunk);
            for j in 0..sp {
                let kv = if j == local {
                    own.clone()
                } else {
                    cache_full.slice(1, j * ls, (j + 1) * ls)
                };
                accum.absorb(ctx, &kv, &kv, None);
            }
            let out = accum.finish(ctx);
            // async allgather of the fresh patches: the result feeds the
            // *next* step's cache, so the transfer runs after (i.e.
            // overlapped with) this step's attention instead of gating it
            let full =
                allgather_patches(ctx, &group, local, &own, &format!("dp.{branch}.fresh"), flows);
            (full, out)
        }
    }
}

/// Run one guided diffusion step of displaced patch parallelism under
/// `plan` (a `pp_degree == 1` plan; each group's stage-0 mesh is the
/// patch mesh, one patch per rank). `caches` carries each branch's full
/// fresh layer input from the previous step; `None` selects the
/// synchronous warm-up schedule (oracle-exact, see the module docs). The
/// toy network is one self-attention layer per step — the same network
/// [`super::pipefusion::guided_pipefusion_oracle`] with `pp = 1`
/// evaluates exactly.
pub fn guided_displaced_step(
    plan: &ParallelPlan,
    p: &DispParams,
    cond_x: &Tensor,
    uncond_x: &Tensor,
    scale: f32,
    caches: Option<(&Tensor, &Tensor)>,
    mode: &ExecMode,
) -> Result<GuidedDispStep> {
    anyhow::ensure!(mode.is_numeric(), "displaced step needs a numeric ExecMode");
    anyhow::ensure!(
        plan.spec.pp_degree == 1,
        "displaced patch parallelism is a flat-mesh schedule (pp_degree == 1); \
         compose with the patch pipeline via SpAlgo inside a stage instead"
    );
    plan.spec.validate_workload(&p.shape)?;
    let sp = plan.spec.ranks_per_stage();
    let ls = p.shape.l / sp;
    anyhow::ensure!(
        ls > 0 && ls % p.chunk == 0,
        "chunk {} must divide the per-rank patch {} (L={} sp={})",
        p.chunk,
        ls,
        p.shape.l,
        sp
    );

    let world = CommWorld::new(plan.cluster.clone());
    world.set_cfg_fused(plan.cfg_fusible());
    let run = run_in_world(&world, mode, |ctx| {
        // ranks outside a subset plan's carve idle (other generation)
        let Some(group) = plan.try_group_of(ctx.rank) else {
            return Vec::new();
        };
        let flows = ctx.nic_flows(&group.ranks());
        let mesh = group.mesh();
        let run_one = |ctx: &mut RankCtx,
                       branch: &'static str,
                       x: &Tensor,
                       cache: Option<&Tensor>|
         -> (Tensor, Tensor) {
            let x_buf = Buf::Real(x.clone());
            let cache_buf = cache.map(|c| Buf::Real(c.clone()));
            let (full, out) =
                branch_step(ctx, p, mesh, branch, &x_buf, cache_buf.as_ref(), flows);
            (full.into_tensor(), out.into_tensor())
        };
        match group.role {
            BranchRole::Conditional => {
                vec![("c", run_one(ctx, "c", cond_x, caches.map(|c| c.0)))]
            }
            BranchRole::Unconditional => {
                vec![("u", run_one(ctx, "u", uncond_x, caches.map(|c| c.1)))]
            }
            BranchRole::Both => {
                let c = run_one(ctx, "c", cond_x, caches.map(|c| c.0));
                // fresh window epoch so the second branch can never read
                // the first branch's exposed buffers
                ctx.next_epoch();
                let u = run_one(ctx, "u", uncond_x, caches.map(|c| c.1));
                vec![("c", c), ("u", u)]
            }
        }
    });

    // Assemble each branch from replica 0 of its role: output shards
    // rank-major, the fresh-input cache from the mesh's base rank.
    let assemble = |role: BranchRole, tag: &str| -> Result<(Tensor, Tensor)> {
        let group = plan.group_for(role, 0);
        let ranks = group.mesh().ranks();
        let shards: Vec<&Tensor> = ranks
            .iter()
            .map(|&r| &branch_out(&run.outputs[r], tag).1)
            .collect();
        let full = Tensor::concat(&shards, 1)?;
        let cache = branch_out(&run.outputs[ranks[0]], tag).0.clone();
        Ok((full, cache))
    };

    let (c_out, cond_cache) = assemble(BranchRole::Conditional, "c")?;
    let (u_out, uncond_cache) = assemble(BranchRole::Unconditional, "u")?;
    let eps = guidance_combine(&c_out, &u_out, scale)?;
    Ok(GuidedDispStep { eps, cond_cache, uncond_cache, makespan: run.makespan() })
}

/// Drive `steps` diffusion steps of displaced patch parallelism: step 0
/// is the synchronous warm-up, later steps attend fresh-own /
/// stale-remote patches. The latent update `x ← x + η·(eps − x)` models
/// the slowly-drifting inputs DistriFusion's temporal-redundancy
/// argument relies on; `cond_bias` is a fixed conditioning offset so the
/// two guidance branches differ. Returns the final latent and the summed
/// per-step makespan. The staleness-free reference is
/// [`super::pipefusion::guided_pipefusion_oracle`] with `pp = 1`.
pub fn guided_displaced_generate(
    plan: &ParallelPlan,
    p: &DispParams,
    steps: usize,
    eta: f32,
    x0: &Tensor,
    cond_bias: &Tensor,
    scale: f32,
    mode: &ExecMode,
) -> Result<(Tensor, f64)> {
    let mut x = x0.clone();
    let mut caches: Option<(Tensor, Tensor)> = None;
    let mut makespan = 0.0;
    for _ in 0..steps {
        let xc = x.add(cond_bias)?;
        let step = guided_displaced_step(
            plan,
            p,
            &xc,
            &x,
            scale,
            caches.as_ref().map(|(c, u)| (c, u)),
            mode,
        )?;
        makespan += step.makespan;
        x = x.add(&step.eps.sub(&x)?.scale(eta))?;
        caches = Some((step.cond_cache, step.uncond_cache));
    }
    Ok((x, makespan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, ParallelSpec, SpDegrees};
    use crate::sp::pipefusion::guided_pipefusion_oracle;
    use crate::sp::tiles::host;
    use crate::sp::SpAlgo;

    #[test]
    fn warmup_step_matches_oracle() {
        // sp2 on one 2-GPU machine, synchronous warm-up.
        let cluster = ClusterSpec::new(1, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(2, 1)),
            SpAlgo::DisplacedPatch,
        )
        .unwrap();
        let shape = AttnShape::new(1, 32, 4, 8);
        let p = DispParams { shape, chunk: 4 };
        let dims = [1, 32, 4, 8];
        let x = Tensor::random(&dims, 21);
        let cb = Tensor::random(&dims, 22).scale(0.5);
        let step = guided_displaced_step(
            &plan,
            &p,
            &x.add(&cb).unwrap(),
            &x,
            3.0,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let xc = x.add(&cb).unwrap();
        let want = guidance_combine(
            &host::attention_oracle(&xc, &xc, &xc),
            &host::attention_oracle(&x, &x, &x),
            3.0,
        )
        .unwrap();
        let diff = step.eps.max_abs_diff(&want);
        assert!(diff < 1e-4, "warm-up vs oracle: {diff}");
        assert!(step.makespan > 0.0);
        // the warm-up cache is the branch's exact layer input
        let c0 = step.cond_cache.max_abs_diff(&xc);
        assert!(c0 < 1e-6, "cache is the step input: {c0}");
    }

    #[test]
    fn steady_step_on_unchanged_input_is_a_fixed_point() {
        // After warm-up, a steady step against *unchanged* inputs must
        // reproduce the oracle exactly (the stale cache equals the fresh
        // activations when the input did not move).
        let cluster = ClusterSpec::new(1, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(2, 1)),
            SpAlgo::DisplacedPatch,
        )
        .unwrap();
        let shape = AttnShape::new(1, 16, 2, 4);
        let p = DispParams { shape, chunk: 4 };
        let dims = [1, 16, 2, 4];
        let x = Tensor::random(&dims, 87);
        let cb = Tensor::random(&dims, 88).scale(0.5);
        let warm = guided_displaced_step(
            &plan,
            &p,
            &x.add(&cb).unwrap(),
            &x,
            2.0,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let steady = guided_displaced_step(
            &plan,
            &p,
            &x.add(&cb).unwrap(),
            &x,
            2.0,
            Some((&warm.cond_cache, &warm.uncond_cache)),
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let diff = steady.eps.max_abs_diff(&warm.eps);
        assert!(diff < 2e-4, "fixed-point steady step vs warm-up: {diff}");
    }

    #[test]
    fn generate_tracks_the_exact_oracle() {
        let cluster = ClusterSpec::new(1, 2);
        let plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(2, 1)),
            SpAlgo::DisplacedPatch,
        )
        .unwrap();
        let shape = AttnShape::new(1, 16, 2, 4);
        let p = DispParams { shape, chunk: 4 };
        let dims = [1, 16, 2, 4];
        let x0 = Tensor::random(&dims, 5);
        let cb = Tensor::random(&dims, 6).scale(0.3);
        let (got, makespan) = guided_displaced_generate(
            &plan,
            &p,
            3,
            0.05,
            &x0,
            &cb,
            2.0,
            &ExecMode::HostNumeric,
        )
        .unwrap();
        let want = guided_pipefusion_oracle(1, 3, 0.05, &x0, &cb, 2.0).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 0.1, "3-step displaced generate vs oracle: {diff}");
        assert!(makespan > 0.0);
    }

    #[test]
    fn step_rejects_pipelined_plans_and_bad_chunks() {
        let cluster = ClusterSpec::new(1, 4);
        let pp_plan = ParallelPlan::build(
            &cluster,
            ParallelSpec::with_pp(1, 2, 1, SpDegrees::new(2, 1)),
            SpAlgo::DisplacedPatch,
        )
        .unwrap();
        let shape = AttnShape::new(1, 32, 4, 8);
        let x = Tensor::random(&[1, 32, 4, 8], 9);
        let err = guided_displaced_step(
            &pp_plan,
            &DispParams { shape, chunk: 4 },
            &x,
            &x,
            1.0,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap_err();
        assert!(err.to_string().contains("pp_degree"), "{err}");
        let flat = ParallelPlan::build(
            &cluster,
            ParallelSpec::new(1, 1, SpDegrees::new(4, 1)),
            SpAlgo::DisplacedPatch,
        )
        .unwrap();
        // chunk 3 does not divide the 8-token patch
        let err = guided_displaced_step(
            &flat,
            &DispParams { shape, chunk: 3 },
            &x,
            &x,
            1.0,
            None,
            &ExecMode::HostNumeric,
        )
        .unwrap_err();
        assert!(err.to_string().contains("chunk"), "{err}");
    }

    #[test]
    fn fastattn_full_window_is_exact_and_pruning_prunes_compute() {
        use crate::cluster::exec::run_cluster;
        // keep_ratio = 1 degenerates to the exact schedule.
        let c = ClusterSpec::new(1, 1);
        let (b, l, h, d) = (1, 64, 2, 8);
        let q = Tensor::random(&[b, l, h, d], 31);
        let k = Tensor::random(&[b, l, h, d], 32);
        let v = Tensor::random(&[b, l, h, d], 33);
        let want = host::attention_oracle(&q, &k, &v);
        let params = SpParams {
            shape: AttnShape::new(b, l, h, d),
            chunk: 8,
            mesh: SpAlgo::DisplacedPatch.mesh(&c, SpDegrees::new(1, 1)),
        };
        let run = run_cluster(&c, &ExecMode::HostNumeric, |ctx| {
            fastattn_attention(
                ctx,
                &params,
                Buf::Real(q.clone()),
                Buf::Real(k.clone()),
                Buf::Real(v.clone()),
                1.0,
            )
            .into_tensor()
        });
        let diff = run.outputs[0].max_abs_diff(&want);
        assert!(diff < 1e-4, "keep_ratio=1 vs oracle: {diff}");
        // pruned windows cost measurably less virtual compute time; use a
        // paper-scale shape so tile flops dominate fixed per-op overheads
        let tshape = AttnShape::new(1, 4096, 8, 64);
        let tparams = SpParams {
            shape: tshape,
            chunk: 256,
            mesh: SpAlgo::DisplacedPatch.mesh(&c, SpDegrees::new(1, 1)),
        };
        let timed = |r: f64| {
            let run = run_cluster(&c, &ExecMode::Timing, |ctx| {
                let s = Buf::Shape(vec![tshape.b, tshape.l, tshape.h, tshape.d]);
                fastattn_attention(ctx, &tparams, s.clone(), s.clone(), s, r);
                ctx.clock.now
            });
            run.outputs[0]
        };
        let full = timed(1.0);
        let half = timed(0.5);
        assert!(half < 0.8 * full, "half window {half} vs full {full}");
    }
}
