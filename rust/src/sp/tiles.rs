//! Tile-level attention operations + the per-rank accumulator.
//!
//! Every SP algorithm reduces distributed attention to three tile ops on
//! `[B, chunk, g, D]` blocks — exactly the contract of the L1 Pallas
//! kernel (Algorithm 2: multiple Q/KV tensors, carried (O', l, m) state,
//! finalize-on-last):
//!
//! * [`attn_partial`] — one KV tile merged into a q-tile's carried state;
//! * [`merge_states`] — combine two states (Appendix C Eq. 3);
//! * [`finalize`]     — O = O' / l.
//!
//! In numeric mode these dispatch to the AOT artifacts
//! `attn_{partial,merge,finalize}_{cfg}_h{g}`; in timing mode they only
//! advance the virtual clock by the roofline cost model. [`AttnAccum`]
//! wraps a rank's q tiles + states and is the workspace all algorithms
//! share.

use crate::cluster::exec::{ExecMode, RankCtx};
use crate::comm::Buf;

use super::AttnState;

fn dims4(b: &Buf) -> (usize, usize, usize, usize) {
    let s = b.shape();
    assert_eq!(s.len(), 4, "expected [B, l, g, D], got {s:?}");
    (s[0], s[1], s[2], s[3])
}

/// In-process tile kernels: the same partial/merge/finalize math as the
/// AOT Pallas artifacts (Algorithm 2), in plain f32 on the host. Backs
/// [`crate::cluster::exec::ExecMode::HostNumeric`] so exact numeric
/// validation needs no PJRT — the property suite and hybrid-plan tests
/// run hermetically.
pub mod host {
    use crate::comm::Buf;
    use crate::sp::AttnState;
    use crate::tensor::Tensor;

    // Layouts match the artifacts: q/k/v/o are [B, l, g, D] row-major;
    // the softmax stats l/m are [B, g, l].
    fn qkv_at(
        data: &[f32],
        l: usize,
        g: usize,
        d: usize,
        bi: usize,
        li: usize,
        gi: usize,
    ) -> &[f32] {
        let base = ((bi * l + li) * g + gi) * d;
        &data[base..base + d]
    }

    fn stat_idx(g: usize, l: usize, bi: usize, gi: usize, li: usize) -> usize {
        (bi * g + gi) * l + li
    }

    /// One KV block merged into a q tile's carried (O', l, m) state —
    /// numerically identical to `attn_partial_*` (any `lk`, so it also
    /// covers the `_s{span}` fused variants).
    pub fn attn_partial(q: &Buf, k: &Buf, v: &Buf, st: AttnState) -> AttnState {
        let qs = q.shape();
        let (b, lq, g, d) = (qs[0], qs[1], qs[2], qs[3]);
        let lk = k.shape()[1];
        let scale = 1.0 / (d as f32).sqrt();

        let qd = q.tensor().data();
        let kd = k.tensor().data();
        let vd = v.tensor().data();
        let mut od = st.o.tensor().data().to_vec();
        let mut ld = st.l.tensor().data().to_vec();
        let mut md = st.m.tensor().data().to_vec();

        let mut scores = vec![0f32; lk];
        for bi in 0..b {
            for gi in 0..g {
                for qi in 0..lq {
                    let qrow = qkv_at(qd, lq, g, d, bi, qi, gi);
                    let mut block_max = f32::NEG_INFINITY;
                    for (ki, s) in scores.iter_mut().enumerate() {
                        let krow = qkv_at(kd, lk, g, d, bi, ki, gi);
                        let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                        *s = dot * scale;
                        block_max = block_max.max(*s);
                    }
                    let si = stat_idx(g, lq, bi, gi, qi);
                    let m_old = md[si];
                    let m_new = m_old.max(block_max);
                    let corr = if m_old == f32::NEG_INFINITY { 0.0 } else { (m_old - m_new).exp() };
                    let mut l_new = ld[si] * corr;
                    let obase = ((bi * lq + qi) * g + gi) * d;
                    for x in &mut od[obase..obase + d] {
                        *x *= corr;
                    }
                    for (ki, &s) in scores.iter().enumerate() {
                        let p = (s - m_new).exp();
                        l_new += p;
                        let vrow = qkv_at(vd, lk, g, d, bi, ki, gi);
                        for (o, &vv) in od[obase..obase + d].iter_mut().zip(vrow) {
                            *o += p * vv;
                        }
                    }
                    ld[si] = l_new;
                    md[si] = m_new;
                }
            }
        }
        AttnState {
            o: Buf::Real(Tensor::new(vec![b, lq, g, d], od).expect("o shape")),
            l: Buf::Real(Tensor::new(vec![b, g, lq], ld).expect("l shape")),
            m: Buf::Real(Tensor::new(vec![b, g, lq], md).expect("m shape")),
        }
    }

    /// Combine two carried states over the same q tile (Appendix C Eq. 3).
    pub fn merge_states(a: AttnState, b2: AttnState) -> AttnState {
        let os = a.o.shape();
        let (b, lq, g, d) = (os[0], os[1], os[2], os[3]);
        let oa = a.o.tensor().data();
        let la = a.l.tensor().data();
        let ma = a.m.tensor().data();
        let ob = b2.o.tensor().data();
        let lb = b2.l.tensor().data();
        let mb = b2.m.tensor().data();

        let mut od = vec![0f32; oa.len()];
        let mut ld = vec![0f32; la.len()];
        let mut md = vec![0f32; ma.len()];
        for bi in 0..b {
            for gi in 0..g {
                for qi in 0..lq {
                    let si = stat_idx(g, lq, bi, gi, qi);
                    let m_new = ma[si].max(mb[si]);
                    let ca = if ma[si] == f32::NEG_INFINITY { 0.0 } else { (ma[si] - m_new).exp() };
                    let cb = if mb[si] == f32::NEG_INFINITY { 0.0 } else { (mb[si] - m_new).exp() };
                    ld[si] = la[si] * ca + lb[si] * cb;
                    md[si] = m_new;
                    let obase = ((bi * lq + qi) * g + gi) * d;
                    for di in 0..d {
                        od[obase + di] = oa[obase + di] * ca + ob[obase + di] * cb;
                    }
                }
            }
        }
        AttnState {
            o: Buf::Real(Tensor::new(vec![b, lq, g, d], od).expect("o shape")),
            l: Buf::Real(Tensor::new(vec![b, g, lq], ld).expect("l shape")),
            m: Buf::Real(Tensor::new(vec![b, g, lq], md).expect("m shape")),
        }
    }

    /// Normalize a carried state: O = O' / l.
    pub fn finalize(st: AttnState) -> Buf {
        let os = st.o.shape();
        let (b, lq, g, d) = (os[0], os[1], os[2], os[3]);
        let od = st.o.tensor().data();
        let ld = st.l.tensor().data();
        let mut out = vec![0f32; od.len()];
        for bi in 0..b {
            for gi in 0..g {
                for qi in 0..lq {
                    let li = stat_idx(g, lq, bi, gi, qi);
                    let obase = ((bi * lq + qi) * g + gi) * d;
                    for di in 0..d {
                        out[obase + di] = od[obase + di] / ld[li];
                    }
                }
            }
        }
        Buf::Real(Tensor::new(vec![b, lq, g, d], out).expect("o shape"))
    }

    /// Single-device reference: plain (non-flash) softmax attention of
    /// `[B, L, H, D]` tensors — an independent code path from the tiled
    /// partial/merge/finalize kernels, so it can serve as the oracle the
    /// distributed algorithms are validated against.
    pub fn attention_oracle(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        let s = q.shape();
        let (b, l, h, d) = (s[0], s[1], s[2], s[3]);
        let lk = k.shape()[1];
        let scale = 1.0 / (d as f32).sqrt();
        let (qd, kd, vd) = (q.data(), k.data(), v.data());
        let mut out = vec![0f32; b * l * h * d];
        let mut scores = vec![0f32; lk];
        for bi in 0..b {
            for gi in 0..h {
                for qi in 0..l {
                    let qrow = qkv_at(qd, l, h, d, bi, qi, gi);
                    for (ki, s) in scores.iter_mut().enumerate() {
                        let krow = qkv_at(kd, lk, h, d, bi, ki, gi);
                        *s = qrow.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                    }
                    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - m).exp();
                        z += *s;
                    }
                    let obase = ((bi * l + qi) * h + gi) * d;
                    for (ki, &p) in scores.iter().enumerate() {
                        let vrow = qkv_at(vd, lk, h, d, bi, ki, gi);
                        for (o, &vv) in out[obase..obase + d].iter_mut().zip(vrow) {
                            *o += p * vv / z;
                        }
                    }
                }
            }
        }
        Tensor::new(vec![b, l, h, d], out).expect("oracle shape")
    }
}

/// Merge one KV tile into the carried state of a q tile.
///
/// `q: [B, lq, g, D]`, `k`/`v`: `[B, lk, g, D]`. Numeric mode requires
/// `lq == lk == cfg.chunk` and `g ∈ cfg.head_groups` (the lowered tile
/// set); timing mode takes any shape.
pub fn attn_partial(ctx: &mut RankCtx, q: &Buf, k: &Buf, v: &Buf, st: AttnState) -> AttnState {
    let (b, lq, g, d) = dims4(q);
    let (_, lk, _, _) = dims4(k);
    ctx.compute(ctx.attn_tile_time(b, lq, lk, g, d));
    match &ctx.mode {
        ExecMode::Timing => st,
        ExecMode::HostNumeric => host::attn_partial(q, k, v, st),
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_partial_{}_h{}", cfg.name, g);
            let out = rt
                .call_owned(
                    &name,
                    vec![
                        q.tensor().clone(),
                        k.tensor().clone(),
                        v.tensor().clone(),
                        st.o.into_tensor(),
                        st.l.into_tensor(),
                        st.m.into_tensor(),
                    ],
                )
                .unwrap_or_else(|e| panic!("attn_partial tile failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Span variant (§Perf optimization L3-2): absorb `span` chunk tiles of
/// KV in ONE fused artifact call (`attn_partial_*_s{span}`) — the
/// Algorithm-2 fusion. `k`/`v`: `[B, span·chunk, g, D]`.
pub fn attn_partial_span(
    ctx: &mut RankCtx,
    q: &Buf,
    k: &Buf,
    v: &Buf,
    st: AttnState,
    span: usize,
) -> AttnState {
    let (b, lq, g, d) = dims4(q);
    let (_, lk, _, _) = dims4(k);
    ctx.compute(ctx.attn_tile_time(b, lq, lk, g, d));
    match &ctx.mode {
        ExecMode::Timing => st,
        // the host kernel fuses arbitrary spans natively (like Algorithm 2)
        ExecMode::HostNumeric => host::attn_partial(q, k, v, st),
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_partial_{}_h{}_s{}", cfg.name, g, span);
            let out = rt
                .call_owned(
                    &name,
                    vec![
                        q.tensor().clone(),
                        k.tensor().clone(),
                        v.tensor().clone(),
                        st.o.into_tensor(),
                        st.l.into_tensor(),
                        st.m.into_tensor(),
                    ],
                )
                .unwrap_or_else(|e| panic!("attn span tile failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Is the `s{span}` artifact available for head group `g`? (Timing mode:
/// always — the modelled GPU kernel fuses arbitrarily, like Algorithm 2.)
fn span_available(ctx: &RankCtx, g: usize, span: usize) -> bool {
    match &ctx.mode {
        ExecMode::Timing | ExecMode::HostNumeric => true,
        ExecMode::Numeric { rt, cfg } => rt
            .manifest()
            .artifacts
            .contains_key(&format!("attn_partial_{}_h{}_s{}", cfg.name, g, span)),
    }
}

/// Carry-chain variant (§Perf optimization L3-1): merge a *sequence* of
/// KV tiles into one q tile's state with a single runtime roundtrip —
/// the (O', l, m) state stays on the PJRT service thread as XLA literals
/// between tiles. Numerically identical to folding [`attn_partial`].
pub fn attn_partial_chain(
    ctx: &mut RankCtx,
    q: &Buf,
    kvs: &[(Buf, Buf)],
    st: AttnState,
) -> AttnState {
    let (b, lq, g, d) = dims4(q);
    for (k, _) in kvs {
        let (_, lk, _, _) = dims4(k);
        ctx.compute(ctx.attn_tile_time(b, lq, lk, g, d));
    }
    match &ctx.mode {
        ExecMode::Timing => st,
        ExecMode::HostNumeric => kvs
            .iter()
            .fold(st, |acc, (k, v)| host::attn_partial(q, k, v, acc)),
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_partial_{}_h{}", cfg.name, g);
            let kv_tensors: Vec<(crate::tensor::Tensor, crate::tensor::Tensor)> = kvs
                .iter()
                .map(|(k, v)| (k.tensor().clone(), v.tensor().clone()))
                .collect();
            let out = rt
                .call_attn_chain(
                    &name,
                    q.tensor(),
                    kv_tensors,
                    (st.o.into_tensor(), st.l.into_tensor(), st.m.into_tensor()),
                )
                .unwrap_or_else(|e| panic!("attn chain failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Combine two carried states over the same q tile (Appendix C Eq. 3).
pub fn merge_states(ctx: &mut RankCtx, a: AttnState, b2: AttnState) -> AttnState {
    let (b, lq, g, d) = dims4(&a.o);
    // merge is memory-bound: touches ~4 state tensors
    let bytes = (2 * (b * lq * g * d) + 4 * (b * g * lq)) as f64 * 4.0;
    let t = ctx.cluster().gpu.tile_time(0.0, bytes);
    ctx.compute(t);
    match &ctx.mode {
        ExecMode::Timing => a,
        ExecMode::HostNumeric => host::merge_states(a, b2),
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_merge_{}_h{}", cfg.name, g);
            let out = rt
                .call_owned(
                    &name,
                    vec![
                        a.o.into_tensor(),
                        a.l.into_tensor(),
                        a.m.into_tensor(),
                        b2.o.into_tensor(),
                        b2.l.into_tensor(),
                        b2.m.into_tensor(),
                    ],
                )
                .unwrap_or_else(|e| panic!("attn_merge tile failed: {e}"));
            let mut it = out.into_iter();
            AttnState {
                o: Buf::Real(it.next().unwrap()),
                l: Buf::Real(it.next().unwrap()),
                m: Buf::Real(it.next().unwrap()),
            }
        }
    }
}

/// Normalize a carried state: O = O' / l.
pub fn finalize(ctx: &mut RankCtx, st: AttnState) -> Buf {
    let (b, lq, g, d) = dims4(&st.o);
    let bytes = (2 * (b * lq * g * d) + b * g * lq) as f64 * 4.0;
    let t = ctx.cluster().gpu.tile_time(0.0, bytes);
    ctx.compute(t);
    match &ctx.mode {
        ExecMode::Timing => st.o,
        ExecMode::HostNumeric => host::finalize(st),
        ExecMode::Numeric { rt, cfg } => {
            let name = format!("attn_finalize_{}_h{}", cfg.name, g);
            let out = rt
                .call_owned(&name, vec![st.o.into_tensor(), st.l.into_tensor()])
                .unwrap_or_else(|e| panic!("attn_finalize tile failed: {e}"));
            Buf::Real(out.into_iter().next().unwrap())
        }
    }
}

/// Per-rank attention workspace: a list of q tiles (each `[B, chunk, g,
/// D]`) with their carried states. KV tiles are absorbed as they arrive
/// (from the ring, the torus stages, or local chunking); `finish`
/// finalizes and reassembles the output in q order.
pub struct AttnAccum {
    pub chunk: usize,
    q_tiles: Vec<Buf>,
    states: Vec<AttnState>,
}

impl AttnAccum {
    /// Split `q` (`[B, Ls, g, D]`, `chunk | Ls`) into tiles with zeroed
    /// states.
    pub fn new(ctx: &RankCtx, q: &Buf, chunk: usize) -> Self {
        let (b, ls, g, d) = dims4(q);
        assert_eq!(ls % chunk, 0, "q len {ls} not a multiple of chunk {chunk}");
        let numeric = ctx.mode.is_numeric();
        let parts = q.split(1, ls / chunk);
        let states = parts
            .iter()
            .map(|_| AttnState::zero(b, chunk, g, d, numeric))
            .collect();
        Self { chunk, q_tiles: parts, states }
    }

    /// Append more q tiles (Torus: pulled Q chunks join the workspace).
    pub fn push_q(&mut self, ctx: &RankCtx, q: &Buf) {
        let (b, ls, g, d) = dims4(q);
        assert_eq!(ls % self.chunk, 0);
        let numeric = ctx.mode.is_numeric();
        for t in q.split(1, ls / self.chunk) {
            self.q_tiles.push(t);
            self.states.push(AttnState::zero(b, self.chunk, g, d, numeric));
        }
    }

    pub fn num_tiles(&self) -> usize {
        self.q_tiles.len()
    }

    /// Absorb a KV block (`[B, Lk, g, D]`, `chunk | Lk`) into the states
    /// of q tiles `idx` (all tiles if `None`). Multi-tile blocks go
    /// through the carry-chain fast path (one runtime roundtrip per q
    /// tile instead of one per KV tile).
    pub fn absorb(&mut self, ctx: &mut RankCtx, k: &Buf, v: &Buf, idx: Option<&[usize]>) {
        let (_, lk, g, _) = dims4(k);
        assert_eq!(lk % self.chunk, 0, "kv len {lk} not a multiple of chunk");
        let nt = lk / self.chunk;
        let all: Vec<usize> = (0..self.q_tiles.len()).collect();
        let targets = idx.unwrap_or(&all);
        // Greedy span decomposition (§Perf L3-2): absorb the block in as
        // few fused calls as possible — largest power-of-two span
        // artifacts first, chunk-sized calls for leftovers.
        let mut plan: Vec<(usize, usize)> = Vec::new(); // (tile offset, span)
        let mut off = 0;
        while off < nt {
            let mut span = 1usize;
            while span * 2 <= nt - off && span_available(ctx, g, span * 2) {
                span *= 2;
            }
            plan.push((off, span));
            off += span;
        }
        for &i in targets {
            let mut st = std::mem::replace(
                &mut self.states[i],
                AttnState::zero(1, 1, 1, 1, false),
            );
            for &(o, span) in &plan {
                let kb = k.slice(1, o * self.chunk, (o + span) * self.chunk);
                let vb = v.slice(1, o * self.chunk, (o + span) * self.chunk);
                if span == 1 {
                    st = attn_partial(ctx, &self.q_tiles[i], &kb, &vb, st);
                } else {
                    st = attn_partial_span(ctx, &self.q_tiles[i], &kb, &vb, st, span);
                }
            }
            self.states[i] = st;
        }
    }

    /// Finalize tiles `idx` (or all) and return their outputs in order.
    pub fn finish_tiles(&mut self, ctx: &mut RankCtx, idx: &[usize]) -> Vec<Buf> {
        idx.iter()
            .map(|&i| {
                let st = std::mem::replace(
                    &mut self.states[i],
                    AttnState::zero(1, 1, 1, 1, false),
                );
                finalize(ctx, st)
            })
            .collect()
    }

    /// Finalize everything and concatenate along the sequence axis.
    pub fn finish(mut self, ctx: &mut RankCtx) -> Buf {
        let n = self.q_tiles.len();
        let idx: Vec<usize> = (0..n).collect();
        let outs = self.finish_tiles(ctx, &idx);
        Buf::concat(&outs, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::exec::{run_cluster, ExecMode};
    use crate::config::ClusterSpec;

    // Numeric-mode tile tests live in rust/tests/ (need artifacts);
    // here: timing-mode structure + cost accounting.

    #[test]
    fn accum_splits_and_reassembles() {
        let c = ClusterSpec::new(1, 1);
        let run = run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 64, 2, 16]);
            let k = Buf::Shape(vec![1, 64, 2, 16]);
            let v = k.clone();
            let mut acc = AttnAccum::new(ctx, &q, 16);
            assert_eq!(acc.num_tiles(), 4);
            acc.absorb(ctx, &k, &v, None);
            let out = acc.finish(ctx);
            assert_eq!(out.shape(), &[1, 64, 2, 16]);
            ctx.clock.now
        });
        assert!(run.outputs[0] > 0.0, "tile ops must cost time");
    }

    #[test]
    fn absorb_subset_only_charges_subset() {
        let c = ClusterSpec::new(1, 1);
        let run = run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 64, 2, 16]);
            let kv = Buf::Shape(vec![1, 16, 2, 16]);
            let mut acc = AttnAccum::new(ctx, &q, 16);
            let t0 = ctx.clock.now;
            acc.absorb(ctx, &kv, &kv, Some(&[0]));
            let one = ctx.clock.now - t0;
            let t1 = ctx.clock.now;
            acc.absorb(ctx, &kv, &kv, None);
            let all = ctx.clock.now - t1;
            (one, all)
        });
        let (one, all) = run.outputs[0];
        assert!(all > 3.0 * one, "4 tiles should cost ~4x one tile");
    }

    #[test]
    fn push_q_extends_workspace() {
        let c = ClusterSpec::new(1, 1);
        run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 32, 1, 8]);
            let mut acc = AttnAccum::new(ctx, &q, 32);
            assert_eq!(acc.num_tiles(), 1);
            acc.push_q(ctx, &Buf::Shape(vec![1, 64, 1, 8]));
            assert_eq!(acc.num_tiles(), 3);
        });
    }

    #[test]
    #[should_panic(expected = "worker thread panicked")]
    fn accum_rejects_ragged_q() {
        let c = ClusterSpec::new(1, 1);
        run_cluster(&c, &ExecMode::Timing, |ctx| {
            let q = Buf::Shape(vec![1, 30, 1, 8]);
            AttnAccum::new(ctx, &q, 16);
        });
    }

    // ---- host tile kernels (ExecMode::HostNumeric backend) ---------------

    use crate::sp::AttnState;
    use crate::tensor::Tensor;

    fn rand_buf(shape: &[usize], seed: u64) -> Buf {
        Buf::Real(Tensor::random(shape, seed))
    }

    #[test]
    fn host_chunked_partials_match_oracle() {
        // Absorbing KV in 4 chunks through the carried state must equal
        // plain softmax attention.
        let (b, l, h, d) = (2, 32, 3, 8);
        let q = Tensor::random(&[b, l, h, d], 1);
        let k = Tensor::random(&[b, l, h, d], 2);
        let v = Tensor::random(&[b, l, h, d], 3);
        let mut st = AttnState::zero(b, l, h, d, true);
        for i in 0..4 {
            let ks = Buf::Real(k.slice(1, i * 8, (i + 1) * 8).unwrap());
            let vs = Buf::Real(v.slice(1, i * 8, (i + 1) * 8).unwrap());
            st = host::attn_partial(&Buf::Real(q.clone()), &ks, &vs, st);
        }
        let got = host::finalize(st).into_tensor();
        let want = host::attention_oracle(&q, &k, &v);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-5, "chunked flash vs plain softmax: {diff}");
    }

    #[test]
    fn host_merge_commutes_and_matches_sequential() {
        let (b, l, h, d) = (1, 8, 2, 4);
        let q = rand_buf(&[b, l, h, d], 10);
        let mk = |seed| (rand_buf(&[b, l, h, d], seed), rand_buf(&[b, l, h, d], seed + 1));
        let (k1, v1) = mk(20);
        let (k2, v2) = mk(30);
        let zero = || AttnState::zero(b, l, h, d, true);
        // independent partials then merge, both orders
        let a = host::attn_partial(&q, &k1, &v1, zero());
        let bb = host::attn_partial(&q, &k2, &v2, zero());
        let ab = host::finalize(host::merge_states(a.clone(), bb.clone())).into_tensor();
        let ba = host::finalize(host::merge_states(bb, a)).into_tensor();
        assert!(ab.max_abs_diff(&ba) < 1e-5, "merge must commute");
        // and equal the sequential chain
        let seq = host::attn_partial(&q, &k2, &v2, host::attn_partial(&q, &k1, &v1, zero()));
        let seq = host::finalize(seq).into_tensor();
        assert!(ab.max_abs_diff(&seq) < 1e-5, "merge must equal chaining");
    }

    #[test]
    fn host_numeric_accum_matches_oracle() {
        // The full AttnAccum plumbing under ExecMode::HostNumeric.
        let c = ClusterSpec::new(1, 1);
        let (b, l, h, d) = (1, 64, 2, 16);
        let q = Tensor::random(&[b, l, h, d], 41);
        let k = Tensor::random(&[b, l, h, d], 42);
        let v = Tensor::random(&[b, l, h, d], 43);
        let want = host::attention_oracle(&q, &k, &v);
        let run = run_cluster(&c, &ExecMode::HostNumeric, |ctx| {
            let mut acc = AttnAccum::new(ctx, &Buf::Real(q.clone()), 16);
            acc.absorb(ctx, &Buf::Real(k.clone()), &Buf::Real(v.clone()), None);
            acc.finish(ctx).into_tensor()
        });
        let diff = run.outputs[0].max_abs_diff(&want);
        assert!(diff < 1e-5, "accum vs oracle: {diff}");
    }
}
